"""End-to-end training driver example (deliverable b): train a small LM for a
few hundred steps with checkpoint/restart, on whatever devices exist.

  PYTHONPATH=src python examples/train_lm.py               # CPU-sized (~2M)
  PYTHONPATH=src python examples/train_lm.py --preset 100m # ~100M (real hw)

Interrupt and re-run: training resumes from the latest atomic checkpoint.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=["cpu", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt_dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.preset == "cpu":
        # reduced qwen3-family config (~2M params): loss visibly falls on CPU
        argv = ["--arch", "qwen3-8b", "--smoke", "--steps",
                str(args.steps or 300), "--seq", "64", "--batch", "8",
                "--lr", "3e-3", "--ckpt_dir", args.ckpt_dir,
                "--ckpt_every", "100", "--log_every", "25"]
    else:
        # ~100M-scale run for real hardware (full qwen3-8b reduced x16)
        argv = ["--arch", "qwen3-8b", "--steps", str(args.steps or 300),
                "--seq", "1024", "--batch", "32", "--lr", "3e-4",
                "--ckpt_dir", args.ckpt_dir, "--ckpt_every", "50",
                "--accum", "4"]
    train_mod.main(argv)


if __name__ == "__main__":
    main()
