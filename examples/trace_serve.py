"""Traced bursty serving: a chrome://tracing view of where request time goes.

Runnable entry point for the observability layer (docs/OBSERVABILITY.md):

    PYTHONPATH=src python examples/trace_serve.py

Replays the examples/serve_sc.py traffic shape — bursts of local-image-
thresholding windows (LIT) and kernel-density estimates (KDE) with shifting
composition — through a ``BankServer(trace=True)``.  The engine records one
root ``request`` span per served request (on its own virtual track) with
``request.queued`` / ``request.staged`` / ``request.inflight`` children
partitioning the admit -> bucket/stage -> launch -> reap lifecycle, plus the
compiler-stage and executor spans that fire inside each ``serve.launch``.

The script writes ``trace_serve.json`` (load it at chrome://tracing or
https://ui.perfetto.dev) and sanity-checks the trace before declaring
victory: every request's phase spans must nest inside its root span and sum
to >= 90% of the request's measured wall-clock.
"""
import json
import time

import jax
import numpy as np

from repro.core.apps import KDE_N
from repro.serve import BankServer, app_request

BL = 256
# Bursty traffic: (n_lit, n_kde) per burst — composition shifts burst to
# burst but revisits earlier mixes (what the bucketing rewards).
BURSTS = [(3, 1), (1, 3), (2, 2), (3, 1), (1, 3), (2, 2)]
OUT = "trace_serve.json"


def main():
    rng = np.random.default_rng(0)
    server = BankServer(max_slots=8, window_s=None, trace=True)
    key = jax.random.key(42)

    t0 = time.perf_counter()
    for n_lit, n_kde in BURSTS:
        reqs = []
        for _ in range(n_lit):
            key, sub = jax.random.split(key)
            reqs.append(app_request("lit", sub, BL,
                                    a=rng.uniform(0.1, 0.9, size=(81,))))
        for _ in range(n_kde):
            key, sub = jax.random.split(key)
            reqs.append(app_request("kde", sub, BL,
                                    x_t=float(rng.uniform(0.2, 0.8)),
                                    hist=rng.uniform(0.2, 0.8, size=(KDE_N,))))
        server.serve(reqs)
    wall_ms = (time.perf_counter() - t0) * 1e3

    tr = server.trace
    chrome = tr.to_chrome_json(indent=1)
    json.loads(chrome)                       # must be loadable JSON
    with open(OUT, "w") as f:
        f.write(chrome)

    # -- sanity-check the per-request lifecycle spans ----------------------
    spans = tr.spans()
    roots = [sp for sp in spans if sp.name == "request"]
    n_requests = sum(a + b for a, b in BURSTS)
    assert len(roots) == n_requests, (len(roots), n_requests)

    phase_names = ("request.queued", "request.staged", "request.inflight")
    worst = 1.0
    for root in roots:
        kids = [sp for sp in spans if sp.parent is root]
        assert sorted(k.name for k in kids) == sorted(phase_names), kids
        for k in kids:                       # children nest inside the root
            assert root.t0 <= k.t0 and k.t1 <= root.t1 + 1e-9, (root, k)
        coverage = sum(k.duration_ms for k in kids) / root.duration_ms
        worst = min(worst, coverage)
    assert worst >= 0.90, f"phase coverage {worst:.1%} < 90%"

    s = tr.summary()
    agg = s["spans"]
    print(f"served {n_requests} requests in {len(BURSTS)} bursts "
          f"({wall_ms:.1f} ms wall)")
    print(f"phase coverage: every request's queued+staged+inflight spans "
          f"sum to >= {worst:.1%} of its wall-clock")
    for name in ("request.queued", "request.staged", "request.inflight",
                 "serve.launch", "exec.stream_gen", "exec.dispatch"):
        a = agg.get(name)
        if a:
            print(f"  {name:22s} x{a['count']:3d}  total {a['total_ms']:8.2f}"
                  f" ms  mean {a['mean_ms']:7.3f} ms")
    hit = s["metrics"]["counters"]
    print(f"counters: admitted {hit.get('serve.requests_admitted', 0)}, "
          f"batches {hit.get('serve.batches_launched', 0)}, "
          f"completed {hit.get('serve.requests_completed', 0)}")
    print(f"wrote {OUT} — load it at chrome://tracing or ui.perfetto.dev")


if __name__ == "__main__":
    main()
