"""Serving example (deliverable b): batched prefill + greedy decode through
the public API, for any of the 10 architectures at reduced scale.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b --new 16
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import ARCHS, reduced_config
from repro.models import RunCtx, init_params
from repro.models.frontend import audio_stub_frames
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    params = init_params(cfg, jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    frames = (audio_stub_frames(cfg, args.batch, jax.random.key(2))
              if cfg.is_encoder_decoder else None)

    t0 = time.time()
    out = greedy_generate(cfg, params, prompt, args.new, RunCtx(),
                          frames=frames)
    dt = time.time() - t0
    print(f"arch={args.arch}  batch={args.batch}  prompt={args.prompt_len}  "
          f"new={args.new}  ({dt:.1f}s incl. compile)")
    print("generated ids (first sequence):")
    print(" ", out[0, args.prompt_len:].tolist())
    assert out.shape == (args.batch, args.prompt_len + args.new)
    print("OK")


if __name__ == "__main__":
    main()
