"""Beyond-paper demo: the paper's stochastic-rounding insight applied to
cross-pod gradient/parameter synchronization (local-SGD style int8 sync with
error feedback).

Runs on 8 *host* devices arranged as a mini 2-pod mesh (2, 2, 2):
each pod trains synchronously; every K steps the pods exchange int8
stochastically-quantized parameter deltas.  Shows: (a) training still
converges, (b) the cross-pod payload shrinks 4x vs an fp32 all-reduce
(measured in the compiled HLO by launch/dryrun.py --pod_sync_study on the
production 2x16x16 mesh).

NOTE: must run as its own process (device count is fixed at jax init):
  PYTHONPATH=src python examples/sc_gradient_compression.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data import SyntheticLM
from repro.models import RunCtx, init_params, model_params
from repro.optim.compress import make_pod_sync
from repro.sharding import make_rules, param_pspec_tree
from repro.train import make_train_step, train_state_init

K_SYNC = 5          # local steps between pod syncs
BITS = 8


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced_config("qwen3-8b")
    rules = make_rules(mesh, fsdp=False)          # tiny model: TP-only specs
    pspecs = param_pspec_tree(model_params(cfg), rules)

    params = init_params(cfg, jax.random.key(0))
    state = train_state_init(cfg, params)
    ctx = RunCtx(mesh=mesh, data_axes=("pod", "data"))
    step = jax.jit(make_train_step(cfg, ctx, lr=3e-3))
    sync = jax.jit(make_pod_sync(mesh, pspecs, bits=BITS))

    pipe = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=8)
    anchor = state.params
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    n_params = sum(p.size for p in jax.tree.leaves(params))
    fp32_bytes = 2 * 4 * n_params                      # ring AR moves ~2x
    int8_bytes = 2 * 1 * n_params                      # 2-pod int8 AG result
    print(f"params: {n_params/1e6:.2f}M | cross-pod bytes/sync: "
          f"fp32 AR ~{fp32_bytes/1e6:.1f}MB vs int{BITS}+EF AG "
          f"~{int8_bytes/1e6:.1f}MB ({fp32_bytes/int8_bytes:.0f}x)")

    for s in range(40):
        state, metrics = step(state, pipe.batch(0))    # overfit one batch
        if (s + 1) % K_SYNC == 0:
            new_p, err = sync(state.params, anchor, err, s)
            anchor = new_p
            state = state._replace(params=new_p)
        if s % 5 == 0 or s == 39:
            print(f"  step {s:3d} loss {float(metrics['loss']):.4f}")
    print("OK: loss decreased under compressed pod sync")


if __name__ == "__main__":
    main()
