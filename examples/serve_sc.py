"""BankServer on a bursty LIT + KDE application mix.

Runnable entry point for the dynamic bank serving path (the counterpart of
examples/serve_lm.py for the SC stack):

    PYTHONPATH=src python examples/serve_sc.py

Bursts of local-image-thresholding windows (LIT, Eq. 5-6) and kernel-density
estimates (KDE, Eq. 10) arrive with shifting composition; the server buckets
each burst into a canonical padded bank template, so after the first
occurrence of each mix every burst reuses a warm BankPlan + jit program.
Every result is bit-identical to a standalone ``appnet_stochastic`` call
with the same per-request key.
"""
import time

import jax
import numpy as np

from repro.core.apps import KDE_N, kde_exact, lit_exact
from repro.serve import BankServer, app_request

BL = 256
# Bursty traffic: (n_lit, n_kde) per burst — composition shifts burst to
# burst but revisits earlier mixes, which is what the bucketing rewards.
BURSTS = [(3, 1), (1, 3), (3, 1), (2, 2), (1, 3), (3, 1), (2, 2), (1, 3)]


def main():
    rng = np.random.default_rng(0)
    server = BankServer(max_slots=8, window_s=None)
    key = jax.random.key(42)
    req_id = 0

    print(f"serving {sum(a + b for a, b in BURSTS)} requests "
          f"in {len(BURSTS)} bursts (LIT 9x9 windows + KDE {KDE_N}-frame "
          f"histories, BL={BL})")
    for bi, (n_lit, n_kde) in enumerate(BURSTS):
        reqs, refs = [], []
        for _ in range(n_lit):
            a = rng.uniform(0.1, 0.9, size=(81,))
            key, sub = jax.random.split(key)
            reqs.append(app_request("lit", sub, BL, a=a))
            refs.append(("LIT", float(lit_exact(a))))
        for _ in range(n_kde):
            x_t = rng.uniform(0.2, 0.8)
            hist = rng.uniform(0.2, 0.8, size=(KDE_N,))
            key, sub = jax.random.split(key)
            reqs.append(app_request("kde", sub, BL, x_t=x_t, hist=hist))
            refs.append(("KDE", float(kde_exact(x_t, hist))))

        t0 = time.perf_counter()
        results = server.serve(reqs)
        dt = (time.perf_counter() - t0) * 1e3
        line = []
        for (what, exact), out in zip(refs, results):
            got = float(np.mean([np.asarray(v) for v in out.values()]))
            line.append(f"{what} {got:.3f} (exact {exact:.3f})")
        print(f"burst {bi}: {n_lit} LIT + {n_kde} KDE in {dt:7.1f} ms   "
              + "; ".join(line[:3]) + (" ..." if len(line) > 3 else ""))

    s = server.stats()
    print(f"\nserved {s['n_requests']} requests in {s['n_batches']} batches: "
          f"bucket hit rate {s['bucket_hit_rate']:.0%}, "
          f"padding waste {s['padding_waste']:.0%}, "
          f"p50 {s['p50_ms']:.1f} ms, p99 {s['p99_ms']:.1f} ms, "
          f"{s['throughput_rps']:.0f} req/s steady-state")


if __name__ == "__main__":
    main()
