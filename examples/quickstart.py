"""Quickstart: the paper's stochastic in-memory computing stack end to end.

  PYTHONPATH=src python examples/quickstart.py

1. stochastic arithmetic on packed bitstreams (Fig. 4/5 semantics);
2. Algorithm 1 scheduling of a netlist onto a 2T-1MTJ subarray;
3. the [n, m] Stoch-IMC architecture cost model (Table 3 machinery);
4. one paper application (object location) in exact / SC / binary form.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apps, bitstream as bs, circuits
from repro.core.appnet import APP_NETLISTS
from repro.core.arch import StochIMCConfig, evaluate_stoch_imc
from repro.core.executor import execute_value
from repro.core.scheduler import schedule

key = jax.random.key(0)
BL = 1024

print("== 1. stochastic arithmetic on packed bitstreams ==")
a, b = 0.3, 0.6
sa = bs.generate(jax.random.key(1), jnp.float32(a), BL)
sb = bs.generate(jax.random.key(2), jnp.float32(b), BL)
print(f"  AND(a,b):  {float(bs.to_value(sa & sb, BL)):.3f}   (a*b = {a * b})")
ca, cb = bs.generate_correlated(key, [jnp.float32(a), jnp.float32(b)], BL)
print(f"  XOR corr:  {float(bs.to_value(ca ^ cb, BL)):.3f}   (|a-b| = {abs(a - b)})")

print("\n== 2. Algorithm 1: schedule the scaled-adder netlist ==")
net = circuits.sc_scaled_add()
sch = schedule(net, n_lanes=256)
print(f"  logic cycles: {sch.logic_cycles} (Fig. 7(b): 4), "
      f"array: {sch.n_rows}x{sch.n_cols} (Table 2: 256x7)")
out = execute_value(net, {"a": jnp.float32(a), "b": jnp.float32(b)}, key, BL)
print(f"  executed value: {float(out['out']):.3f}  ((a+b)/2 = {(a + b) / 2})")

print("\n== 3. [16,16] Stoch-IMC architecture cost (one OL evaluation) ==")
cfg = StochIMCConfig()
ol = APP_NETLISTS["ol"]()
cost = evaluate_stoch_imc(ol, schedule(ol, n_lanes=1), cfg)
print(f"  cycles={cost.total_cycles} (incl. {cost.accumulation_cycles} "
      f"n+m accumulation), energy={cost.total_energy_j:.3e} J")

print("\n== 4. object-location application, three ways ==")
p = np.random.default_rng(0).random((4, 6)) * 0.5 + 0.5
print("  exact:     ", np.round(apps.ol_exact(p), 4))
print("  stochastic:", np.round(np.asarray(apps.ol_stochastic(key, p, BL)), 4))
print("  binary-8b: ", np.round(apps.ol_binary8(np.random.default_rng(1), p), 4))
print("  stochastic @20% bitflips:",
      np.round(np.asarray(apps.ol_stochastic(key, p, BL, bitflip_rate=0.2)), 4))
