"""Batched vs per-PI stream generation (BENCH_sng.json).

Times the OL application netlist — 96 stream PIs feeding 160 gates, the
most PI-heavy circuit in the reproduction — over the paper's full workload:
a 64x64 probability grid (Section 5.3.2), i.e. a 256-tile batch through the
16-pixel netlist, so stream generation (not logic) dominates end-to-end cost
exactly as Khatamifard et al. report for SC memory systems.  Two key
disciplines:

  * **legacy** — one PRNG split and one ``bitstream.generate`` dispatch per
    PI inside the jit, each materializing an unpacked ``(W, 32)`` uniform
    tensor (the pre-PR-3 behavior, kept as ``key_mode="legacy"``);
  * **batched** — ONE fused threshold+pack pass over the plan's stream table
    (``bitstream.generate_batch`` / kernels/sng.py), packing by
    compare-and-accumulate with no unpacked tensor.

Both run end-to-end through ``executor.execute_value`` (generation + gate
passes + decode in one jit), so the headline ``speedup`` is the acceptance
metric: batched must be >= 3X faster end-to-end at BL=1024.  A gen-only
microbench isolates the stream-generation phase itself.

Output schema:
  {"bitstream_length", "netlist", "n_stream_pis", "batch", "legacy_ms",
   "batched_ms", "speedup", "gen_only": {"legacy_ms", "batched_ms",
   "speedup"}}
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import apps, executor
from repro.core.appnet import APP_NETLISTS
from repro.core.plan import compile_plan

from .common import time_ms as _time


def run(verbose: bool = True, smoke: bool = False) -> dict:
    # Smoke keeps enough work (batch x BL) for the gen-vs-pass balance to
    # resemble the full run, so the CI perf diff against the committed
    # record stays meaningful.
    bl = 512 if smoke else 1024
    iters = 3 if smoke else 20
    batch = 64 if smoke else 256          # full run: the 64x64 grid, 16 px/tile
    net = APP_NETLISTS["ol"]()
    rng = np.random.default_rng(0)
    # appnet_inputs returns host-f32 leaves (cheap splat, serving-friendly);
    # this loop re-dispatches the SAME values, so pin them on device once —
    # otherwise every timed call pays 96 host->device transfers that dwarf
    # the generation phase being measured.
    values = {k: jax.numpy.asarray(v) for k, v in
              apps.appnet_inputs("ol", p=rng.uniform(0.5, 1.0, (batch, 16, 6))).items()}
    key = jax.random.key(0)
    n_pis = compile_plan(net).stream_table.n_rows   # stream PIs only

    end_to_end = {}
    for mode in ("legacy", "batched"):
        end_to_end[mode] = _time(
            lambda m=mode: executor.execute_value(net, values, key, bl,
                                                  key_mode=m), iters)

    # Gen-only phase: the same per-PI loop vs one stream-table pass, jitted
    # standalone so the logic passes don't dilute the comparison.
    gen_only = {}
    for mode in ("legacy", "batched"):
        fn = jax.jit(lambda k, m=mode: executor._gen_pi_streams(
            tuple(net.pis), values, k, bl, key_mode=m))
        gen_only[mode] = _time(lambda: fn(key), iters)

    results = {
        "bitstream_length": bl,
        "netlist": net.name,
        "n_stream_pis": n_pis,
        "batch": batch,
        "legacy_ms": round(end_to_end["legacy"], 3),
        "batched_ms": round(end_to_end["batched"], 3),
        "speedup": round(end_to_end["legacy"] / end_to_end["batched"], 2),
        "gen_only": {
            "legacy_ms": round(gen_only["legacy"], 3),
            "batched_ms": round(gen_only["batched"], 3),
            "speedup": round(gen_only["legacy"] / gen_only["batched"], 2),
        },
        # Phase breakdown (Table-8 style): the gen-only microbench is the
        # stream-generation phase of the batched end-to-end run; the rest
        # is logic passes + decode.
        "phases": {
            "gen_ms": round(gen_only["batched"], 3),
            "pass_ms": round(max(end_to_end["batched"]
                                 - gen_only["batched"], 0.0), 3),
            "total_ms": round(end_to_end["batched"], 3),
        },
    }
    if verbose:
        print(f"\n== SNG bench: batched vs per-PI generation "
              f"({net.name}, {n_pis} streams, batch={batch}, BL={bl}) ==")
        print(f"  end-to-end  legacy : {end_to_end['legacy']:8.3f} ms "
              f"({n_pis} generate dispatches in-trace)")
        print(f"  end-to-end  batched: {end_to_end['batched']:8.3f} ms "
              f"(1 fused stream-table pass)")
        print(f"  speedup: {results['speedup']:.1f}X  (target: >= 3X)")
        print(f"  gen-only    legacy : {gen_only['legacy']:8.3f} ms   "
              f"batched: {gen_only['batched']:8.3f} ms  "
              f"({results['gen_only']['speedup']:.1f}X)")
    return results


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny BL/iters: CI-sized sanity pass")
    parser.add_argument("--out", default=None,
                        help="output path (default BENCH_sng.json; "
                             "smoke writes BENCH_sng_smoke.json)")
    args = parser.parse_args()
    out = args.out or ("BENCH_sng_smoke.json" if args.smoke
                       else "BENCH_sng.json")
    res = run(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")
