"""Interpreter-vs-compiled netlist execution benchmark (BENCH_plan_exec.json).

Times ``executor.execute_value`` on the Table-2 arithmetic netlists under the
gate-by-gate reference interpreter and under the compiled execution plan
(core/plan.py + kernels/netlist_exec.py), at the paper-scale BL=1024.  The
compiled path runs stream generation, all fused gate-level passes, the
sequential word-scan (scaled division) and the StoB decode as ONE XLA
program; the interpreter pays one dispatch per gate (and eagerly unpacks
sequential circuits to time-major bits).

Also times two composed application netlists (appnet.py) where level
batching matters most — hundreds of gates collapse to a few dozen fused
passes.  The tracked headline is the geomean speedup over the Table-2 ops
(acceptance: >= 5X); appnet rows are reported separately.

Output schema (written by benchmarks/run.py to BENCH_plan_exec.json):
  {"bitstream_length": ..., "ops": [{"op", "gates", "passes", "fused_mux",
   "interpreter_ms", "compiled_ms", "speedup"}, ...],
   "geomean_speedup_table2": ..., "appnets": [...]}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits, executor
from repro.core.appnet import APP_NETLISTS
from repro.core.plan import compile_plan

from .common import fmt_table, geomean, time_ms

TABLE2_OPS = (
    ("scaled_add", circuits.sc_scaled_add, {"a": 0.3, "b": 0.7}),
    ("multiply", circuits.sc_multiply, {"a": 0.6, "b": 0.5}),
    ("abs_sub", circuits.sc_abs_sub, {"a": 0.8, "b": 0.3}),
    ("scaled_div", circuits.sc_scaled_div, {"a": 0.3, "b": 0.5}),
    ("sqrt", circuits.sc_sqrt, {"a": 0.5}),
    ("exp", circuits.sc_exp, {"a": 0.5}),
)


def _time_backend(net, values, key, bl, backend, iters) -> float:
    """Min-of-iters wall time (ms) for one execute_value call (shared
    measurement protocol — see benchmarks/common.py time_ms)."""
    fn = lambda: executor.execute_value(net, values, key, bl, backend=backend)
    return time_ms(fn, iters)


def _bench_net(name, net, values, key, bl, iters) -> dict:
    plan = compile_plan(net)
    interp = _time_backend(net, values, key, bl, "reference", iters)
    comp = _time_backend(net, values, key, bl, "compiled", iters)
    return {
        "op": name, "gates": plan.n_gates, "passes": plan.n_passes,
        "fused_mux": plan.n_fused_mux,
        "interpreter_ms": round(interp, 4), "compiled_ms": round(comp, 4),
        "speedup": round(interp / comp, 2),
    }


def _appnet_cases(smoke: bool):
    from repro.core import apps
    ol_values = apps.appnet_inputs("ol", p=np.full((16, 6), 0.9))
    cases = [("ol_app_x16", APP_NETLISTS["ol"](), ol_values)]
    if not smoke:
        lit_values = apps.appnet_inputs("lit", a=np.linspace(0.1, 0.9, 81))
        cases.append(("lit_app", APP_NETLISTS["lit"](), lit_values))
    return cases


def run(verbose=True, smoke=False) -> dict:
    bl = 128 if smoke else 1024
    iters = 3 if smoke else 30
    key = jax.random.key(0)

    ops = []
    for name, builder, values in TABLE2_OPS:
        net = builder()
        vals = {k: jnp.float32(x) for k, x in values.items()}
        ops.append(_bench_net(name, net, vals, key, bl, iters))

    appnets = [_bench_net(name, net, vals, key, min(bl, 256), max(iters // 3, 2))
               for name, net, vals in _appnet_cases(smoke)]

    g = geomean([o["speedup"] for o in ops])

    # Phase breakdown for one representative op (Table-8 style attribution):
    # stream generation on its own jitted entry vs the full compiled run.
    pname, pbuilder, pvalues = TABLE2_OPS[1]        # multiply
    pnet = pbuilder()
    pvals = {k: jnp.float32(x) for k, x in pvalues.items()}
    gen_fn = jax.jit(lambda k: executor._gen_pi_streams(
        tuple(pnet.pis), pvals, k, bl))
    gen_ms = time_ms(lambda: gen_fn(key), iters)
    total_ms = next(o["compiled_ms"] for o in ops if o["op"] == pname)
    phases = {"op": pname, "gen_ms": round(gen_ms, 4),
              "pass_ms": round(max(total_ms - gen_ms, 0.0), 4),
              "total_ms": total_ms}

    results = {"bitstream_length": bl, "ops": ops,
               "geomean_speedup_table2": round(g, 2), "appnets": appnets,
               "phases": phases}
    if verbose:
        rows = [[o["op"], o["gates"], o["passes"], o["fused_mux"],
                 f"{o['interpreter_ms']:.3f}", f"{o['compiled_ms']:.3f}",
                 f"{o['speedup']:.1f}X"] for o in ops + appnets]
        print(fmt_table(
            ["Netlist", "Gates", "Passes", "FusedMUX", "Interp(ms)",
             "Compiled(ms)", "Speedup"],
            rows, title=f"\n== Plan-exec bench: interpreter vs compiled "
                        f"(BL={bl}) =="))
        print(f"\n  Geomean speedup over Table-2 ops: {g:.1f}X "
              f"(target: >= 5X)")
    return results


if __name__ == "__main__":
    import json
    res = run()
    with open("BENCH_plan_exec.json", "w") as f:
        json.dump(res, f, indent=2)
    print("wrote BENCH_plan_exec.json")
