"""Soft-fail perf-regression check: smoke bench records vs committed records.

  PYTHONPATH=src python -m benchmarks.check_regression

Compares the aggregate ``*speedup*`` fields of each freshly-written smoke
record (``BENCH_*_smoke.json``) against the same field of the committed
full-size record (``BENCH_*.json``), recursing into nested dicts.  Per-op
*list* entries (BENCH_plan_exec's ``ops``/``appnets`` arrays) are
deliberately NOT compared: single-op smoke timings at BL=128 are sub-ms and
routinely deviate >2X run to run, so warning on them would be noise — the
geomean and bank/SNG headlines are the watched signals.  Smoke runs use tiny
sizes, so absolute timings are incomparable — but a smoke *speedup ratio*
collapsing far below the committed one is the early-warning signal that a PR
regressed a fused path back toward its looped baseline.

Always exits 0 (soft fail): regressions print GitHub-annotation
``::warning::`` lines so they are visible on the PR without blocking it.
"""
from __future__ import annotations

import json
import os
import sys

#: Per-record tolerance: smoke speedup may sit this far below the committed
#: full-size speedup before a warning fires.  Smoke sizes shrink fused-path
#: wins by design and CI machines add timing noise on top; the SNG record
#: gets extra headroom because its smoke workload (batch=64, BL=512) is
#: structurally further from the full run (batch=256, BL=1024) than the
#: pass-count-dominated records — repeated single-core smoke runs land in a
#: 3.1-3.3X band against the 12.8X committed record, so 0.2 keeps the
#: warning under that noise floor while a collapse toward 1X is still caught.
PAIRS = [
    ("BENCH_plan_exec_smoke.json", "BENCH_plan_exec.json", 0.4),
    ("BENCH_bank_plan_smoke.json", "BENCH_bank_plan.json", 0.4),
    ("BENCH_sng_smoke.json", "BENCH_sng.json", 0.2),
    # The serve record's cold baseline is compile-time-dominated and the
    # smoke trace is 4X smaller, so only an order-of-magnitude collapse of
    # the bucketing win should warn.
    ("BENCH_serve_smoke.json", "BENCH_serve.json", 0.05),
    # The multi-bank win is execution-bound: at smoke sizes (BL=128, 24
    # requests) per-request host overhead — identical for both servers —
    # floors the ratio well below the committed full-size one, so the
    # threshold only catches the async path collapsing to (or below) the
    # single-bank baseline.
    ("BENCH_serve_multibank_smoke.json", "BENCH_serve_multibank.json", 0.25),
    # The fault record's only speedup field is chaos_vs_clean_speedup —
    # clean-replay time over chaos-replay time, ~0.9X when recovery is
    # cheap.  Sub-ms smoke replays are noisy, so only the chaos path
    # getting an order of magnitude slower than clean should warn.
    ("BENCH_faults_smoke.json", "BENCH_faults.json", 0.15),
    # The chunked-streaming win is cache-locality-bound: smoke sizes
    # (BL=2048, batch=8) fit in cache so the smoke ratio sits near 1X
    # against the ~4X committed paper-scale run by design.  0.2 only
    # warns when chunking turns into a real slowdown (< ~0.8X).
    ("BENCH_megakernel_smoke.json", "BENCH_megakernel.json", 0.2),
]


def speedup_fields(record: dict, prefix: str = "") -> dict[str, float]:
    """Flatten the aggregate numeric fields whose name mentions 'speedup'.

    Recurses into nested dicts; list entries (per-op arrays) are skipped on
    purpose — see the module docstring.
    """
    out: dict[str, float] = {}
    for k, v in record.items():
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(speedup_fields(v, f"{path}."))
        elif isinstance(v, (int, float)) and "speedup" in k:
            out[path] = float(v)
    return out


def check_pair(smoke_path: str, committed_path: str,
               tolerance: float) -> list[str]:
    if not os.path.exists(smoke_path) or not os.path.exists(committed_path):
        return [f"::notice::{smoke_path} or {committed_path} missing; "
                "skipping perf diff"]
    with open(smoke_path) as f:
        smoke = speedup_fields(json.load(f))
    with open(committed_path) as f:
        committed = speedup_fields(json.load(f))
    lines = []
    for field, want in sorted(committed.items()):
        got = smoke.get(field)
        if got is None:
            lines.append(f"::warning::{smoke_path}: field {field} missing "
                         f"(committed {committed_path} has {want:.2f}X)")
        elif got < want * tolerance:
            lines.append(
                f"::warning::perf regression signal: {smoke_path} {field} = "
                f"{got:.2f}X vs committed {want:.2f}X in {committed_path} "
                f"(< {tolerance:.0%} of committed)")
        else:
            lines.append(f"::notice::{smoke_path}: {field} smoke {got:.2f}X "
                         f"vs committed {want:.2f}X in {committed_path}  ok")
    return lines


def main() -> int:
    any_warn = False
    for smoke_path, committed_path, tolerance in PAIRS:
        for line in check_pair(smoke_path, committed_path, tolerance):
            any_warn |= line.startswith("::warning::")
            print(line)
    print("perf diff complete"
          + (" — warnings above are advisory (soft fail)" if any_warn else ""))
    return 0                               # soft fail by design


if __name__ == "__main__":
    sys.exit(main())
