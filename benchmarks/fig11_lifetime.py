"""Fig. 11 — lifetime improvement (Eq. 11 over utilized cells) of Stoch-IMC
and [22] relative to binary IMC, per application.
"""
from __future__ import annotations

from repro.core import apps

from . import table3_apps
from .common import fmt_table, geomean


def run(verbose=True) -> dict:
    t3 = table3_apps.run(verbose=False)
    results = {}
    rows = []
    for app in apps.APPS:
        lt = t3["apps"][app]["lifetime"]
        ours = lt["stoch"] / lt["binary"]
        cram = lt["cram"] / lt["binary"]
        results[app] = {"stoch_vs_binary": ours, "cram_vs_binary": cram,
                        "stoch_vs_cram": ours / cram}
        rows.append([app.upper(), f"{cram:.4f}X", f"{ours:.2f}X",
                     f"{ours / cram:.1f}X"])
    g_ours = geomean([r["stoch_vs_binary"] for r in results.values()])
    g_vs_cram = geomean([r["stoch_vs_cram"] for r in results.values()])
    if verbose:
        print(fmt_table(["App", "[22] vs binary", "Stoch-IMC vs binary",
                         "Stoch-IMC vs [22]"], rows,
                        title="\n== Fig. 11: lifetime improvement (Eq. 11) =="))
        print(f"\n  Geomean lifetime vs binary: {g_ours:.1f}X (paper: 4.9X); "
              f"vs [22]: {g_vs_cram:.1f}X (paper: 216.3X)")
    return {"apps": results, "geomean_vs_binary": g_ours,
            "geomean_vs_cram": g_vs_cram}


if __name__ == "__main__":
    run()
