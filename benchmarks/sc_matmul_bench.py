"""SC matmul kernel benchmark: accuracy vs bitstream length + CPU-interpret
throughput, plus the analytic TPU cost note (DESIGN.md §6: on TPU the SC
path costs ~2*BL/32 VPU ops per MAC vs 1 MXU MAC — it is an approximation /
fault-tolerance feature, not a speed win; the paper's latency win is specific
to in-memory hardware).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sc_matmul import sc_matmul

from .common import fmt_table


def run(verbose=True, smoke=False) -> dict:
    key = jax.random.key(0)
    m, k, n = (8, 64, 16) if smoke else (32, 256, 64)
    a = jax.random.uniform(jax.random.key(1), (m, k))
    w = jax.random.uniform(jax.random.key(2), (k, n))
    exact = a @ w
    scale = float(jnp.abs(exact).mean())

    rows, results = [], {}
    for bl in ((32, 128) if smoke else (32, 64, 128, 256, 512)):
        t0 = time.time()
        approx = sc_matmul(a, w, bl, bm=8, bn=64, bk=64, interpret=True)
        approx.block_until_ready()
        dt = time.time() - t0
        err = float(jnp.abs(approx - exact).mean()) / scale
        pred_err = 1.0 / np.sqrt(bl * k) * np.sqrt(k) / 2 / scale  # ~p(1-p) bound
        results[bl] = {"rel_err": err, "seconds_interpret": dt}
        rows.append([bl, f"{100 * err:.2f}%", f"{dt:.2f}s",
                     f"{2 * bl / 32:.0f} VPU-ops/MAC"])
    if verbose:
        print(fmt_table(["BL", "rel.err", "CPU-interpret t", "TPU cost model"],
                        rows, title="\n== SC matmul kernel (popcount(AND) "
                                    "approximation of a 32x256 @ 256x64) =="))
        print("  err ~ 1/sqrt(BL): doubling BL halves variance "
              "(unipolar Bernoulli sampling).")
    return results


if __name__ == "__main__":
    run()
