"""Liveness-driven streaming execution benchmark (BENCH_megakernel.json).

Two views of the PR-9 memory-model work:

* **Peak-live words** — for each Table-3 application's merged cost-stage
  bank, the naive executor keeps every PI and intermediate alive at the
  full stream width (``naive_live`` x W words), while the liveness-
  allocated plan holds at most ``max_live`` buffers and the word-tiled
  streamer (``ExecOptions.word_chunk``) narrows each to one chunk of
  words.  The tracked ratio is ``naive_live * W / (max_live * chunk)``
  — the KDE bank is the acceptance headline (>= 4X at BL=16384).  The
  same ``max_live`` sizes the whole-plan megakernel's VMEM scratch pool
  and is priced as subarray occupancy by ``arch.evaluate_bank_plan``.

* **Wall clock** — the KDE application netlist (932 gates, combinational)
  with a batch dim at BL=16384, chunked-streamed vs the one-shot per-pass
  jnp path.  At full width every live buffer is batch x 512 words and the
  working set falls out of cache; streaming at the auto-tuned chunk keeps
  it resident (acceptance: >= 1.3X on CPU).  Both paths are bit-identical
  (also asserted here on the decoded outputs).

Smoke sizes (BL=2048, batch=8) fit CI but sit near 1X by design — the
cache win needs paper-scale working sets — so check_regression.py gives
this record a collapse-only tolerance.

Output schema (written here and by benchmarks/run.py):
  {"bitstream_lengths", "stream_chunk", "banks": {app: {"members",
   "max_live", "naive_live", "live_reduction", "live_occupancy_frac",
   "peak_live_words": {bl: {"naive", "streamed", "reduction"}}}},
   "wallclock": {"app", "bitstream_length", "batch", "word_chunk",
   "unchunked_ms", "chunked_ms", "chunked_speedup", "bit_identical"}}
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import apps, arch, bitstream as bs, executor
from repro.core.appnet import APP_NETLISTS
from repro.core.plan import compile_bank_plan, compile_plan

from .common import fmt_table, time_ms

#: Streaming chunk (words) used for the peak-live-words table: the
#: wall-clock auto-tune below lands on 64 at paper scale, and 64 words x
#: 32 bits is two VREG lanes' worth per live row — clamped to W when a
#: small BL has fewer words than that.
STREAM_CHUNK = 64


def _bank_record(app: str, bls, chunk_cap: int) -> dict:
    bank = compile_bank_plan(apps.cost_stage_netlists(app))
    cost = arch.evaluate_bank_plan(bank, arch.StochIMCConfig())
    peak = {}
    for bl in bls:
        w = bs.n_words(bl)
        chunk = min(chunk_cap, w)
        naive = cost.naive_live * w
        streamed = cost.max_live * chunk
        peak[str(bl)] = {"naive": naive, "streamed": streamed,
                         "reduction": round(naive / max(streamed, 1), 2)}
    return {"members": cost.n_members,
            "max_live": cost.max_live, "naive_live": cost.naive_live,
            "live_reduction": round(cost.live_reduction, 2),
            "live_occupancy_frac": round(cost.live_occupancy_frac, 4),
            "peak_live_words": peak}


def _wallclock(bl: int, batch: int, chunks, iters: int) -> dict:
    net = APP_NETLISTS["kde"]()
    rng = np.random.default_rng(0)
    vals = apps.appnet_inputs(
        "kde", x_t=rng.uniform(0.2, 0.8, (batch,)).astype(np.float32),
        hist=rng.uniform(0.1, 0.9, (batch, 8)).astype(np.float32))
    key = jax.random.key(0)

    def run(chunk):
        opts = executor.ExecOptions(bitstream_length=bl, decode=True,
                                    word_chunk=chunk)
        return executor.run(executor.ExecRequest(net, vals, key, opts))

    base_out = run(None)
    base_ms = time_ms(lambda: run(None), iters)
    best = None
    for ch in chunks:
        ms = time_ms(lambda: run(ch), iters)
        if best is None or ms < best[1]:
            best = (ch, ms)
    chunk, chunked_ms = best
    chunk_out = run(chunk)
    identical = all(bool((chunk_out[k] == base_out[k]).all())
                    for k in base_out)
    # Phase breakdown: the unchunked run's stream-generation phase on its
    # own jitted entry; the chunked scan interleaves gen with passes, so
    # only the unchunked split is separable.
    plan = compile_plan(net)
    gen_fn = jax.jit(lambda k: executor._gen_pi_streams(
        tuple(plan.pis), vals, k, bl))
    gen_ms = time_ms(lambda: gen_fn(key), iters)
    phases = {"gen_ms": round(gen_ms, 3),
              "pass_ms": round(max(base_ms - gen_ms, 0.0), 3),
              "total_ms": round(base_ms, 3)}
    return {"app": "kde_appnet", "bitstream_length": bl, "batch": batch,
            "word_chunk": chunk,
            "unchunked_ms": round(base_ms, 3),
            "chunked_ms": round(chunked_ms, 3),
            "chunked_speedup": round(base_ms / chunked_ms, 2),
            "phases": phases,
            "bit_identical": identical}


def run(verbose: bool = True, smoke: bool = False) -> dict:
    bls = (512, 2048) if smoke else (1024, 4096, 16384)
    banks = {app: _bank_record(app, bls, STREAM_CHUNK) for app in apps.APPS}
    wc = (_wallclock(2048, 8, (16, 32), iters=3) if smoke
          else _wallclock(16384, 32, (32, 64, 128), iters=10))

    results = {"bitstream_lengths": list(bls), "stream_chunk": STREAM_CHUNK,
               "banks": banks, "wallclock": wc}
    if verbose:
        bl_hi = str(bls[-1])
        rows = [[app.upper(), r["members"], r["naive_live"], r["max_live"],
                 f"{r['live_reduction']:.2f}X",
                 f"{r['peak_live_words'][bl_hi]['reduction']:.1f}X"]
                for app, r in banks.items()]
        print(fmt_table(
            ["Bank", "Members", "NaiveLive", "MaxLive", "BufReuse",
             f"PeakWords@{bl_hi}"],
            rows, title=f"\n== Megakernel bench: liveness-allocated "
                        f"streaming (chunk={STREAM_CHUNK} words) =="))
        print(f"\n  KDE wall-clock @ BL={wc['bitstream_length']} "
              f"batch={wc['batch']}: unchunked {wc['unchunked_ms']:.1f} ms "
              f"-> chunked {wc['chunked_ms']:.1f} ms "
              f"(chunk={wc['word_chunk']}, {wc['chunked_speedup']:.1f}X, "
              f"bit_identical={wc['bit_identical']})"
              + ("" if smoke else "  (target: >= 1.3X)"))
    return results


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny BL/batch: CI-sized sanity pass")
    parser.add_argument("--out", default=None,
                        help="output path (default BENCH_megakernel.json; "
                             "smoke writes BENCH_megakernel_smoke.json)")
    args = parser.parse_args()
    out = args.out or ("BENCH_megakernel_smoke.json" if args.smoke
                       else "BENCH_megakernel.json")
    res = run(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")
