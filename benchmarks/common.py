"""Shared benchmark utilities: method cost evaluation + table formatting."""
from __future__ import annotations

import math
import time

import jax

from repro.core import arch, circuits
from repro.core.arch import StochIMCConfig
from repro.core.scheduler import schedule

CFG = StochIMCConfig()          # the paper's evaluation setup: [16,16], BL=256

# Binary-IMC counterpart builders for each stochastic circuit (8-bit
# fixed-point, Section 5-1's constructions).
BINARY_OF = {
    "sc_multiply": lambda: circuits.binary_multiplier(8),
    "sc_scaled_add": lambda: circuits.binary_ripple_carry_adder(8),
    "sc_scaled_add_var": lambda: circuits.binary_ripple_carry_adder(8),
    "sc_abs_sub": lambda: circuits.binary_subtractor(8),
    "sc_scaled_div": lambda: circuits.binary_divider(8),
    "sc_sqrt": lambda: circuits.binary_sqrt(8),
    "sc_exp_c1": lambda: circuits.binary_exp(8),
    "sc_exp_c0.8": lambda: circuits.binary_exp(8),
}


def binary_builder_for(netlist_name: str):
    for prefix, builder in BINARY_OF.items():
        if netlist_name.startswith(prefix):
            return builder
    raise KeyError(netlist_name)


def stoch_cost(net, n_instances=1, q=None, cfg=CFG):
    """Stoch-IMC cost: bit-parallel across subarrays; q lanes per subarray."""
    lanes = q if q is not None else min(cfg.bitstream_length, cfg.subarray_rows)
    sch = schedule(net, n_lanes=lanes)
    return arch.evaluate_stoch_imc(net, sch, cfg, n_instances=n_instances)


def cram_cost(net, n_instances=1, cfg=CFG):
    """[22] SC-CRAM cost: bit-serial in a single subarray."""
    sch = schedule(net, n_lanes=1)
    return arch.evaluate_sc_cram(net, sch, cfg, n_instances=n_instances)


def binary_cost(net, n_instances=1, cfg=CFG):
    # Binary compositions (sqrt 32x1413-scale, exp 17x1255) exceed the
    # reliable 256x256 subarray — the paper reports their *minimum array
    # size* as-is and flags the reliability problem (Section 5-2); we
    # schedule them unconstrained for the same accounting.
    sch = schedule(net, r_available=1 << 16, c_available=1 << 16)
    return arch.evaluate_binary_imc(net, sch, cfg, n_instances=n_instances)


def compute_cycles(cost):
    """Computation-part cycles (Table 2 convention: no StoB accumulation)."""
    return cost.total_cycles - cost.accumulation_cycles


def fmt_table(headers, rows, title=None):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    out = []
    if title:
        out.append(title)
    out.append(" | ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    out.append("-+-".join("-" * w for w in widths))
    for r in rows:
        out.append(" | ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def geomean(xs):
    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


def time_ms(fn, iters: int) -> float:
    """Min-of-iters wall time (ms); two warmup calls (trace + steady state).

    The shared measurement protocol for the perf benches — keep the wall-
    clock records comparable across BENCH_*.json files (check_regression.py
    diffs their speedup ratios against each other PR over PR).
    """
    jax.block_until_ready(fn())
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def traced_phases(fn, trace=None) -> dict:
    """One traced run of ``fn``: per-span-name total times + wall-clock.

    The BENCH_*.json phase-breakdown helper (Table-8 style attribution):
    runs ``fn`` once under a fresh ``core.obs`` trace (untimed — the timed
    measurement stays untraced) and flattens the trace summary into
    ``{"<span>_ms": total, ..., "wall_ms": wall}``.  Host-side spans only;
    see docs/OBSERVABILITY.md for what each span covers.
    """
    from repro.core import obs
    tr = trace if trace is not None else obs.Trace("bench-phases")
    t0 = time.perf_counter()
    with obs.tracing(tr):
        jax.block_until_ready(fn())
    wall_ms = (time.perf_counter() - t0) * 1e3
    phases = {f"{name}_ms": agg["total_ms"]
              for name, agg in sorted(tr.summary()["spans"].items())}
    phases["wall_ms"] = round(wall_ms, 3)
    return phases


def request_phases(stats: dict) -> "dict | None":
    """Lift the serve engine's per-request phase histograms out of a traced
    server's ``stats()`` into a flat BENCH-record block (mean ms per phase:
    queued → staged → inflight, plus end-to-end latency)."""
    hists = stats.get("metrics", {}).get("histograms", {})
    if not hists:
        return None
    out = {}
    for phase in ("queued", "staged", "inflight", "latency"):
        h = hists.get(f"serve.{phase}_ms")
        if h and h.get("count"):
            out[f"{phase}_mean_ms"] = round(h["mean"], 3)
            out[f"{phase}_p99_ms"] = round(h["p99"], 3)
    return out or None
