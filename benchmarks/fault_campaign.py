"""Fault campaign: accuracy under STT-MRAM fault models + chaos serving.

Two halves, one record (``BENCH_faults.json``):

* **Accuracy sweep** — extends Table 4's uniform-bitflip study to the full
  STT-MRAM fault taxonomy in ``core/faults.py``: each application's average
  output error (%) is swept over fault *rate* x fault *kind*:

    - ``transient``  — ``FaultModel(flip_rate=r)``: per-read random flips
      (retention/read-disturb upsets).  Bit-identical to the legacy
      ``bitflip_rate`` path at every rate.
    - ``stuck_at``   — ``FaultModel(stuck0_rate=r/2, stuck1_rate=r/2)``:
      manufacturing stuck-at cells, split evenly between SA0 and SA1.
    - ``dead_rows``  — ``FaultModel(dead_row_rate=r)``: whole word-line
      failures (a dead row zeroes one stream entirely).

  The sweep runs the *functional* app paths (``apps.*_stochastic``) where a
  checkpoint flip models one STT-MRAM array read, so each kind draws its
  masks per array exactly like the per-gate executor path does per gate.

* **Chaos serving trace** — replays an ``sc_multiply`` request trace through
  a ``BankServer`` whose ``fault_injector`` deterministically kills devices
  mid-run (rotating victim, periodic kill windows).  With retry + quarantine
  enabled the server must lose ZERO tickets, return bit-identical results to
  standalone execution, and keep p99 latency bounded; the clean-replay /
  chaos-replay time ratio is tracked as ``chaos_vs_clean_speedup`` so
  recovery overhead regressions surface in ``check_regression.py``.

Run standalone, the bench forces 4 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) so quarantine has
somewhere to re-dispatch; imported in-process (benchmarks.run) it honors the
host's device count and skips the chaos half below 2 devices.

Output schema:
  {"bitstream_length", "rates", "kinds", "apps",
   "accuracy": {app: {kind: [err%, ...]}},
   "chaos": {"n_requests", "n_devices", "injected_failures", "retries",
             "quarantines", "redispatched_requests", "failed_tickets",
             "lost_tickets", "bit_identical", "p99_ms", "clean_s",
             "chaos_s", "chaos_vs_clean_speedup"} | None}
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4").strip()

import dataclasses
import time

import jax
import numpy as np

from repro.core import apps, circuits, executor
from repro.core.faults import FaultModel

from .common import fmt_table, request_phases
from .table4_bitflip import _cases

RATES = (0.0, 0.05, 0.10, 0.15, 0.20)
SMOKE_RATES = (0.0, 0.10)
KINDS = ("transient", "stuck_at", "dead_rows")
BL = 256


def _model(kind: str, r: float) -> "FaultModel | None":
    """The swept FaultModel for one (kind, rate) cell; None = clean."""
    if r <= 0.0:
        return None
    if kind == "transient":
        return FaultModel(flip_rate=r)
    if kind == "stuck_at":
        return FaultModel(stuck0_rate=r / 2, stuck1_rate=r / 2)
    if kind == "dead_rows":
        return FaultModel(dead_row_rate=r)
    raise ValueError(f"unknown fault kind {kind!r}")


def accuracy_sweep(verbose: bool = True, smoke: bool = False) -> dict:
    """Average output error (%) per app x fault kind x rate."""
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    lit_a, ol_p, hdp_v, kde_x, kde_h = _cases(rng, smoke)
    # Smoke drops HDP: its Gaines-divider recurrence is the slowest app and
    # the three remaining apps already cover the >= 3-app acceptance bar.
    app_names = ("lit", "ol", "kde") if smoke else apps.APPS
    rates = SMOKE_RATES if smoke else RATES
    exact = {
        "lit": apps.lit_exact(lit_a),
        "ol": apps.ol_exact(ol_p),
        "hdp": apps.hdp_exact(hdp_v),
        "kde": apps.kde_exact(kde_x, kde_h),
    }

    def stoch(app, model):
        if app == "lit":
            return np.asarray(apps.lit_stochastic(key, lit_a, BL,
                                                  fault_model=model))
        if app == "ol":
            return np.asarray(apps.ol_stochastic(key, ol_p, BL,
                                                 fault_model=model))
        if app == "hdp":
            return np.asarray(apps.hdp_stochastic(key, hdp_v, BL,
                                                  fault_model=model))
        return np.asarray(apps.kde_stochastic(key, kde_x, kde_h, BL,
                                              fault_model=model))

    results, rows = {}, []
    for app in app_names:
        results[app] = {}
        for kind in KINDS:
            errs = [float(np.abs(stoch(app, _model(kind, r))
                                 - exact[app]).mean()) * 100
                    for r in rates]
            results[app][kind] = errs
            rows.append([app.upper(), kind] + [f"{e:.2f}" for e in errs])
    if verbose:
        hdr = ["App", "Kind"] + [f"@{int(r * 100)}%" for r in rates]
        print(fmt_table(hdr, rows,
                        title="\n== Fault campaign: avg output error (%) vs "
                              "fault rate x kind =="))
    return {"rates": list(rates), "kinds": list(KINDS),
            "apps": list(app_names), "by_app": results}


class ChaosInjector:
    """Deterministic rotating device killer for the serving trace.

    Counts batch launches; for each window of ``period`` launches one victim
    device is "down" — every launch placed on it fails.  The victim rotates
    each window, so every device dies at some point, accumulates the
    consecutive failures that trip the quarantine breaker, and must hand
    its in-flight work to the others.  Health probes (batch is None) always
    pass — a "device" recovers the moment its quarantine expires,
    exercising re-admission.
    """

    def __init__(self, devices, period: int = 6):
        self.dev_index = {d: i for i, d in enumerate(devices)}
        self.period = period
        self.launches = 0
        self.kills = 0

    def __call__(self, device, batch):
        if batch is None:                     # health probe: recovered
            return
        i = self.launches
        self.launches += 1
        victim = (i // self.period) % len(self.dev_index)
        if self.dev_index.get(device) == victim:
            self.kills += 1
            raise RuntimeError(f"chaos: injected device failure on {device}")


def _chaos_trace(n: int, bl: int, seed: int = 0):
    from repro.serve import circuit_request
    net = circuits.sc_multiply()
    rng = np.random.default_rng(seed)
    keys = jax.random.split(jax.random.key(seed), n)
    return [circuit_request(net,
                            {"a": float(rng.uniform(0.1, 0.9)),
                             "b": float(rng.uniform(0.1, 0.9))},
                            keys[i], bl)
            for i in range(n)]


def _replay(server, reqs):
    """Submit the whole trace, drain, and account for every ticket."""
    t0 = time.perf_counter()
    tickets = [server.submit(r) for r in reqs]
    server.flush()
    outs, failed, lost = [], 0, 0
    for t in tickets:
        try:
            outs.append(t.result(timeout=60.0))
        except TimeoutError:                  # never resolved: a LOST ticket
            lost += 1
            outs.append(None)
        except Exception:                     # resolved, but with an error
            failed += 1
            outs.append(None)
    return time.perf_counter() - t0, outs, failed, lost


def _spot_check(outs, reqs, n: int = 8) -> bool:
    """Served (chaos-recovered) results vs standalone executor.run."""
    import jax.numpy as jnp
    idxs = np.linspace(0, len(reqs) - 1, n).astype(int)
    for i in idxs:
        if outs[i] is None:
            return False
        r = reqs[i]
        ref = executor.run(
            r, options=dataclasses.replace(r.options, decode=True))
        if not all(bool(jnp.array_equal(outs[i][k], ref[k])) for k in ref):
            return False
    return True


def _server(devices, injector=None):
    from repro.serve import BankServer
    return BankServer(max_slots=8, devices=devices, max_inflight=2,
                      placement="round_robin", max_retries=3,
                      retry_backoff_s=0.002, quarantine_after=2,
                      quarantine_s=0.02, fault_injector=injector)


def chaos_trace(verbose: bool = True, smoke: bool = False) -> "dict | None":
    devices = jax.devices()
    if len(devices) < 2:
        if verbose:
            print("\n[skip] chaos serving trace: only 1 jax device — run "
                  "`python -m benchmarks.fault_campaign` standalone to "
                  "force 4 host devices")
        return None
    n_requests = 24 if smoke else 96
    bl = 128 if smoke else 512
    reqs = _chaos_trace(n_requests, bl)
    reps = 1 if smoke else 3

    # Clean replay: identical server config, no injector.  Round-robin
    # placement rotates batches onto a different device offset each replay,
    # so warm up twice — enough rotations to land every batch shape on
    # every device before anything is timed.
    clean = _server(devices)
    _replay(clean, reqs)
    _replay(clean, reqs)
    clean_s = float("inf")
    for _ in range(reps):
        clean.reset_stats()
        s, _, _, _ = _replay(clean, reqs)
        clean_s = min(clean_s, s)
    # One extra traced replay (untimed): per-request queued/staged/inflight
    # attribution for the clean baseline.  Timed replays stay untraced.
    from repro.core import obs
    clean.trace = obs.Trace("fault-campaign-clean")
    _replay(clean, reqs)
    phases = request_phases(clean.stats())
    clean.trace = None
    clean.close()

    # Chaos replay: the injector rotates kills across all devices; retries
    # and quarantine re-dispatch must absorb every failure.
    chaos_s, stats, injector = float("inf"), None, None
    failed = lost = 0
    outs = []
    for _ in range(reps):
        inj = ChaosInjector(devices)
        srv = _server(devices, injector=inj)
        s, o, f, l = _replay(srv, reqs)
        st = srv.stats()
        srv.close()
        failed, lost = max(failed, f), max(lost, l)
        if s < chaos_s:
            chaos_s, stats, injector, outs = s, st, inj, o
    bit_identical = _spot_check(outs, reqs)

    res = {
        "n_requests": n_requests,
        "bitstream_length": bl,
        "n_devices": len(devices),
        "injected_failures": injector.kills,
        "retries": stats["retries"],
        "quarantines": stats["quarantines"],
        "redispatched_requests": stats["redispatched_requests"],
        "failed_tickets": failed,
        "lost_tickets": lost,
        "bit_identical": bool(bit_identical),
        "p99_ms": round(stats["p99_ms"], 3),
        "clean_s": round(clean_s, 4),
        "chaos_s": round(chaos_s, 4),
        "chaos_vs_clean_speedup": round(clean_s / chaos_s, 3),
        "phases": phases,
    }
    if verbose:
        print(f"\n== Chaos serving trace: {n_requests} requests, "
              f"{len(devices)} devices, BL={bl} ==")
        print(f"  injected failures : {injector.kills:4d}  "
              f"(retries {stats['retries']}, "
              f"quarantines {stats['quarantines']}, "
              f"re-dispatched {stats['redispatched_requests']})")
        print(f"  lost tickets      : {lost:4d}  (target: 0)")
        print(f"  failed tickets    : {failed:4d}  (target: 0)")
        print(f"  bit-identical     : {bit_identical}")
        print(f"  p99 latency       : {stats['p99_ms']:.1f} ms")
        print(f"  clean {clean_s:.3f} s vs chaos {chaos_s:.3f} s  "
              f"(recovery cost {chaos_s / clean_s:.2f}X)")
    return res


def run(verbose: bool = True, smoke: bool = False) -> dict:
    acc = accuracy_sweep(verbose=verbose, smoke=smoke)
    chaos = chaos_trace(verbose=verbose, smoke=smoke)
    return {
        "bitstream_length": BL,
        "rates": acc["rates"],
        "kinds": acc["kinds"],
        "apps": acc["apps"],
        "accuracy": acc["by_app"],
        "chaos": chaos,
    }


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="reduced rates/apps/trace: CI-sized sanity pass")
    parser.add_argument("--out", default=None,
                        help="output path (default BENCH_faults.json; smoke "
                             "writes BENCH_faults_smoke.json)")
    args = parser.parse_args()
    out = args.out or ("BENCH_faults_smoke.json" if args.smoke
                       else "BENCH_faults.json")
    res = run(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")
