"""Fig. 10 — energy breakdown (logic / preset / input-init / peripheral) per
application for binary IMC, [22], and Stoch-IMC.
"""
from __future__ import annotations

from repro.core import apps

from . import table3_apps
from .common import fmt_table


def run(verbose=True) -> dict:
    t3 = table3_apps.run(verbose=False)
    results = {}
    rows = []
    for app in apps.APPS:
        bd = t3["apps"][app]["energy_breakdown"]
        res = {}
        for method, e in (("binary", bd["binary"]), ("[22]", bd["cram"]),
                          ("stoch-imc", bd["stoch"])):
            res[method] = e.shares()
            rows.append([app.upper(), method] +
                        [f"{100 * res[method][k]:.1f}%" for k in
                         ("logic", "preset", "input_init", "peripheral")])
        results[app] = res
    if verbose:
        print(fmt_table(["App", "Method", "logic", "preset(reset)",
                         "input-init", "peripheral"], rows,
                        title="\n== Fig. 10: energy breakdown =="))
        print("\n  Paper (qualitative): logic+reset dominate everywhere; "
              "stochastic methods shift share from logic to reset; Stoch-IMC "
              "peripheral > [22] (accumulators + BtoS).")
    return results


if __name__ == "__main__":
    run()
