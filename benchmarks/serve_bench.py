"""Dynamic bank serving under synthetic mixed traffic (BENCH_serve.json).

Replays a heterogeneous request trace — 64 requests across >= 4 distinct
member sets, arriving in bursts — through three serving models:

  * **server** — ``repro.serve.BankServer``: requests admit into bucketed,
    padded bank templates (padded slot counts + identity pads + active
    masks), so repeat traffic mixes reuse ONE BankPlan and ONE jit program.
    Measured at steady state (one warmup replay, stats reset, timed replay);
    the tracked headline is its throughput plus p50/p99 request latency and
    bucket hit rate.
  * **per_request** — one warm ``executor.execute_value`` dispatch per
    request (netlists reused, plan/jit caches hot): the pre-bank-merging
    serving model.
  * **cold_many** — what a naive merged-batch (``executor.run([...])``) server does under
    changing traffic: every burst builds fresh netlists and starts from
    cleared plan/bank caches, so each member set recompiles its merged bank
    and retraces its jit — the cost the bucketing exists to amortize.
    (Timed once over the trace; cold is the steady state of that design.)

Acceptance (ISSUE 4): server throughput >= 2X cold_many on the 64-request
trace, bucket hit rate >= 90% after warmup.  Bit-identity of served results
is pinned by tests/test_serve.py, not re-checked here.

Output schema:
  {"bitstream_length", "n_requests", "n_bursts", "n_member_sets",
   "max_slots", "server": {...stats...}, "server_s", "per_request_s",
   "cold_many_s", "server_rps", "per_request_rps", "cold_many_rps",
   "speedup_vs_cold", "speedup_vs_per_request"}
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import circuits, executor, plan
from repro.serve import BankServer, circuit_request

from .common import request_phases

# One netlist object per structure (reused across the trace, so the warm
# paths hit the plan memo the way a real server would).
_STRUCTS = {
    "mul": circuits.sc_multiply(),
    "sadd": circuits.sc_scaled_add(),
    "abs": circuits.sc_abs_sub(),
    "sqrt": circuits.sc_sqrt(),
    "exp": circuits.sc_exp(),
    "div": circuits.sc_scaled_div(),
}

_VALUES = {
    "mul": {"a": 0.3, "b": 0.7},
    "sadd": {"a": 0.2, "b": 0.9},
    "abs": {"a": 0.4, "b": 0.1},
    "sqrt": {"a": 0.5},
    "exp": {"a": 0.5},
    "div": {"a": 0.4, "b": 0.2},
}

# >= 4 distinct member sets, cycled into bursts: heterogeneous sizes and
# compositions, incl. a sequential member (div) and count variation that
# exercises the power-of-two slot padding (3 vs 4 muls share a bucket).
# Burst widths sit near max_slots so the merged bank dispatch has the
# cross-member width the paper's Fig. 8 bank exploits.
MEMBER_SETS = [
    ("A", ["mul"] * 6 + ["sadd"] * 4 + ["abs"] * 3 + ["sqrt"] * 3),
    ("B", ["mul"] * 4 + ["abs"] * 4 + ["exp"] * 6 + ["sadd"] * 2),
    ("C", ["mul"] * 3 + ["sadd", "sqrt", "exp", "exp", "div"]),
    ("D", ["mul"] * 8 + ["sadd"] * 4 + ["sqrt"] * 4),
]


def _spread(structs: list, k: int) -> list:
    """First ``k`` slots favoring structural diversity: one of each distinct
    structure (preserving the set's sequential/exp members), then repeats."""
    out = list(dict.fromkeys(structs))[:k]
    i = 0
    while len(out) < k:
        out.append(structs[i % len(structs)])
        i += 1
    return out


def build_trace(n_requests: int, seed: int = 0,
                max_burst: int | None = None):
    """Bursts cycling the member sets until ``n_requests`` requests exist.

    Returns ``[(set_name, [(struct_name, values, key), ...]), ...]`` — values
    are jittered per request so no burst is a literal repeat of another.
    ``max_burst`` shrinks each burst to a diversity-preserving slice (smoke
    traces stay short but still serve every structure, incl. the sequential
    divider, and still replay distinct member multisets).
    """
    keys = jax.random.split(jax.random.key(seed), n_requests)
    bursts = []
    ki = 0
    i = 0
    while ki < n_requests:
        name, structs = MEMBER_SETS[i % len(MEMBER_SETS)]
        if max_burst is not None:
            structs = _spread(structs, max_burst)
        burst = []
        for s in structs:
            if ki >= n_requests:
                break
            jitter = 0.9 + 0.2 * ((ki % 7) / 6.0)
            vals = {k: jnp.float32(min(v * jitter, 1.0))
                    for k, v in _VALUES[s].items()}
            burst.append((s, vals, keys[ki]))
            ki += 1
        bursts.append((name, burst))
        i += 1
    return bursts


def _replay_server(server: BankServer, bursts, bl: int) -> float:
    t0 = time.perf_counter()
    for _, burst in bursts:
        server.serve([circuit_request(_STRUCTS[s], vals, key, bl)
                      for s, vals, key in burst])
    return time.perf_counter() - t0


def _replay_per_request(bursts, bl: int) -> float:
    t0 = time.perf_counter()
    for _, burst in bursts:
        outs = [executor.execute_value(_STRUCTS[s], vals, key, bl)
                for s, vals, key in burst]
        jax.block_until_ready(outs)
    return time.perf_counter() - t0


def _replay_cold_many(bursts, bl: int) -> float:
    builders = {"mul": circuits.sc_multiply, "sadd": circuits.sc_scaled_add,
                "abs": circuits.sc_abs_sub, "sqrt": circuits.sc_sqrt,
                "exp": circuits.sc_exp, "div": circuits.sc_scaled_div}
    t0 = time.perf_counter()
    for _, burst in bursts:
        # Fresh netlists + cleared caches: the naive server's steady state
        # under changing member sets (every burst recompiles its bank).
        plan.clear_cache()
        outs = executor.run(
            [executor.ExecRequest(builders[s](), vals, key,
                                  executor.ExecOptions(bitstream_length=bl,
                                                       decode=True))
             for s, vals, key in burst])
        jax.block_until_ready(outs)
    return time.perf_counter() - t0


def run(verbose: bool = True, smoke: bool = False) -> dict:
    bl = 128 if smoke else 1024
    n_requests = 20 if smoke else 64
    bursts = build_trace(n_requests, max_burst=5 if smoke else None)
    # Distinct member *multisets* actually replayed (not burst labels).
    n_sets = len({tuple(sorted(s for s, _, _ in burst))
                  for _, burst in bursts})

    reps = 1 if smoke else 5                    # best-of: steady-state timing
    server = BankServer(max_slots=16, window_s=None)
    _replay_server(server, bursts, bl)          # warmup: compile + trace
    # Stats reset per rep (caches stay warm): the reported block is the best
    # rep's own counters, so every field describes the same replay.
    server_s, stats = float("inf"), None
    for _ in range(reps):
        server.reset_stats()
        s = _replay_server(server, bursts, bl)
        if s < server_s:
            server_s, stats = s, server.stats()

    # One extra traced replay (untimed) for the phase breakdown: the engine
    # stamps admit/stage/launch/reap per request and its histograms give the
    # queued/staged/inflight attribution.  Timed replays stay untraced.
    from repro.core import obs
    server.trace = obs.Trace("serve-bench")
    _replay_server(server, bursts, bl)
    phases = request_phases(server.stats())
    server.trace = None

    _replay_per_request(bursts, bl)             # warm the per-request jits
    per_request_s = min(_replay_per_request(bursts, bl)
                        for _ in range(reps))

    cold_many_s = _replay_cold_many(bursts, bl)
    # Leave the process-wide caches sane for whoever runs after us.
    plan.clear_cache()

    results = {
        "bitstream_length": bl,
        "n_requests": n_requests,
        "n_bursts": len(bursts),
        "n_member_sets": n_sets,
        "max_slots": server.max_slots,
        "server": {k: round(v, 4) if isinstance(v, float) else v
                   for k, v in stats.items()},
        "server_s": round(server_s, 4),
        "per_request_s": round(per_request_s, 4),
        "cold_many_s": round(cold_many_s, 4),
        "server_rps": round(n_requests / server_s, 2),
        "per_request_rps": round(n_requests / per_request_s, 2),
        "cold_many_rps": round(n_requests / cold_many_s, 2),
        "speedup_vs_cold": round(cold_many_s / server_s, 2),
        "speedup_vs_per_request": round(per_request_s / server_s, 2),
        "phases": phases,
    }
    if verbose:
        print(f"\n== Serve bench: dynamic bank serving "
              f"({n_requests} requests, {len(bursts)} bursts, "
              f"{n_sets} member sets, BL={bl}) ==")
        print(f"  server      : {server_s:8.3f} s  "
              f"({results['server_rps']:8.1f} req/s, "
              f"p50 {stats['p50_ms']:.2f} ms, p99 {stats['p99_ms']:.2f} ms, "
              f"bucket hit {stats['bucket_hit_rate']:.0%}, "
              f"padding waste {stats['padding_waste']:.0%})")
        print(f"  per-request : {per_request_s:8.3f} s  "
              f"({results['per_request_rps']:8.1f} req/s, warm jit loop)")
        print(f"  cold many   : {cold_many_s:8.3f} s  "
              f"({results['cold_many_rps']:8.1f} req/s, recompile per burst)")
        print(f"  speedup vs cold-recompile: "
              f"{results['speedup_vs_cold']:.1f}X  (target: >= 2X)   "
              f"vs per-request: {results['speedup_vs_per_request']:.1f}X")
    return results


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny BL/trace: CI-sized sanity pass")
    parser.add_argument("--out", default=None,
                        help="output path (default BENCH_serve.json; smoke "
                             "writes BENCH_serve_smoke.json)")
    args = parser.parse_args()
    out = args.out or ("BENCH_serve_smoke.json" if args.smoke
                       else "BENCH_serve.json")
    res = run(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")
