"""Table 3 — application-level comparison: total time steps, energy, area for
LIT / OL / HDP / KDE under Stoch-IMC, [22], and binary IMC.

Each application's stochastic circuit is given to Algorithm 1 stage by stage
(apps.*_cost_stages); the binary counterpart swaps every stochastic stage for
its 8-bit fixed-point netlist.  Accumulation (StoB) is charged once per
application output, matching the paper's application accounting.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import apps
from repro.core.energy import EnergyBreakdown

from .common import (CFG, binary_builder_for, binary_cost, cram_cost,
                     fmt_table, geomean, stoch_cost)

# Paper Table 3 ratios (this work / binary) for the comparison columns.
PAPER = {
    "lit": {"time": 0.003, "time22": 0.463, "energy": 5.711, "energy22": 5.694},
    "ol": {"time": 0.085, "time22": 5.908, "energy": 1.244, "energy22": 0.816},
    "hdp": {"time": 0.004, "time22": 0.454, "energy": 0.056, "energy22": 0.046},
    "kde": {"time": 0.003, "time22": 0.565, "energy": 0.455, "energy22": 0.449},
}

STAGES = {
    "lit": apps.lit_cost_stages,
    "ol": apps.ol_cost_stages,
    "hdp": apps.hdp_cost_stages,
    "kde": apps.kde_cost_stages,
}

# Binary work units matching one composed stochastic netlist instance
# (OL is batched 16 pixel-circuits per netlist — Section 5.3.2).
BINARY_WORK_MULT = {"lit": 1, "ol": 16, "hdp": 1, "kde": 1}


def _acc(costs, acc_cycles_once):
    """Sum stage costs; charge hierarchical accumulation once."""
    total_cycles = sum(c.total_cycles - c.accumulation_cycles for c in costs)
    total_cycles += acc_cycles_once
    e = EnergyBreakdown(
        logic_j=sum(c.energy.logic_j for c in costs),
        preset_j=sum(c.energy.preset_j for c in costs),
        input_init_j=sum(c.energy.input_init_j for c in costs),
        peripheral_j=sum(c.energy.peripheral_j for c in costs))
    cells = max(sum(c.cells_used for c in costs), 1)
    writes = sum(c.cell_writes for c in costs)
    return total_cycles, e, cells, writes


def app_costs(app: str):
    """Costs for one application work unit under the three methods.

    Stoch-IMC and [22] evaluate the *composed per-bit netlist* (appnet —
    instance-per-row, exactly what Algorithm 1 receives in the paper);
    binary IMC evaluates the equivalent 8-bit fixed-point stages with
    intra-subarray instance parallelism ([3,8] baseline).
    """
    from repro.core.appnet import APP_NETLISTS
    net = APP_NETLISTS[app]()
    ours = _acc([stoch_cost(net, n_instances=1, q=1)],
                CFG.accumulation_steps())
    cram = _acc([cram_cost(net, n_instances=1)], CFG.bitstream_length)

    b_costs = []
    for st in STAGES[app]():
        b_net = binary_builder_for(st.netlist.name)()
        b_costs.append(binary_cost(
            b_net, n_instances=st.n_instances * BINARY_WORK_MULT[app]))
    binary = _acc(b_costs, 0)
    return ours, cram, binary


def _exec_check(bl: int = 256) -> dict:
    """Run every composed appnet end to end through the compiled plan.

    The cost model above only *schedules* these netlists; this executes them
    (fused level passes; HDP's divider scans over words) and reports the
    decoded output plus per-evaluation latency — the proof that the circuits
    Algorithm 1 maps are the circuits we can actually run.
    """
    from repro.core.appnet import APP_NETLISTS
    key = jax.random.key(11)
    inputs = {
        "lit": {"a": np.linspace(0.1, 0.9, 81)},
        "ol": {"p": np.full((16, 6), 0.9)},
        "hdp": {"v": {k: 0.5 for k in apps.HDP_KEYS}},
        "kde": {"x_t": 0.4, "hist": np.linspace(0.2, 0.8, apps.KDE_N)},
    }
    out = {}
    for app in apps.APPS:
        net = APP_NETLISTS[app]()
        run_once = lambda: apps.appnet_stochastic(app, key, bl, net=net,
                                                  **inputs[app])
        first = run_once()                         # trace + compile
        jax.block_until_ready(first)
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(run_once())
            ts.append(time.perf_counter() - t0)
        val = float(next(iter(first.values())))    # deterministic per key
        out[app] = {"value": val, "ms_per_eval": min(ts) * 1e3}
    return out


def run(verbose=True, exec_check=False) -> dict:
    # exec_check is opt-in: fig10/fig11 re-enter run() for the cost model
    # only, and the check recompiles every appnet (fresh node names defeat
    # the plan cache) — benchmarks.run requests it once at top level.
    rows = []
    results = {}
    for app in apps.APPS:
        (s_cyc, s_e, s_cells, s_w), (c_cyc, c_e, c_cells, c_w), \
            (b_cyc, b_e, b_cells, b_w) = app_costs(app)
        res = {
            "time_ratio": s_cyc / b_cyc,
            "time_ratio_cram": c_cyc / b_cyc,
            "energy_ratio": s_e.total_j / b_e.total_j,
            "energy_ratio_cram": c_e.total_j / b_e.total_j,
            "area_ratio": s_cells / b_cells,
            "cycles": {"stoch": s_cyc, "cram": c_cyc, "binary": b_cyc},
            "lifetime": {"stoch": s_cells / s_w, "cram": c_cells / c_w,
                         "binary": b_cells / b_w},
            "energy_breakdown": {"stoch": s_e, "cram": c_e, "binary": b_e},
            "paper": PAPER[app],
        }
        results[app] = res
        rows.append([app.upper(), b_cyc, c_cyc, s_cyc,
                     f"{res['time_ratio_cram']:.3f}X", f"{res['time_ratio']:.4f}X",
                     f"{PAPER[app]['time']:.3f}X",
                     f"{res['energy_ratio']:.3f}X", f"{PAPER[app]['energy']:.3f}X"])
    perf_vs_binary = 1.0 / geomean([r["time_ratio"] for r in results.values()])
    perf_vs_cram = geomean([r["time_ratio_cram"] / r["time_ratio"]
                            for r in results.values()])
    energy_vs_binary = 1.0 / geomean([r["energy_ratio"]
                                      for r in results.values()])
    summary = {"perf_vs_binary": perf_vs_binary, "perf_vs_cram": perf_vs_cram,
               "energy_vs_binary": energy_vs_binary}
    exec_results = _exec_check() if exec_check else {}
    if verbose and exec_results:
        print("\n  Compiled-plan execution of the composed appnets (BL=256):")
        for app, r in exec_results.items():
            print(f"    {app.upper():4s} out={r['value']:.3f}  "
                  f"{r['ms_per_eval']:.2f} ms/eval")
    if verbose:
        print(fmt_table(
            ["App", "BinCyc", "[22]Cyc", "OurCyc", "T[22](norm)",
             "T this(norm)", "T paper", "E this(norm)", "E paper"],
            rows, title="\n== Table 3: applications "
                        "(normalized to binary IMC) =="))
        print(f"\n  Perf improvement vs binary IMC (geomean): "
              f"{perf_vs_binary:.1f}X   (paper: 135.7X)")
        print(f"  Perf improvement vs [22] (geomean):       "
              f"{perf_vs_cram:.1f}X   (paper: 124.2X)")
        print(f"  Energy reduction vs binary IMC (geomean): "
              f"{energy_vs_binary:.2f}X   (paper: 1.5X)")
    return {"apps": results, "summary": summary, "exec": exec_results}


if __name__ == "__main__":
    run()
