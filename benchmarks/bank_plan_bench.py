"""Merged bank-plan vs looped per-netlist execution (BENCH_bank_plan.json).

Times one heterogeneous bank of 16 Table-2/Table-3 netlist instances at
BL=1024 two ways:

  * **looped** — one ``executor.execute_value`` dispatch per member, the
    pre-bank-merging serving model (each member is itself a compiled fused
    plan, so this baseline is already the PR-1 fast path);
  * **merged** — ONE ``executor.run([ExecRequest, ...])`` call: all members merge
    into a single bank plan (``core/plan.compile_bank_plan``) whose levels
    type-batch gates across members, executed as a single jit dispatch
    (sequential members share one merged scan).

Both paths are bit-identical (pinned by tests/test_bank_plan.py); the tracked
headline is the merged-over-looped wall-clock speedup (acceptance: >= 3X for
the 16-member bank).  The record also maps the pass counts onto the [n, m]
bank cycle model (``arch.evaluate_bank_plan``) for the measured bank and for
each Table-3 application's full cost-stage instance set — the architectural
view of the same memory-level-parallelism win.

The record also splits merged wall-clock into a stream-generation phase
(``gen_ms`` — the batched bulk-BtoS pass, timed via
``executor.generate_bank_streams``) and the remaining logic/decode phase
(``pass_ms = merged_ms - gen_ms``), so PR-over-PR perf work can see which
phase moved.

Each arch record also carries the Algorithm-1 *scheduled* cycle pricing
(``schedule_cycles`` / ``looped_schedule_cycles``, from the ``Schedule`` the
compiler pipeline attaches to every plan) and their ratio
``schedule_speedup`` — named with the "speedup" substring so
``check_regression.py`` auto-tracks it PR over PR.

Output schema (written here and by benchmarks/run.py):
  {"bitstream_length", "n_members", "members", "key_mode", "looped_ms",
   "merged_ms", "gen_ms", "pass_ms", "speedup", "merged_passes",
   "looped_passes", "arch_bank": {..., "schedule_cycles",
   "schedule_speedup"}, "table3_banks": {app: {...}}}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import apps, arch, circuits, executor
from repro.core.plan import compile_bank_plan

from .common import time_ms as _time


def bank_members() -> tuple[list, list, list]:
    """16 heterogeneous members: one bank serving stage-circuit instances of
    all four Table-3 applications (the circuits ``apps.*_cost_stages`` feeds
    Algorithm 1: LIT's square/mean/abs-sub/sqrt, OL's product multiplies,
    HDP's variable-select MUXes and divider, KDE's abs-sub/exp ladder) plus a
    Table-2 exp instance — the paper's Fig. 8 workload shape, many small
    circuit instances per bank."""
    members = [
        ("lit/square", circuits.sc_multiply, {"a": 0.45, "b": 0.45}),
        ("lit/mean", circuits.sc_scaled_add, {"a": 0.4, "b": 0.6}),
        ("lit/var", circuits.sc_abs_sub, {"a": 0.5, "b": 0.2}),
        ("lit/sigma", circuits.sc_sqrt, {"a": 0.3}),
        ("ol/prod0", circuits.sc_multiply, {"a": 0.9, "b": 0.9}),
        ("ol/prod1", circuits.sc_multiply, {"a": 0.81, "b": 0.9}),
        ("ol/prod2", circuits.sc_multiply, {"a": 0.73, "b": 0.81}),
        ("hdp/mux_e", circuits.sc_scaled_add_var,
         {"a": 0.5, "b": 0.5, "s": 0.5}),
        ("hdp/mux_ne", circuits.sc_scaled_add_var,
         {"a": 0.4, "b": 0.6, "s": 0.5}),
        ("hdp/num", circuits.sc_multiply, {"a": 0.5, "b": 0.5}),
        ("hdp/div", circuits.sc_scaled_div, {"a": 0.25, "b": 0.25}),
        ("kde/dist", circuits.sc_abs_sub, {"a": 0.4, "b": 0.7}),
        ("kde/exp", lambda: circuits.sc_exp(0.8), {"a": 0.3}),
        ("kde/prod", circuits.sc_multiply, {"a": 0.7, "b": 0.7}),
        ("kde/mean", circuits.sc_scaled_add, {"a": 0.5, "b": 0.3}),
        ("t2/exp", circuits.sc_exp, {"a": 0.5}),
    ]
    nets = [builder() for _, builder, _ in members]
    values = [{k: jnp.float32(v) for k, v in vals.items()}
              for _, _, vals in members]
    names = [name for name, _, _ in members]
    return nets, values, names


def _arch_record(bank, cfg) -> dict:
    c = arch.evaluate_bank_plan(bank, cfg)
    # "schedule_speedup" keeps the *speedup* substring on purpose:
    # check_regression.py auto-tracks speedup-named numeric fields.
    return {"n_members": c.n_members, "merged_passes": c.merged_passes,
            "looped_passes": c.looped_passes,
            "pipeline_factor": c.pipeline_factor,
            "merged_cycles": c.merged_cycles, "looped_cycles": c.looped_cycles,
            "simd_speedup": round(c.simd_speedup, 2),
            "schedule_cycles": c.schedule_cycles,
            "looped_schedule_cycles": c.looped_schedule_cycles,
            "schedule_speedup": round(c.schedule_speedup, 2)}


def run(verbose: bool = True, smoke: bool = False) -> dict:
    bl = 128 if smoke else 1024
    iters = 3 if smoke else 20
    nets, values, names = bank_members()
    keys = jax.random.split(jax.random.key(0), len(nets))

    merged_opts = executor.ExecOptions(bitstream_length=bl, decode=True)
    merged_fn = lambda: executor.run(
        [executor.ExecRequest(n, v, keys[i], merged_opts)
         for i, (n, v) in enumerate(zip(nets, values))])
    looped_fn = lambda: [executor.execute_value(n, v, keys[i], bl)
                         for i, (n, v) in enumerate(zip(nets, values))]
    merged_ms = _time(merged_fn, iters)
    looped_ms = _time(looped_fn, iters)

    bank = compile_bank_plan(nets)
    # Phase split: time the stream-generation phase on its own jitted entry;
    # the remainder of the merged wall-clock is logic passes + decode.
    vals_f32 = tuple({k: jnp.asarray(v, jnp.float32) for k, v in v_.items()}
                     for v_ in values)
    gen_fn = lambda: executor.generate_bank_streams(bank, vals_f32, keys, bl)
    gen_ms = _time(gen_fn, iters)
    cfg = arch.StochIMCConfig(bitstream_length=bl)
    table3 = {app: _arch_record(
        compile_bank_plan(apps.cost_stage_netlists(app)), cfg)
        for app in apps.APPS}

    results = {
        "bitstream_length": bl,
        "n_members": len(nets),
        "members": names,
        "key_mode": executor.DEFAULT_KEY_MODE,
        "looped_ms": round(looped_ms, 3),
        "merged_ms": round(merged_ms, 3),
        "gen_ms": round(gen_ms, 3),
        "pass_ms": round(max(merged_ms - gen_ms, 0.0), 3),
        "phases": {
            "gen_ms": round(gen_ms, 3),
            "pass_ms": round(max(merged_ms - gen_ms, 0.0), 3),
            "total_ms": round(merged_ms, 3),
        },
        "speedup": round(looped_ms / merged_ms, 2),
        "merged_passes": bank.n_passes,
        "looped_passes": bank.n_passes_looped,
        "arch_bank": _arch_record(bank, cfg),
        "table3_banks": table3,
    }
    if verbose:
        print(f"\n== Bank-plan bench: merged vs looped "
              f"({len(nets)} members, BL={bl}) ==")
        print(f"  looped : {looped_ms:8.3f} ms  "
              f"({bank.n_passes_looped} passes + {len(nets)} dispatches)")
        print(f"  merged : {merged_ms:8.3f} ms  "
              f"({bank.n_passes} passes, 1 dispatch; "
              f"gen {results['gen_ms']:.3f} ms + "
              f"pass {results['pass_ms']:.3f} ms)")
        print(f"  speedup: {results['speedup']:.1f}X  (target: >= 3X)")
        print("  [n, m] bank model — Table-3 apps as full cost-stage banks:")
        for app, r in table3.items():
            print(f"    {app.upper():4s} {r['n_members']:4d} members  "
                  f"passes {r['looped_passes']:5d} -> {r['merged_passes']:4d}  "
                  f"cycles {r['looped_cycles']:6d} -> {r['merged_cycles']:5d}  "
                  f"({r['simd_speedup']:.1f}X)")
    return results


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny BL/iters: CI-sized sanity pass")
    parser.add_argument("--out", default=None,
                        help="output path (default BENCH_bank_plan.json; "
                             "smoke writes BENCH_bank_plan_smoke.json)")
    args = parser.parse_args()
    out = args.out or ("BENCH_bank_plan_smoke.json" if args.smoke
                       else "BENCH_bank_plan.json")
    res = run(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")
