"""Multi-bank async serving vs the single-bank sync server (BENCH_serve_multibank.json).

Replays a 64-request bursty LIT + KDE application trace (the
examples/serve_sc.py traffic shape: burst composition shifts and revisits)
through two server configurations:

  * **single_bank** — the PR-4 serving model, expressed as
    ``BankServer(devices=[d0], max_inflight=0)`` driven burst-by-burst with
    ``serve()``: every burst forms one padded bank, dispatches to the one
    device, and blocks on its results before the next burst is admitted.
  * **multibank_async** — the full engine: requests stream in across burst
    boundaries (``submit`` only), so admission overlaps in-flight execution
    (JAX async dispatch, ``max_inflight`` batches per device), batches fill
    to ``max_slots`` across bursts (continuous batching widens each bank and
    eliminates padding for this trace), and staged banks shard round-robin
    over every available device.

Run standalone, the bench forces 4 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) so the sharded path
is exercised on CPU; imported in-process (benchmarks.run) it uses however
many devices the host already has and the runner skips it when only one
exists.

Acceptance (ISSUE 6): multibank_async sustains >= 2X the steady-state
throughput of single_bank on the 64-request trace, and a spot check of
served results is bit-identical to standalone ``executor.execute_value``
with the same per-request key (full per-request identity is pinned by
tests/test_serve_multibank.py).

Output schema:
  {"bitstream_length", "n_requests", "n_bursts", "n_devices",
   "max_slots_async", "max_slots_single", "bit_identical",
   "single_bank_s", "multibank_s", "single_bank_rps", "multibank_rps",
   "speedup_vs_single_bank", "single_bank": {...stats...},
   "multibank": {...stats...}}
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4").strip()

import time

import jax
import numpy as np

from repro.core import executor
from repro.core.apps import KDE_N
from repro.serve import BankServer, app_request

from .common import request_phases

# Four bursts of (n_lit, n_kde) sum to 8 LIT + 8 KDE: each 16-request
# admission window packs one power-of-two bank with zero padding, so the
# async server's continuous batching gets full credit for widening banks.
BURST_PATTERN = [(3, 1), (1, 3), (2, 2), (2, 2)]


def build_trace(n_requests: int, bl: int, seed: int = 0):
    """``[(burst of SCRequest, ...), ...]`` plus flat (net, values, key) refs."""
    rng = np.random.default_rng(seed)
    keys = jax.random.split(jax.random.key(seed), n_requests)
    bursts, refs = [], []
    ki = 0
    bi = 0
    while ki < n_requests:
        n_lit, n_kde = BURST_PATTERN[bi % len(BURST_PATTERN)]
        burst = []
        for is_kde in [False] * n_lit + [True] * n_kde:
            if ki >= n_requests:
                break
            if is_kde:
                x_t = float(rng.uniform(0.2, 0.8))
                hist = rng.uniform(0.2, 0.8, size=(KDE_N,))
                req = app_request("kde", keys[ki], bl, x_t=x_t, hist=hist)
            else:
                a = rng.uniform(0.1, 0.9, size=(81,))
                req = app_request("lit", keys[ki], bl, a=a)
            burst.append(req)
            refs.append(req)
            ki += 1
        bursts.append(burst)
        bi += 1
    return bursts, refs


def _replay_single(server: BankServer, bursts) -> float:
    """PR-4 drive: serve (and block on) each burst before the next arrives."""
    t0 = time.perf_counter()
    for burst in bursts:
        server.serve(burst)
    return time.perf_counter() - t0


def _replay_async(server: BankServer, bursts) -> tuple:
    """Stream every burst through submit(); wait only at the very end."""
    t0 = time.perf_counter()
    tickets = [server.submit(r) for burst in bursts for r in burst]
    server.flush()
    outs = [t.result() for t in tickets]
    return time.perf_counter() - t0, outs


def _spot_check(outs, refs, n: int = 8) -> bool:
    """Served results vs standalone execute_value for ``n`` spread requests."""
    import jax.numpy as jnp
    idxs = np.linspace(0, len(refs) - 1, n).astype(int)
    for i in idxs:
        r = refs[i]
        ref = executor.execute_value(r.net, r.values, r.key,
                                     r.bitstream_length)
        got = outs[i]
        if not all(bool(jnp.array_equal(got[k], ref[k])) for k in ref):
            return False
    return True


def run(verbose: bool = True, smoke: bool = False) -> dict:
    # Full size uses a long bitstream so per-batch execution dominates the
    # (linear, width-independent) host-side argument processing: that is the
    # regime the bank-level batching targets.  Smoke stays tiny for CI —
    # host overheads then dominate both servers and the smoke speedup ratio
    # sits far below the committed one (check_regression tolerance covers
    # the gap).
    bl = 128 if smoke else 2048
    n_requests = 24 if smoke else 64
    devices = jax.devices()
    bursts, refs = build_trace(n_requests, bl)
    reps = 1 if smoke else 5

    # Single-bank sync baseline: one device, block per batch, per-burst
    # admission (PR-4 defaults: max_slots=8, padded templates).
    single = BankServer(max_slots=8, devices=[devices[0]], max_inflight=0)
    _replay_single(single, bursts)              # warmup: compile + trace
    single_s, single_stats = float("inf"), None
    for _ in range(reps):
        single.reset_stats()
        s = _replay_single(single, bursts)
        if s < single_s:
            single_s, single_stats = s, single.stats()

    # Multi-bank async server: all devices, overlapped admission, wide banks.
    # Affinity placement keeps repeat layouts on jit-warm devices and spills
    # to a cold one only when the warm set is saturated — placement is then
    # deterministic across reps, so one warmup replay warms every device the
    # steady state touches (round_robin would rotate onto cold devices).
    multi = BankServer(max_slots=16, devices=devices, max_inflight=4,
                       placement="affinity")
    _, outs = _replay_async(multi, bursts)      # warmup
    bit_identical = _spot_check(outs, refs)
    multi_s, multi_stats = float("inf"), None
    for _ in range(reps):
        multi.reset_stats()
        s, outs = _replay_async(multi, bursts)
        if s < multi_s:
            multi_s, multi_stats = s, multi.stats()

    # One extra traced replay (untimed) for the per-request phase breakdown
    # (queued/staged/inflight histograms).  Timed replays stay untraced.
    from repro.core import obs
    multi.trace = obs.Trace("serve-multibank-bench")
    _replay_async(multi, bursts)
    phases = request_phases(multi.stats())
    multi.trace = None

    results = {
        "bitstream_length": bl,
        "n_requests": n_requests,
        "n_bursts": len(bursts),
        "n_devices": len(devices),
        "max_slots_async": multi.max_slots,
        "max_slots_single": single.max_slots,
        "bit_identical": bool(bit_identical),
        "single_bank_s": round(single_s, 4),
        "multibank_s": round(multi_s, 4),
        "single_bank_rps": round(n_requests / single_s, 2),
        "multibank_rps": round(n_requests / multi_s, 2),
        "speedup_vs_single_bank": round(single_s / multi_s, 2),
        "single_bank": {k: round(v, 4) if isinstance(v, float) else v
                        for k, v in single_stats.items()
                        if not isinstance(v, list)},
        "multibank": {k: round(v, 4) if isinstance(v, float) else v
                      for k, v in multi_stats.items()
                      if not isinstance(v, list)},
        "multibank_devices": multi_stats["devices"],
        "phases": phases,
    }
    if verbose:
        print(f"\n== Multi-bank serve bench: {n_requests} requests, "
              f"{len(bursts)} bursts, {len(devices)} device(s), BL={bl} ==")
        print(f"  single-bank sync : {single_s:8.3f} s  "
              f"({results['single_bank_rps']:8.1f} req/s, "
              f"{single_stats['n_batches']} batches, "
              f"padding waste {single_stats['padding_waste']:.0%})")
        print(f"  multi-bank async : {multi_s:8.3f} s  "
              f"({results['multibank_rps']:8.1f} req/s, "
              f"{multi_stats['n_batches']} batches, "
              f"padding waste {multi_stats['padding_waste']:.0%}, "
              f"bit-identical: {bit_identical})")
        print(f"  speedup vs single-bank server: "
              f"{results['speedup_vs_single_bank']:.1f}X  (target: >= 2X)")
    return results


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny BL/trace: CI-sized sanity pass")
    parser.add_argument("--out", default=None,
                        help="output path (default BENCH_serve_multibank.json;"
                             " smoke writes BENCH_serve_multibank_smoke.json)")
    args = parser.parse_args()
    out = args.out or ("BENCH_serve_multibank_smoke.json" if args.smoke
                       else "BENCH_serve_multibank.json")
    res = run(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")
