"""Table 4 — average output error (%) under injected bitflips, binary-IMC
(8-bit) vs Stoch-IMC (256-bit), across the four applications.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import apps

from .common import fmt_table

RATES = (0.0, 0.05, 0.10, 0.15, 0.20)
BL = 256

PAPER_STOCH_20 = {"lit": 6.4, "ol": 0.18, "hdp": 0.13, "kde": 1.53}


def _cases(rng, smoke=False):
    n = 2 if smoke else 1       # smoke: halve batch sizes, keep BL/rates
    lit_a = rng.random((48 // n, 81))
    ol_p = rng.random((128 // n, 6)) * 0.5 + 0.5
    # HDP keeps its full batch: its divider error sits closest to the 10%
    # validation bound and needs the sample size to stay below it.
    hdp_v = {k: rng.random(64) * 0.8 + 0.1 for k in apps.HDP_KEYS}
    kde_x = rng.random(16 // n)
    kde_h = rng.random((16 // n, apps.KDE_N))
    return lit_a, ol_p, hdp_v, kde_x, kde_h


def run(verbose=True, smoke=False) -> dict:
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    lit_a, ol_p, hdp_v, kde_x, kde_h = _cases(rng, smoke)
    exact = {
        "lit": apps.lit_exact(lit_a),
        "ol": apps.ol_exact(ol_p),
        "hdp": apps.hdp_exact(hdp_v),
        "kde": apps.kde_exact(kde_x, kde_h),
    }

    def stoch(app, rate):
        if app == "lit":
            return np.asarray(apps.lit_stochastic(key, lit_a, BL, rate))
        if app == "ol":
            return np.asarray(apps.ol_stochastic(key, ol_p, BL, rate))
        if app == "hdp":
            return np.asarray(apps.hdp_stochastic(key, hdp_v, BL, rate))
        return np.asarray(apps.kde_stochastic(key, kde_x, kde_h, BL, rate))

    def binary(app, rate):
        r = np.random.default_rng(1)
        if app == "lit":
            return apps.lit_binary8(r, lit_a, rate)
        if app == "ol":
            return apps.ol_binary8(r, ol_p, rate)
        if app == "hdp":
            return apps.hdp_binary8(r, hdp_v, rate)
        return apps.kde_binary8(r, kde_x, kde_h, rate)

    results = {}
    rows = []
    for app in apps.APPS:
        b_err = [float(np.abs(binary(app, r) - exact[app]).mean()) * 100
                 for r in RATES]
        s_err = [float(np.abs(stoch(app, r) - exact[app]).mean()) * 100
                 for r in RATES]
        results[app] = {"binary": b_err, "stoch": s_err,
                        "paper_stoch_20": PAPER_STOCH_20[app]}
        rows.append([app.upper()] + [f"{e:.1f}" for e in b_err]
                    + [f"{e:.2f}" for e in s_err])
    if verbose:
        hdr = (["App"] + [f"bin@{int(r*100)}%" for r in RATES]
               + [f"sc@{int(r*100)}%" for r in RATES])
        print(fmt_table(hdr, rows,
                        title="\n== Table 4: avg output error (%) vs injected "
                              "bitflip rate =="))
        worst = max(results[a]["stoch"][-1] for a in apps.APPS)
        print(f"\n  Stoch-IMC worst error @20% flips: {worst:.2f}% "
              f"(paper: <6.5% across apps)")
    return results


if __name__ == "__main__":
    run()
