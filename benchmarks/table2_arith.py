"""Table 2 — arithmetic operations: Stoch-IMC vs [22] vs binary IMC.

Columns (normalized to the in-memory binary implementation, as in the
paper): minimum array size, area (used cells), computation time steps,
energy.  The paper's binary baselines are its printed array layouts
(1x88 serial adder, 16x161 multiplier, ...); ours are the closest
constructions in circuits.py — absolute shapes are printed for comparison.
"""
from __future__ import annotations

import jax

from repro.core import circuits, executor
from repro.core.scheduler import schedule

from .common import (CFG, binary_cost, cram_cost, fmt_table,
                     stoch_cost)

OPS = [
    ("Scaled Addition", circuits.sc_scaled_add,
     lambda: circuits.binary_adder_nand_serial(8)),
    ("Multiplication", circuits.sc_multiply,
     lambda: circuits.binary_multiplier(8)),
    ("Abs Subtraction", circuits.sc_abs_sub,
     lambda: circuits.binary_subtractor_serial(8)),   # paper's 1x90 layout
    ("Scaled Division", circuits.sc_scaled_div,
     lambda: circuits.binary_divider(8)),
    ("Square Root", circuits.sc_sqrt, lambda: circuits.binary_sqrt(8)),
    ("Exponential", circuits.sc_exp, lambda: circuits.binary_exp(8)),
]

# Paper Table 2 time-step ratios (Stoch-IMC / binary), for the comparison row.
PAPER_TIME_RATIO = {
    "Scaled Addition": 0.056, "Multiplication": 0.012,
    "Abs Subtraction": 0.088, "Scaled Division": 0.008,
    "Square Root": 0.002, "Exponential": 0.019,
}

# Executed-value check: (inputs, exact closed form) per op — the netlist is
# *run* through the compiled execution plan and its decoded output compared
# against the op's math (sqrt's reconstructed circuit computes 1-(1-cx)^2).
EXEC_CHECK = {
    "Scaled Addition": ({"a": 0.3, "b": 0.7}, lambda a, b: (a + b) / 2),
    "Multiplication": ({"a": 0.6, "b": 0.5}, lambda a, b: a * b),
    "Abs Subtraction": ({"a": 0.8, "b": 0.3}, lambda a, b: abs(a - b)),
    "Scaled Division": ({"a": 0.3, "b": 0.5}, lambda a, b: a / (a + b)),
    "Square Root": ({"a": 0.5},
                    lambda a: 1.0 - (1.0 - circuits.SQRT_C * a) ** 2),
    "Exponential": ({"a": 0.5}, lambda a: 2.718281828 ** (-a)),
}

EXEC_BL = 4096


def _exec_value_err(name: str, net) -> float:
    """|decoded - exact| of the op netlist executed via the compiled plan."""
    inputs, exact = EXEC_CHECK[name]
    out = executor.execute_value(net, inputs, jax.random.key(42), EXEC_BL)
    return abs(float(next(iter(out.values()))) - exact(**inputs))


def run(verbose=True) -> dict:
    rows = []
    results = {}
    for name, sc_builder, bin_builder in OPS:
        sc_net, bin_net = sc_builder(), bin_builder()
        s = stoch_cost(sc_net)
        c = cram_cost(sc_net)
        b = binary_cost(bin_net)
        sc_sch = schedule(sc_net, n_lanes=CFG.bitstream_length)
        bin_sch = schedule(bin_net, r_available=1 << 16, c_available=1 << 16)
        # Table 2's printed ratios track pure logic cycles (4/72 = 0.056 for
        # scaled addition); init/preset are charged at the application level.
        t_ratio = s.logic_cycles / b.logic_cycles
        t_ratio_cram = c.logic_cycles / b.logic_cycles
        area_ratio = s.cells_used / b.cells_used
        e_ratio = s.total_energy_j / b.total_energy_j
        exec_err = _exec_value_err(name, sc_net)
        results[name] = {
            "array_bin": f"{bin_sch.n_rows}x{bin_sch.n_cols}",
            "array_stoch": f"{sc_sch.n_rows}x{sc_sch.n_cols}",
            "area_ratio": area_ratio, "time_ratio": t_ratio,
            "time_ratio_cram": t_ratio_cram, "energy_ratio": e_ratio,
            "paper_time_ratio": PAPER_TIME_RATIO[name],
            "exec_value_err": exec_err,
        }
        rows.append([name, f"{bin_sch.n_rows}x{bin_sch.n_cols}",
                     f"{sc_sch.n_rows}x{sc_sch.n_cols}",
                     f"{area_ratio:.3f}X", f"{t_ratio_cram:.2f}X",
                     f"{t_ratio:.4f}X", f"{PAPER_TIME_RATIO[name]:.3f}X",
                     f"{e_ratio:.3f}X", f"{exec_err:.4f}"])
    if verbose:
        print(fmt_table(
            ["Operation", "BinArray", "StochArray", "Area(norm)",
             "T [22](norm)", "T this(norm)", "T paper", "Energy(norm)",
             "ExecErr"],
            rows, title="\n== Table 2: arithmetic operations "
                        "(normalized to binary IMC; ExecErr = compiled-plan "
                        f"executed value vs exact @ BL={EXEC_BL}) =="))
    return results


if __name__ == "__main__":
    run()
