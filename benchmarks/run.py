"""Benchmark driver: one module per paper table/figure + kernel benches.

  PYTHONPATH=src python -m benchmarks.run            # full paper-claim run
  PYTHONPATH=src python -m benchmarks.run --smoke    # tiny sizes (CI job)

Emits BENCH_plan_exec.json (interpreter-vs-compiled netlist execution
timings) and BENCH_bank_plan.json (merged bank-plan vs looped per-netlist
execution) so the perf trajectory is tracked PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


_EPILOG = """\
paper-claim checks (always run; no flag disables them):
  after the benches finish, a PAPER-CLAIM VALIDATION SUMMARY table prints
  one [PASS]/[FAIL] line per tracked claim — perf/energy/lifetime vs the
  binary-IMC and in-memory-SC baselines, bitflip accuracy bounds, and (full
  runs only) the compiled-exec / bank-plan / SNG / serve / chaos / streaming
  speedup targets.  Any FAIL makes the process exit 1; the thresholds live
  in this file and documented deviations are marked [DEV*] with their
  rationale printed under the summary table.

outputs:
  full runs write the tracked BENCH_*.json records (plan_exec, sng,
  bank_plan, serve, serve_multibank, faults, megakernel); every record
  carries a "phases" block attributing time to stream-generation vs logic
  passes (or queued/staged/inflight for the serving benches).  --smoke
  writes BENCH_*_smoke.json variants instead so indicative timings never
  clobber the tracked records, and skips the bank/serve/fault/megakernel
  benches that CI runs as standalone steps.  Compare smoke vs committed
  with `python -m benchmarks.check_regression` (soft-fail perf diff).

multi-device benches:
  serve_multibank and the chaos half of the fault campaign need >= 2 jax
  devices; run with XLA_FLAGS=--xla_force_host_platform_device_count=4 (or
  run those benches standalone, which force it) to exercise them on CPU.
"""


def main(argv=None):
    parser = argparse.ArgumentParser(
        description=__doc__, epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny BL/sizes: fast paper-claim sanity pass")
    parser.add_argument("--bench-out", default=None,
                        help="where to write the plan-exec benchmark record "
                             "(default: BENCH_plan_exec.json; smoke runs "
                             "write BENCH_plan_exec_smoke.json so indicative "
                             "timings never clobber the tracked record)")
    args = parser.parse_args(argv)
    if args.bench_out is None:
        args.bench_out = ("BENCH_plan_exec_smoke.json" if args.smoke
                          else "BENCH_plan_exec.json")

    t0 = time.time()
    from . import (bank_plan_bench, fault_campaign, fig10_energy,
                   fig11_lifetime, megakernel_bench, plan_exec_bench,
                   sc_matmul_bench, serve_bench, serve_multibank_bench,
                   sng_bench, table2_arith, table3_apps, table4_bitflip)

    print("=" * 72)
    print("Stoch-IMC reproduction benchmarks (paper: 10.1016/j.aeue.2024.155614)")
    if args.smoke:
        print("SMOKE MODE: reduced sizes — timings indicative only")
    print("=" * 72)

    t2 = table2_arith.run()
    t3 = table3_apps.run(exec_check=not args.smoke)  # opt-in: once, here only
    t4 = table4_bitflip.run(smoke=args.smoke)
    f10 = fig10_energy.run()
    f11 = fig11_lifetime.run()
    mm = sc_matmul_bench.run(smoke=args.smoke)
    pe = plan_exec_bench.run(smoke=args.smoke)
    sg = sng_bench.run(smoke=args.smoke)
    # Smoke runs skip the bank and serve benches: CI exercises them as their
    # own steps (`python -m benchmarks.bank_plan_bench --smoke` /
    # `python -m benchmarks.serve_bench --smoke`), which write the
    # BENCH_*_smoke.json records — running them here too would just repeat
    # the jit-compile + timing cost to overwrite the same files.
    bp = None if args.smoke else bank_plan_bench.run()
    sv = None if args.smoke else serve_bench.run()
    # The multi-bank record needs >1 device to mean anything; standalone runs
    # force 4 host devices (see serve_multibank_bench), but in-process jax is
    # already initialised by the benches above, so honour whatever the host
    # gave us and skip rather than report an unsharded "sharded" number.
    import jax
    mb = None
    if not args.smoke:
        if jax.device_count() >= 2:
            mb = serve_multibank_bench.run()
        else:
            print("\n[skip] multi-bank serve bench: only 1 jax device — "
                  "run `XLA_FLAGS=--xla_force_host_platform_device_count=4 "
                  "python -m benchmarks.serve_multibank_bench` or rerun "
                  "benchmarks.run with that XLA_FLAGS setting")
    # Fault campaign: smoke runs it as its own CI step
    # (`python -m benchmarks.fault_campaign --smoke`, like the serve
    # benches); the chaos half skips itself below 2 devices.
    fc = None if args.smoke else fault_campaign.run()
    # Megakernel/streaming bench: smoke runs it as its own CI step too
    # (`python -m benchmarks.megakernel_bench --smoke`).
    mk = None if args.smoke else megakernel_bench.run()

    with open(args.bench_out, "w") as f:
        json.dump(pe, f, indent=2)
    sng_out = "BENCH_sng_smoke.json" if args.smoke else "BENCH_sng.json"
    with open(sng_out, "w") as f:
        json.dump(sg, f, indent=2)
    if bp is not None:
        with open("BENCH_bank_plan.json", "w") as f:
            json.dump(bp, f, indent=2)
    if sv is not None:
        with open("BENCH_serve.json", "w") as f:
            json.dump(sv, f, indent=2)
    if mb is not None:
        with open("BENCH_serve_multibank.json", "w") as f:
            json.dump(mb, f, indent=2)
    if fc is not None:
        with open("BENCH_faults.json", "w") as f:
            json.dump(fc, f, indent=2)
    if mk is not None:
        with open("BENCH_megakernel.json", "w") as f:
            json.dump(mk, f, indent=2)
    print(f"\nwrote {args.bench_out} and {sng_out}"
          + ("" if bp is None else " and BENCH_bank_plan.json")
          + ("" if sv is None else " and BENCH_serve.json")
          + ("" if mb is None else " and BENCH_serve_multibank.json")
          + ("" if fc is None else " and BENCH_faults.json")
          + ("" if mk is None else " and BENCH_megakernel.json"))

    s = t3["summary"]
    print("\n" + "=" * 72)
    print("PAPER-CLAIM VALIDATION SUMMARY")
    print("=" * 72)
    checks = [
        ("Perf vs binary IMC [DEV*]", f"{s['perf_vs_binary']:.1f}X",
         "135.7X", s["perf_vs_binary"] > 5),
        ("Perf vs in-memory SC [22]", f"{s['perf_vs_cram']:.1f}X",
         "124.2X", s["perf_vs_cram"] > 20),
        ("Energy vs binary IMC", f"{s['energy_vs_binary']:.2f}X",
         "1.5X", 0.2 < s["energy_vs_binary"] < 10),
        ("Lifetime vs binary IMC [DEV*]", f"{f11['geomean_vs_binary']:.1f}X",
         "4.9X", f11["geomean_vs_binary"] > 0.05),
        ("Lifetime vs [22]", f"{f11['geomean_vs_cram']:.1f}X",
         "216.3X", f11["geomean_vs_cram"] > 50),
        # Smoke halves the Table-4 sample sizes, so the bound widens with
        # the extra sampling noise (HDP sits at ~10% even at full size).
        ("Bitflip: SC worst err @20%",
         f"{max(t4[a]['stoch'][-1] for a in t4):.2f}%", "<6.5%",
         max(t4[a]["stoch"][-1] for a in t4) < (12.0 if args.smoke else 10.0)),
        ("Exec: compiled == paper math (Table 2)",
         f"{max(t2[o]['exec_value_err'] for o in t2):.4f}", "small",
         max(t2[o]["exec_value_err"] for o in t2) < 0.05),
    ]
    if not args.smoke:
        checks.append(
            ("Plan-exec speedup vs interpreter",
             f"{pe['geomean_speedup_table2']:.1f}X", ">=5X (target)",
             pe["geomean_speedup_table2"] >= 5.0))
        checks.append(
            ("Bank-plan speedup vs looped execute",
             f"{bp['speedup']:.1f}X", ">=3X (target)",
             bp["speedup"] >= 3.0))
        checks.append(
            ("Batched SNG speedup vs per-PI loop",
             f"{sg['speedup']:.1f}X", ">=3X (target)",
             sg["speedup"] >= 3.0))
        checks.append(
            ("Serve engine vs cold-recompile many",
             f"{sv['speedup_vs_cold']:.1f}X", ">=2X (target)",
             sv["speedup_vs_cold"] >= 2.0
             and sv["server"]["bucket_hit_rate"] >= 0.9))
        if mb is not None:
            checks.append(
                ("Multi-bank async vs single-bank server",
                 f"{mb['speedup_vs_single_bank']:.1f}X", ">=2X (target)",
                 mb["speedup_vs_single_bank"] >= 2.0
                 and mb["bit_identical"]))
        if fc is not None:
            worst_tr = max(fc["accuracy"][a]["transient"][-1]
                           for a in fc["apps"])
            checks.append(
                ("Fault sweep: transient worst err @20%",
                 f"{worst_tr:.2f}%", "<10%", worst_tr < 10.0))
            if fc["chaos"] is not None:
                ch = fc["chaos"]
                checks.append(
                    ("Chaos serve: lost tickets",
                     f"{ch['lost_tickets'] + ch['failed_tickets']}", "0",
                     ch["lost_tickets"] == 0 and ch["failed_tickets"] == 0
                     and ch["bit_identical"]))
        wc = mk["wallclock"]
        kde_peak = mk["banks"]["kde"]["peak_live_words"]["16384"]["reduction"]
        checks.append(
            ("Streamed peak-live words (KDE bank)",
             f"{kde_peak:.1f}X", ">=4X (target)", kde_peak >= 4.0))
        checks.append(
            ("Chunked-stream vs one-shot exec",
             f"{wc['chunked_speedup']:.1f}X", ">=1.3X (target)",
             wc["chunked_speedup"] >= 1.3 and wc["bit_identical"]))
    ok = True
    for name, got, paper, passed in checks:
        mark = "PASS" if passed else "FAIL"
        ok &= passed
        print(f"  [{mark}] {name:38s} ours: {got:>9s}   paper: {paper}")
    print("\n  [DEV*] documented deviations (EXPERIMENTS.md #paper-validation):")
    print("    perf-vs-binary: every app is individually faster than binary and")
    print("    the op-level Table 2 ratios reproduce tightly (0.0556X vs paper's")
    print("    0.056X for scaled addition), but the paper's 135.7X app geomean")
    print("    rests on per-application mapping/batching choices shown only in")
    print("    unavailable figures; our text-faithful Algorithm-1 mapping gives")
    print("    9.8X.  Both numbers use identical accounting for all 3 methods.")
    print("    our scheduler never reuses output cells, equalizing write density")
    print("    across methods; the paper's binary baseline concentrates writes")
    print("    via cell reuse in bounded arrays (figure-level detail), which is")
    print("    what its 4.9X binary-lifetime edge rests on.  The [22] lifetime")
    print("    claim (216.3X) — the paper's headline — reproduces at 256X.")
    print(f"\nTotal benchmark time: {time.time() - t0:.1f}s")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
