"""Bit-identity of every Pallas logic kernel against the jnp gate algebra.

Parametrized over every op ``packed_logic`` implements (including the fused
4-gate MUX), every per-input complement mask (the in-kernel ``neg`` folding
of absorbed lone NOTs), and odd non-tile-aligned shapes — the kernels must
agree with ``core.bitstream``'s packed boolean algebra on every word, in
interpret mode (CI) and compiled alike.  Also pins the whole-plan megakernel
unit behavior: engagement, scratch reuse, and its documented fallbacks.
"""
import itertools

import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream as bs
from repro.core import circuits
from repro.core.plan import compile_plan
from repro.core.streams import _gen_pi_streams
from repro.kernels.netlist_exec import run_combinational
from repro.kernels.packed_logic import packed_logic
from repro.kernels.plan_megakernel import combinational_megakernel

pytestmark = pytest.mark.pallas

#: op name -> (arity, jnp reference over packed words)
_OPS = {
    "not": (1, bs.not_),
    "and": (2, bs.and_),
    "nand": (2, bs.nand),
    "or": (2, bs.or_),
    "nor": (2, bs.nor),
    "xor": (2, bs.xor),
    "mux": (3, lambda a, b, s: bs.mux(a, b, s)),
}

_SHAPES = [(8, 128), (5, 7), (17, 129), (1, 1), (3, 300)]


def _words(i, shape):
    return jax.random.bits(jax.random.key(i), shape, dtype=jnp.uint32)


@pytest.mark.parametrize("op", sorted(_OPS))
@pytest.mark.parametrize("shape", _SHAPES, ids=str)
def test_packed_logic_all_ops_all_shapes(op, shape):
    n_in, ref = _OPS[op]
    args = [_words(i, shape) for i in range(n_in)]
    got = packed_logic(op, *args, interpret=True)
    assert (got == ref(*args)).all(), (op, shape)


@pytest.mark.parametrize("op", sorted(_OPS))
def test_packed_logic_neg_masks_fold_in_kernel(op):
    # Every complement mask equals pre-complementing outside the kernel.
    n_in, ref = _OPS[op]
    args = [_words(10 + i, (5, 70)) for i in range(n_in)]
    for neg in itertools.product((False, True), repeat=n_in):
        got = packed_logic(op, *args, neg=neg, interpret=True)
        want = ref(*[~x if nb else x for x, nb in zip(args, neg)])
        assert (got == want).all(), (op, neg)


def test_packed_logic_validates_arity_and_neg():
    a, b = _words(0, (4, 4)), _words(1, (4, 4))
    with pytest.raises(ValueError):
        packed_logic("and", a, interpret=True)
    with pytest.raises(ValueError):
        packed_logic("and", a, b, neg=(True,), interpret=True)
    with pytest.raises(ValueError):
        packed_logic("frob", a, b, interpret=True)


# ------------------------------ whole-plan megakernel ------------------------------

def _plan_env(net, vals, bl=1024, shape=None):
    plan = compile_plan(net)
    streams = _gen_pi_streams(
        plan.pis, {k: jnp.float32(v) for k, v in vals.items()},
        jax.random.key(5), bl, batch_shape=shape)
    return plan, streams


@pytest.mark.parametrize("builder,vals", [
    (circuits.sc_multiply, {"a": 0.3, "b": 0.7}),
    (circuits.sc_scaled_add, {"a": 0.2, "b": 0.9}),
    (circuits.sc_abs_sub, {"a": 0.4, "b": 0.1}),
    (circuits.sc_sqrt, {"a": 0.5}),
    (circuits.sc_exp, {"a": 0.5}),
], ids=lambda x: getattr(x, "__name__", ""))
def test_megakernel_engages_and_matches_per_pass(builder, vals):
    plan, streams = _plan_env(builder(), vals, shape=(3,))
    ref_env = dict(streams)
    run_combinational(plan, ref_env)
    got = combinational_megakernel(plan, dict(streams), interpret=True)
    assert got is not None, "megakernel unexpectedly fell back"
    for o in plan.outputs:
        assert (got[o] == ref_env[o]).all(), o


def test_megakernel_scratch_pool_smaller_than_node_count():
    # sc_exp reuses slots: the VMEM pool is sized by liveness, not node count.
    plan = compile_plan(circuits.sc_exp())
    assert 0 < plan.max_live < plan.naive_live


def test_megakernel_falls_back_on_heterogeneous_pi_shapes():
    plan, streams = _plan_env(circuits.sc_multiply(), {"a": 0.3, "b": 0.7})
    streams = dict(streams)
    k = next(iter(streams))
    streams[k] = jnp.broadcast_to(streams[k], (2,) + streams[k].shape)
    assert combinational_megakernel(plan, streams, interpret=True) is None


def test_megakernel_rejects_fault_injection():
    net = circuits.sc_multiply()
    plan = compile_plan(net, fuse_mux=False)
    streams = _gen_pi_streams(
        plan.pis, {"a": jnp.float32(0.3), "b": jnp.float32(0.7)},
        jax.random.key(5), 1024)
    with pytest.raises(ValueError, match="megakernel"):
        run_combinational(plan, dict(streams),
                          gate_fkeys=jax.random.split(jax.random.key(0),
                                                      plan.n_gates),
                          bitflip_rate=0.1, megakernel=True)
