"""Distribution-stack tests on a small host-device mesh: sharding rules,
train step, optimizer, compression, data pipeline, checkpointing.

Runs on 1 CPU device (mesh (1,1)) — the semantics, pytree plumbing and
resume behaviour are device-count independent; the 256/512-way versions are
exercised by launch/dryrun.py.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.checkpoint import latest_step, restore, save
from repro.configs import reduced_config
from repro.data import SyntheticLM
from repro.models import RunCtx, init_params, model_params
from repro.optim import adamw_init, adamw_update, compress_decompress
from repro.sharding import make_rules, param_pspec_tree, validate_divisibility
from repro.train import make_train_step, train_state_init

pytestmark = pytest.mark.slow  # full distribution stack: excluded from CI default


def small_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


# ------------------------------- sharding rules -----------------------------------

def test_param_pspec_tree_covers_every_leaf():
    cfg = reduced_config("qwen3-8b")
    mesh = small_mesh()
    sr = make_rules(mesh)
    skel = model_params(cfg)
    specs = param_pspec_tree(skel, sr)
    n_skel = len(jax.tree.leaves(skel, is_leaf=lambda x: hasattr(x, "axes")))
    n_spec = len(jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, PS)))
    assert n_skel == n_spec > 10


def test_divisibility_fallback_reports_and_replicates():
    """whisper: 20 heads / 51866 vocab don't divide a 16-way model axis."""
    import repro.configs.whisper_large_v3 as w
    cfg = w.config()
    devs = jax.devices() * 256          # fake a 16x16 shape check (sizes only)
    mesh = small_mesh()                 # actual spec math uses axis sizes

    # Build a fake 16x16 mesh object via axis-size monkeypatching: rules only
    # read mesh.shape, so use a simple namespace.
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    from repro.sharding.rules import ShardingRules
    sr = ShardingRules(mesh=FakeMesh(), rules=make_rules(mesh).rules,
                       batch=("data",))
    skel = model_params(cfg)
    notes = validate_divisibility(skel, sr)
    assert any("heads=20" in n for n in notes)
    assert any("vocab=51866" in n for n in notes)


# ------------------------------- optimizer ----------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([2.0, -3.0, 1.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}          # d/dw |w|^2
        params, state = adamw_update(grads, state, params, lr=5e-2,
                                     weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state.step) == 300


def test_adamw_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = adamw_update(huge, state, params, lr=1.0, clip_norm=1.0,
                         weight_decay=0.0)
    assert float(jnp.abs(p2["w"]).max()) < 10.0


# --------------------------- gradient compression ----------------------------------

def test_compression_unbiased_and_error_feedback_telescopes():
    key = jax.random.key(0)
    g = {"a": jax.random.normal(jax.random.key(1), (512,))}
    # Unbiasedness: mean over many independent quantizations ~ g.
    reps = []
    for i in range(30):
        dq, _ = compress_decompress(g, jax.random.key(i), bits=4)
        reps.append(dq["a"])
    mean = jnp.stack(reps).mean(0)
    assert float(jnp.abs(mean - g["a"]).mean()) < 0.05
    # Error feedback: quantized + residual == pre-quantization signal.
    dq, err = compress_decompress(g, key, bits=4)
    recon = dq["a"] + err["a"]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g["a"]), atol=1e-5)


def test_compression_with_feedback_tracks_sum_over_steps():
    # With error feedback, sum of dequantized grads ~ sum of true grads
    # (telescoping: sum dq_t = sum g + e_0 - e_T).  At very low bit widths
    # the residual can random-walk (amax is data-dependent), so test at 4.
    g = {"a": jnp.linspace(-1, 1, 256)}
    err = None
    total = jnp.zeros(256)
    for i in range(50):
        dq, err = compress_decompress(g, jax.random.key(i), bits=4, errors=err)
        total = total + dq["a"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g["a"]),
                               atol=0.05)


# ------------------------------- data pipeline -------------------------------------

def test_data_pipeline_deterministic_and_resumable():
    pipe = SyntheticLM(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    b1 = pipe.batch(7)
    b2 = pipe.batch(7)                          # same step -> same batch
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    b3 = pipe.batch(8)
    assert not (b1["tokens"] == b3["tokens"]).all()


def test_data_pipeline_host_slices_partition_global_batch():
    pipe = SyntheticLM(vocab_size=97, seq_len=8, global_batch=8)
    full = pipe.batch(0)["tokens"]
    parts = [pipe.host_batch(0, h, 4)["tokens"] for h in range(4)]
    assert (jnp.concatenate(parts) == full).all()


# ------------------------------- train step ---------------------------------------

@pytest.mark.parametrize("accum", [1, 2])
def test_train_step_loss_decreases(accum):
    cfg = reduced_config("qwen3-8b")
    mesh = small_mesh()
    rules = make_rules(mesh)
    ctx = RunCtx(mesh=mesh, act_spec=NamedSharding(mesh, rules.act_spec()),
                 data_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    state = train_state_init(cfg, params)
    step = jax.jit(make_train_step(cfg, ctx, accum_steps=accum, lr=5e-3))
    pipe = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4)
    losses = []
    for i in range(8):
        state, m = step(state, pipe.batch(0))   # same batch: must overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_train_step_accum_matches_full_batch_loss():
    cfg = reduced_config("rwkv6-1.6b")
    mesh = small_mesh()
    ctx = RunCtx(mesh=mesh, data_axes=("data",))
    params = init_params(cfg, jax.random.key(0))
    pipe = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4)
    batch = pipe.batch(0)
    s1 = train_state_init(cfg, params)
    s2 = train_state_init(cfg, params)
    _, m1 = jax.jit(make_train_step(cfg, ctx, accum_steps=1))(s1, batch)
    _, m2 = jax.jit(make_train_step(cfg, ctx, accum_steps=2))(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)


# ------------------------------- checkpointing -------------------------------------

def test_checkpoint_save_restore_roundtrip(tmp_path):
    cfg = reduced_config("qwen3-8b")
    params = init_params(cfg, jax.random.key(0))
    state = train_state_init(cfg, params)
    d = str(tmp_path / "ckpt")
    save(d, 10, state)
    save(d, 20, state)
    assert latest_step(d) == 20
    restored = restore(d, 20, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        if hasattr(a, "dtype") and jax.dtypes.issubdtype(a.dtype,
                                                         jax.dtypes.prng_key):
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    params = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "ckpt")
    path = save(d, 1, params)
    # Corrupt a leaf on disk.
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError):
        restore(d, 1, params)


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    params = {"w": jnp.zeros(4)}
    d = str(tmp_path / "ckpt")
    save(d, 1, params)
    entries = os.listdir(d)
    assert entries == ["step_00000001"]         # no tmp leftovers


def test_train_driver_resume(tmp_path):
    """launch/train.py resumes from the latest checkpoint (auto-resume)."""
    from repro.launch import train as train_mod
    d = str(tmp_path / "ck")
    train_mod.main(["--arch", "rwkv6-1.6b", "--smoke", "--steps", "4",
                    "--seq", "16", "--batch", "2", "--ckpt_dir", d,
                    "--ckpt_every", "2", "--log_every", "100"])
    assert latest_step(d) == 4
    # Re-invoke with more steps: must resume from 4, not restart.
    train_mod.main(["--arch", "rwkv6-1.6b", "--smoke", "--steps", "6",
                    "--seq", "16", "--batch", "2", "--ckpt_dir", d,
                    "--ckpt_every", "2", "--log_every", "100"])
    assert latest_step(d) == 6
