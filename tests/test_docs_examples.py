"""Docs stay true: fenced python blocks run, stage names match the compiler,
relative links resolve.

Every ```python fence in README.md and docs/*.md executes in a fresh
namespace (so documented snippets cannot rot), the canonical pipeline
stage line is pinned against ``repro.core.plan.DEFAULT_PIPELINE``, and
every relative markdown link must point at an existing file.
"""
import re
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def _python_blocks():
    params = []
    for path in DOC_FILES:
        for i, m in enumerate(_FENCE_RE.finditer(path.read_text())):
            # Blocks nested under list items carry the bullet's indentation.
            params.append(pytest.param(path, textwrap.dedent(m.group(1)),
                                       id=f"{path.name}-block{i}"))
    return params


def test_docs_exist():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()
    assert (ROOT / "docs" / "OBSERVABILITY.md").is_file()
    assert len(_python_blocks()) >= 4


@pytest.mark.parametrize("path,code", _python_blocks())
def test_python_block_executes(path, code):
    """Each documented snippet must be self-contained and runnable."""
    exec(compile(code, f"<{path.name}>", "exec"), {"__name__": "__docs__"})


def test_pipeline_stage_names_match_docs():
    """The stage lists printed in the docs must track the real pipeline."""
    from repro.core.plan import DEFAULT_PIPELINE
    stages = list(DEFAULT_PIPELINE.stage_names)
    canonical = " → ".join(stages)
    readme = (ROOT / "README.md").read_text()
    assert canonical in readme, (
        f"README.md pipeline line out of date; expected: {canonical}")
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    # Ordered occurrence: every stage name appears, in pipeline order.
    pos = 0
    for name in stages:
        nxt = arch.find(name, pos)
        assert nxt >= 0, (
            f"docs/ARCHITECTURE.md missing stage {name!r} after offset {pos}")
        pos = nxt + len(name)


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(path):
    text = path.read_text()
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#",
                              "chrome://")):
            continue
        target = target.split("#", 1)[0]
        assert (path.parent / target).exists(), (
            f"{path.name}: broken relative link -> {target}")
