"""Multi-bank async serving + the unified ExecRequest/ExecOptions API.

Pins for the device-sharded BankServer and the executor.run() redesign:

  * every legacy ``execute*`` entry point is a bit-identical thin shim over
    ``executor.run(ExecRequest(...))`` — pinned for all six spellings, both
    ``key_mode``s, with bitflip injection and declared batch shapes;
  * the deprecated plural-kwarg spellings (``keys=`` / ``batch_shapes=``)
    raise ``DeprecationWarning`` but still compute the same bits;
  * serving sharded across devices is bit-identical to single-device
    serving and to standalone ``execute_value`` (the ISSUE acceptance
    anchor), for every placement policy;
  * continuous batching: a request arriving while a compatible batch is
    staged-but-held joins that batch in place (no extra dispatch);
  * a failed dispatch propagates its exception to *every* ticket of the
    batch and leaves the server serviceable;
  * ``Ticket.result(timeout=...)`` bounds the wait and keeps the ticket
    retryable;
  * per-device stats account every dispatched batch/request.

Multi-device cases skip on single-device hosts; CI forces
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so they run there.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import circuits, executor
from repro.core.executor import ExecOptions, ExecRequest
from repro.core.plan import compile_plan, compile_bank_template, \
    template_members
from repro.serve import BankServer, app_request, circuit_request

KEY = jax.random.key(21)
FLIP = jax.random.key(2121)
BL = 256

MUL = circuits.sc_multiply()
SADD = circuits.sc_scaled_add()
SQRT = circuits.sc_sqrt()
EXP = circuits.sc_exp()

POOL = {
    "mul": (MUL, {"a": 0.3, "b": 0.7}),
    "sadd": (SADD, {"a": 0.2, "b": 0.9}),
    "sqrt": (SQRT, {"a": 0.5}),
    "exp": (EXP, {"a": 0.4}),
}

KEY_MODES = ["batched", "legacy"]


def tree_eq(a, b) -> bool:
    if a is None or b is None:
        return a is b
    if sorted(a) != sorted(b):
        return False
    return all(bool(jnp.array_equal(a[k], b[k])) for k in a)


# ----------------------------- run()/shim parity ----------------------------------


@pytest.mark.parametrize("key_mode", KEY_MODES)
def test_execute_shim_matches_run(key_mode):
    vals = {"a": 0.3, "b": 0.7}
    shim = executor.execute(MUL, vals, KEY, BL, key_mode=key_mode)
    new = executor.run(ExecRequest(MUL, vals, KEY, ExecOptions(
        key_mode=key_mode, bitstream_length=BL)))
    assert tree_eq(shim, new)


@pytest.mark.parametrize("key_mode", KEY_MODES)
def test_execute_value_shim_matches_run(key_mode):
    vals = {"a": 0.2, "b": 0.9}
    shim = executor.execute_value(SADD, vals, KEY, BL, key_mode=key_mode)
    new = executor.run(ExecRequest(SADD, vals, KEY, ExecOptions(
        key_mode=key_mode, bitstream_length=BL, decode=True)))
    assert tree_eq(shim, new)


def test_execute_with_bitflip_and_batch_shape_matches_run():
    vals = {"a": np.full((4,), 0.5, np.float32)}
    shim = executor.execute(SQRT, vals, KEY, BL, bitflip_rate=0.05,
                            flip_key=FLIP)
    new = executor.run(ExecRequest(SQRT, vals, KEY, ExecOptions(
        bitstream_length=BL, bitflip_rate=0.05, flip_key=FLIP)))
    assert tree_eq(shim, new)
    # All-const batch declaration flows through options.batch_shape.
    shim = executor.execute(MUL, {"a": 0.3, "b": 0.7}, KEY, BL,
                            batch_shape=(3,))
    new = executor.run(ExecRequest(MUL, {"a": 0.3, "b": 0.7}, KEY,
                                   ExecOptions(bitstream_length=BL,
                                               batch_shape=(3,))))
    assert tree_eq(shim, new)


def test_execute_binary_shim_matches_run():
    bits = {"A": jnp.asarray([0x0F0F0F0F], jnp.uint32),
            "B": jnp.asarray([0x00FF00FF], jnp.uint32)}
    shim = executor.execute_binary(MUL, bits)
    new = executor.run(ExecRequest(MUL, dict(bits),
                                   options=ExecOptions(binary=True)))
    assert tree_eq(shim, new)


@pytest.mark.parametrize("key_mode", KEY_MODES)
def test_execute_many_shims_match_run(key_mode):
    names = ["mul", "sadd", "sqrt"]
    nets = [POOL[n][0] for n in names]
    values = [dict(POOL[n][1]) for n in names]
    keys = jax.random.split(KEY, len(nets))
    shared = ExecOptions(key_mode=key_mode, bitstream_length=BL)
    reqs = [ExecRequest(nets[i], values[i], keys[i], shared)
            for i in range(len(nets))]
    legacy = executor.execute_many(nets, values, keys, BL, key_mode=key_mode)
    assert all(tree_eq(a, b) for a, b in zip(legacy, executor.run(reqs)))
    legacy = executor.execute_value_many(nets, values, keys, BL,
                                         key_mode=key_mode)
    reqs = [ExecRequest(nets[i], values[i], keys[i],
                        ExecOptions(key_mode=key_mode, bitstream_length=BL,
                                    decode=True))
            for i in range(len(nets))]
    assert all(tree_eq(a, b) for a, b in zip(legacy, executor.run(reqs)))


def test_plural_kwargs_deprecated_but_identical():
    nets = [MUL, SADD]
    values = [dict(POOL["mul"][1]), dict(POOL["sadd"][1])]
    keys = jax.random.split(KEY, 2)
    want = executor.execute_many(nets, values, keys, BL)
    with pytest.warns(DeprecationWarning, match="keys=.*deprecated"):
        got = executor.execute_many(nets, values, keys=keys,
                                    bitstream_length=BL)
    assert all(tree_eq(a, b) for a, b in zip(want, got))
    with pytest.warns(DeprecationWarning, match="batch_shapes=.*deprecated"):
        got = executor.execute_value_many(nets, values, keys, BL,
                                          batch_shapes=[None, None])
    want = executor.execute_value_many(nets, values, keys, BL)
    assert all(tree_eq(a, b) for a, b in zip(want, got))


@pytest.mark.parametrize("key_mode", KEY_MODES)
def test_run_template_matches_execute_bank_and_standalone(key_mode):
    names = ["mul", "sadd", "mul"]
    plans = [compile_plan(POOL[n][0]) for n in names]
    bank = compile_bank_template(plans)
    members = template_members(plans)
    keys = jax.random.split(KEY, len(names))
    # Bind each request to the first free slot holding its plan.
    slot_reqs = [None] * bank.n_members
    taken = set()
    for i, n in enumerate(names):
        s = next(j for j, p in enumerate(members)
                 if p is plans[i] and j not in taken)
        taken.add(s)
        slot_reqs[s] = ExecRequest(POOL[n][0], dict(POOL[n][1]), keys[i],
                                   ExecOptions(key_mode=key_mode,
                                               bitstream_length=BL,
                                               decode=True))
    outs = executor.run(slot_reqs, template=bank)
    for s, req in enumerate(slot_reqs):
        if req is None:
            assert outs[s] is None
            continue
        ref = executor.execute_value(req.net, req.values, req.key, BL,
                                     key_mode=key_mode)
        assert tree_eq(outs[s], ref)


# ----------------------------- sharded serving ------------------------------------


def _mixed_requests(n, bl=BL, seed=3):
    keys = jax.random.split(jax.random.key(seed), n)
    names = sorted(POOL)
    return [circuit_request(POOL[names[i % len(names)]][0],
                            dict(POOL[names[i % len(names)]][1]),
                            keys[i], bl)
            for i in range(n)]


@pytest.mark.parametrize("key_mode", KEY_MODES)
def test_sharded_matches_single_device_and_standalone(key_mode):
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    reqs = _mixed_requests(12)
    sharded = BankServer(max_slots=4, devices=jax.devices(),
                         placement="round_robin", max_inflight=2,
                         key_mode=key_mode)
    single = BankServer(max_slots=4, devices=[jax.devices()[0]],
                        key_mode=key_mode)
    outs_s = sharded.serve(reqs)
    outs_1 = single.serve(reqs)
    for r, a, b in zip(reqs, outs_s, outs_1):
        assert tree_eq(a, b)
        assert tree_eq(a, executor.execute_value(r.net, r.values, r.key, BL,
                                                 key_mode=key_mode))
    # round_robin over >= 2 devices must actually have used more than one.
    st_ = sharded.stats()
    assert sum(1 for d in st_["devices"] if d["n_batches"]) >= 2


@pytest.mark.parametrize("placement", ["affinity", "least_loaded"])
def test_placements_stay_bit_identical(placement):
    reqs = _mixed_requests(8)
    server = BankServer(max_slots=4, devices=jax.devices(),
                        placement=placement, max_inflight=2)
    for r, out in zip(reqs, server.serve(reqs)):
        ref = executor.execute_value(r.net, r.values, r.key, BL)
        assert tree_eq(out, ref)


def test_per_device_stats_account_everything():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    reqs = _mixed_requests(16)
    server = BankServer(max_slots=4, devices=jax.devices(),
                        placement="round_robin", max_inflight=1)
    server.serve(reqs)
    st_ = server.stats()
    assert st_["n_devices"] == jax.device_count()
    assert len(st_["devices"]) == jax.device_count()
    assert sum(d["n_batches"] for d in st_["devices"]) == st_["n_batches"]
    assert sum(d["n_requests"] for d in st_["devices"]) == len(reqs)
    assert "joined_requests" in st_


# ----------------------------- continuous batching --------------------------------


def test_late_request_joins_staged_batch():
    server = BankServer(max_slots=4)
    server.hold()
    # 3x mul + 1x sqrt hits max_slots: the batch forms and stages (held, so
    # it does not dispatch).  pad_counts rounds the mul run to 4 slots, so
    # the staged batch holds exactly one free mul slot for a late joiner.
    keys = jax.random.split(jax.random.key(31), 5)
    reqs = [circuit_request(MUL, {"a": 0.1 * (i + 1), "b": 0.5}, keys[i], BL)
            for i in range(3)]
    reqs.append(circuit_request(SQRT, {"a": 0.6}, keys[3], BL))
    tickets = [server.submit(r) for r in reqs]
    assert server.stats()["n_batches"] == 0          # staged, not dispatched
    late = circuit_request(MUL, {"a": 0.45, "b": 0.55}, keys[4], BL)
    t_late = server.submit(late)                     # joins the held batch
    server.release()
    outs = [t.result() for t in tickets]
    assert server.stats()["n_batches"] == 1          # one dispatch total
    assert server.stats()["joined_requests"] >= 1
    for r, out in zip(reqs, outs):
        assert tree_eq(out, executor.execute_value(r.net, r.values, r.key,
                                                   BL))
    assert tree_eq(t_late.result(),
                   executor.execute_value(late.net, late.values, late.key,
                                          BL))


# ----------------------------- failure handling -----------------------------------


def test_failure_propagates_to_every_ticket_and_server_survives():
    server = BankServer(max_slots=2)
    good = circuit_request(MUL, {"a": 0.3, "b": 0.7}, jax.random.key(4), BL)
    bad = circuit_request(MUL, {"a": 0.3}, jax.random.key(5), BL)  # missing b
    t1 = server.submit(good)
    t2 = server.submit(bad)                 # max_slots reached: one batch
    with pytest.raises(Exception):
        t2.result()
    with pytest.raises(Exception):
        t1.result()                                  # same batch -> same error
    # The server stays serviceable after a failed batch.
    ok = _mixed_requests(1, seed=7)[0]
    out = server.serve([ok])[0]
    assert tree_eq(out, executor.execute_value(ok.net, ok.values, ok.key, BL))


def test_result_timeout_keeps_ticket_retryable():
    server = BankServer(max_slots=2, window_s=0.0)
    # Big enough that the async dispatch cannot have finished synchronously.
    req = circuit_request(EXP, {"a": np.full((512,), 0.4, np.float32)},
                          jax.random.key(9), 4096)
    ticket = server.submit(req)
    server.flush()
    try:
        out = ticket.result(timeout=0.0)
    except TimeoutError:
        out = ticket.result()                        # retry without bound
    ref = executor.execute_value(req.net, req.values, req.key, 4096)
    assert tree_eq(out, ref)


# ----------------------------- property sweep -------------------------------------


@given(data=st.data())
@settings(max_examples=8, deadline=None)
def test_property_serving_bit_identity_across_devices(data):
    n_dev = data.draw(st.integers(min_value=1,
                                  max_value=jax.device_count()),
                      label="n_devices")
    placement = data.draw(st.sampled_from(["affinity", "round_robin",
                                           "least_loaded"]),
                          label="placement")
    names = data.draw(st.lists(st.sampled_from(sorted(POOL)), min_size=1,
                               max_size=6), label="mix")
    max_inflight = data.draw(st.integers(min_value=0, max_value=2),
                             label="max_inflight")
    keys = jax.random.split(jax.random.key(17), len(names))
    reqs = [circuit_request(POOL[n][0], dict(POOL[n][1]), keys[i], 64)
            for i, n in enumerate(names)]
    server = BankServer(max_slots=4, devices=jax.devices()[:n_dev],
                        placement=placement, max_inflight=max_inflight)
    for r, out in zip(reqs, server.serve(reqs)):
        ref = executor.execute_value(r.net, r.values, r.key, 64)
        assert tree_eq(out, ref)


def test_app_request_builders_return_canonical_execrequests():
    a = np.linspace(0.1, 0.9, 81)
    req = app_request("lit", KEY, BL, a=a)
    assert isinstance(req, ExecRequest)
    assert isinstance(req.options, ExecOptions)
    assert req.options.decode is False               # server decodes via opts
    out = executor.run(ExecRequest(req.net, req.values, req.key, ExecOptions(
        bitstream_length=BL, decode=True)))
    ref = executor.execute_value(req.net, req.values, req.key, BL)
    assert tree_eq(out, ref)
