"""Validate the while-aware HLO analyzer against known-FLOPs programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    m, k, n = 64, 128, 32
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    t = analyze(_hlo(lambda x, y: x @ y, a, b))
    assert t.flops == 2 * m * k * n


def test_scan_multiplies_body_flops_by_trip_count():
    m = 32
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    trips = 7

    def fn(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    t = analyze(_hlo(fn, a))
    expect = trips * 2 * m * m * m
    # trip-count detection is heuristic (largest constant in the condition);
    # require exactness here since the loop is clean
    assert t.flops == expect, (t.flops, expect)


def test_nested_scan():
    m = 16
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    t = analyze(_hlo(fn, a))
    assert t.flops == 5 * 3 * 2 * m ** 3


def test_traffic_nonzero_and_scales_with_loop():
    m = 64
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def loop(x, n):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    t2 = analyze(_hlo(lambda x: loop(x, 2), a))
    t8 = analyze(_hlo(lambda x: loop(x, 8), a))
    assert t8.traffic_bytes > 3 * t2.traffic_bytes > 0


def test_matches_xla_cost_analysis_when_unrolled():
    """On a loop-free program, our FLOPs ~ XLA's cost_analysis flops."""
    d = 128
    a = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def fn(x):
        for _ in range(4):
            x = jnp.tanh(x @ x)
        return x

    compiled = jax.jit(fn).lower(a).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per device
        ca = ca[0]
    xla_flops = float(ca["flops"])
    ours = analyze(compiled.as_text()).flops
    assert ours == pytest.approx(xla_flops, rel=0.05)
