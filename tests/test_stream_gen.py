"""Batched stream generation (stream tables) + plan-level CSE/BUFF/XOR passes.

Pins the PR's two contracts:

  * ``key_mode="legacy"`` reproduces the pre-batching outputs bit-exactly
    (hand-rolled per-PI key splits as the oracle), and ``key_mode="batched"``
    is statistically equivalent, bit-identical across backends, and
    bit-identical between merged bank execution and looped execution.
  * The structural plan passes (BUFF elision, CSE, XOR fusion) reduce pass
    counts while staying exact stream identities.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apps, bitstream as bs, circuits, executor
from repro.core.appnet import APP_NETLISTS
from repro.core.gates import Netlist, PIKind
from repro.core.plan import build_stream_table, cache_info, compile_plan

KEY = jax.random.key(11)
BL = 4096
TOL = 4.0 / np.sqrt(BL)


def val(words):
    return float(bs.to_value(words, BL))


# ----------------------------- generate_batch -------------------------------------

def test_generate_batch_means_within_ci():
    p = jnp.asarray([0.0, 0.05, 0.3, 0.5, 0.77, 0.95, 1.0], jnp.float32)
    words = bs.generate_batch(KEY, p[:, None], BL)          # (7, 1, W)
    got = np.asarray(bs.to_value(words, BL))[:, 0]
    np.testing.assert_allclose(got, np.asarray(p), atol=TOL)
    assert got[0] == 0.0
    assert got[-1] >= 1.0 - 2.0 / BL


def test_generate_batch_batched_values():
    p = jnp.stack([jnp.linspace(0.1, 0.9, 8), jnp.full((8,), 0.4)]).astype(jnp.float32)
    words = bs.generate_batch(jax.random.key(1), p, BL)     # (2, 8, W)
    np.testing.assert_allclose(np.asarray(bs.to_value(words, BL)),
                               np.asarray(p), atol=TOL)


def test_generate_batch_corr_lane_decodes_exact_abs_difference():
    # Rows sharing a key lane share uniforms: XOR decodes |a-b| EXACTLY
    # (as decoded values, not just in expectation).
    ps = jnp.asarray([0.8, 0.3], jnp.float32)
    a, b = bs.generate_batch(jax.random.key(2), ps, BL,
                             lanes=jnp.zeros((2,), jnp.uint32))
    assert val(a ^ b) == abs(val(a) - val(b))
    assert abs(val(a ^ b) - 0.5) < TOL


def test_generate_batch_distinct_lanes_are_independent():
    ps = jnp.asarray([0.5, 0.5], jnp.float32)
    a, b = bs.generate_batch(jax.random.key(3), ps, BL)
    # Independent fair streams: XOR value ~ 2*p*(1-p) = 0.5, AND ~ 0.25.
    assert abs(val(a ^ b) - 0.5) < TOL
    assert abs(val(a & b) - 0.25) < TOL


def test_generate_batch_pallas_is_bit_identical():
    ps = jnp.asarray([[0.2], [0.9], [0.5]], jnp.float32)
    a = bs.generate_batch(jax.random.key(4), ps, 512, use_pallas=False)
    b = bs.generate_batch(jax.random.key(4), ps, 512, use_pallas=True)
    assert (a == b).all()


def test_generate_correlated_deloop_still_exact():
    # The de-looped (stacked-threshold) generate_correlated keeps the exact
    # |a-b| XOR identity of the legacy loop.
    a, b = bs.generate_correlated(jax.random.key(5),
                                  [jnp.float32(0.9), jnp.float32(0.25)], BL)
    assert val(a ^ b) == abs(val(a) - val(b))


def test_generate_batch_refuses_counter_wrap():
    # uint32 bit counters cap one call at 2^32 bits per row; wrapping would
    # silently correlate far-apart batch elements, so the generator raises.
    from repro.kernels.sng import sng_words
    seeds = jnp.zeros((1,), jnp.uint32)
    thr = jnp.zeros((1, (1 << 32) // 1024 + 1), jnp.uint32)
    with pytest.raises(ValueError, match="counter space"):
        sng_words(seeds, thr, 1024 // 32)


# ----------------------------- stream tables --------------------------------------

def test_stream_table_layout_groups_then_singles():
    net = circuits.sc_abs_sub()           # corr group g0: A, B
    t = compile_plan(net).stream_table
    assert t.names == ("A", "B") and t.lanes == (0, 0) and t.n_groups == 1
    net = circuits.sc_sqrt()              # 4 singles, declaration order
    t = compile_plan(net).stream_table
    assert t.names == ("A1", "A2", "C1", "C2")
    assert t.lanes == (0, 1, 2, 3)
    assert t.const_values[2:] == (circuits.SQRT_C, circuits.SQRT_C)


def test_stream_table_excludes_state_pis():
    t = compile_plan(circuits.sc_scaled_div()).stream_table
    assert t.names == ("A", "B")


def test_stream_table_mixed_groups_and_singles_lanes():
    net = Netlist("mix")
    net.add_pi("X", value_key="x")
    net.add_pi("A", value_key="a", corr_group="g")
    net.add_pi("B", value_key="b", corr_group="g")
    net.add_gate("NAND", ["A", "B"], "n")
    net.add_gate("NAND", ["X", "n"], "out")
    net.set_outputs(["out"])
    t = build_stream_table(net.pis)
    # group lanes first (sorted group names), then singles.
    assert t.names == ("A", "B", "X")
    assert t.lanes == (0, 0, 1)


# -------------------------- key_mode="legacy" pinning -----------------------------

def legacy_streams(net, values, key, bl):
    """Hand-rolled oracle for the legacy key discipline: one split per
    sorted correlation group, then one per single PI in declaration order."""
    shape = jnp.broadcast_shapes(*[jnp.shape(jnp.asarray(v))
                                   for v in values.values()]) if values else ()
    groups, singles = {}, []
    for pi in net.pis:
        if pi.kind == PIKind.STATE:
            continue
        if pi.corr_group is not None:
            groups.setdefault(pi.corr_group, []).append(pi)
        else:
            singles.append(pi)
    keys = jax.random.split(key, max(len(groups) + len(singles), 1))
    streams, ki = {}, 0
    for _, gpis in sorted(groups.items()):
        vals = [jnp.broadcast_to(jnp.asarray(
            values[pi.value_key] if pi.value_key else pi.const_value,
            jnp.float32), shape) for pi in gpis]
        for pi, o in zip(gpis, bs.generate_correlated(keys[ki], vals, bl)):
            streams[pi.name] = o
        ki += 1
    for pi in singles:
        v = values[pi.value_key] if pi.value_key is not None else pi.const_value
        streams[pi.name] = bs.generate(
            keys[ki], jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape), bl)
        ki += 1
    return streams


@pytest.mark.parametrize("builder,values", [
    (circuits.sc_multiply, {"a": 0.3, "b": 0.7}),
    (circuits.sc_abs_sub, {"a": 0.4, "b": 0.1}),
    (circuits.sc_sqrt, {"a": 0.5}),
])
def test_key_mode_legacy_is_bit_exact(builder, values):
    # Legacy-mode execution == reference gate math over the hand-rolled
    # legacy streams: the pre-batching behavior, pinned bit for bit.
    net = builder()
    values = {k: jnp.float32(v) for k, v in values.items()}
    env = legacy_streams(net, values, KEY, 512)
    for g in net.gates:
        env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
    for backend in ("compiled", "reference"):
        got = executor.execute(net, values, KEY, 512, backend=backend,
                               key_mode="legacy")
        for o in net.outputs:
            assert (got[o] == env[o]).all(), f"{net.name}:{o} ({backend})"


def test_key_mode_legacy_many_matches_loop():
    nets = [circuits.sc_multiply(), circuits.sc_abs_sub(),
            circuits.sc_scaled_div()]
    values = [{"a": jnp.float32(0.3), "b": jnp.float32(0.7)},
              {"a": jnp.float32(0.9), "b": jnp.float32(0.2)},
              {"a": jnp.float32(0.4), "b": jnp.float32(0.5)}]
    keys = jax.random.split(KEY, 3)
    merged = executor.execute_many(nets, values, keys, 512, key_mode="legacy")
    for i, (net, vals) in enumerate(zip(nets, values)):
        ref = executor.execute(net, vals, keys[i], 512, key_mode="legacy")
        for o in ref:
            assert (merged[i][o] == ref[o]).all()


def test_key_mode_rejected_when_unknown():
    with pytest.raises(ValueError, match="key_mode"):
        executor.execute(circuits.sc_multiply(),
                         {"a": jnp.float32(0.5), "b": jnp.float32(0.5)},
                         KEY, 256, key_mode="banana")


# --------------------------- batched-mode semantics -------------------------------

def test_batched_mode_statistics_and_correlation():
    out = executor.execute_value(circuits.sc_multiply(),
                                 {"a": jnp.float32(0.6), "b": jnp.float32(0.5)},
                                 KEY, BL)
    assert abs(float(out["out"]) - 0.3) < 5 / np.sqrt(BL)
    out = executor.execute_value(circuits.sc_abs_sub(),
                                 {"a": jnp.float32(0.85), "b": jnp.float32(0.2)},
                                 KEY, BL)
    assert abs(float(out["out"]) - 0.65) < 5 / np.sqrt(BL)
    # Independent copies stay independent under batched lanes: E[a1*a2] = a^2.
    out = executor.execute_value(circuits.sc_sqrt(),
                                 {"a": jnp.float32(0.5)}, KEY, BL)
    expect = 2 * circuits.SQRT_C * 0.5 - (circuits.SQRT_C * 0.5) ** 2
    assert abs(float(out["out"]) - expect) < 5 / np.sqrt(BL)


def test_batched_mode_appnet_kde_corr_groups():
    # KDE leans on correlation groups (per-factor |x-h| XOR pairs) — the
    # batched table must keep each pair co-laned.
    hist = np.linspace(0.2, 0.8, 8)
    out = apps.appnet_stochastic("kde", jax.random.key(9), bl=2048,
                                 x_t=0.5, hist=hist)
    exact = apps.kde_exact(np.asarray(0.5), hist)
    got = float(next(iter(out.values())))
    assert abs(got - float(exact)) < 0.1


def test_batch_shape_generates_batched_const_only_streams():
    # Regression: a netlist whose stream PIs are all const-valued used to
    # fall back to scalar shape () even when downstream use is batched.
    net = Netlist("const_only")
    net.add_pi("C", kind=PIKind.CONSTANT, const_value=0.5)
    net.add_pi("D", kind=PIKind.CONSTANT, const_value=0.25)
    net.add_gate("NAND", ["C", "D"], "out")
    net.set_outputs(["out"])
    for mode in ("batched", "legacy"):
        out = executor.execute(net, {}, KEY, 512, key_mode=mode,
                               batch_shape=(4,))
        assert out["out"].shape == (4, 512 // 32)
        # Without the declaration the legacy fallback shape was scalar.
        out = executor.execute(net, {}, KEY, 512, key_mode=mode)
        assert out["out"].shape == (512 // 32,)


def test_batch_shape_broadcasts_against_values():
    net = circuits.sc_multiply()
    out = executor.execute(net, {"a": jnp.float32(0.5), "b": jnp.float32(0.5)},
                           KEY, 512, batch_shape=(3,))
    assert out["out"].shape == (3, 512 // 32)


def test_batch_shapes_in_bank_matches_loop():
    nets = [circuits.sc_multiply(), circuits.sc_multiply()]
    values = [{"a": jnp.float32(0.3), "b": jnp.float32(0.7)},
              {"a": jnp.float32(0.6), "b": jnp.float32(0.2)}]
    keys = jax.random.split(KEY, 2)
    merged = executor.run(
        [executor.ExecRequest(nets[i], values[i], keys[i],
                              executor.ExecOptions(bitstream_length=512,
                                                   batch_shape=bs))
         for i, bs in enumerate([(4,), None])])
    for i, shape in enumerate([(4, 16), (16,)]):
        assert merged[i]["out"].shape == shape
        ref = executor.execute(nets[i], values[i], keys[i], 512,
                               batch_shape=(4,) if i == 0 else None)
        assert (merged[i]["out"] == ref["out"]).all()


# ------------------------- plan-level structural passes ---------------------------

def test_xor_fusion_collapses_abs_sub_to_one_pass():
    plan = compile_plan(circuits.sc_abs_sub())
    assert plan.n_passes == 1
    assert plan.levels[0][0].op == "XOR"
    assert plan.n_fused_xor == 1


def test_xor_fusion_blocked_by_observable_intermediate():
    net = Netlist("xor_obs")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    n1 = net.add_gate("NAND", [a, b], "n1")
    n2 = net.add_gate("NAND", [a, n1], "n2")
    n3 = net.add_gate("NAND", [b, n1], "n3")
    net.add_gate("NAND", [n2, n3], "out")
    net.set_outputs(["out", "n1"])        # n1 observable -> no fusion
    plan = compile_plan(net)
    assert plan.n_fused_xor == 0
    vals = {"a": jnp.float32(0.7), "b": jnp.float32(0.2)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_fusion_respects_alias_resolved_protection():
    # Regression: an elided observable node (BUFF of a fusion-absorbable
    # intermediate as a primary output) makes its SURVIVOR observable; the
    # pattern matchers must not absorb it, or re-exposing the alias crashes.
    net = Netlist("xor_tapped")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    n1 = net.add_gate("NAND", [a, b], "n1")
    n2 = net.add_gate("NAND", [a, n1], "n2")
    n3 = net.add_gate("NAND", [b, n1], "n3")
    net.add_gate("NAND", [n2, n3], "out")
    net.add_gate("BUFF", [n1], "tap")
    net.set_outputs(["out", "tap"])
    plan = compile_plan(net)
    assert plan.n_fused_xor == 0          # n1 observable through the tap
    assert ("tap", "n1") in plan.aliases
    vals = {"a": jnp.float32(0.6), "b": jnp.float32(0.2)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    assert set(cmp) == {"out", "tap"}
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_unobserved_cse_duplicate_does_not_block_or_break_fusion():
    # Regression: a dangling CSE duplicate of a MUX feeder left an alias to
    # a node fusion then absorbed, crashing execution (KeyError).  The alias
    # is not observable, so it must be dropped and fusion must proceed.
    net = Netlist("dangling_dup")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    s = net.add_pi("S", value_key="s")
    ns = net.add_gate("NOT", [s], "ns")
    g1 = net.add_gate("NAND", [a, s], "g1")
    g2 = net.add_gate("NAND", [b, ns], "g2")
    net.add_gate("NAND", [g1, g2], "out")
    net.add_gate("NAND", [s, a], "dup")   # unused commutative duplicate of g1
    net.set_outputs(["out"])
    plan = compile_plan(net)
    assert plan.n_cse_elided == 1
    assert plan.aliases == ()             # dup unobservable -> no alias kept
    assert plan.n_fused_mux == 1          # fusion proceeds over the survivor
    vals = {"a": jnp.float32(0.3), "b": jnp.float32(0.6), "s": jnp.float32(0.5)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_cse_dedupes_identical_gates_and_keeps_outputs_observable():
    net = Netlist("dup")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    net.add_gate("NAND", [a, b], "n1")
    net.add_gate("NAND", [b, a], "n2")    # commutative duplicate
    net.add_gate("NOT", ["n1"], "o1")
    net.add_gate("NOT", ["n2"], "o2")     # becomes duplicate after CSE of n2
    net.set_outputs(["o1", "o2", "n2"])
    plan = compile_plan(net)
    assert plan.n_cse_elided == 2         # n2, then o2 transitively
    assert plan.n_passes == 2             # one NAND pass + one NOT pass
    assert ("n2", "n1") in plan.aliases and ("o2", "o1") in plan.aliases
    vals = {"a": jnp.float32(0.4), "b": jnp.float32(0.6)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    assert set(cmp) == {"o1", "o2", "n2"}
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_buff_elision_drops_copies_and_aliases_outputs():
    net = Netlist("buffy")
    a = net.add_pi("A", value_key="a")
    net.add_gate("BUFF", [a], "c1")
    net.add_gate("BUFF", ["c1"], "c2")    # chain resolves to A
    net.add_gate("NOT", ["c2"], "out")
    net.set_outputs(["out", "c2"])        # elided BUFF is itself an output
    plan = compile_plan(net)
    assert plan.n_buff_elided == 2
    assert plan.n_passes == 1
    assert ("c2", "A") in plan.aliases
    vals = {"a": jnp.float32(0.3)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_opt_passes_disabled_for_fault_injection_plans():
    net = circuits.sc_abs_sub()
    plan = compile_plan(net, fuse_mux=False)
    assert plan.n_fused_xor == plan.n_cse_elided == plan.n_buff_elided == 0
    assert plan.aliases == ()
    # And injected runs stay bit-identical to the reference interpreter.
    vals = {"a": jnp.float32(0.4), "b": jnp.float32(0.1)}
    kw = dict(bitflip_rate=0.1, flip_key=jax.random.key(13))
    ref = executor.execute(net, vals, KEY, 512, backend="reference", **kw)
    cmp = executor.execute(net, vals, KEY, 512, **kw)
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_opt_passes_value_identical_on_table_netlists():
    # The acceptance sweep: every Table-2 / Table-3 stage circuit and appnet
    # executes bit-identically (reference vs optimized compiled plan), and
    # XOR-bearing netlists get fewer passes than gates surviving elision.
    cases = [
        (circuits.sc_multiply(), {"a": 0.3, "b": 0.7}),
        (circuits.sc_scaled_add(), {"a": 0.2, "b": 0.9}),
        (circuits.sc_abs_sub(), {"a": 0.4, "b": 0.1}),
        (circuits.sc_sqrt(), {"a": 0.5}),
        (circuits.sc_exp(), {"a": 0.5}),
        (circuits.sc_scaled_div(), {"a": 0.4, "b": 0.4}),
        (APP_NETLISTS["lit"](), {f"a{i}": 0.5 for i in range(81)}),
        (APP_NETLISTS["ol"](), {f"p{r}_{j}": 0.9 for r in range(16)
                                for j in range(6)}),
    ]
    for net, values in cases:
        values = {k: jnp.float32(v) for k, v in values.items()}
        ref = executor.execute(net, values, KEY, 256, backend="reference")
        cmp = executor.execute(net, values, KEY, 256)
        for o in ref:
            assert (ref[o] == cmp[o]).all(), f"{net.name}:{o}"
    lit = compile_plan(APP_NETLISTS["lit"]())
    assert lit.n_fused_xor >= 1 and lit.n_buff_elided >= 1
    kde = compile_plan(APP_NETLISTS["kde"]())
    assert kde.n_fused_xor >= 8 and kde.n_buff_elided >= 8


def test_cache_info_reports_elision_counters():
    info = cache_info()
    for k in ("plans", "banks", "buff_elided", "cse_elided", "mux_fused",
              "xor_fused"):
        assert k in info
