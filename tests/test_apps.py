"""Application-level tests: the SC accuracy paths track the float references
(Section 5-3) and degrade gracefully under bitflips (Table 4's qualitative
claim: stochastic error stays small and grows slowly with flip rate, binary
error explodes).
"""
import jax
import numpy as np
import pytest

from repro.core import apps

RNG = np.random.default_rng(7)
KEY = jax.random.key(7)
BL = 1024


def test_lit_tracks_exact():
    a = RNG.random((16, 81))
    exact = apps.lit_exact(a)
    sc = np.asarray(apps.lit_stochastic(KEY, a, BL))
    assert np.abs(sc - exact).mean() < 0.06


def test_ol_tracks_exact():
    p = RNG.random((64, 6)) * 0.5 + 0.5      # keep products away from 0
    exact = apps.ol_exact(p)
    sc = np.asarray(apps.ol_stochastic(KEY, p, BL))
    assert np.abs(sc - exact).mean() < 0.05


def test_hdp_tracks_exact():
    v = {k: RNG.random(32) * 0.8 + 0.1 for k in apps.HDP_KEYS}
    exact = apps.hdp_exact(v)
    sc = np.asarray(apps.hdp_stochastic(KEY, v, 2048))
    assert np.abs(sc - exact).mean() < 0.08


def test_kde_tracks_exact():
    x_t = RNG.random(8)
    hist = RNG.random((8, apps.KDE_N))
    exact = apps.kde_exact(x_t, hist)
    sc = np.asarray(apps.kde_stochastic(KEY, x_t, hist, 512))
    assert np.abs(sc - exact).mean() < 0.08


@pytest.mark.parametrize("app", ["lit", "ol"])
def test_stochastic_error_grows_slowly_with_bitflips(app):
    # Table 4: Stoch-IMC error < 6.5% even at 20% flips.
    if app == "lit":
        a = RNG.random((8, 81))
        exact = apps.lit_exact(a)
        run = lambda r: np.asarray(apps.lit_stochastic(KEY, a, BL, bitflip_rate=r))
    else:
        p = RNG.random((32, 6)) * 0.5 + 0.5
        exact = apps.ol_exact(p)
        run = lambda r: np.asarray(apps.ol_stochastic(KEY, p, BL, bitflip_rate=r))
    err20 = np.abs(run(0.20) - exact).mean()
    assert err20 < 0.15, err20


def test_binary_error_explodes_faster_than_stochastic_at_high_flip_rate():
    # The Table 4 crossover: at 20% flips binary IMC error >> Stoch-IMC error.
    p = RNG.random((256, 6)) * 0.5 + 0.5
    exact = apps.ol_exact(p)
    sc_err = np.abs(np.asarray(apps.ol_stochastic(KEY, p, BL, bitflip_rate=0.2))
                    - exact).mean()
    bin_err = np.abs(apps.ol_binary8(np.random.default_rng(0), p, bitflip_rate=0.2)
                     - exact).mean()
    assert bin_err > 2 * sc_err, (bin_err, sc_err)


def test_cost_stages_schedule_within_subarray():
    from repro.core.scheduler import schedule
    for stages in (apps.lit_cost_stages(), apps.ol_cost_stages(),
                   apps.hdp_cost_stages(), apps.kde_cost_stages()):
        for st in stages:
            sch = schedule(st.netlist, n_lanes=st.q_lanes)
            assert sch.n_cols <= 256 and sch.n_rows <= 256
