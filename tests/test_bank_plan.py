"""Bank-level plan merging: merged execution is bit-identical to looped.

Pins the tentpole claim of the bank layer (core/plan.py merge_plans /
compile_bank_plan + executor.execute_many): executing N heterogeneous
netlists through ONE merged bank plan produces, member by member and bit for
bit, the streams a loop of per-netlist ``execute`` calls produces with the
same per-member keys — for mixed combinational+sequential member sets,
heterogeneous batch shapes, and under bitflip injection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apps, arch, circuits, executor
from repro.core.appnet import APP_NETLISTS
from repro.core.plan import compile_bank_plan, compile_plan, merge_plans

KEY = jax.random.key(7)
FLIP_KEY = jax.random.key(77)
BL = 512


def mixed_bank():
    """Heterogeneous members: comb + sequential, Table-2 + appnet circuits."""
    nets = [circuits.sc_multiply(), circuits.sc_scaled_div(),
            circuits.sc_abs_sub(), circuits.sc_exp(),
            circuits.sc_scaled_div(), APP_NETLISTS["ol"]()]
    values = [{"a": jnp.float32(0.3), "b": jnp.float32(0.7)},
              {"a": jnp.float32(0.4), "b": jnp.float32(0.4)},
              {"a": jnp.float32(0.8), "b": jnp.float32(0.3)},
              {"a": jnp.float32(0.5)},
              {"a": jnp.float32(0.2), "b": jnp.float32(0.6)},
              apps.appnet_inputs("ol", p=np.full((16, 6), 0.9))]
    return nets, values


def assert_bank_matches_loop(nets, values, bl=BL, **kw):
    keys = jax.random.split(KEY, len(nets))
    flip_keys = None
    if kw.get("bitflip_rate", 0.0) > 0.0:
        flip_keys = jax.random.split(FLIP_KEY, len(nets))
    merged = executor.execute_many(nets, values, keys, bl,
                                   flip_keys=flip_keys, **kw)
    for i, (net, vals) in enumerate(zip(nets, values)):
        ref = executor.execute(net, vals, keys[i], bl,
                               flip_key=flip_keys[i] if flip_keys is not None
                               else None, **kw)
        assert set(merged[i]) == set(ref)
        for o in ref:
            assert merged[i][o].shape == ref[o].shape, f"member {i}:{o}"
            assert (merged[i][o] == ref[o]).all(), \
                f"member {i} ({net.name}) output {o} diverges"


# --------------------------------- parity -----------------------------------------

def test_mixed_comb_seq_bank_bit_identical():
    nets, values = mixed_bank()
    assert_bank_matches_loop(nets, values)


@pytest.mark.parametrize("rate", [0.05, 0.2])
def test_mixed_bank_bit_identical_under_bitflip(rate):
    nets, values = mixed_bank()
    assert_bank_matches_loop(nets, values, bitflip_rate=rate)


def test_heterogeneous_batch_shapes_bit_identical():
    # Combinational members with arbitrary batch shapes (shape-grouped
    # passes), sequential members with broadcast-compatible shapes.
    nets = [circuits.sc_multiply(), circuits.sc_multiply(),
            circuits.sc_sqrt(), circuits.sc_scaled_div(),
            circuits.sc_scaled_div()]
    values = [{"a": jnp.asarray(np.linspace(0.1, 0.9, 8), jnp.float32),
               "b": jnp.full((8,), 0.5, jnp.float32)},
              {"a": jnp.float32(0.3), "b": jnp.float32(0.7)},
              {"a": jnp.asarray(np.linspace(0.2, 0.8, 5), jnp.float32)},
              {"a": jnp.asarray(np.linspace(0.1, 0.6, 4), jnp.float32),
               "b": jnp.full((4,), 0.3, jnp.float32)},
              {"a": jnp.float32(0.4), "b": jnp.float32(0.4)}]
    assert_bank_matches_loop(nets, values)


def test_single_key_splits_like_loop():
    nets, values = mixed_bank()
    keys = jax.random.split(KEY, len(nets))
    merged = executor.execute_many(nets, values, KEY, BL)   # one key, split
    for i, (net, vals) in enumerate(zip(nets, values)):
        ref = executor.execute(net, vals, keys[i], BL)
        for o in ref:
            assert (merged[i][o] == ref[o]).all()


def test_execute_value_many_decodes_like_loop():
    nets, values = mixed_bank()
    keys = jax.random.split(KEY, len(nets))
    merged = executor.execute_value_many(nets, values, keys, BL)
    for i, (net, vals) in enumerate(zip(nets, values)):
        ref = executor.execute_value(net, vals, keys[i], BL)
        for o in ref:
            np.testing.assert_array_equal(np.asarray(merged[i][o]),
                                          np.asarray(ref[o]))


def test_state_only_member_in_bank():
    # A zero-stream-PI recurrence merged with ordinary members.
    from repro.core.gates import Netlist, PIKind
    osc = Netlist("osc")
    q = osc.add_pi("Q", kind=PIKind.STATE)
    osc.add_gate("NOT", [q], "Qn")
    osc.bind_state(q, "Qn", init=0.0)
    osc.set_outputs(["Qn"])
    nets = [osc, circuits.sc_scaled_div(), circuits.sc_multiply()]
    values = [{}, {"a": jnp.float32(0.4), "b": jnp.float32(0.2)},
              {"a": jnp.float32(0.5), "b": jnp.float32(0.5)}]
    assert_bank_matches_loop(nets, values)


def test_reference_backend_loops():
    nets, values = mixed_bank()
    keys = jax.random.split(KEY, len(nets))
    ref = executor.execute_many(nets, values, keys, 256, backend="reference")
    cmp = executor.execute_many(nets, values, keys, 256)
    for r, c in zip(ref, cmp):
        for o in r:
            assert (r[o] == c[o]).all()


def test_reference_backend_loops_under_bitflip():
    # Regression: the reference branch tested its per-member flip-key array
    # for truthiness, which is ambiguous for arrays.
    nets, values = mixed_bank()
    keys = jax.random.split(KEY, len(nets))
    fks = jax.random.split(FLIP_KEY, len(nets))
    ref = executor.execute_many(nets, values, keys, 256, bitflip_rate=0.1,
                                flip_keys=fks, backend="reference")
    cmp = executor.execute_many(nets, values, keys, 256, bitflip_rate=0.1,
                                flip_keys=fks)
    for r, c in zip(ref, cmp):
        for o in r:
            assert (r[o] == c[o]).all()


# ------------------------------ appnet serving ------------------------------------

def test_appnet_stochastic_many_matches_per_request():
    requests = [("ol", {"p": np.full((16, 6), 0.9)}),
                ("hdp", {"v": {k: 0.5 for k in apps.HDP_KEYS}}),
                ("ol", {"p": np.full((16, 6), 0.7)})]
    nets = [APP_NETLISTS[app]() for app, _ in requests]
    keys = jax.random.split(KEY, len(requests))
    merged = apps.appnet_stochastic_many(requests, keys, bl=256, nets=nets)
    for i, (app, inp) in enumerate(requests):
        ref = apps.appnet_stochastic(app, keys[i], bl=256, net=nets[i], **inp)
        for o in ref:
            np.testing.assert_array_equal(np.asarray(merged[i][o]),
                                          np.asarray(ref[o]))


# ----------------------------- plan-level properties ------------------------------

def test_bank_plan_merges_passes_across_members():
    nets = [circuits.sc_multiply() for _ in range(8)]
    bank = compile_bank_plan(nets)
    # 8 structurally-equal members intern to one member plan and collapse to
    # that plan's passes: the NAND+NOT pair folds to ONE batched AND pass.
    assert len(set(bank.members)) == 1
    assert bank.n_passes == bank.members[0].n_passes == 1
    assert bank.n_passes_looped == 8
    assert bank.comb.levels[0][0].op == "AND"
    assert bank.comb.levels[0][0].n_batched == 8


def test_bank_plan_is_cached():
    nets = [circuits.sc_multiply(), circuits.sc_abs_sub()]
    assert compile_bank_plan(nets) is compile_bank_plan(
        [circuits.sc_multiply(), circuits.sc_abs_sub()])


def test_bank_plan_partitions_comb_and_seq():
    nets = [circuits.sc_multiply(), circuits.sc_scaled_div(),
            circuits.sc_exp()]
    bank = compile_bank_plan(nets)
    assert bank.comb_members == (0, 2)
    assert bank.seq_members == (1,)
    assert not bank.comb.is_sequential
    assert bank.seq.is_sequential
    # Namespaced outputs scatter back per member.
    assert bank.comb.outputs == ("b0/out", "b2/s1")
    assert bank.seq.outputs == ("b1/Q_next",)


def test_merge_plans_rejects_mixed_kinds():
    comb = compile_plan(circuits.sc_multiply())
    seq = compile_plan(circuits.sc_scaled_div())
    with pytest.raises(ValueError, match="mix"):
        merge_plans([comb, seq], [0, 1], "bad")


def test_merged_gids_are_offset_per_member():
    p = compile_plan(circuits.sc_multiply(), fuse_mux=False)
    merged = merge_plans([p, p], [0, 1], "two")
    gids = sorted(g for level in merged.levels for cop in level
                  for g in cop.gids)
    assert gids == [0, 1, 2, 3]          # member 1's gids offset by n_gates=2


# ------------------------------- arch accounting ----------------------------------

def test_evaluate_bank_plan_reflects_bank_simd():
    cfg = arch.StochIMCConfig()
    for app in apps.APPS:
        bank = compile_bank_plan(apps.cost_stage_netlists(app))
        cost = arch.evaluate_bank_plan(bank, cfg)
        assert cost.n_members == bank.n_members
        assert cost.merged_passes <= cost.looped_passes
        assert cost.merged_cycles < cost.looped_cycles
        assert cost.simd_speedup > 1.0
        # Accumulation is charged once bank-wide vs once per dispatch.
        assert cost.looped_cycles - cost.looped_passes * cost.pipeline_factor \
            == cost.accumulation_cycles * cost.n_members


def test_bank_pipeline_factor_scales_with_bitstream_length():
    bank = compile_bank_plan(apps.cost_stage_netlists("ol"))
    small = arch.evaluate_bank_plan(bank, arch.StochIMCConfig())
    big = arch.evaluate_bank_plan(
        bank, arch.StochIMCConfig(bitstream_length=4 * 256 * 256 * 2),
        q_lanes=256)
    assert small.pipeline_factor == 1
    assert big.pipeline_factor == 8
    assert big.merged_cycles > small.merged_cycles
