"""Netlist interpreter tests: sequential circuits, correlation, fault injection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitstream as bs, circuits, executor, sc_ops
from repro.core.gates import Netlist, PIKind

BL = 4096


def test_vectorized_execution_broadcasts_over_batch():
    net = circuits.sc_multiply()
    a = jnp.asarray(np.linspace(0.1, 0.9, 8), jnp.float32)
    b = jnp.full((8,), 0.5, jnp.float32)
    out = executor.execute_value(net, {"a": a, "b": b}, jax.random.key(0), BL)
    np.testing.assert_allclose(np.asarray(out["out"]), np.asarray(a) * 0.5,
                               atol=5 / np.sqrt(BL))


def test_sequential_divider_state_scan_matches_functional_op():
    a, b = 0.4, 0.4
    net = circuits.sc_scaled_div()
    out = executor.execute_value(net, {"a": jnp.float32(a), "b": jnp.float32(b)},
                                 jax.random.key(1), 16384)
    assert abs(float(out["Q_next"]) - 0.5) < 0.03


def test_bitflip_injection_shifts_extreme_values_toward_half():
    net = circuits.sc_multiply()
    vals = {"a": jnp.float32(0.95), "b": jnp.float32(0.95)}
    clean = executor.execute_value(net, vals, jax.random.key(2), BL)
    noisy = executor.execute_value(net, vals, jax.random.key(2), BL,
                                   bitflip_rate=0.2, flip_key=jax.random.key(3))
    # flipping 20% of bits pulls high-probability streams toward 0.5
    assert float(noisy["out"]) < float(clean["out"])
    assert abs(float(clean["out"]) - 0.9025) < 5 / np.sqrt(BL)


def test_flip_bits_rate_statistics():
    w = jnp.zeros((64, BL // 32), jnp.uint32)
    flipped = sc_ops.flip_bits(jax.random.key(4), w, 0.1)
    rate = float(bs.popcount(flipped).sum()) / (64 * BL)
    assert abs(rate - 0.1) < 0.01


def test_correlation_groups_share_randomness():
    net = Netlist("corr")
    a = net.add_pi("A", value_key="a", corr_group="g")
    b = net.add_pi("B", value_key="b", corr_group="g")
    net.add_gate("NAND", [a, b], "n")
    net.add_gate("NOT", ["n"], "out")    # AND of correlated = min(a, b)
    net.set_outputs(["out"])
    out = executor.execute_value(net, {"a": jnp.float32(0.3), "b": jnp.float32(0.8)},
                                 jax.random.key(5), BL)
    assert abs(float(out["out"]) - 0.3) < 5 / np.sqrt(BL)   # min, not product


def test_independent_copies_are_decorrelated():
    net = Netlist("indep")
    a1 = net.add_pi("A1", value_key="a", indep_copy=0)
    a2 = net.add_pi("A2", value_key="a", indep_copy=1)
    net.add_gate("NAND", [a1, a2], "n")
    net.add_gate("NOT", ["n"], "out")    # AND of independent copies = a^2
    net.set_outputs(["out"])
    out = executor.execute_value(net, {"a": jnp.float32(0.5)}, jax.random.key(6), BL)
    assert abs(float(out["out"]) - 0.25) < 5 / np.sqrt(BL)


def test_constant_pis_fill_from_const_value():
    net = Netlist("const")
    a = net.add_pi("A", value_key="a")
    c = net.add_pi("C", kind=PIKind.CONSTANT, const_value=0.5)
    net.add_gate("NAND", [a, c], "n")
    net.add_gate("NOT", ["n"], "out")
    net.set_outputs(["out"])
    out = executor.execute_value(net, {"a": jnp.float32(0.8)}, jax.random.key(7), BL)
    assert abs(float(out["out"]) - 0.4) < 5 / np.sqrt(BL)


# ------------------------------ strict validation ---------------------------------

@pytest.mark.parametrize("backend", ["compiled", "reference"])
def test_bitflip_without_flip_key_raises(backend):
    # Regression: this used to be a bare assert, stripped under `python -O`.
    net = circuits.sc_multiply()
    vals = {"a": jnp.float32(0.5), "b": jnp.float32(0.5)}
    with pytest.raises(ValueError, match="flip_key"):
        executor.execute(net, vals, jax.random.key(0), 256,
                         bitflip_rate=0.1, backend=backend)


@pytest.mark.parametrize("backend", ["compiled", "reference"])
def test_binary_fractional_const_raises(backend):
    # Regression: 0 < const_value < 1 was silently floored to an all-zeros
    # word; a binary constant cell can only hold 0 or 1.
    net = Netlist("frac_const")
    a = net.add_pi("A", kind=PIKind.BINARY, value_key="a", row=0)
    c = net.add_pi("C", kind=PIKind.BINARY, const_value=0.5, row=0)
    net.add_gate("AND", [a, c], "o", row=0)
    net.set_outputs(["o"])
    with pytest.raises(ValueError, match="const_value"):
        executor.execute_binary(net, {"A": jnp.zeros((4,), jnp.uint32)},
                                backend=backend)


# --------------------------- state-only recurrences -------------------------------

def _oscillator() -> Netlist:
    # Q' = NOT(Q): no non-state stream PIs at all (the jnp.stack([]) crash).
    net = Netlist("osc")
    q = net.add_pi("Q", kind=PIKind.STATE)
    net.add_gate("NOT", [q], "Qn")
    net.bind_state(q, "Qn", init=0.0)
    net.set_outputs(["Qn"])
    return net


@pytest.mark.parametrize("backend", ["compiled", "reference"])
def test_sequential_without_stream_pis_executes(backend):
    out = executor.execute(_oscillator(), {}, jax.random.key(0), 256,
                           backend=backend)
    # Q starts 0, is emitted after the NOT: 1,0,1,0,... -> exactly 0.5.
    assert float(bs.to_value(out["Qn"], 256)) == 0.5


def test_sequential_without_stream_pis_backends_bit_identical():
    ref = executor.execute(_oscillator(), {}, jax.random.key(1), 128,
                           backend="reference")
    cmp = executor.execute(_oscillator(), {}, jax.random.key(1), 128,
                           backend="compiled")
    assert (ref["Qn"] == cmp["Qn"]).all()


# ------------------------- jit-boundary value packing -----------------------------

def test_pack_values_seq_groups_leaves_per_shape():
    # The bank jit boundary must flatten a handful of leaves per slot, not
    # one per PI: host scalars collapse into one f32 vector, host arrays
    # into one stacked leaf per distinct shape; jax arrays pass through
    # untouched (packing them would force a device sync).
    dev = jnp.ones((4,), jnp.float32)
    vals = {
        "s2": 0.2, "s1": np.float32(0.1), "s3": 0.3,          # 3 scalars
        "b1": np.full((16, 6), 0.5), "b0": np.full((16, 6), 0.4),
        "b2": np.full((16, 6), 0.6),                          # 3 of one shape
        "c0": np.linspace(0.0, 1.0, 8),                       # 1 of another
        "j0": dev,                                            # jax leaf
    }
    values_seq, names = executor._pack_values_seq([vals, {"x": 0.7}])
    # Slot 0: 1 scalar vector + 2 grouped arrays + 1 jax array; slot 1: 1
    # scalar vector (+ empty groups/rest).
    leaves = jax.tree_util.tree_leaves(values_seq)
    assert len(leaves) == 4 + 1
    packed, grouped, rest = values_seq
    assert packed[0].shape == (3,) and packed[1].shape == (1,)
    assert [g.shape for g in grouped[0]] == [(1, 8), (3, 16, 6)]
    assert rest[0]["j0"] is dev
    # Static layout spec is hashable (jit static arg) and fully ordered.
    hash(names)
    assert names[0][0] == ("s1", "s2", "s3")
    assert names[0][1] == (((8,), ("c0",)), ((16, 6), ("b0", "b1", "b2")))
    # Round trip: the trace-time unpack rebuilds the per-slot dicts exactly.
    rebuilt = executor._unpack_values_seq(values_seq, names)
    assert set(rebuilt[0]) == set(vals)
    for k, v in vals.items():
        np.testing.assert_array_equal(np.asarray(rebuilt[0][k], np.float32),
                                      np.asarray(v, np.float32))
    assert set(rebuilt[1]) == {"x"}
