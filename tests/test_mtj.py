"""MTJ stochastic-switching model tests (paper Eqs. (1)-(2), Fig. 3, Table 1)."""

import numpy as np
import pytest

from repro.core import mtj


def test_fig3_anchor_point():
    # Fig. 3: a 310 mV / 4 ns pulse switches with probability ~0.7.
    p = mtj.switching_probability(0.310, 4e-9)
    assert abs(p - 0.7) < 0.05


def test_probability_monotonic_in_voltage_and_duration():
    # Non-strict at the float-saturated tails (P -> 0 or 1 exactly); strictly
    # increasing through the Fig. 3 transition region.
    for t_p in (3e-9, 5e-9, 10e-9):
        ps = [mtj.switching_probability(v, t_p) for v in np.linspace(0.2, 0.4, 9)]
        assert all(b >= a for a, b in zip(ps, ps[1:]))
        assert ps[-1] > ps[0]
    for v in (0.28, 0.3, 0.32):
        ps = [mtj.switching_probability(v, t) for t in np.linspace(3e-9, 10e-9, 9)]
        assert all(b >= a for a, b in zip(ps, ps[1:]))
        assert ps[-1] > ps[0]


@pytest.mark.parametrize("p_target", [0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99])
@pytest.mark.parametrize("t_p", [3e-9, 4e-9, 10e-9])
def test_pulse_voltage_inverts_model(p_target, t_p):
    v = mtj.pulse_voltage_for(p_target, t_p)
    assert abs(mtj.switching_probability(v, t_p) - p_target) < 1e-9


def test_optimal_pulse_is_energy_minimal_on_grid():
    spec = mtj.optimal_pulse(0.5, n_grid=32)
    for t_p in np.linspace(mtj.T_P_MIN_S, mtj.T_P_MAX_S, 32):
        v = mtj.pulse_voltage_for(0.5, float(t_p))
        if v > 0:
            assert spec.energy_j <= mtj.write_energy(v, float(t_p)) + 1e-30
    assert mtj.switching_probability(spec.v_p, spec.t_p) == pytest.approx(0.5, abs=1e-6)


def test_btos_lut_shape_and_monotonicity():
    lut = mtj.btos_lut(8)
    assert len(lut) == 256                      # 2^8 entries = 256 B BtoS memory
    assert mtj.lut_size_bytes(8) == 256
    probs = [e.p_sw for e in lut]
    assert probs == sorted(probs)
    assert lut[0].energy_j == 0.0
    # Switching energies are sub-femtojoule scale for this MTJ (aJ..fJ).
    assert 0 < lut[128].energy_j < 1e-13


def test_sbg_energy_positive_and_small():
    e = mtj.sbg_energy(0.5)
    assert 0 < e < 1e-13
