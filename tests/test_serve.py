"""Dynamic bank serving: bucketed/padded BankPlans + BankServer.

Pins the tentpole guarantees of the serving layer:

  * padded/bucketed bank execution (``plan.compile_bank_template`` +
    ``executor.execute_bank`` with an active-slot mask) is **bit-identical**
    per bound slot to standalone ``execute`` — for random member subsets,
    batch shapes, both ``key_mode``s, and under bitflip injection;
  * ``BankServer`` results are bit-identical to per-request
    ``execute_value``, and its bucketing reuses templates (and jit traces)
    across request sets that fit the same bucket;
  * the plan/bank caches are LRU-bounded with evictions reported in
    ``cache_info()``;
  * the NOT-directed fusion passes (AND folding, lone-NOT absorption) reduce
    passes and stay bit-identical on the exp/Horner netlists.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.core import arch, circuits, executor
from repro.core.plan import (bucket_count, cache_info, compile_bank_template,
                             compile_plan, identity_plan, merged_pass_count,
                             set_cache_caps, template_members)
from repro.serve import BankServer, app_netlist, app_request, circuit_request

KEY = jax.random.key(11)
FLIP_KEY = jax.random.key(111)
BL = 256

# Shared structure pool: reusing these objects interns each to one plan.
MUL = circuits.sc_multiply()
SADD = circuits.sc_scaled_add()
ABS = circuits.sc_abs_sub()
SQRT = circuits.sc_sqrt()
EXP = circuits.sc_exp()
DIV = circuits.sc_scaled_div()

POOL = [
    (MUL, {"a": 0.3, "b": 0.7}),
    (SADD, {"a": 0.2, "b": 0.9}),
    (ABS, {"a": 0.4, "b": 0.1}),
    (SQRT, {"a": 0.5}),
    (EXP, {"a": 0.5}),
    (DIV, {"a": 0.4, "b": 0.2}),
]


def _requests(member_ids, batch=None):
    nets, values = [], []
    for m in member_ids:
        net, vals = POOL[m]
        nets.append(net)
        vals = {k: jnp.float32(v) for k, v in vals.items()}
        if batch:
            vals = {k: jnp.broadcast_to(v, batch) for k, v in vals.items()}
        values.append(vals)
    return nets, values


def _bind(template, plans):
    """Request -> slot binding over a template (first free slot per plan)."""
    from collections import defaultdict, deque
    free = defaultdict(deque)
    for s, m in enumerate(template.members):
        free[id(m)].append(s)
    return [free[id(p)].popleft() for p in plans]


def assert_padded_matches_loop(member_ids, batch=None, key_mode="batched",
                               bitflip_rate=0.0, bl=BL):
    nets, values = _requests(member_ids, batch)
    keys = jax.random.split(KEY, len(nets))
    fkeys = jax.random.split(FLIP_KEY, len(nets)) \
        if bitflip_rate > 0.0 else None
    fuse = bitflip_rate == 0.0
    plans = [compile_plan(n, fuse_mux=fuse or n.is_sequential) for n in nets]
    template = compile_bank_template(
        plans, n_slots=bucket_count(len(template_members(plans))))
    slots = _bind(template, plans)
    n = template.n_members
    values_seq = [{} for _ in range(n)]
    key_rows = [keys[0]] * n
    fk_rows = [fkeys[0] if fkeys is not None else keys[0]] * n
    active = [False] * n
    for r, s in enumerate(slots):
        values_seq[s] = values[r]
        key_rows[s] = keys[r]
        active[s] = True
        if fkeys is not None:
            fk_rows[s] = fkeys[r]
    outs = executor.execute_bank(
        template, values_seq, key_rows, bl, active=active,
        bitflip_rate=bitflip_rate,
        flip_keys=fk_rows if fkeys is not None else None, key_mode=key_mode)
    for r, s in enumerate(slots):
        ref = executor.execute(nets[r], values[r], keys[r], bl,
                               key_mode=key_mode, bitflip_rate=bitflip_rate,
                               flip_key=fkeys[r] if fkeys is not None
                               else None)
        assert set(outs[s]) == set(ref)
        for o in ref:
            assert outs[s][o].shape == ref[o].shape
            assert (outs[s][o] == ref[o]).all(), \
                f"member {r} ({nets[r].name}) output {o} diverges"
    for s in range(n):
        if s not in slots:
            assert outs[s] is None


# ------------------------------ padded execution ----------------------------------

@pytest.mark.parametrize("key_mode", ["batched", "legacy"])
def test_padded_bank_bit_identical(key_mode):
    assert_padded_matches_loop([0, 0, 0, 3, 5], key_mode=key_mode)


def test_padded_bank_bit_identical_with_batch():
    assert_padded_matches_loop([0, 1, 2, 4], batch=(5,))


@pytest.mark.parametrize("rate", [0.05, 0.2])
def test_padded_bank_bit_identical_under_bitflip(rate):
    assert_padded_matches_loop([0, 0, 3, 5], bitflip_rate=rate)


def test_active_all_true_normalizes_to_maskless():
    # A fully-bound template must share its jit signature with active=None.
    assert executor._normalize_active(None, 3) is None
    assert executor._normalize_active([True, True, True], 3) is None
    assert executor._normalize_active([True, False, True], 3) == \
        (True, False, True)
    with pytest.raises(ValueError, match="active"):
        executor._normalize_active([True], 3)


def test_execute_bank_rejects_reference_backend():
    template = compile_bank_template([compile_plan(MUL)])
    with pytest.raises(ValueError, match="reference"):
        executor.execute_bank(template, [{"a": jnp.float32(0.5),
                                          "b": jnp.float32(0.5)}],
                              KEY, BL, backend="reference")


# --------------------------------- templates --------------------------------------

def test_template_pads_counts_to_pow2_and_total_with_identity():
    p_mul, p_sqrt = compile_plan(MUL), compile_plan(SQRT)
    members = template_members([p_mul, p_mul, p_mul, p_sqrt], n_slots=8)
    assert members.count(p_mul) == 4          # 3 -> 4 (power of two)
    assert members.count(p_sqrt) == 1
    assert members.count(identity_plan()) == 3
    assert len(members) == 8


def test_template_is_canonical_across_arrival_order():
    p_mul, p_sqrt, p_div = (compile_plan(MUL), compile_plan(SQRT),
                            compile_plan(DIV))
    t1 = compile_bank_template([p_mul, p_sqrt, p_mul, p_div], n_slots=8)
    t2 = compile_bank_template([p_div, p_mul, p_mul, p_sqrt], n_slots=8)
    assert t1 is t2                           # same bucket -> same BankPlan
    # Counts that pad to the same power of two share the bucket: 3 and 4
    # muls both occupy a 4-slot structure group.
    t3 = compile_bank_template([p_mul] * 3 + [p_sqrt, p_div], n_slots=8)
    t4 = compile_bank_template([p_mul] * 4 + [p_sqrt, p_div], n_slots=8)
    assert t3 is t4


def test_template_bucket_counts():
    assert [bucket_count(n) for n in (1, 2, 3, 4, 5, 9)] == [1, 2, 4, 4, 8, 16]


def test_identity_plan_is_inert_singleton():
    ip = identity_plan()
    assert ip is identity_plan()
    assert ip.is_identity and ip.n_passes == 0 and not ip.outputs


def test_merged_pass_count_matches_bank():
    plans = [compile_plan(n) for n, _ in POOL]
    template = compile_bank_template(plans, n_slots=8)
    assert merged_pass_count(list(template.members)) == template.n_passes


# ------------------------------- arch accounting ----------------------------------

def test_evaluate_bank_plan_reports_padding_overhead():
    p_mul, p_exp = compile_plan(MUL), compile_plan(EXP)
    template = compile_bank_template([p_mul, p_mul, p_mul, p_exp], n_slots=8)
    # Bind only the three mul requests: exp's slot is padding this batch.
    active = [False] * template.n_members
    bound = 0
    for s, m in enumerate(template.members):
        if m is p_mul and bound < 3:
            active[s] = True
            bound += 1
    cost = arch.evaluate_bank_plan(template, arch.StochIMCConfig(),
                                   active=active)
    assert cost.active_members == 3
    assert cost.active_passes == merged_pass_count([p_mul])
    assert cost.padding_overhead_passes == \
        template.n_passes - cost.active_passes > 0
    assert 0.0 < cost.padding_overhead_frac < 1.0
    # Default accounting (no mask) excludes identity pads from "active".
    cost_all = arch.evaluate_bank_plan(template, arch.StochIMCConfig())
    assert cost_all.active_members == template.n_members - \
        template.n_identity_members
    assert cost_all.padding_overhead_passes == 0


# --------------------------------- BankServer -------------------------------------

def test_bank_server_bit_identical_to_per_request_execute():
    server = BankServer(max_slots=4, window_s=None)
    keys = jax.random.split(jax.random.key(3), 8)
    reqs = [circuit_request(MUL, {"a": jnp.float32(0.3),
                                  "b": jnp.float32(0.7)}, keys[0]),
            circuit_request(MUL, {"a": jnp.asarray([0.2, 0.8], jnp.float32),
                                  "b": jnp.full((2,), 0.5, jnp.float32)},
                            keys[1]),
            circuit_request(SQRT, {"a": jnp.float32(0.6)}, keys[2]),
            circuit_request(DIV, {"a": jnp.float32(0.4),
                                  "b": jnp.float32(0.4)}, keys[3])]
    results = server.serve(reqs)
    for r, req in enumerate(reqs):
        ref = executor.execute_value(req.net, req.values, req.key,
                                     req.bitstream_length)
        assert set(results[r]) == set(ref)
        for o in ref:
            np.testing.assert_array_equal(np.asarray(results[r][o]),
                                          np.asarray(ref[o]))


def test_bank_server_buckets_hit_across_shuffled_waves():
    server = BankServer(max_slots=8, window_s=None)
    keys = jax.random.split(jax.random.key(4), 16)

    def wave(order, key_off):
        reqs = []
        for j, (net, vals) in enumerate(order):
            vals = {k: jnp.float32(v) for k, v in vals.items()}
            reqs.append(circuit_request(net, vals, keys[key_off + j]))
        return server.serve(reqs)

    base = [POOL[0], POOL[0], POOL[0], POOL[3], POOL[5]]
    wave(base, 0)
    assert server.stats()["bucket_hit_rate"] == 0.0   # cold first batch
    wave(list(reversed(base)), 5)                     # same multiset, shuffled
    wave(base, 10)                                    # repeat traffic mix
    stats = server.stats()
    assert stats["n_batches"] == 3
    assert stats["bucket_hits"] == 2
    # mul pads 3 -> 4 and the 6-member template pads to 8 total slots.
    assert stats["padding_waste"] > 0.0
    assert stats["identity_slots"] > 0


def test_bank_server_mixed_bitstream_lengths_split_batches():
    server = BankServer(max_slots=8, window_s=None)
    keys = jax.random.split(jax.random.key(6), 4)
    reqs = [circuit_request(MUL, {"a": jnp.float32(0.4),
                                  "b": jnp.float32(0.6)}, keys[0], 256),
            circuit_request(MUL, {"a": jnp.float32(0.4),
                                  "b": jnp.float32(0.6)}, keys[1], 512)]
    res = server.serve(reqs)
    assert server.stats()["n_batches"] == 2           # bl is a static split
    for r, req in enumerate(reqs):
        ref = executor.execute_value(req.net, req.values, req.key,
                                     req.bitstream_length)
        for o in ref:
            np.testing.assert_array_equal(np.asarray(res[r][o]),
                                          np.asarray(ref[o]))


def test_bank_server_max_slots_triggers_flush_and_tickets_resolve():
    server = BankServer(max_slots=2, window_s=None)
    keys = jax.random.split(jax.random.key(7), 3)
    t1 = server.submit(circuit_request(MUL, {"a": jnp.float32(0.1),
                                             "b": jnp.float32(0.9)}, keys[0]))
    assert not t1.done()
    t2 = server.submit(circuit_request(MUL, {"a": jnp.float32(0.2),
                                             "b": jnp.float32(0.8)}, keys[1]))
    assert t1.done() and t2.done()                    # max_slots reached
    t3 = server.submit(circuit_request(SQRT, {"a": jnp.float32(0.3)},
                                       keys[2]))
    assert not t3.done()
    out = t3.result()                                 # result() flushes
    ref = executor.execute_value(SQRT, {"a": jnp.float32(0.3)}, keys[2], 256)
    np.testing.assert_array_equal(np.asarray(out["out"]),
                                  np.asarray(ref["out"]))
    assert t3.latency_s is not None and t3.latency_s >= 0.0


def test_bank_server_mixed_batch_shape_declarations_in_one_batch():
    # Regression: same-structure requests with and without a declared
    # batch_shape share a batch; the canonical-order sort must not compare
    # None against a tuple.
    server = BankServer(max_slots=4, window_s=None)
    keys = jax.random.split(jax.random.key(14), 2)
    reqs = [circuit_request(SQRT, {"a": jnp.float32(0.4)}, keys[0]),
            circuit_request(SQRT, {"a": jnp.full((3,), 0.6, jnp.float32)},
                            keys[1], batch_shape=(3,))]
    res = server.serve(reqs)
    for r, req in enumerate(reqs):
        ref = executor.execute_value(req.net, req.values, req.key, 256,
                                     batch_shape=req.batch_shape)
        for o in ref:
            np.testing.assert_array_equal(np.asarray(res[r][o]),
                                          np.asarray(ref[o]))


def test_bank_server_max_slots_flushes_only_the_filled_group():
    # Regression: one group reaching max_slots must not force other groups'
    # partial batches out early (they keep accumulating toward their own
    # triggers).
    server = BankServer(max_slots=2, window_s=None)
    keys = jax.random.split(jax.random.key(13), 3)
    t_slow = server.submit(circuit_request(MUL, {"a": jnp.float32(0.2),
                                                 "b": jnp.float32(0.4)},
                                           keys[0], 512))
    server.submit(circuit_request(MUL, {"a": jnp.float32(0.3),
                                        "b": jnp.float32(0.5)}, keys[1], 256))
    t_256b = server.submit(circuit_request(MUL, {"a": jnp.float32(0.6),
                                                 "b": jnp.float32(0.7)},
                                           keys[2], 256))
    assert t_256b.done()                      # bl=256 group hit max_slots
    assert not t_slow.done()                  # bl=512 group still queued
    ref = executor.execute_value(MUL, {"a": jnp.float32(0.2),
                                       "b": jnp.float32(0.4)}, keys[0], 512)
    np.testing.assert_array_equal(np.asarray(t_slow.result()["out"]),
                                  np.asarray(ref["out"]))


def test_bank_server_window_zero_flushes_on_submit():
    # window_s=0.0: a queued request never waits behind another submit; the
    # synchronous engine evaluates the window at submit time.
    server = BankServer(max_slots=8, window_s=0.0)
    key = jax.random.key(12)
    t = server.submit(circuit_request(MUL, {"a": jnp.float32(0.3),
                                            "b": jnp.float32(0.5)}, key))
    assert t.done()
    ref = executor.execute_value(MUL, {"a": jnp.float32(0.3),
                                       "b": jnp.float32(0.5)}, key, 256)
    np.testing.assert_array_equal(np.asarray(t.result()["out"]),
                                  np.asarray(ref["out"]))


def test_bank_server_bitflip_requests_thread_flip_keys():
    server = BankServer(max_slots=4, window_s=None)
    keys = jax.random.split(jax.random.key(8), 2)
    fks = jax.random.split(jax.random.key(9), 2)
    reqs = [circuit_request(MUL, {"a": jnp.float32(0.3),
                                  "b": jnp.float32(0.7)}, keys[i],
                            bitflip_rate=0.1, flip_key=fks[i])
            for i in range(2)]
    res = server.serve(reqs)
    for r, req in enumerate(reqs):
        ref = executor.execute_value(req.net, req.values, req.key, 256,
                                     bitflip_rate=0.1, flip_key=fks[r])
        for o in ref:
            np.testing.assert_array_equal(np.asarray(res[r][o]),
                                          np.asarray(ref[o]))
    with pytest.raises(ValueError, match="flip_key"):
        server.submit(circuit_request(MUL, {"a": jnp.float32(0.1),
                                            "b": jnp.float32(0.2)},
                                      keys[0], bitflip_rate=0.1))


def test_app_request_served_matches_appnet_stochastic():
    from repro.core import apps
    server = BankServer(max_slots=4, window_s=None)
    keys = jax.random.split(jax.random.key(10), 2)
    p = np.full((16, 6), 0.9)
    res = server.serve([app_request("ol", keys[0], 256, p=p),
                        app_request("ol", keys[1], 256, p=p * 0.8)])
    ref = apps.appnet_stochastic("ol", keys[0], bl=256,
                                 net=app_netlist("ol"), p=p)
    for o in ref:
        np.testing.assert_array_equal(np.asarray(res[0][o]),
                                      np.asarray(ref[o]))


# ----------------------------------- LRU caches -----------------------------------

def test_plan_cache_lru_bounded_with_evictions_reported():
    caps = set_cache_caps()
    before = cache_info()["plan_evictions"]
    try:
        set_cache_caps(plans=2)
        nets = [circuits.sc_exp(c=0.1 * (i + 1)) for i in range(5)]
        plans = [compile_plan(n) for n in nets]
        info = cache_info()
        assert info["plans"] <= 2
        assert info["plan_evictions"] >= before + 3
        # Live (memoized) plans still intern per netlist instance.
        assert compile_plan(nets[-1]) is plans[-1]
    finally:
        set_cache_caps(plans=caps["plans"], banks=caps["banks"])


def test_bank_cache_lru_bounded_with_evictions_reported():
    caps = set_cache_caps()
    before = cache_info()["bank_evictions"]
    try:
        set_cache_caps(banks=1)
        p = compile_plan(MUL)
        for n_slots in (2, 4, 8, 16):
            compile_bank_template([p], n_slots=n_slots)
        info = cache_info()
        assert info["banks"] <= 1
        assert info["bank_evictions"] >= before + 3
    finally:
        set_cache_caps(plans=caps["plans"], banks=caps["banks"])


def test_cache_info_reports_caps_and_eviction_counters():
    info = cache_info()
    for k in ("plans", "banks", "plan_cap", "bank_cap", "plan_evictions",
              "bank_evictions", "and_fused", "not_absorbed"):
        assert k in info


# ------------------------------ NOT-directed fusion -------------------------------

def test_and_folding_collapses_multiply_and_exp_ladder():
    p_mul = compile_plan(circuits.sc_multiply())
    assert p_mul.n_passes == 1 and p_mul.n_fused_and == 1
    assert p_mul.levels[0][0].op == "AND"
    # The exp Horner ladder: every NOT(NAND(A_k, C_k)) pair folds.
    p_exp = compile_plan(circuits.sc_exp())
    assert p_exp.n_fused_and == 4
    assert p_exp.n_passes < p_exp.n_gates - p_exp.n_fused_and


@pytest.mark.parametrize("c", [1.0, 0.8])
def test_fused_exp_horner_bit_identical(c):
    net = circuits.sc_exp(c)
    vals = {"a": jnp.float32(0.5)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_not_absorption_reduces_divider_passes_bit_identically():
    net = circuits.sc_scaled_div()
    plan = compile_plan(net)
    assert plan.n_not_absorbed >= 1
    assert plan.n_passes == 1                 # MUX fusion + NOT absorption
    vals = {"a": jnp.float32(0.4), "b": jnp.float32(0.4)}
    ref = executor.execute(net, vals, KEY, 1024, backend="reference")
    cmp = executor.execute(net, vals, KEY, 1024)
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_not_absorption_keeps_observable_nots():
    from repro.core.gates import Netlist
    net = Netlist("obs_not")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    net.add_gate("NOT", [a], "na")
    net.add_gate("NAND", ["na", b], "out")
    net.set_outputs(["out", "na"])            # the NOT is observable
    plan = compile_plan(net)
    assert plan.n_not_absorbed == 0
    vals = {"a": jnp.float32(0.3), "b": jnp.float32(0.8)}
    ref = executor.execute(net, vals, KEY, 512, backend="reference")
    cmp = executor.execute(net, vals, KEY, 512)
    assert set(cmp) == {"out", "na"}
    for o in ref:
        assert (ref[o] == cmp[o]).all()


def test_fusion_disabled_without_fuse_mux():
    plan = compile_plan(circuits.sc_multiply(), fuse_mux=False)
    assert plan.n_fused_and == plan.n_not_absorbed == 0
    assert plan.n_passes == 2


# --------------------------------- property test ----------------------------------

if HAVE_HYPOTHESIS:
    member_sets = st.lists(st.integers(min_value=0, max_value=len(POOL) - 1),
                           min_size=1, max_size=6)
    batches = st.sampled_from([None, (2,), (3,)])
    key_modes = st.sampled_from(["batched", "legacy"])
    rates = st.sampled_from([0.0, 0.1])
else:                                          # placeholders; @given skips
    member_sets = batches = key_modes = rates = None


@settings(max_examples=20, deadline=None)
@given(member_sets, batches, key_modes, rates)
def test_property_padded_bank_bit_identical(members, batch, key_mode, rate):
    """Padded-bank execution == looped execute for random member subsets,
    batch shapes, both key modes, including bitflip injection."""
    assert_padded_matches_loop(members, batch=batch, key_mode=key_mode,
                               bitflip_rate=rate, bl=128)
