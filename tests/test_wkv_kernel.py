"""WKV Pallas kernel vs (a) the chunked jnp oracle, (b) a brute-force
sequential recurrence — the ground truth the chunked algebra must equal."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.kernels.ref_wkv import wkv_ref
from repro.kernels.wkv import wkv


def _inputs(key, b, s, h, hd):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, hd)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.3)
    u = jax.random.normal(ks[4], (h, hd)) * 0.5
    return r, k, v, lw, u


def brute_force(r, k, v, log_w, u):
    """out_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ); S_t = diag(w_t) S_{t-1} + k_t v_tᵀ."""
    b, s, h, hd = r.shape
    out = np.zeros((b, s, h, hd), np.float64)
    r, k, v, w = (np.asarray(t, np.float64) for t in (r, k, v, np.exp(log_w)))
    u = np.asarray(u, np.float64)
    for bi in range(b):
        for hi in range(h):
            S = np.zeros((hd, hd))
            for t in range(s):
                kv = np.outer(k[bi, t, hi], v[bi, t, hi])
                out[bi, t, hi] = r[bi, t, hi] @ (S + u[hi][:, None] * kv)
                S = w[bi, t, hi][:, None] * S + kv
    return out


def test_kernel_matches_brute_force():
    r, k, v, lw, u = _inputs(jax.random.key(0), 1, 64, 2, 8)
    got = wkv(r, k, v, lw, u, chunk=16, interpret=True)
    want = brute_force(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4)


def test_kernel_matches_ref_exactly_same_chunking():
    r, k, v, lw, u = _inputs(jax.random.key(1), 2, 128, 3, 16)
    got = wkv(r, k, v, lw, u, chunk=32, interpret=True)
    ref = wkv_ref(r, k, v, lw, u, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_chunk_size_invariance():
    r, k, v, lw, u = _inputs(jax.random.key(2), 1, 96, 2, 8)
    a = wkv(r, k, v, lw, u, chunk=16, interpret=True)
    c = wkv(r, k, v, lw, u, chunk=48, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-4)


@settings(max_examples=6)
@given(st.sampled_from([16, 32]), st.integers(1, 3), st.sampled_from([8, 16]))
def test_kernel_vs_ref_shape_sweep(chunk, h, hd):
    r, k, v, lw, u = _inputs(jax.random.key(7), 1, chunk * 3, h, hd)
    got = wkv(r, k, v, lw, u, chunk=chunk, interpret=True)
    ref = wkv_ref(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-3)


def test_matches_production_rwkv_path():
    """The models/recurrent.py chunked scan computes the same WKV values
    (pre-groupnorm) — cross-validate via identical per-step math."""
    r, k, v, lw, u = _inputs(jax.random.key(3), 1, 64, 2, 16)
    got = wkv(r, k, v, lw, u, chunk=32, interpret=True)
    want = brute_force(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4)
    # decode-step recurrence agrees at t=0: out_0 = r0 · (u ⊙ k0 v0ᵀ)
    first = np.einsum("bhk,bhk,bhv->bhv", np.asarray(r[:, 0], np.float64),
                      np.asarray(u, np.float64)[None] * np.asarray(k[:, 0], np.float64),
                      np.asarray(v[:, 0], np.float64))
    np.testing.assert_allclose(np.asarray(got[:, 0]), first, atol=1e-4)
