"""Packed-bitstream layer tests: generation statistics, packing, gate algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro.core import bitstream as bs

KEY = jax.random.key(42)
BL = 4096
TOL = 4.0 / np.sqrt(BL)  # ~4 sigma of Bernoulli noise


def val(words):
    return float(bs.to_value(words, BL))


def test_pack_unpack_roundtrip():
    w = jax.random.bits(KEY, (5, 7), dtype=jnp.uint32)
    assert (bs.pack_bits(bs.unpack_bits(w)) == w).all()


def test_generate_value_matches_probability():
    p = jnp.asarray([0.0, 0.1, 0.25, 0.5, 0.9, 1.0], jnp.float32)
    streams = bs.generate(KEY, p, BL)
    got = bs.to_value(streams, BL)
    np.testing.assert_allclose(np.asarray(got), np.asarray(p), atol=TOL)
    # Degenerate endpoints must be (nearly) deterministic.
    assert float(got[0]) == 0.0
    assert float(got[-1]) >= 1.0 - 2.0 / BL


def test_popcount_matches_numpy():
    w = jax.random.bits(KEY, (3, 8), dtype=jnp.uint32)
    ref = np.array([[bin(int(x)).count("1") for x in row] for row in np.asarray(w)])
    assert (np.asarray(jax.lax.population_count(w)) == ref).all()
    assert (np.asarray(bs.popcount(w)) == ref.sum(-1)).all()


@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_and_multiplies(pa, pb):
    a = bs.generate(jax.random.key(1), jnp.float32(pa), BL)
    b = bs.generate(jax.random.key(2), jnp.float32(pb), BL)
    assert abs(val(a & b) - pa * pb) < TOL


@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_mux_scaled_adds(pa, pb):
    a = bs.generate(jax.random.key(3), jnp.float32(pa), BL)
    b = bs.generate(jax.random.key(4), jnp.float32(pb), BL)
    s = bs.generate(jax.random.key(5), jnp.float32(0.5), BL)
    assert abs(val(bs.mux(a, b, s)) - (pa + pb) / 2) < TOL


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_correlated_xor_is_abs_difference(pa, pb):
    a, b = bs.generate_correlated(jax.random.key(6), [jnp.float32(pa), jnp.float32(pb)], BL)
    assert abs(val(a ^ b) - abs(pa - pb)) < TOL


def test_independent_xor_is_not_abs_difference():
    # Sanity: independence breaks the |a-b| identity (value = a(1-b)+b(1-a)).
    a = bs.generate(jax.random.key(7), jnp.float32(0.5), BL)
    b = bs.generate(jax.random.key(8), jnp.float32(0.5), BL)
    assert abs(val(a ^ b) - 0.5) < TOL        # not 0.0


def test_not_complements():
    a = bs.generate(KEY, jnp.float32(0.3), BL)
    assert abs(val(~a) - 0.7) < TOL


def test_maj3_identity():
    ws = [jax.random.bits(jax.random.key(i), (4,), dtype=jnp.uint32) for i in range(3)]
    got = bs.maj3(*ws)
    ref = (ws[0] & ws[1]) | (ws[0] & ws[2]) | (ws[1] & ws[2])
    assert (got == ref).all()


def test_maj5_matches_bit_count():
    ws = [jax.random.bits(jax.random.key(10 + i), (2,), dtype=jnp.uint32) for i in range(5)]
    got = bs.unpack_bits(bs.maj5(*ws))
    bits = sum(bs.unpack_bits(w).astype(np.int32) for w in ws)
    assert (np.asarray(got) == (np.asarray(bits) >= 3)).all()


def test_bad_bitstream_length_rejected():
    with pytest.raises(ValueError):
        bs.n_words(100)


def test_threshold_top_of_range_is_exact():
    # Regression: float32 rounds 2^32 - 1 up to 2^32, so the old float-side
    # minimum was a no-op and p=1.0 hit an out-of-range float->uint32 cast
    # that only "worked" because XLA:CPU saturates (undefined elsewhere).
    # The integer-side clamp must pin the top of the range on every backend.
    assert int(bs._threshold_u32(jnp.float32(1.0))) == 0xFFFFFFFF
    assert int(bs._threshold_u32(jnp.float32(1.0 - 2.0 ** -32))) == 0xFFFFFFFF
    assert int(bs._threshold_u32(jnp.float32(0.0))) == 0
    # Monotone and in-range across the interior.
    ps = jnp.linspace(0.0, 1.0, 257, dtype=jnp.float32)
    th = np.asarray(bs._threshold_u32(ps), dtype=np.uint64)
    assert (np.diff(th.astype(np.int64)) >= 0).all()
    assert th[-1] == 0xFFFFFFFF


def test_p_one_decodes_to_one():
    for bl in (32, 1024):
        v = bs.to_value(bs.generate(KEY, jnp.float32(1.0), bl), bl)
        assert float(v) >= 1.0 - 2.0 / bl
    near = jnp.float32(1.0 - 2.0 ** -32)   # rounds to 1.0 in float32
    v = bs.to_value(bs.generate(KEY, near, 1024), 1024)
    assert float(v) >= 1.0 - 2.0 / 1024
