"""STT-MRAM fault models (core/faults.py) through every execution path.

Pins for the PR-8 fault taxonomy:

  * ``FaultModel(flip_rate=r)`` is bit-identical to the legacy
    ``bitflip_rate=r`` path (the raw-fkey transient discipline);
  * compiled == reference under a composite model, both key_modes;
  * faulty runs are deterministic in ``flip_key`` (same key -> same bits,
    different key -> different bits) and a null model IS the clean path;
  * rate extremes pin the mask semantics: all-stuck-0 reads zero,
    all-stuck-1 reads one, sa1 wins over sa0;
  * static components (``dead_cols`` spans, ``sa0/sa1_words``) need no key
    and mask exactly the declared cells;
  * wear accounting (``worn``) is monotone and saturates at rate 1;
  * bank/template execution and serving reproduce standalone bits;
  * validation: mutual exclusion with ``bitflip_rate``, required
    ``flip_key``, malformed models raise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import bitstream as bs
from repro.core import circuits, executor
from repro.core.executor import ExecOptions, ExecRequest
from repro.core.faults import (FaultModel, apply_faults, injecting,
                               normalize_fault_model)
from repro.serve import BankServer, circuit_request

KEY = jax.random.key(7)
FLIP = jax.random.key(77)
BL = 256
W = BL // 32

MUL = circuits.sc_multiply()
SADD = circuits.sc_scaled_add()
DIV = circuits.sc_scaled_div()
VALUES = {"a": 0.3, "b": 0.7}

COMPOSITE = FaultModel(flip_rate=0.02, stuck0_rate=0.03, stuck1_rate=0.01,
                       dead_row_rate=0.05)


def tree_eq(a, b) -> bool:
    if sorted(a) != sorted(b):
        return False
    return all(bool(jnp.array_equal(a[k], b[k])) for k in a)


# ------------------------- model construction / views -------------------------


def test_null_model_normalizes_to_none():
    assert normalize_fault_model(None) is None
    assert normalize_fault_model(FaultModel()) is None
    assert normalize_fault_model(FaultModel(flip_rate=0.0)) is None
    m = FaultModel(stuck0_rate=0.1)
    assert normalize_fault_model(m) is m


def test_model_is_hashable_and_frozen():
    m = FaultModel(flip_rate=0.1, dead_cols=((0, 4),))
    assert hash(m) == hash(FaultModel(flip_rate=0.1, dead_cols=((0, 4),)))
    with pytest.raises(dataclasses.FrozenInstanceError):
        m.flip_rate = 0.2


def test_needs_keys_vs_static_only():
    assert FaultModel(flip_rate=0.1).needs_keys
    assert FaultModel(stuck0_rate=0.1).needs_keys
    assert FaultModel(dead_row_rate=0.1).needs_keys
    static = FaultModel(dead_cols=((0, 8),), sa1_words=(1,) * W)
    assert not static.needs_keys
    assert not static.is_null


def test_wear_is_monotone_and_saturates():
    m = FaultModel(stuck0_rate=0.1, wear_stuck_per_pass=0.05)
    assert m.effective_stuck0 == pytest.approx(0.1)
    worn = m.worn(3)
    assert worn.wear_passes == 3
    assert worn.effective_stuck0 == pytest.approx(0.25)
    assert worn.worn(2).wear_passes == 5
    assert m.worn(100).effective_stuck0 == 1.0   # saturates at a full array
    assert m.wear_passes == 0                    # worn() never mutates


def test_model_validation_errors():
    with pytest.raises(ValueError, match="flip_rate"):
        FaultModel(flip_rate=1.5)
    with pytest.raises(ValueError, match="dead_cols"):
        FaultModel(dead_cols=((4, 2),))
    with pytest.raises(ValueError, match="sa0_words"):
        FaultModel(sa0_words=(1 << 40,))
    with pytest.raises(ValueError, match="wear_passes"):
        FaultModel(wear_passes=-1)
    with pytest.raises(TypeError, match="FaultModel"):
        normalize_fault_model(0.1)


# ------------------------------ mask semantics --------------------------------


def test_apply_faults_null_model_is_flip_bits():
    words = bs.generate(KEY, jnp.float32(0.5), BL)
    from repro.core import sc_ops
    got = apply_faults(FLIP, words, 0.1, None)
    assert jnp.array_equal(got, sc_ops.flip_bits(FLIP, words, 0.1))


def test_stuck0_rate_one_reads_zero():
    words = bs.generate(KEY, jnp.float32(0.9), BL)
    got = apply_faults(FLIP, words, 0.0, FaultModel(stuck0_rate=1.0))
    assert int(jnp.sum(got)) == 0


def test_stuck1_rate_one_reads_one():
    words = bs.generate(KEY, jnp.float32(0.1), BL)
    got = apply_faults(FLIP, words, 0.0, FaultModel(stuck1_rate=1.0))
    assert bool(jnp.all(got == jnp.uint32(0xFFFFFFFF)))


def test_sa1_wins_over_sa0():
    words = bs.generate(KEY, jnp.float32(0.5), BL)
    full = (0xFFFFFFFF,) * W
    m = FaultModel(sa0_words=full, sa1_words=full)
    got = apply_faults(FLIP, words, 0.0, m)
    assert bool(jnp.all(got == jnp.uint32(0xFFFFFFFF)))


def test_dead_cols_mask_exact_bits():
    words = jnp.full((W,), jnp.uint32(0xFFFFFFFF))
    got = np.asarray(apply_faults(FLIP, words, 0.0,
                                  FaultModel(dead_cols=((0, 3), (40, 42)))))
    bits = np.asarray(bs.unpack_bits(jnp.asarray(got))).reshape(-1)
    dead = {0, 1, 2, 40, 41}
    assert all(int(bits[b]) == (0 if b in dead else 1) for b in range(BL))


def test_sa_words_length_mismatch_raises():
    words = jnp.zeros((W,), jnp.uint32)
    with pytest.raises(ValueError, match="sa0_words"):
        apply_faults(FLIP, words, 0.0, FaultModel(sa0_words=(1, 2)))
    with pytest.raises(ValueError, match="sa1_words"):
        apply_faults(FLIP, words, 0.0, FaultModel(sa1_words=(1, 2)))


def test_dead_row_rate_one_kills_every_stream():
    words = bs.generate(KEY, jnp.full((5,), 0.8), BL)
    got = apply_faults(FLIP, words, 0.0, FaultModel(dead_row_rate=1.0))
    assert int(jnp.sum(got)) == 0


# ------------------------- executor-level bit identity ------------------------


@pytest.mark.parametrize("key_mode", ["batched", "legacy"])
@pytest.mark.parametrize("rate", [0.05, 0.2])
def test_flip_rate_model_matches_legacy_bitflip(key_mode, rate):
    legacy = executor.execute(MUL, VALUES, KEY, BL, bitflip_rate=rate,
                              flip_key=FLIP, key_mode=key_mode)
    model = executor.execute(MUL, VALUES, KEY, BL, flip_key=FLIP,
                             key_mode=key_mode,
                             fault_model=FaultModel(flip_rate=rate))
    assert tree_eq(legacy, model)


@pytest.mark.parametrize("key_mode", ["batched", "legacy"])
@pytest.mark.parametrize("net", [MUL, SADD, DIV],
                         ids=lambda n: n.name)
def test_compiled_matches_reference_under_faults(key_mode, net):
    kw = dict(flip_key=FLIP, key_mode=key_mode, fault_model=COMPOSITE)
    compiled = executor.execute(net, VALUES, KEY, BL, backend="compiled", **kw)
    reference = executor.execute(net, VALUES, KEY, BL, backend="reference",
                                 **kw)
    assert tree_eq(compiled, reference)


def test_faulty_run_deterministic_in_flip_key():
    a = executor.execute(MUL, VALUES, KEY, BL, flip_key=FLIP,
                         fault_model=COMPOSITE)
    b = executor.execute(MUL, VALUES, KEY, BL, flip_key=FLIP,
                         fault_model=COMPOSITE)
    c = executor.execute(MUL, VALUES, KEY, BL,
                         flip_key=jax.random.key(123456),
                         fault_model=COMPOSITE)
    assert tree_eq(a, b)
    assert not tree_eq(a, c)


def test_null_model_is_clean_path():
    clean = executor.execute(MUL, VALUES, KEY, BL)
    null = executor.execute(MUL, VALUES, KEY, BL,
                            fault_model=FaultModel())
    assert tree_eq(clean, null)


def test_static_model_needs_no_flip_key():
    m = FaultModel(dead_cols=((0, 32),))
    out = executor.execute(MUL, VALUES, KEY, BL, fault_model=m)
    clean = executor.execute(MUL, VALUES, KEY, BL)
    assert sorted(out) == sorted(clean)
    # The first dead word zeroes 32 of 256 positions on every stream.
    assert int(np.asarray(out["out"])[..., 0]) == 0


def test_stuck_faults_degrade_value():
    v_clean = executor.execute_value(DIV, VALUES, KEY, BL)["Q_next"]
    v_fault = executor.execute_value(
        DIV, VALUES, KEY, BL, flip_key=FLIP,
        fault_model=FaultModel(stuck0_rate=0.3))["Q_next"]
    assert float(v_fault) < float(v_clean)


def test_mutual_exclusion_and_missing_key_raise():
    with pytest.raises(ValueError, match="not both"):
        executor.execute(MUL, VALUES, KEY, BL, bitflip_rate=0.1,
                         flip_key=FLIP, fault_model=COMPOSITE)
    with pytest.raises(ValueError, match="requires"):
        executor.execute(MUL, VALUES, KEY, BL, fault_model=COMPOSITE)


# ------------------------- bank / serving bit identity ------------------------


def test_bank_run_matches_standalone_under_faults():
    reqs = [ExecRequest(MUL, {"a": 0.2 + 0.1 * i, "b": 0.6},
                        jax.random.key(i),
                        ExecOptions(bitstream_length=BL, flip_key=FLIP,
                                    fault_model=COMPOSITE))
            for i in range(3)]
    merged = executor.run(reqs)
    for req, got in zip(reqs, merged):
        assert tree_eq(got, executor.run(req))


def test_served_faulty_requests_match_standalone():
    model = FaultModel(flip_rate=0.05, stuck0_rate=0.05)
    with BankServer(max_slots=4) as srv:
        reqs = [circuit_request(MUL, {"a": 0.1 * (i + 1), "b": 0.5},
                                jax.random.key(i), BL,
                                flip_key=jax.random.key(1000 + i),
                                fault_model=model)
                for i in range(4)]
        outs = [t.result() for t in [srv.submit(r) for r in reqs]]
    for req, got in zip(reqs, outs):
        ref = executor.run(req, options=dataclasses.replace(
            req.options, decode=True))
        assert tree_eq(got, ref)


@given(st.floats(min_value=0.0, max_value=1.0),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=15)
def test_property_flip_rate_model_equals_legacy(rate, frac):
    """Any flip rate: the model path reproduces the legacy path bit-exactly."""
    values = {"a": float(frac), "b": 0.5}
    legacy = executor.execute(MUL, values, KEY, BL, bitflip_rate=float(rate),
                              flip_key=FLIP)
    model = executor.execute(MUL, values, KEY, BL, flip_key=FLIP,
                             fault_model=FaultModel(flip_rate=float(rate)))
    assert tree_eq(legacy, model)


def test_injecting_predicate():
    assert not injecting(0.0, None)
    assert injecting(0.1, None)
    assert injecting(0.0, FaultModel(stuck0_rate=0.1))
    # normalize first: dispatch never sees a null model as "injecting".
    assert normalize_fault_model(FaultModel()) is None
