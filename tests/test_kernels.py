"""Per-kernel tests: Pallas (interpret mode) vs the pure-jnp ref.py oracle.

Every kernel uses the same counter-based RNG as its oracle, so equality is
*exact* (bit-for-bit), not approximate; statistical tests then check the SC
semantics against float math.  Hypothesis sweeps shapes/odd sizes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.common import gen_packed_bits, hash_u32, threshold_u32
from repro.kernels.packed_logic import packed_logic
from repro.kernels.popcount_tree import popcount_hier
from repro.kernels.sc_matmul import sc_matmul
from repro.kernels.sng import lane_seeds, sng_pack, sng_words

KEY = jax.random.key(0)


# ------------------------------- common.py ---------------------------------------

def test_hash_u32_is_deterministic_and_mixing():
    x = jnp.arange(1 << 16, dtype=jnp.uint32)
    h = hash_u32(x)
    # no collisions over consecutive counters (murmur3 finalizer is a bijection)
    assert len(np.unique(np.asarray(h))) == 1 << 16
    # bit balance: each output bit ~half set
    bits = np.unpackbits(np.asarray(h).view(np.uint8)).mean()
    assert abs(bits - 0.5) < 0.01


def test_threshold_endpoints():
    assert int(threshold_u32(jnp.float32(0.0))) == 0
    assert int(threshold_u32(jnp.float32(1.0))) == 0xFFFFFFFF


def test_gen_packed_bits_statistics():
    base = (jnp.arange(2048, dtype=jnp.uint32) * 32)
    words = gen_packed_bits(jnp.uint32(9), base, jnp.full((2048,), 0.3, jnp.float32))
    rate = float(jax.lax.population_count(words).sum()) / (2048 * 32)
    assert abs(rate - 0.3) < 0.01


# ------------------------------- sng kernel --------------------------------------

@settings(max_examples=10)
@given(st.integers(1, 300), st.sampled_from([32, 64, 128, 256]))
def test_sng_kernel_equals_ref_all_shapes(n, bl):
    p = jax.random.uniform(jax.random.key(n), (n,))
    k = sng_pack(p, bl, interpret=True)
    r = ref.sng_pack_ref(p, bl)
    assert (k == r).all()


def test_sng_values_match_probabilities():
    p = jnp.asarray([0.0, 0.2, 0.5, 0.8, 1.0], jnp.float32)
    words = sng_pack(p, 4096, interpret=True)
    got = jax.lax.population_count(words).sum(-1) / 4096.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(p), atol=0.05)


def test_sng_is_tiling_independent():
    p = jax.random.uniform(KEY, (100,))
    a = sng_pack(p, 128, block=256, interpret=True)
    b = sng_pack(p, 128, block=32, interpret=True)
    assert (a == b).all()


# --------------------------- batched stream-table sng -----------------------------

@settings(max_examples=10)
@given(st.integers(1, 24), st.integers(1, 40), st.sampled_from([32, 64, 128]))
def test_sng_words_pallas_equals_ref_all_shapes(n, b, bl):
    thr = threshold_u32(jax.random.uniform(jax.random.key(n * 100 + b), (n, b)))
    seeds = lane_seeds(jnp.uint32(5), jnp.arange(n, dtype=jnp.uint32))
    k = sng_words(seeds, thr, bl // 32, use_pallas=True, interpret=True)
    r = ref.sng_words_ref(seeds, thr, bl // 32)
    assert k.shape == (n, b, bl // 32)
    assert (k == r).all()


def test_sng_words_block_independent_and_equals_ref():
    thr = threshold_u32(jax.random.uniform(KEY, (3, 100)))
    seeds = lane_seeds(jnp.uint32(1), jnp.arange(3, dtype=jnp.uint32))
    a = sng_words(seeds, thr, 4, use_pallas=True, block_elems=256, interpret=True)
    b = sng_words(seeds, thr, 4, use_pallas=True, block_elems=17, interpret=True)
    assert (a == b).all()
    assert (a == ref.sng_words_ref(seeds, thr, 4)).all()


def test_sng_words_rows_independent_of_stacking():
    # A row's stream depends only on (seed, element, bit) — stacking more
    # rows alongside it must not change its bits (the property bank-level
    # generation relies on to stay bit-identical to per-member generation).
    thr = threshold_u32(jax.random.uniform(jax.random.key(3), (4, 16)))
    seeds = lane_seeds(jnp.uint32(2), jnp.arange(4, dtype=jnp.uint32))
    full = sng_words(seeds, thr, 8)
    solo = sng_words(seeds[2:3], thr[2:3], 8)
    assert (full[2] == solo[0]).all()


def test_sng_words_shared_lane_shares_uniforms():
    # Equal row seeds (one correlation group) => streams are threshold-nested:
    # wherever the lower-threshold row has a 1, the higher-threshold row must.
    thr = jnp.stack([threshold_u32(jnp.full((64,), 0.3, jnp.float32)),
                     threshold_u32(jnp.full((64,), 0.7, jnp.float32))])
    seeds = lane_seeds(jnp.uint32(4), jnp.zeros((2,), jnp.uint32))
    w = sng_words(seeds, thr, 8)
    assert (w[0] & ~w[1]).sum() == 0


# ----------------------------- packed logic --------------------------------------

@pytest.mark.parametrize("op,n_in", [("not", 1), ("and", 2), ("nand", 2),
                                     ("or", 2), ("nor", 2), ("xor", 2), ("mux", 3)])
def test_packed_logic_matches_ref(op, n_in):
    args = [jax.random.bits(jax.random.key(i), (16, 256), dtype=jnp.uint32)
            for i in range(n_in)]
    k = packed_logic(op, *args, interpret=True)
    r = ref.sc_eltwise_ref(op, *args)
    assert (k == r).all()


@settings(max_examples=10)
@given(st.integers(1, 40), st.integers(1, 300))
def test_packed_logic_odd_shapes(rows, words):
    a = jax.random.bits(jax.random.key(rows), (rows, words), dtype=jnp.uint32)
    b = jax.random.bits(jax.random.key(words), (rows, words), dtype=jnp.uint32)
    assert (packed_logic("nand", a, b, interpret=True)
            == ref.sc_eltwise_ref("nand", a, b)).all()


# ---------------------------- popcount tree --------------------------------------

@settings(max_examples=10)
@given(st.integers(1, 64), st.integers(1, 300))
def test_popcount_kernel_matches_ref(n, w):
    words = jax.random.bits(jax.random.key(n * 1000 + w), (n, w), dtype=jnp.uint32)
    k = popcount_hier(words, interpret=True)
    r = ref.popcount_hier_ref(words, group=16)
    exact = np.array([[bin(int(x)).count("1") for x in row]
                      for row in np.asarray(words)]).sum(-1)
    assert (np.asarray(k) == exact).all()
    assert (np.asarray(r) == exact).all()


# ------------------------------ sc matmul ----------------------------------------

@settings(max_examples=8)
@given(st.integers(1, 24), st.integers(1, 48), st.integers(1, 48),
       st.sampled_from([32, 64, 128]))
def test_sc_matmul_kernel_equals_ref(m, k, n, bl):
    a = jax.random.uniform(jax.random.key(m), (m, k))
    w = jax.random.uniform(jax.random.key(n), (k, n))
    out_k = sc_matmul(a, w, bl, bm=8, bn=16, bk=16, interpret=True)
    out_r = ref.sc_matmul_ref(a, w, bl)
    assert (out_k == out_r).all()


def test_sc_matmul_tiling_independent():
    a = jax.random.uniform(jax.random.key(1), (16, 64))
    w = jax.random.uniform(jax.random.key(2), (64, 24))
    o1 = sc_matmul(a, w, 64, bm=4, bn=8, bk=16, interpret=True)
    o2 = sc_matmul(a, w, 64, bm=16, bn=24, bk=64, interpret=True)
    assert (o1 == o2).all()


def test_sc_matmul_unbiased_and_converges_with_bl():
    a = jax.random.uniform(jax.random.key(3), (8, 128))
    w = jax.random.uniform(jax.random.key(4), (128, 8))
    exact = a @ w
    errs = []
    for bl in (32, 128, 512):
        approx = ref.sc_matmul_ref(a, w, bl)
        errs.append(float(jnp.abs(approx - exact).mean()))
    assert errs[2] < errs[0]                 # error shrinks with BL
    assert errs[2] / float(jnp.abs(exact).mean()) < 0.05


def test_ops_dispatch_paths_agree():
    a = jax.random.uniform(jax.random.key(5), (8, 32))
    w = jax.random.uniform(jax.random.key(6), (32, 8))
    assert (ops.sc_matmul(a, w, 64, use_pallas=True)
            == ops.sc_matmul(a, w, 64, use_pallas=False)).all()
    p = jax.random.uniform(jax.random.key(7), (50,))
    assert (ops.sng(p, 64, use_pallas=True) == ops.sng(p, 64, use_pallas=False)).all()
    thr = threshold_u32(jax.random.uniform(jax.random.key(8), (4, 20)))
    seeds = lane_seeds(jnp.uint32(3), jnp.arange(4, dtype=jnp.uint32))
    assert (ops.sng_table(seeds, thr, 64, use_pallas=True)
            == ops.sng_table(seeds, thr, 64, use_pallas=False)).all()
    words = jax.random.bits(KEY, (16, 8), dtype=jnp.uint32)
    assert (ops.stob_counts(words, use_pallas=True)
            == ops.stob_counts(words, use_pallas=False)).all()
