"""Reliability semantics of the serving engine (PR-8).

Pins for the fault-tolerant BankServer:

  * **backpressure** — ``max_queue`` bounds admission; ``"reject"`` fails
    the new ticket with ``RequestShed``, ``"shed_oldest"`` evicts the
    oldest queued request; both count in stats;
  * **deadlines** — ``deadline_ms`` fails the ticket with the *permanent*
    ``DeadlineExceeded`` (deliberately NOT a ``TimeoutError`` subclass:
    ``Ticket.result(timeout=...)`` raises ``TimeoutError`` and stays
    retryable);
  * **retry** — failed batches re-queue with backoff up to ``max_retries``;
    a successful retry is bit-identical to a clean single-shot run; past
    the budget the ORIGINAL exception (with a ``[BankServer]`` note)
    fails the ticket;
  * **quarantine** — consecutive device failures trip the breaker,
    in-flight work re-dispatches to healthy devices without consuming
    retry budget, the last healthy device is never quarantined, and a
    healed device is re-admitted after its probe passes;
  * **chaos** — a rotating-kill trace loses zero tickets and stays
    bit-identical;
  * **shutdown** — ``close()``/``__exit__`` drains every outstanding
    ticket (even while a device is quarantined); ``close(drain=False)``
    fails undispatched tickets with ``ServerClosed``.

Multi-device cases skip on single-device hosts; CI forces
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so they run there.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import circuits, executor
from repro.serve import (BankServer, DeadlineExceeded, RequestShed,
                         ServerClosed, circuit_request)

BL = 128
MUL = circuits.sc_multiply()

needs_multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 jax devices (CI sets "
           "--xla_force_host_platform_device_count=4)")


def req(i: int, **kw):
    return circuit_request(MUL, {"a": 0.1 + 0.05 * (i % 10), "b": 0.6},
                           jax.random.key(i), BL, **kw)


def ref(r):
    return executor.run(r, options=dataclasses.replace(r.options,
                                                       decode=True))


def tree_eq(a, b) -> bool:
    return sorted(a) == sorted(b) and \
        all(bool(jnp.array_equal(a[k], b[k])) for k in a)


class FailFirstN:
    """Injector failing the first ``n`` batch launches (probes pass)."""

    def __init__(self, n: int):
        self.remaining = n
        self.kills = 0

    def __call__(self, device, batch):
        if batch is None:
            return
        if self.remaining > 0:
            self.remaining -= 1
            self.kills += 1
            raise RuntimeError("injected launch failure")


class FailDeviceNth:
    """Fail the ``nth`` launch (0-based) seen on one specific device."""

    def __init__(self, device, nth: int = 1):
        self.device = device
        self.nth = nth
        self.seen = 0

    def __call__(self, device, batch):
        if batch is None or device != self.device:
            return
        i = self.seen
        self.seen += 1
        if i == self.nth:
            raise RuntimeError("injected device failure")


class FailDeviceWhile:
    """Fail every launch on ``device`` while ``self.down`` is True."""

    def __init__(self, device):
        self.device = device
        self.down = True

    def __call__(self, device, batch):
        if batch is not None and self.down and device == self.device:
            raise RuntimeError("device is down")


# ------------------------------- backpressure ---------------------------------


def test_reject_overload_fails_new_ticket():
    with BankServer(max_slots=8, max_queue=2, overload="reject") as srv:
        t0, t1 = srv.submit(req(0)), srv.submit(req(1))
        t2 = srv.submit(req(2))
        with pytest.raises(RequestShed):
            t2.result()
        assert srv.stats()["shed_requests"] == 1
        srv.flush()
        assert tree_eq(t0.result(), ref(req(0)))
        assert tree_eq(t1.result(), ref(req(1)))


def test_shed_oldest_evicts_queue_head():
    with BankServer(max_slots=8, max_queue=2,
                    overload="shed_oldest") as srv:
        t0 = srv.submit(req(0))
        srv.submit(req(1))
        t2 = srv.submit(req(2))          # evicts t0, admits t2
        with pytest.raises(RequestShed):
            t0.result()
        srv.flush()
        assert tree_eq(t2.result(), ref(req(2)))
        assert srv.stats()["shed_requests"] == 1


# --------------------------------- deadlines ----------------------------------


def test_deadline_exceeded_is_permanent_and_typed():
    with BankServer(max_slots=8) as srv:     # held: batch never forms
        t = srv.submit(req(0, deadline_ms=5.0))
        time.sleep(0.02)
        with pytest.raises(DeadlineExceeded):
            t.result()
        # Permanent: a second wait re-raises instead of retrying.
        with pytest.raises(DeadlineExceeded):
            t.result()
        assert srv.stats()["deadline_exceeded"] == 1
    assert not issubclass(DeadlineExceeded, TimeoutError)


def test_result_timeout_stays_retryable():
    # result() drives the engine, so a queued request simply executes; the
    # observable wait is a retry backoff window.  Fail the first launch
    # with a long backoff: the bounded wait raises TimeoutError, the
    # unbounded one rides out the backoff and returns clean bits.
    with BankServer(max_slots=1, max_retries=1, retry_backoff_s=0.2,
                    quarantine_after=100,
                    fault_injector=FailFirstN(1)) as srv:
        t = srv.submit(req(0))
        with pytest.raises(TimeoutError):
            t.result(timeout=0.01)
        assert not t.done()                  # retryable: ticket still live
        assert tree_eq(t.result(), ref(req(0)))


def test_generous_deadline_is_met():
    with BankServer(max_slots=1) as srv:
        t = srv.submit(req(0, deadline_ms=60_000.0))
        assert tree_eq(t.result(), ref(req(0)))
        assert srv.stats()["deadline_exceeded"] == 0


# ----------------------------------- retry ------------------------------------


def test_retry_after_failures_is_bit_identical():
    inj = FailFirstN(2)
    with BankServer(max_slots=1, max_retries=3, retry_backoff_s=0.001,
                    quarantine_after=100, fault_injector=inj) as srv:
        t = srv.submit(req(0))
        assert tree_eq(t.result(), ref(req(0)))
        assert inj.kills == 2
        assert srv.stats()["retries"] == 2


def test_retry_budget_exhausted_raises_original_with_note():
    class Boom(ValueError):
        pass

    def always_fail(device, batch):
        if batch is not None:
            raise Boom("boom")

    with BankServer(max_slots=1, max_retries=1, retry_backoff_s=0.001,
                    quarantine_after=100,
                    fault_injector=always_fail) as srv:
        t = srv.submit(req(0))
        with pytest.raises(Boom, match="boom") as exc_info:
            t.result()
        notes = getattr(exc_info.value, "__notes__", [])
        assert any("[BankServer]" in n for n in notes)
        assert len(notes) == 1               # noted once, not per retry
        assert srv.stats()["retries"] == 1


def test_no_retry_budget_fails_fast():
    inj = FailFirstN(1)
    with BankServer(max_slots=1, quarantine_after=100,
                    fault_injector=inj) as srv:
        t = srv.submit(req(0))
        with pytest.raises(RuntimeError, match="injected"):
            t.result()
        assert srv.stats()["retries"] == 0


@given(st.integers(min_value=1, max_value=3),
       st.sampled_from(["batched", "legacy"]),
       st.sampled_from(["affinity", "round_robin", "least_loaded"]),
       st.integers(min_value=1, max_value=max(1, jax.device_count())),
       st.sampled_from([1, 2, 100]))
@settings(max_examples=10, deadline=None)
def test_property_faulted_serving_bit_identical(n_failures, key_mode,
                                                placement, ndev, qafter):
    """Retries AND quarantine re-dispatch reproduce clean single-shot bits
    across key_modes, placements and device counts.  Low ``qafter`` with
    several devices trips the breaker (re-dispatch path); ``qafter=100``
    absorbs every failure through retries alone."""
    devices = jax.devices()[:ndev]
    with BankServer(max_slots=2, devices=devices, max_inflight=2,
                    placement=placement, key_mode=key_mode,
                    max_retries=3, retry_backoff_s=0.001,
                    quarantine_after=qafter, quarantine_s=0.005,
                    fault_injector=FailFirstN(n_failures)) as srv:
        reqs = [req(i) for i in range(6)]
        tickets = [srv.submit(r) for r in reqs]
        srv.flush()
        for r, t in zip(reqs, tickets):
            clean = executor.run(r, options=dataclasses.replace(
                r.options, decode=True, key_mode=key_mode))
            assert tree_eq(t.result(timeout=60.0), clean)


# --------------------------------- quarantine ---------------------------------


@needs_multidevice
def test_quarantine_redispatches_inflight_work():
    devices = jax.devices()
    inj = FailDeviceNth(devices[0], nth=1)
    with BankServer(max_slots=1, devices=devices, max_inflight=4,
                    placement="round_robin", max_retries=1,
                    retry_backoff_s=0.001, quarantine_after=1,
                    quarantine_s=30.0, fault_injector=inj) as srv:
        # hold() stages everything so flush launches the batches
        # back-to-back: the first launch on the victim device is still in
        # flight (not yet reaped) when its second launch is killed.
        srv.hold()
        reqs = [req(i) for i in range(2 * len(devices))]
        tickets = [srv.submit(r) for r in reqs]
        srv.flush()
        for r, t in zip(reqs, tickets):
            assert tree_eq(t.result(), ref(r))
        st_ = srv.stats()
        assert st_["quarantines"] == 1
        quarantined = [d for d in st_["devices"] if d["quarantined"]]
        assert len(quarantined) == 1
        # The batch in flight on the killed device was moved, not retried
        # (re-dispatch consumes no retry budget); only the killed launch
        # itself spent one retry.
        assert st_["redispatched_requests"] >= 1
        assert st_["retries"] <= 1


def test_last_healthy_device_never_quarantined():
    d0 = jax.devices()[0]
    inj = FailFirstN(2)
    with BankServer(max_slots=1, devices=[d0], max_retries=3,
                    retry_backoff_s=0.001, quarantine_after=1,
                    fault_injector=inj) as srv:
        t = srv.submit(req(0))
        assert tree_eq(t.result(), ref(req(0)))
        assert srv.stats()["quarantines"] == 0


@needs_multidevice
def test_quarantined_device_readmitted_after_heal():
    devices = jax.devices()
    inj = FailDeviceWhile(devices[0])
    with BankServer(max_slots=1, devices=devices, max_inflight=2,
                    placement="round_robin", max_retries=3,
                    retry_backoff_s=0.001, quarantine_after=2,
                    quarantine_s=0.005, fault_injector=inj) as srv:
        tickets = [srv.submit(req(i)) for i in range(2 * len(devices))]
        srv.flush()
        [t.result() for t in tickets]
        assert srv.stats()["quarantines"] >= 1
        inj.down = False                     # the device comes back
        deadline = time.monotonic() + 5.0
        while any(d["quarantined"] for d in srv.stats()["devices"]):
            srv.flush()
            if time.monotonic() > deadline:
                pytest.fail("healed device was never re-admitted")
            time.sleep(0.005)
        # And it serves again: round-robin will reach it within a few
        # batches once healthy.
        tickets = [srv.submit(req(100 + i)) for i in range(2 * len(devices))]
        srv.flush()
        for i, t in enumerate(tickets):
            assert tree_eq(t.result(), ref(req(100 + i)))


# ----------------------------------- chaos ------------------------------------


@needs_multidevice
def test_chaos_trace_loses_zero_tickets():
    devices = jax.devices()

    class RotatingKiller:
        def __init__(self, period=4):
            self.period = period
            self.launches = 0
            self.kills = 0

        def __call__(self, device, batch):
            if batch is None:
                return
            i = self.launches
            self.launches += 1
            victim = (i // self.period) % len(devices)
            if device == devices[victim]:
                self.kills += 1
                raise RuntimeError("chaos kill")

    inj = RotatingKiller()
    with BankServer(max_slots=4, devices=devices, max_inflight=2,
                    placement="round_robin", max_retries=3,
                    retry_backoff_s=0.001, quarantine_after=2,
                    quarantine_s=0.005, fault_injector=inj) as srv:
        reqs = [req(i) for i in range(32)]
        tickets = [srv.submit(r) for r in reqs]
        srv.flush()
        for r, t in zip(reqs, tickets):
            assert tree_eq(t.result(timeout=60.0), ref(r))
    assert inj.kills > 0


# ---------------------------------- shutdown ----------------------------------


def test_close_drains_outstanding_tickets():
    srv = BankServer(max_slots=8)            # held: nothing dispatches
    reqs = [req(i) for i in range(3)]
    tickets = [srv.submit(r) for r in reqs]
    srv.close()                              # drain=True default
    for r, t in zip(reqs, tickets):
        assert tree_eq(t.result(), ref(r))
    with pytest.raises(ServerClosed):
        srv.submit(req(9))
    srv.close()                              # idempotent


def test_close_without_drain_fails_queued_tickets():
    srv = BankServer(max_slots=8)
    t = srv.submit(req(0))
    srv.close(drain=False)
    with pytest.raises(ServerClosed):
        t.result()


def test_context_exit_drains_under_retry_load():
    inj = FailFirstN(2)
    with BankServer(max_slots=1, max_retries=3, retry_backoff_s=0.001,
                    quarantine_after=100, fault_injector=inj) as srv:
        reqs = [req(i) for i in range(3)]
        tickets = [srv.submit(r) for r in reqs]
        # exit drains: no explicit flush/result before close
    for r, t in zip(reqs, tickets):
        assert tree_eq(t.result(), ref(r))


@needs_multidevice
def test_close_while_device_quarantined_resolves_all():
    devices = jax.devices()
    inj = FailDeviceWhile(devices[0])
    srv = BankServer(max_slots=1, devices=devices, max_inflight=2,
                     placement="round_robin", max_retries=3,
                     retry_backoff_s=0.001, quarantine_after=1,
                     quarantine_s=60.0, fault_injector=inj)
    reqs = [req(i) for i in range(2 * len(devices))]
    tickets = [srv.submit(r) for r in reqs]
    srv.close()                              # drain with dev0 quarantined
    for r, t in zip(reqs, tickets):
        assert tree_eq(t.result(), ref(r))


def test_failed_batch_leaves_server_serviceable():
    inj = FailFirstN(1)
    with BankServer(max_slots=1, quarantine_after=100,
                    fault_injector=inj) as srv:
        t0 = srv.submit(req(0))
        with pytest.raises(RuntimeError):
            t0.result()
        t1 = srv.submit(req(1))              # server still works
        assert tree_eq(t1.result(), ref(req(1)))
