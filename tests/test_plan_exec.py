"""Compiled execution plans are bit-identical to the reference interpreter.

Every backend claim of executor.py is pinned here with exact stream equality
(not value tolerance): combinational, sequential (Gaines-divider class),
bitflip-injected, and binary netlists; MUX fusion; plan/jit cache reuse; and
the Pallas-routed pass variant.
"""
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import apps, circuits, executor
from repro.core import plan as plan_mod
from repro.core.appnet import APP_NETLISTS
from repro.core.gates import Netlist
from repro.core.plan import (DEFAULT_PIPELINE, FUSED_MUX, PassPipeline,
                             compile_plan, lower_netlist)

KEY = jax.random.key(0)
FLIP_KEY = jax.random.key(99)
BL = 1024

SC_CASES = [
    (circuits.sc_multiply, {"a": 0.3, "b": 0.7}),
    (circuits.sc_scaled_add, {"a": 0.2, "b": 0.9}),
    (circuits.sc_scaled_add_var, {"a": 0.2, "b": 0.9, "s": 0.4}),
    (circuits.sc_abs_sub, {"a": 0.4, "b": 0.1}),
    (circuits.sc_sqrt, {"a": 0.5}),
    (circuits.sc_exp, {"a": 0.5}),
]


def assert_streams_equal(net, values, bl=BL, **kw):
    ref = executor.execute(net, values, KEY, bl, backend="reference", **kw)
    cmp = executor.execute(net, values, KEY, bl, backend="compiled", **kw)
    assert set(ref) == set(cmp)
    for o in ref:
        assert (ref[o] == cmp[o]).all(), f"{net.name}:{o} diverges"


# ------------------------------ combinational -------------------------------------

@pytest.mark.parametrize("builder,values", SC_CASES,
                         ids=[b.__name__ for b, _ in SC_CASES])
def test_combinational_bit_identical(builder, values):
    assert_streams_equal(builder(), {k: jnp.float32(v) for k, v in values.items()})


def test_combinational_batched_values_bit_identical():
    net = circuits.sc_multiply()
    a = jnp.asarray(np.linspace(0.1, 0.9, 8), jnp.float32)
    assert_streams_equal(net, {"a": a, "b": jnp.full((8,), 0.5, jnp.float32)})


def test_mux_tree_bit_identical_and_fused():
    net = Netlist("tree")
    leaves = [net.add_pi(f"L{i}", value_key=f"v{i}") for i in range(8)]
    root = circuits.sc_mux_tree(leaves, net)
    net.set_outputs([root])
    vals = {f"v{i}": jnp.float32(0.1 * (i + 1)) for i in range(8)}
    assert_streams_equal(net, vals)
    plan = compile_plan(net)
    assert plan.n_fused_mux == 7           # balanced tree over 8 leaves
    assert plan.n_passes < plan.n_gates


# -------------------------------- sequential --------------------------------------

def test_sequential_divider_bit_identical():
    net = circuits.sc_scaled_div()
    assert_streams_equal(net, {"a": jnp.float32(0.4), "b": jnp.float32(0.4)},
                         bl=2048)


def test_sequential_batched_bit_identical():
    net = circuits.sc_scaled_div()
    a = jnp.asarray(np.linspace(0.1, 0.6, 4), jnp.float32)
    assert_streams_equal(net, {"a": a, "b": jnp.full((4,), 0.3, jnp.float32)},
                         bl=512)


def test_sequential_inverting_output_bit_identical_and_correct():
    # Regression: an output driven by a NOT gate carries garbage in bits
    # 1..31 of the per-step values; both backends must mask before packing.
    net = Netlist("div_with_qbar_out")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    from repro.core.gates import PIKind
    q = net.add_pi("Q", kind=PIKind.STATE)
    qb = net.add_gate("NOT", [q], "Q_bar")
    bb = net.add_gate("NOT", [b], "B_bar")
    n1 = net.add_gate("NAND", [a, qb], "n1")
    n2 = net.add_gate("NAND", [bb, q], "n2")
    qn = net.add_gate("NAND", [n1, n2], "Q_next")
    qnb = net.add_gate("NOT", [qn], "Qn_bar")
    net.bind_state(q, qn, init=0.0)
    net.set_outputs([qn, qnb])
    vals = {"a": jnp.float32(0.4), "b": jnp.float32(0.5)}
    assert_streams_equal(net, vals, bl=2048)
    out = executor.execute_value(net, vals, jax.random.key(2), 16384)
    assert abs(float(out["Q_next"]) - 0.4 / 0.9) < 0.03
    assert abs(float(out["Qn_bar"]) - (1 - 0.4 / 0.9)) < 0.03


def test_sequential_value_converges():
    # The scan-over-words path reproduces the divider fixed point.
    out = executor.execute_value(circuits.sc_scaled_div(),
                                 {"a": jnp.float32(0.4), "b": jnp.float32(0.4)},
                                 jax.random.key(1), 16384, backend="compiled")
    assert abs(float(out["Q_next"]) - 0.5) < 0.03


# --------------------------------- bitflips ---------------------------------------

@pytest.mark.parametrize("rate", [0.05, 0.2])
def test_bitflip_combinational_bit_identical(rate):
    for builder, values in SC_CASES[:3]:
        assert_streams_equal(builder(),
                             {k: jnp.float32(v) for k, v in values.items()},
                             bitflip_rate=rate, flip_key=FLIP_KEY)


def test_bitflip_sequential_bit_identical():
    assert_streams_equal(circuits.sc_scaled_div(),
                         {"a": jnp.float32(0.4), "b": jnp.float32(0.2)},
                         bl=512, bitflip_rate=0.1, flip_key=FLIP_KEY)


def test_bitflip_uses_unfused_plan():
    net = circuits.sc_scaled_add()
    assert compile_plan(net, fuse_mux=True).n_fused_mux == 1
    assert compile_plan(net, fuse_mux=False).n_fused_mux == 0


# ---------------------------------- binary ----------------------------------------

@pytest.mark.parametrize("n_bits", [3, 8])
def test_binary_adder_bit_identical_and_correct(n_bits):
    rng = np.random.default_rng(n_bits)
    a = jnp.asarray(rng.integers(0, 1 << n_bits, 64), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << n_bits, 64), jnp.uint32)
    net = circuits.binary_ripple_carry_adder(n_bits)
    bits = circuits.rca_prepare_inputs(a, b, n_bits)
    ref = executor.execute_binary(net, bits, backend="reference")
    cmp = executor.execute_binary(net, bits, backend="compiled")
    for o in ref:
        assert (ref[o] == cmp[o]).all()
    dec = circuits.rca_decode_outputs(cmp, n_bits)
    assert (np.asarray(dec) == np.asarray(a) + np.asarray(b)).all()


def test_binary_missing_operand_raises():
    net = circuits.binary_ripple_carry_adder(2)
    with pytest.raises(KeyError):
        executor.execute_binary(net, {"A0": jnp.zeros((4,), jnp.uint32)},
                                backend="compiled")


# --------------------------------- appnets ----------------------------------------

def test_appnet_ol_bit_identical_and_level_batched():
    net = APP_NETLISTS["ol"]()
    vals = apps.appnet_inputs("ol", p=np.full((16, 6), 0.8))
    assert_streams_equal(net, vals, bl=256)
    plan = compile_plan(net)
    # 16 parallel pixel circuits collapse to one fused pass per level.
    assert plan.n_gates == 160 and plan.n_passes <= 10


def test_appnet_hdp_sequential_bit_identical():
    vals = {k: jnp.float32(0.5) for k in apps.HDP_KEYS}
    net = APP_NETLISTS["hdp"]()
    assert_streams_equal(net, apps.appnet_inputs("hdp", v=vals), bl=256)


def test_appnet_stochastic_tracks_exact_product():
    p = np.full((16, 6), 0.9)
    out = apps.appnet_stochastic("ol", jax.random.key(3), bl=2048, p=p)
    got = np.asarray(list(out.values())).mean()
    assert abs(got - 0.9 ** 6) < 0.05


# ------------------------------ plan properties -----------------------------------

def test_plan_cache_interns_equal_structures():
    p1 = compile_plan(circuits.sc_multiply())
    p2 = compile_plan(circuits.sc_multiply())
    assert p1 is p2


def test_mutating_compiled_netlist_recompiles():
    # Regression: the per-instance memo was keyed on PI/gate *counts*, so an
    # in-place gate replacement at equal count returned the stale plan.
    net = circuits.sc_multiply()            # NAND(A,B) -> NOT -> out = a*b
    p1 = compile_plan(net)
    net.replace_gate(0, gtype="NOR")        # same gate count, new structure
    p2 = compile_plan(net)
    assert p2 is not p1
    assert p2.levels[0][0].op == "NOR"
    # And the recompiled plan executes the *new* semantics:
    # out = NOT(NOR(a, b)) = a OR b.
    vals = {"a": jnp.float32(0.3), "b": jnp.float32(0.6)}
    out = executor.execute_value(net, vals, jax.random.key(0), 8192)
    expected = 0.3 + 0.6 - 0.3 * 0.6
    assert abs(float(out["out"]) - expected) < 0.03
    assert_streams_equal(net, vals)
    # The per-instance memo stays bounded across mutate/recompile cycles
    # (stale-version entries are evicted on the next compile).
    for gt in ("NAND", "NOR", "AND", "OR") * 2:
        net.replace_gate(0, gtype=gt)
        compile_plan(net)
    assert len(net._plan_memo) <= 2


def test_fusion_is_not_applied_to_observable_intermediates():
    # If a MUX intermediate is also a primary output it must stay
    # materialized — no fusion may swallow it.
    net = Netlist("observed_mux")
    a = net.add_pi("A", value_key="a")
    b = net.add_pi("B", value_key="b")
    s = net.add_pi("S", value_key="s")
    sb = net.add_gate("NOT", [s], "sb")
    n1 = net.add_gate("NAND", [a, s], "n1")
    n2 = net.add_gate("NAND", [b, sb], "n2")
    net.add_gate("NAND", [n1, n2], "out")
    net.set_outputs(["out", "n1"])
    plan = compile_plan(net)
    assert plan.n_fused_mux == 0
    vals = {"a": jnp.float32(0.3), "b": jnp.float32(0.6), "s": jnp.float32(0.5)}
    assert_streams_equal(net, vals)


def test_fused_plan_collapses_scaled_add_to_single_pass():
    plan = compile_plan(circuits.sc_scaled_add())
    assert plan.n_passes == 1
    assert plan.levels[0][0].op == FUSED_MUX


# --------------------------- pinned pipeline goldens ------------------------------
# tests/golden_digests.json was captured from the pre-refactor compiler: the
# staged PassPipeline must reproduce every stream bit-for-bit and every
# optimization counter exactly (drift here means the refactor changed
# semantics, not just structure).

_GOLD = json.loads((pathlib.Path(__file__).parent
                    / "golden_digests.json").read_text())
GOLD_KEY = jax.random.key(42)
GOLD_FLIP = jax.random.key(7)
GOLD_BL = _GOLD["bitstream_length"]

GOLD_VALUES = {
    "sc_multiply": {"a": 0.3, "b": 0.7},
    "sc_scaled_add": {"a": 0.2, "b": 0.9},
    "sc_scaled_add_var": {"a": 0.2, "b": 0.9, "s": 0.4},
    "sc_abs_sub": {"a": 0.4, "b": 0.1},
    "sc_sqrt": {"a": 0.5},
    "sc_exp": {"a": 0.5},
    "sc_scaled_div": {"a": 0.4, "b": 0.4},
}


def _digest(streams, order) -> str:
    # Hash output streams by declared-output POSITION, not node name: node
    # names embed a process-global counter, so they depend on how many
    # netlists were built earlier in the process (goldens must not).
    h = hashlib.sha256()
    for i, name in enumerate(order):
        arr = np.asarray(streams[name])
        h.update(str(i).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()[:16]


def _golden_case(name):
    """(netlist, values, bitstream_length) for a golden-digest case name."""
    if name == "sc_multiply_batched":
        a = jnp.asarray(np.linspace(0.1, 0.9, 8), jnp.float32)
        return (circuits.sc_multiply(),
                {"a": a, "b": jnp.full((8,), 0.5, jnp.float32)}, GOLD_BL)
    if name.startswith("appnet_"):
        app = name.removeprefix("appnet_")
        kw = ({"p": np.full((16, 6), 0.8)} if app == "ol" else
              {"v": {k: jnp.float32(0.5) for k in apps.HDP_KEYS}})
        return APP_NETLISTS[app](), apps.appnet_inputs(app, **kw), 256
    return (getattr(circuits, name)(),
            {k: jnp.float32(v) for k, v in GOLD_VALUES[name].items()}, GOLD_BL)


@pytest.mark.parametrize("case", sorted(_GOLD["digests"]))
def test_pipeline_matches_pre_refactor_golden_digest(case):
    name, key_mode, variant = case.split("/")
    net, vals, bl = _golden_case(name)
    kw = dict(bitflip_rate=0.05, flip_key=GOLD_FLIP) \
        if variant == "bitflip" else {}
    streams = executor.execute(net, vals, GOLD_KEY, bl,
                               key_mode=key_mode, **kw)
    assert _digest(streams, net.outputs) == _GOLD["digests"][case], case


def test_plan_counters_match_goldens():
    for name, want in _GOLD["plan_counters"].items():
        net, _, _ = _golden_case(name)
        p = compile_plan(net)
        got = {k: getattr(p, k) for k in want}
        assert got == want, name


_DRIFT_KEYS = ("buff_elided", "cse_elided", "mux_fused", "xor_fused",
               "and_fused", "not_absorbed")


@pytest.mark.parametrize("app", sorted(_GOLD["app_pass_counters"]))
def test_app_pass_counters_no_drift(app):
    # CI drift check (see pyproject/README): the cache_info() optimization
    # counters for each Table-3 app bank are pinned — a pipeline-stage change
    # that alters how many nodes fuse/elide must update the goldens on
    # purpose, not silently.
    want = _GOLD["app_pass_counters"][app]
    plan_mod.clear_cache()
    before = {k: plan_mod.cache_info().get(k, 0) for k in _DRIFT_KEYS}
    bank = plan_mod.compile_bank_plan(apps.cost_stage_netlists(app))
    after = plan_mod.cache_info()
    got = {k: after.get(k, 0) - before[k] for k in _DRIFT_KEYS}
    got["merged_passes"] = bank.n_passes
    got["looped_passes"] = bank.n_passes_looped
    # Liveness pin: the merged bank's peak simultaneously-live streams
    # (scratch slots).  Drift means the liveness stage's allocation — and so
    # megakernel scratch sizing and subarray occupancy — changed.
    got["max_live"] = max(g.max_live
                          for g in (bank.comb, bank.seq) if g is not None)
    assert got == want, app


def test_clear_cache_invalidates_per_netlist_memo():
    # Regression (cache staleness): clear_cache() empties the interning
    # caches, but the per-netlist _plan_memo used to keep pointing at the
    # old plan object — a post-clear compile returned a plan no longer in
    # any cache, silently defeating the clear.
    net = circuits.sc_multiply()
    p1 = compile_plan(net)
    plan_mod.clear_cache()
    p2 = compile_plan(net)
    assert p2 is not p1
    # Epoch-stale memo entries are pruned, not accumulated.
    for _ in range(5):
        plan_mod.clear_cache()
        compile_plan(net)
        compile_plan(net, fuse_mux=False)
    assert len(net._plan_memo) <= 2


def test_every_plan_carries_a_schedule():
    for name in ("sc_multiply", "sc_scaled_div", "appnet_ol"):
        net, _, _ = _golden_case(name)
        p = compile_plan(net)
        assert p.schedule is not None
        assert p.schedule.logic_cycles >= p.n_passes


@settings(max_examples=20, deadline=None)
@given(idx=st.integers(0, len(GOLD_VALUES) - 1),
       fuse=st.booleans(),
       key_mode=st.sampled_from(("batched", "legacy")),
       frac=st.floats(0.05, 0.95))
def test_property_pipeline_bit_identical(idx, fuse, key_mode, frac):
    # Property (random netlist x pipeline config x fuse_mux): the staged
    # pipeline's compiled output is bit-identical to the reference
    # interpreter, and rebuilding the PassPipeline from its own stages
    # lowers to the identical pass program.
    name = sorted(GOLD_VALUES)[idx]
    net = getattr(circuits, name)()
    vals = {k: jnp.float32(round(min(max(v * frac * 2.0, 0.05), 0.95), 3))
            for k, v in GOLD_VALUES[name].items()}
    # fuse=False exercises the unfused plan via the bitflip path (the only
    # execute() entry that selects it).
    kw = {} if fuse else dict(bitflip_rate=0.05, flip_key=GOLD_FLIP)
    ref = executor.execute(net, vals, GOLD_KEY, 256, backend="reference",
                           key_mode=key_mode, **kw)
    cmp = executor.execute(net, vals, GOLD_KEY, 256, backend="compiled",
                           key_mode=key_mode, **kw)
    assert set(ref) == set(cmp)
    for o in ref:
        assert (ref[o] == cmp[o]).all(), f"{name}:{o}"
    p_default = lower_netlist(net, fuse_mux=fuse)
    p_rebuilt = lower_netlist(
        net, fuse_mux=fuse,
        pipeline=PassPipeline(stages=DEFAULT_PIPELINE.stages))
    assert p_rebuilt.levels == p_default.levels
    assert p_rebuilt.aliases == p_default.aliases
    assert p_rebuilt.stream_table == p_default.stream_table
    assert p_rebuilt.schedule.logic_cycles == p_default.schedule.logic_cycles


# ---------------------------------- pallas ----------------------------------------

@pytest.mark.pallas
def test_pallas_backend_bit_identical():
    for builder, values in (SC_CASES[0], SC_CASES[3]):
        net = builder()
        vals = {k: jnp.float32(v) for k, v in values.items()}
        ref = executor.execute(net, vals, KEY, 256, backend="reference")
        pal = executor.execute(net, vals, KEY, 256, backend="compiled_pallas")
        for o in ref:
            assert (ref[o] == pal[o]).all()


# ------------------- word-tiled streaming & megakernel goldens --------------------
# The chunked-jnp scan path and the whole-plan Pallas megakernel must both
# reproduce the pre-refactor golden digests bit for bit, in both key modes —
# streaming/fusing the execution may never change a single output bit.

_CLEAN_CASES = sorted(c for c in _GOLD["digests"] if c.endswith("/fused"))


def _is_sequential_case(name: str) -> bool:
    net, _, _ = _golden_case(name)
    return net.is_sequential


@pytest.mark.parametrize(
    "case", [c for c in _CLEAN_CASES if not _is_sequential_case(c.split("/")[0])])
def test_chunked_streaming_matches_golden_digest(case):
    name, key_mode, _ = case.split("/")
    net, vals, bl = _golden_case(name)
    w = bl // 32
    for chunk in (1, w // 2):
        streams = executor.run(executor.ExecRequest(
            net, vals, GOLD_KEY, executor.ExecOptions(
                bitstream_length=bl, key_mode=key_mode, word_chunk=chunk)))
        assert _digest(streams, net.outputs) == _GOLD["digests"][case], \
            (case, chunk)


@pytest.mark.pallas
@pytest.mark.parametrize("case", _CLEAN_CASES)
def test_megakernel_matches_golden_digest(case):
    name, key_mode, _ = case.split("/")
    net, vals, bl = _golden_case(name)
    streams = executor.run(executor.ExecRequest(
        net, vals, GOLD_KEY, executor.ExecOptions(
            bitstream_length=bl, key_mode=key_mode,
            backend="compiled_megakernel", interpret=True)))
    assert _digest(streams, net.outputs) == _GOLD["digests"][case], case


@pytest.mark.pallas
def test_chunked_megakernel_composes():
    # word_chunk + megakernel: the scan body runs the fused kernel per chunk.
    net, vals, bl = _golden_case("sc_exp")
    case = "sc_exp/batched/fused"
    streams = executor.run(executor.ExecRequest(
        net, vals, GOLD_KEY, executor.ExecOptions(
            bitstream_length=bl, word_chunk=4,
            backend="compiled_megakernel", interpret=True)))
    assert _digest(streams, net.outputs) == _GOLD["digests"][case]


def _state_only_oscillator() -> Netlist:
    from repro.core.gates import PIKind
    n = Netlist("osc")
    q = n.add_pi("Q", kind=PIKind.STATE)
    qn = n.add_gate("NOT", [q], "Qn")
    n.bind_state(q, qn, init=0.0)
    n.set_outputs([qn])
    return n


def test_sequential_zero_stream_pi_respects_batch_shape():
    # Regression: a sequential plan with zero stream PIs used to ignore
    # batch_shape= entirely — the scan fell back to scalar state, returning
    # (W,) outputs for a (5,)-batched request.
    net = _state_only_oscillator()
    out = executor.execute(net, {}, KEY, 256, batch_shape=(5,))
    assert out["Qn"].shape == (5, 8)
    base = executor.execute(net, {}, KEY, 256)
    assert base["Qn"].shape == (8,)
    for i in range(5):
        assert (out["Qn"][i] == base["Qn"]).all()


def test_sequential_zero_stream_pi_word_chunk_raises():
    # The streaming executor cannot re-chunk a state recurrence; asking for
    # word_chunk on such a plan must fail loudly, not silently ignore it.
    net = _state_only_oscillator()
    with pytest.raises(ValueError, match="word_chunk"):
        executor.run(executor.ExecRequest(
            net, {}, KEY, executor.ExecOptions(
                bitstream_length=256, batch_shape=(5,), word_chunk=2)))


def test_word_chunk_rejects_injection_and_bad_sizes():
    net, vals, bl = _golden_case("sc_multiply")
    with pytest.raises(ValueError, match="fault injection"):
        executor.run(executor.ExecRequest(
            net, vals, GOLD_KEY, executor.ExecOptions(
                bitstream_length=bl, word_chunk=4,
                bitflip_rate=0.05, flip_key=GOLD_FLIP)))
    with pytest.raises(ValueError, match="divide"):
        executor.run(executor.ExecRequest(
            net, vals, GOLD_KEY, executor.ExecOptions(
                bitstream_length=bl, word_chunk=5)))
    with pytest.raises(ValueError, match="single-plan"):
        executor.run([executor.ExecRequest(
            net, vals, GOLD_KEY, executor.ExecOptions(
                bitstream_length=bl, word_chunk=4))] * 2)


def test_liveness_annotation_invariants():
    # Every compiled plan carries a valid register-allocation: slots stay
    # below max_live, a slot is never reassigned while its node is live, and
    # outputs/state drivers are never freed.
    for name in ("sc_exp", "sc_sqrt", "appnet_ol", "appnet_hdp"):
        net, _, _ = _golden_case(name)
        p = compile_plan(net)
        assert 0 < p.max_live <= p.naive_live
        alias = dict(p.aliases)
        protected = {alias.get(nm, nm)
                     for nm in (*p.outputs, *p.state_drivers)}
        slot_of = {pi.name: s for pi, s in zip(p.pis, p.pi_slots) if s >= 0}
        live = dict(slot_of)
        for level in p.levels:
            for cop in level:
                assert len(cop.slots) == len(cop.outputs)
                for nm, s in zip(cop.outputs, cop.slots):
                    assert 0 <= s < p.max_live
                    # Slot must be free: no OTHER live node holds it.
                    holders = [n for n, ls in live.items() if ls == s]
                    assert holders in ([], [nm]), (name, nm, holders)
                    live[nm] = s
                for nm in cop.free_after:
                    assert nm not in protected, (name, nm)
                    live.pop(nm, None)
        assert max(live.values(), default=-1) < p.max_live


def test_free_after_releases_dead_intermediates():
    # The per-pass executor drops dead nodes from env as it goes: after a
    # full run only live-at-exit names (plus aliases) remain.
    net, vals, bl = _golden_case("sc_exp")
    p = compile_plan(net)
    from repro.core.streams import _gen_pi_streams
    from repro.kernels.netlist_exec import run_combinational
    env = dict(_gen_pi_streams(p.pis, {k: jnp.float32(v) for k, v in
                                       vals.items()}, GOLD_KEY, bl))
    n_pis = len(env)
    run_combinational(p, env)
    # env holds at most the liveness bound plus re-exposed aliases — not one
    # entry per node (sc_exp has 13 gates + PIs).
    assert len(env) <= p.max_live + len(p.aliases)
    assert n_pis + p.n_gates > p.max_live  # the bound actually binds
    for o in p.outputs:
        assert o in env
