"""Integration: the multi-pod dry-run CLI lowers+compiles real cells.

Runs in a subprocess because the 512-host-device XLA flag must be set
before jax initializes (tests themselves run single-device).  Uses the
cheapest cells to keep suite time bounded.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 512-device dry-run compiles: excluded from CI default

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)


@pytest.mark.parametrize("mesh_args", [[], ["--multi_pod"]])
def test_dryrun_cheapest_cell_compiles(tmp_path, mesh_args):
    out = str(tmp_path / "r.json")
    r = _run(["--arch", "rwkv6-1.6b", "--shape", "long_500k", "--out", out]
             + mesh_args)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(out))["results"][0]
    assert rec["n_devices"] == (512 if mesh_args else 256)
    roof = rec["roofline"]
    assert roof["hlo_flops_per_device"] > 0
    assert roof["dominant"] in ("compute", "memory", "collective")
    assert rec["memory"]["temp_bytes"] is not None


def test_dryrun_decode_tp_reduces_collectives(tmp_path):
    base, opt = str(tmp_path / "b.json"), str(tmp_path / "o.json")
    r1 = _run(["--arch", "qwen3-8b", "--shape", "decode_32k", "--out", base])
    r2 = _run(["--arch", "qwen3-8b", "--shape", "decode_32k", "--decode_tp",
               "--out", opt])
    assert r1.returncode == 0 and r2.returncode == 0, r2.stdout[-1500:]
    b = json.load(open(base))["results"][0]["collectives"]["effective_bytes"]
    o = json.load(open(opt))["results"][0]["collectives"]["effective_bytes"]
    assert o < 0.8 * b, (b, o)   # the §Perf decode lever holds
