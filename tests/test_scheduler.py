"""Algorithm 1 tests — the paper's worked examples and hardware constraints.

Key paper anchors:
  * Fig. 7(b): stochastic 4-bit-equivalent scaled addition = 4 logic cycles,
    independent of bitstream length.
  * Fig. 7(a) / Section 4-1: binary ripple-carry addition = 2(n-1) cycles of
    carry transfer + 3 (even n) or 4 (odd n) for the MSB => 9 cycles at n=4.
  * Table 2 column budgets for the six arithmetic circuits.
"""
import pytest

from repro.core import circuits
from repro.core.gates import Netlist, PIKind
from repro.core.scheduler import input_init_cycles, schedule


def test_stochastic_scaled_add_is_4_cycles_any_bitstream_length():
    for lanes in (1, 16, 256):
        sch = schedule(circuits.sc_scaled_add(), n_lanes=lanes)
        assert sch.logic_cycles == 4, lanes
        assert sch.n_cols == 7                      # Table 2: 256x7
        assert sch.n_rows == lanes


@pytest.mark.parametrize("n_bits,expected", [(2, 5), (3, 8), (4, 9), (6, 13), (8, 17)])
def test_binary_rca_cycles_match_paper_formula(n_bits, expected):
    # 2*(n-1) + 3 for even n, 2*(n-1) + 4 for odd n  (Section 4-1).
    sch = schedule(circuits.binary_ripple_carry_adder(n_bits))
    assert sch.logic_cycles == expected


def test_stochastic_vs_binary_speedup_at_4_bits():
    stoch = schedule(circuits.sc_scaled_add(), n_lanes=256)
    binary = schedule(circuits.binary_ripple_carry_adder(4))
    assert binary.logic_cycles == 9 and stoch.logic_cycles == 4


TABLE2_COLS = {
    "sc_multiply": 4,        # Table 2: 256x4
    "sc_scaled_add": 7,      # 256x7
    "sc_abs_sub": 8,         # 256x8
    "sc_scaled_div": 13,     # 256x13
    "sc_sqrt": 10,           # 256x10
    "sc_exp": 31,            # 256x31
}


@pytest.mark.parametrize("name,builder", [
    ("sc_multiply", circuits.sc_multiply),
    ("sc_scaled_add", circuits.sc_scaled_add),
    ("sc_abs_sub", circuits.sc_abs_sub),
    ("sc_scaled_div", circuits.sc_scaled_div),
    ("sc_sqrt", circuits.sc_sqrt),
    ("sc_exp", circuits.sc_exp),
])
def test_table2_column_budgets(name, builder):
    sch = schedule(builder(), n_lanes=256)
    assert sch.n_cols <= TABLE2_COLS[name], (name, sch.n_cols)
    assert sch.n_rows == 256


def test_no_shared_fanin_within_cycle():
    # Two gates reading the same node must not fire in the same cycle.
    net = Netlist("fanin")
    a = net.add_pi("A")
    b = net.add_pi("B")
    net.add_gate("NAND", [a, b], "x")
    net.add_gate("NAND", [a, b], "y")     # same fan-in as x
    net.set_outputs(["x", "y"])
    sch = schedule(net, n_lanes=4)
    cyc = {o.out_col: o.cycle for o in sch.ops}
    cycles = [o.cycle for o in sch.ops if not o.is_copy]
    assert cycles[0] != cycles[1]


def test_independent_row_local_gates_parallelize_into_one_cycle():
    # Algorithm 1's input-column-aligned subsets: same gate type in different
    # rows with aligned operand columns fire in a single cycle (one V_SL
    # drive pattern serves every row — the Fig. 7(a) parallelism).
    net = Netlist("par")
    for r in range(4):
        net.add_pi(f"A{r}", kind=PIKind.BINARY, row=r)   # col 0 of row r
        net.add_pi(f"B{r}", kind=PIKind.BINARY, row=r)   # col 1 of row r
    for r in range(4):
        net.add_gate("NAND", [f"A{r}", f"B{r}"], f"o{r}", row=r)
    net.set_outputs([f"o{r}" for r in range(4)])
    sch = schedule(net)
    assert sch.logic_cycles == 1          # all four NANDs fire together


def test_simd_gates_serialize_per_row_constraint():
    # Two distinct ALL_ROWS gates occupy every row, so they cannot share a
    # cycle (one logic op per row per cycle).
    net = Netlist("simd2")
    pis = [net.add_pi(f"I{i}") for i in range(4)]
    net.add_gate("NAND", [pis[0], pis[1]], "x")
    net.add_gate("NAND", [pis[2], pis[3]], "y")
    net.set_outputs(["x", "y"])
    sch = schedule(net, n_lanes=16)
    assert sch.logic_cycles == 2


def test_strict_same_type_serializes_mixed_types():
    net = Netlist("mixed")
    a, b = net.add_pi("A"), net.add_pi("B")
    net.add_gate("NAND", [a, b], "x")
    net.add_gate("NOT", [a], "y")         # different type, shares operand A
    net.set_outputs(["x", "y"])
    loose = schedule(net, n_lanes=1)
    strict = schedule(net, n_lanes=1, strict_same_type=True)
    assert strict.logic_cycles >= loose.logic_cycles


def test_cross_row_copy_inserted_for_binary_operands():
    net = Netlist("xrow")
    a = net.add_pi("A", kind=PIKind.BINARY, row=0)
    b = net.add_pi("B", kind=PIKind.BINARY, row=1)
    net.add_gate("NAND", [a, b], "o", row=0)    # B must be copied into row 0
    net.set_outputs(["o"])
    sch = schedule(net)
    assert sch.n_copies == 1
    copies = [o for o in sch.ops if o.is_copy]
    assert copies[0].src_row == 1 and copies[0].row == 0
    assert sch.logic_cycles == 2                # copy cycle + NAND cycle


def test_subarray_capacity_enforced():
    with pytest.raises(ValueError):
        schedule(circuits.sc_scaled_add(), n_lanes=512, r_available=256)
    net = Netlist("wide")
    pis = [net.add_pi(f"I{i}") for i in range(300)]
    prev = pis[0]
    for i in range(1, 300):
        prev = net.add_gate("NAND", [prev, pis[i]], f"n{i}")
    net.set_outputs([prev])
    with pytest.raises(ValueError):
        schedule(net, n_lanes=1, c_available=256)


def test_priority_follows_inverse_topological_order():
    # The gate furthest from the outputs fires first when both are ready.
    net = Netlist("prio")
    a, b, c = net.add_pi("A"), net.add_pi("B"), net.add_pi("C")
    deep = net.add_gate("NAND", [a, b], "deep")     # feeds a chain of 2
    net.add_gate("NAND", [a, c], "shallow")         # feeds nothing further
    x = net.add_gate("NOT", [deep], "x")
    net.add_gate("NAND", [x, c], "out")
    net.set_outputs(["out", "shallow"])
    sch = schedule(net, n_lanes=1)
    cycle_of = {}
    for op, g in zip([o for o in sch.ops if not o.is_copy], net.gates):
        pass  # ops order == commit order; map via placements instead
    # deep (inv-topo 2) must not be scheduled after shallow (inv-topo 0)
    ops = [o for o in sch.ops if not o.is_copy]
    assert ops[0].cycle <= ops[1].cycle


def test_schedule_accounting_consistency():
    sch = schedule(circuits.sc_exp(), n_lanes=64)
    assert sch.preset_count == sum(sch.gate_exec_counts.values())
    assert sch.cells_used <= sch.n_rows * sch.n_cols
    assert sch.cell_writes >= sch.input_cells + 2 * sch.preset_count
    assert sch.total_cycles() == sch.logic_cycles + 1   # preset overlap (+1st)


def test_input_init_cycles_accounting():
    assert input_init_cycles(circuits.sc_multiply()) == 2       # preset + SBG
    rca = circuits.binary_ripple_carry_adder(4)
    # binary: preset + one write cycle per occupied row (4 rows)
    assert input_init_cycles(rca) == 1 + 4
