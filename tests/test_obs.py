"""Tracing + metrics layer (core/obs.py) and its hot-path guarantees.

Covers the tentpole contracts of the observability PR:

* span nesting + attrs are correct across worker threads (one shared Trace,
  per-thread open-span stacks, distinct tids);
* ``to_chrome_json`` emits schema-valid chrome://tracing JSON (metadata +
  "X" spans + "i" instants, virtual tracks named);
* the serving engine's counters match a known request trace exactly, and
  its per-request phase spans partition the root request span;
* tracing is observability only: enabling it changes NO bits, under both
  key modes, through the executor and the server.
"""
import json
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.core import circuits, executor, obs
from repro.serve import BankServer, SCRequest, circuit_request


# ----------------------------- Trace core ----------------------------------

def test_span_nesting_and_attrs():
    tr = obs.Trace("t")
    with tr.span("outer", step=1) as outer:
        with tr.span("inner") as inner:
            inner.set("k", "v")
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    assert spans[0].parent is spans[1]
    assert spans[1].parent is None
    assert spans[1].attrs == {"step": 1}
    assert spans[0].attrs == {"k": "v"}
    assert spans[0].duration_ms <= spans[1].duration_ms


def test_span_nesting_across_threads():
    """Each thread gets its own open-span stack on a shared Trace: a span
    opened on a worker never parents under (or corrupts) the main thread's
    open span, and records the worker's tid."""
    tr = obs.Trace("t")
    done = threading.Event()

    def worker():
        with tr.span("worker-span"):
            pass
        done.set()

    with tr.span("main-span"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert done.wait(1.0)
    by_name = {s.name: s for s in tr.spans()}
    assert by_name["worker-span"].parent is None
    assert by_name["main-span"].parent is None
    assert by_name["worker-span"].tid != by_name["main-span"].tid


def test_module_level_span_noop_when_disabled():
    assert obs.current_trace() is None
    sp = obs.span("anything", x=1)
    assert sp is obs.NULL_SPAN
    with sp:
        sp.set("k", 2)          # inert
    obs.event("nothing")        # no raise, nowhere to go


def test_tracing_context_and_install():
    tr = obs.Trace("ctx")
    with obs.tracing(tr):
        with obs.span("in-ctx"):
            pass
    assert obs.current_trace() is None
    try:
        obs.install(tr)
        with obs.span("installed"):
            pass
    finally:
        obs.install(None)
    assert {s.name for s in tr.spans()} == {"in-ctx", "installed"}


def test_chrome_json_schema():
    tr = obs.Trace("export")
    vt = tr.virtual_tid("track-a")
    with tr.span("live", n=3):
        pass
    tr.add_span("retro", tr.t_origin, tr.t_origin + 0.001, tid=vt, who="me")
    tr.event("ping", code=7)
    doc = json.loads(tr.to_chrome_json(indent=1))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
        assert {"name", "ph", "pid", "tid"} <= set(e)
    # process_name + one thread_name per virtual track
    meta = {e["name"]: e for e in by_ph["M"]}
    assert meta["process_name"]["args"]["name"] == "export"
    assert meta["thread_name"]["args"]["name"] == "track-a"
    assert meta["thread_name"]["tid"] == vt
    xs = {e["name"]: e for e in by_ph["X"]}
    assert xs["live"]["args"] == {"n": 3}
    assert xs["live"]["dur"] >= 0
    assert xs["retro"]["tid"] == vt
    assert abs(xs["retro"]["dur"] - 1000.0) < 1.0     # 1 ms in us
    (instant,) = by_ph["i"]
    assert instant["name"] == "ping" and instant["s"] == "t"


def test_metrics_registry():
    reg = obs.MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2)
    reg.gauge("g").set(0.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("h", v)
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 0.5}
    h = snap["histograms"]["h"]
    assert h["count"] == 4 and h["sum"] == 10.0 and h["min"] == 1.0
    assert h["max"] == 4.0 and h["p50"] == 3.0
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


# ------------------------- engine counter accuracy --------------------------

def test_server_counters_match_known_trace():
    """6 requests in 2 bursts of 3 (max_slots=4 pads each to one batch):
    the trace's counters, span counts and phase partition must match."""
    net = circuits.sc_multiply()
    keys = jax.random.split(jax.random.key(0), 6)
    with BankServer(max_slots=4, window_s=None, trace=True) as server:
        for burst in (keys[:3], keys[3:]):
            server.serve([circuit_request(net, {"a": 0.3, "b": 0.7}, k, 64)
                          for k in burst])
        tr = server.trace
        counters = server.stats()["metrics"]["counters"]
    assert counters["serve.requests_admitted"] == 6
    assert counters["serve.batches_launched"] == 2
    assert counters["serve.requests_completed"] == 6

    spans = tr.spans()
    roots = [s for s in spans if s.name == "request"]
    assert len(roots) == 6
    assert len([s for s in spans if s.name == "serve.launch"]) == 2
    for root in roots:
        kids = [s for s in spans if s.parent is root]
        assert sorted(k.name for k in kids) == [
            "request.inflight", "request.queued", "request.staged"]
        # exact partition: the three phases cover the root span
        covered = sum(k.duration_ms for k in kids)
        assert covered == pytest.approx(root.duration_ms, rel=1e-6)
        for k in kids:
            assert root.t0 <= k.t0 and k.t1 <= root.t1 + 1e-9
    hist = tr.metrics.snapshot()["histograms"]
    assert hist["serve.latency_ms"]["count"] == 6
    assert hist["serve.queued_ms"]["count"] == 6


def test_compiler_and_exec_spans_via_options_trace():
    tr = obs.Trace("exec")
    opts = executor.ExecOptions(bitstream_length=64, decode=True, trace=tr)
    executor.run(executor.ExecRequest(
        circuits.sc_scaled_add(), {"a": 0.2, "b": 0.8},
        jax.random.key(3), opts))
    names = {s.name for s in tr.spans()}
    assert "exec.dispatch" in names
    # Fresh-compile spans appear only on a cache miss; assert only on the
    # always-present dispatch span plus json validity.
    json.loads(tr.to_chrome_json())


# ------------------------------ bit identity -------------------------------

@pytest.mark.parametrize("key_mode", ["batched", "legacy"])
def test_tracing_changes_no_bits_executor(key_mode):
    net = circuits.sc_sqrt()
    key = jax.random.key(11)
    base = executor.run(executor.ExecRequest(
        net, {"a": 0.4}, key,
        executor.ExecOptions(bitstream_length=128, key_mode=key_mode)))
    tr = obs.Trace("pin")
    traced = executor.run(executor.ExecRequest(
        net, {"a": 0.4}, key,
        executor.ExecOptions(bitstream_length=128, key_mode=key_mode,
                             trace=tr)))
    assert base.keys() == traced.keys()
    for k in base:
        assert bool(jnp.array_equal(base[k], traced[k]))
    assert len(tr.spans()) > 0          # tracing actually happened


@pytest.mark.parametrize("key_mode", ["batched", "legacy"])
def test_tracing_changes_no_bits_server(key_mode):
    net = circuits.sc_multiply()
    keys = jax.random.split(jax.random.key(5), 4)
    opts = executor.ExecOptions(bitstream_length=64, key_mode=key_mode,
                                decode=True)

    def serve(trace):
        with BankServer(max_slots=4, window_s=None, trace=trace) as s:
            return s.serve([SCRequest(net, {"a": 0.6, "b": 0.5}, k,
                                      options=opts)
                            for k in keys])
    base = serve(None)
    traced = serve(True)
    for b, t in zip(base, traced):
        assert b.keys() == t.keys()
        for k in b:
            assert bool(jnp.array_equal(b[k], t[k]))
