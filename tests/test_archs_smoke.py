"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill->decode continuity on CPU; asserts output shapes
and finiteness (assignment requirement f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import (RunCtx, decode_step, forward, init_params,
                          prefill)
from repro.models.frontend import audio_stub_frames, vq_stub_tokens

B, S = 2, 32
KEY = jax.random.key(0)


def _inputs(cfg):
    if cfg.frontend == "vq_stub":
        tokens = vq_stub_tokens(cfg, B, S, jax.random.key(1))
    else:
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    frames = (audio_stub_frames(cfg, B, jax.random.key(2))
              if cfg.is_encoder_decoder else None)
    return tokens, frames


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)
    tokens, frames = _inputs(cfg)
    logits, aux = forward(cfg, params, tokens, RunCtx(), frames=frames)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert bool(jnp.isfinite(jnp.asarray(aux, jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    """A couple of SGD steps on one batch must reduce next-token loss."""
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)
    tokens, frames = _inputs(cfg)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = forward(cfg, p, tokens, RunCtx(), frames=frames)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    finite = jax.tree.reduce(
        lambda a, g: a and bool(jnp.isfinite(g.astype(jnp.float32)).all()),
        grads, True)
    assert finite, f"{arch}: non-finite grads"
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss1 = loss_fn(params2)
    assert float(loss1) < float(loss0), (arch, float(loss0), float(loss1))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode_step at position S (after prefill of S tokens) must agree with
    the full forward over S+1 tokens — the KV/recurrent caches are faithful."""
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)
    tokens_full, frames = _inputs(cfg)
    extra = jax.random.randint(jax.random.key(3), (B, 1), 0, cfg.vocab_size)
    seq = jnp.concatenate([tokens_full, extra], axis=1)

    # Reference: full forward over S+1 tokens, logits at the last position.
    ref_logits, _ = forward(cfg, params, seq, RunCtx(), frames=frames)
    ref_last = ref_logits[:, -1]

    # Prefill S tokens, then decode token S.
    _, cache = prefill(cfg, params, tokens_full, RunCtx(), frames=frames)
    cache = grow_cache_for_decode(cfg, cache, S + 8)
    enc_out = None
    if cfg.is_encoder_decoder:
        from repro.models.model import encoder_stack
        enc_out = encoder_stack(cfg, params, frames.astype(cfg.dtype), RunCtx())
    step_logits, _ = decode_step(cfg, params, extra, jnp.int32(S), cache,
                                 RunCtx(), enc_out=enc_out)
    got = step_logits[:, 0]

    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_last, np.float32),
                               rtol=0.12, atol=0.12)


def grow_cache_for_decode(cfg, cache, new_len):
    """Pad prefill caches (prompt-length) out to decode capacity."""
    def grow(path_leaf):
        return path_leaf

    def pad_kv(a, target, axis):
        pad = target - a.shape[axis]
        if pad <= 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)

    def fix(leaf):
        return leaf

    import jax
    from repro.models.attention import KVCache, MLACache

    def map_cache(c):
        if isinstance(c, dict):
            return {k: map_cache(v) for k, v in c.items()}
        if isinstance(c, list):
            return [map_cache(v) for v in c]
        if isinstance(c, KVCache):
            axis = c.k.ndim - 3        # seq axis ((units,)B,S,H,hd)
            size = c.k.shape[axis]
            if size >= cfg.local_window and size < new_len and size != cfg.local_window:
                pass
            target = size if size == min(cfg.local_window, new_len) else new_len
            return KVCache(pad_kv(c.k, target, axis), pad_kv(c.v, target, axis))
        if isinstance(c, MLACache):
            axis = c.c_kv.ndim - 2
            return MLACache(pad_kv(c.c_kv, new_len, axis),
                            pad_kv(c.k_rope, new_len, axis))
        return c

    return map_cache(cache)
