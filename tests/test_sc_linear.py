"""ScLinear (the paper's technique inside the LM) — mode equivalence and
noise-model calibration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.mlp import sc_linear


def _cfg(mode, bl=256):
    cfg = reduced_config("qwen3-8b")
    return dataclasses.replace(cfg, sc_mode=mode, sc_bitstream_length=bl)


KEY = jax.random.key(0)
X = jax.random.normal(jax.random.key(1), (8, 32)) * 0.5
W = jax.random.normal(jax.random.key(2), (32, 16)) * 0.3


def test_off_mode_is_exact():
    y = sc_linear(X, W, _cfg("off"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(X @ W), rtol=1e-6)


def test_analytic_mode_unbiased():
    cfg = _cfg("analytic", bl=256)
    ys = [sc_linear(X, W, cfg, key=jax.random.key(i)) for i in range(48)]
    mean = jnp.stack(ys).mean(0)
    exact = X @ W
    resid = float(jnp.abs(mean - exact).mean())
    scale = float(jnp.abs(exact).mean())
    assert resid < 0.15 * scale, (resid, scale)


def test_analytic_noise_shrinks_with_bl():
    errs = []
    for bl in (64, 1024):
        cfg = _cfg("analytic", bl=bl)
        y = sc_linear(X, W, cfg, key=KEY)
        errs.append(float(jnp.abs(y - X @ W).mean()))
    assert errs[1] < errs[0]


def test_exact_mode_matches_ref_oracle_statistics():
    # exact mode = packed-bitstream kernels via the bipolar decomposition;
    # must approximate the true product with ~1/sqrt(BL) relative error.
    cfg = _cfg("exact", bl=256)
    y = sc_linear(X, W, cfg)
    exact = X @ W
    rel = float(jnp.abs(y - exact).mean() / jnp.abs(exact).mean())
    assert rel < 0.5, rel


def test_exact_mode_deterministic_given_seed():
    cfg = _cfg("exact", bl=64)
    y1 = sc_linear(X, W, cfg, seed=3)
    y2 = sc_linear(X, W, cfg, seed=3)
    assert (y1 == y2).all()
    y3 = sc_linear(X, W, cfg, seed=4)
    assert not (y1 == y3).all()


def test_sc_mlp_forward_runs_in_model():
    import repro.models as M
    cfg = dataclasses.replace(reduced_config("qwen3-8b"), sc_mode="analytic",
                              sc_bitstream_length=128)
    params = M.init_params(cfg, KEY)
    tokens = jax.random.randint(jax.random.key(5), (2, 16), 0, cfg.vocab_size)
    logits, _ = M.forward(cfg, params, tokens,
                          M.RunCtx(rng=jax.random.key(6)))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
