"""Multi-device semantics, via subprocesses with forced host device counts
(the in-process suite runs single-device):

* MoE expert-parallel path == dense reference path (the EP all_to_all
  dispatch/combine is a pure re-layout);
* elastic checkpoint restore: save under one mesh shape, restore under
  another (the fault-tolerance contract).
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess multi-device runs: excluded from CI default

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, n_devices: int):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          cwd=REPO, env=env, capture_output=True, text=True,
                          timeout=600)


def test_moe_ep_matches_dense_reference():
    r = _run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import reduced_config
        from repro.models import moe
        from repro.models.common import materialize

        cfg = dataclasses.replace(reduced_config('deepseek-v2-lite-16b'),
                                  capacity_factor=8.0)  # no drops -> exact
        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        p = materialize(moe.moe_params(cfg), jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model),
                              jnp.float32) * 0.1

        y_ref, aux_ref = moe.moe_dense(cfg, p, x)
        ep = jax.jit(lambda p, x: moe.moe_ep(cfg, p, x, mesh, ('data',)))
        y_ep, aux_ep = ep(p, x)
        err = float(jnp.abs(y_ep - y_ref).max())
        scale = float(jnp.abs(y_ref).max())
        assert err < 2e-2 * scale + 1e-4, (err, scale)
        print('MOE_OK', err, scale)
    """, n_devices=4)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MOE_OK" in r.stdout


def test_elastic_restore_across_mesh_shapes(tmp_path):
    d = str(tmp_path / "ck")
    save_code = f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import save
        mesh = jax.make_mesh((4, 1), ('data', 'model'))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh, PS('data', None)))
        save({d!r}, 1, {{'w': w}})
        print('SAVED')
    """
    restore_code = f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import restore
        mesh = jax.make_mesh((1, 2), ('data', 'model'))   # different shape
        like = {{'w': jnp.zeros((8, 8))}}
        sh = {{'w': NamedSharding(mesh, PS(None, 'model'))}}
        out = restore({d!r}, 1, like, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out['w']),
                                      np.arange(64.0).reshape(8, 8))
        assert out['w'].sharding.spec == PS(None, 'model')
        print('RESTORED')
    """
    r1 = _run_py(save_code, n_devices=4)
    assert r1.returncode == 0 and "SAVED" in r1.stdout, r1.stdout + r1.stderr
    r2 = _run_py(restore_code, n_devices=2)
    assert r2.returncode == 0 and "RESTORED" in r2.stdout, r2.stdout + r2.stderr
