"""Optional-hypothesis shim for property tests.

``hypothesis`` is a dev-only dependency; the tier-1 suite must collect and
pass without it.  Import ``given``/``settings``/``st`` from here instead of
from ``hypothesis``: when the real library is installed these are simple
re-exports, otherwise ``@given(...)`` turns the property test into a clean
per-test skip (non-property tests in the same module still run).
"""
from __future__ import annotations

import inspect

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            # Hide the property arguments so pytest doesn't look for fixtures.
            _skipped.__signature__ = inspect.Signature()
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub: strategy constructors are only evaluated inside @given(...),
        which skips before drawing, so any placeholder object works."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
