"""Functional correctness of the Fig. 5 stochastic netlists and the binary
baselines: every circuit, when *executed*, computes what the paper says.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro.core import circuits, executor
from repro.core.gates import restrict_to_reliable

BL = 8192
TOL = 5.0 / np.sqrt(BL)


def run(net, values, bl=BL, seed=0):
    out = executor.execute_value(net, {k: jnp.float32(v) for k, v in values.items()},
                                 jax.random.key(seed), bl)
    return {k: float(v) for k, v in out.items()}


# ------------------------------ stochastic ops ------------------------------------

def test_all_stochastic_circuits_use_reliable_gates():
    for b in (circuits.sc_multiply, circuits.sc_scaled_add, circuits.sc_abs_sub,
              circuits.sc_scaled_div, circuits.sc_sqrt, circuits.sc_exp):
        restrict_to_reliable(b())    # must not raise


@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_multiply(a, b):
    out = run(circuits.sc_multiply(), {"a": a, "b": b})
    assert abs(out["out"] - a * b) < TOL


@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_scaled_add(a, b):
    out = run(circuits.sc_scaled_add(), {"a": a, "b": b})
    assert abs(out["out"] - (a + b) / 2) < TOL


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_abs_sub_correlated(a, b):
    out = run(circuits.sc_abs_sub(), {"a": a, "b": b})
    assert abs(out["out"] - abs(a - b)) < TOL


@pytest.mark.parametrize("a,b", [(0.2, 0.6), (0.5, 0.5), (0.7, 0.1), (0.05, 0.9)])
def test_scaled_division_converges_to_a_over_a_plus_b(a, b):
    # The Gaines JK divider is a stochastic fixed-point iteration; tolerance
    # is looser (autocorrelated output stream).
    out = run(circuits.sc_scaled_div(), {"a": a, "b": b}, bl=16384)
    assert abs(out["Q_next"] - a / (a + b)) < 0.03


def test_sqrt_circuit_matches_its_documented_polynomial():
    # The reconstructed Fig. 5(e) circuit computes 1-(1-c*x)^2 (cost path).
    c = circuits.SQRT_C
    for x in (0.1, 0.4, 0.8):
        out = run(circuits.sc_sqrt(), {"a": x})
        expect = 1.0 - (1.0 - c * x) ** 2
        assert abs(out["out"] - expect) < TOL


@pytest.mark.parametrize("c", [0.5, 0.8, 1.0])
def test_exp_circuit_tracks_exponential(c):
    net = circuits.sc_exp(c)
    for x in (0.1, 0.5, 0.9):
        out = run(net, {"a": x})
        # 5th-order Maclaurin truncation error < 1e-3 for c*x <= 1.
        assert abs(list(out.values())[0] - np.exp(-c * x)) < TOL + 2e-3


def test_exp_rejects_c_out_of_unipolar_range():
    with pytest.raises(ValueError):
        circuits.sc_exp(1.5)


def test_mux_tree_computes_mean():
    from repro.core.gates import Netlist
    net = Netlist("tree")
    leaves = [net.add_pi(f"L{i}", value_key=f"v{i}") for i in range(4)]
    root = circuits.sc_mux_tree(leaves, net)
    net.set_outputs([root])
    vals = {f"v{i}": v for i, v in enumerate((0.1, 0.3, 0.5, 0.9))}
    out = run(net, vals)
    assert abs(out[root] - 0.45) < TOL


# ------------------------------- binary ops ---------------------------------------

@pytest.mark.parametrize("n_bits", [2, 3, 4, 8])
def test_binary_rca_exhaustive_small(n_bits):
    rng = np.random.default_rng(n_bits)
    n = min(1 << (2 * n_bits), 256)
    a = jnp.asarray(rng.integers(0, 1 << n_bits, n), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << n_bits, n), jnp.uint32)
    net = circuits.binary_ripple_carry_adder(n_bits)
    outs = executor.execute_binary(net, circuits.rca_prepare_inputs(a, b, n_bits))
    dec = circuits.rca_decode_outputs(outs, n_bits)
    assert (np.asarray(dec) == np.asarray(a) + np.asarray(b)).all()


def test_binary_nand_serial_adder_is_slower_than_row_parallel():
    from repro.core.scheduler import schedule
    serial = schedule(circuits.binary_adder_nand_serial(8))
    rowpar = schedule(circuits.binary_ripple_carry_adder(8))
    assert serial.logic_cycles > rowpar.logic_cycles
    assert serial.n_rows == 1


def test_binary_structural_circuits_have_plausible_size():
    # Cost-accounting constructions: sanity-check their scale against Table 2
    # (binary multiplier 16x161 cells => hundreds of gates; divider larger).
    mul = circuits.binary_multiplier(8)
    div = circuits.binary_divider(8)
    sqrt = circuits.binary_sqrt(8)
    exp = circuits.binary_exp(8)
    add = circuits.binary_ripple_carry_adder(8)
    assert len(mul.gates) > 3 * len(add.gates)
    assert len(div.gates) > len(mul.gates)
    assert len(sqrt.gates) > len(add.gates)
    assert len(exp.gates) > len(add.gates)
