"""Stoch-IMC [n, m] architecture model tests (Section 4-3 worked examples,
Table 2/3 qualitative structure, Eq. (11) lifetime).
"""

from repro.core import circuits
from repro.core.arch import StochIMCConfig, evaluate_binary_imc, evaluate_sc_cram, \
    evaluate_stoch_imc, lifetime_improvement
from repro.core.scheduler import schedule

CFG = StochIMCConfig()  # paper setup: n=m=16, 256x256 subarrays, BL=256


def test_hierarchical_accumulation_n_plus_m():
    # Section 4-3 example: 256-bit bitstream, n=m=16 -> 32 steps vs 256.
    assert CFG.accumulation_steps() == 32
    assert CFG.accumulation_steps_ungrouped() == 256


def test_accumulator_register_widths():
    from repro.core.energy import accumulator_register_bits
    local, glob = accumulator_register_bits(16, 16)
    assert local == 5       # floor(log2(16)) + 1
    assert glob == 9        # floor(log2(256)) + 1


def compute_cycles(cost):
    """Table 2 'computation part' accounting: exclude StoB accumulation (the
    conversion happens once per application output, not per operation)."""
    return cost.total_cycles - cost.accumulation_cycles


def test_stoch_multiply_beats_binary_multiply_on_cycles():
    # Table 2: stochastic multiplication total time = 0.012X of binary.
    s_sch = schedule(circuits.sc_multiply(), n_lanes=256)
    s_cost = evaluate_stoch_imc(circuits.sc_multiply(), s_sch, CFG)
    b_sch = schedule(circuits.binary_multiplier(8))
    b_cost = evaluate_binary_imc(circuits.binary_multiplier(8), b_sch, CFG)
    ratio = compute_cycles(s_cost) / compute_cycles(b_cost)
    assert ratio < 0.05, ratio     # paper: 0.012X — well over an order


def test_stoch_addition_slower_area_but_faster_time_than_binary():
    # Table 2 scaled addition: area 20x binary, time 0.056X binary.  The
    # paper's binary-addition baseline is the 1x88 single-row serial layout.
    s_sch = schedule(circuits.sc_scaled_add(), n_lanes=256)
    s_cost = evaluate_stoch_imc(circuits.sc_scaled_add(), s_sch, CFG)
    b_net = circuits.binary_adder_nand_serial(8)
    b_sch = schedule(b_net)
    b_cost = evaluate_binary_imc(b_net, b_sch, CFG)
    assert compute_cycles(s_cost) < 0.15 * compute_cycles(b_cost)
    assert s_cost.cells_used > b_cost.cells_used     # the area trade-off


def test_sc_cram_bit_serial_is_much_slower_than_stoch_imc():
    # [22] repeats the per-bit circuit BL times in one subarray.
    net = circuits.sc_multiply()
    sch_lanes = schedule(net, n_lanes=256)
    sch_1 = schedule(net, n_lanes=1)
    ours = evaluate_stoch_imc(net, sch_lanes, CFG)
    theirs = evaluate_sc_cram(net, sch_1, CFG)
    assert compute_cycles(theirs) > 50 * compute_cycles(ours)


def test_pipeline_passes_scale_with_bitstream_demand():
    net = circuits.sc_multiply()
    sch = schedule(net, n_lanes=1)      # 1 lane/subarray -> 256 lanes/pass
    cost1 = evaluate_stoch_imc(net, sch, CFG, n_instances=1)
    cost4 = evaluate_stoch_imc(net, sch, CFG, n_instances=4)
    assert cost1.n_passes == 1
    assert cost4.n_passes == 4
    assert compute_cycles(cost4) == 4 * compute_cycles(cost1)


def test_parallel_mode_collapses_passes():
    net = circuits.sc_multiply()
    sch = schedule(net, n_lanes=1)
    pipe = evaluate_stoch_imc(net, sch, CFG, n_instances=4)
    par_cfg = StochIMCConfig(mode="parallel", n_banks=4)
    par = evaluate_stoch_imc(net, sch, par_cfg, n_instances=4)
    assert par.total_cycles < pipe.total_cycles


def test_lifetime_stoch_beats_sc_cram_by_orders_of_magnitude():
    # Fig. 11: 216.3X average over [22] — bit-serial reuse hammers one subarray.
    net = circuits.sc_multiply()
    ours = evaluate_stoch_imc(net, schedule(net, n_lanes=256), CFG)
    cram = evaluate_sc_cram(net, schedule(net, n_lanes=1), CFG)
    imp = lifetime_improvement(ours, cram)
    assert imp > 50, imp


def test_energy_breakdown_shares_sum_to_one():
    net = circuits.sc_scaled_add()
    cost = evaluate_stoch_imc(net, schedule(net, n_lanes=256), CFG)
    shares = cost.energy.shares()
    assert abs(sum(shares.values()) - 1.0) < 1e-9
    assert all(v >= 0 for v in shares.values())
    # Fig. 10: logic + preset dominate in stochastic methods.
    assert shares["logic"] + shares["preset"] > shares["peripheral"]
