"""Shared test config.

NOTE: tests must see the single real CPU device — the 512-device
XLA_FLAGS override belongs to launch/dryrun.py ONLY.

``hypothesis`` is optional: when installed, the fast profile below is
registered; when absent, property tests skip per-test via tests/_hyp.py.

``pytest-timeout`` is likewise optional: the ``timeout`` ini option in
pyproject.toml guards the suite against a hung serving drive loop.  When
the plugin is absent this conftest registers the option itself and enforces
it with a SIGALRM fallback (main-thread, POSIX only — a no-op elsewhere,
matching the plugin's own signal-method constraints).
"""
import os
import signal
import sys
import threading

import pytest

# Make `import repro` work without an editable install.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "fast",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("fast")

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ModuleNotFoundError:
    _HAVE_PYTEST_TIMEOUT = False


def pytest_addoption(parser):
    # pytest-timeout owns the `timeout` ini key when installed; claim it
    # only for the fallback so the two never double-register.
    if not _HAVE_PYTEST_TIMEOUT:
        parser.addini("timeout",
                      "per-test timeout in seconds (SIGALRM fallback)",
                      default="0")


if not _HAVE_PYTEST_TIMEOUT:

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        try:
            limit = float(item.config.getini("timeout") or 0)
        except (TypeError, ValueError):
            limit = 0.0
        if (limit <= 0 or not hasattr(signal, "SIGALRM")
                or threading.current_thread() is not threading.main_thread()):
            yield
            return

        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded {limit:.0f}s (conftest SIGALRM fallback; "
                "install pytest-timeout for richer reporting)")

        old = signal.signal(signal.SIGALRM, _on_alarm)
        signal.setitimer(signal.ITIMER_REAL, limit)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)
