"""Shared test config.

NOTE: tests must see the single real CPU device — the 512-device
XLA_FLAGS override belongs to launch/dryrun.py ONLY.

``hypothesis`` is optional: when installed, the fast profile below is
registered; when absent, property tests skip per-test via tests/_hyp.py.
"""
import os
import sys

# Make `import repro` work without an editable install.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "fast",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("fast")
