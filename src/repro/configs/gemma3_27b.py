"""gemma3-27b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-27b].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; head_dim=128
(explicit, not d_model/n_heads — the Gemma convention); sliding window 1024
on local layers; qk-norm.  Runs long_500k: 5/6 of layers are windowed
(ring-buffer caches); the 1-in-6 global layers keep a full, seq-sharded KV
cache.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        qk_norm=True, local_window=1024,
        layer_pattern=("local_attn",) * 5 + ("attn",), mlp_kind="dense",
        remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke", family="dense",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        qk_norm=True, local_window=16,
        layer_pattern=("local_attn",) * 5 + ("attn",), mlp_kind="dense",
    )
