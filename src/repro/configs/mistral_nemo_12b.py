"""mistral-nemo-12b [dense] — 128k context [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; head_dim=128.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072,
        layer_pattern=("attn",), mlp_kind="dense", remat="full",
        rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        layer_pattern=("attn",), mlp_kind="dense",
    )
