"""llama4-scout-17b-a16e [moe] — top-1 MoE + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff_expert=8192 vocab=202048; 16 routed
experts, top-1 routing, one always-on shared expert; early-fusion multimodal
input via the VQ-token stub (text + image tokens share the vocabulary).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        layer_pattern=("attn",), mlp_kind="moe",
        n_experts=16, n_shared_experts=1, top_k=1, d_ff_expert=8192,
        frontend="vq_stub", remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        layer_pattern=("attn",), mlp_kind="moe",
        n_experts=4, n_shared_experts=1, top_k=1, d_ff_expert=128,
        frontend="vq_stub",
    )
