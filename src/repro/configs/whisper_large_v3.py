"""whisper-large-v3 [audio] — encoder-decoder [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866; head_dim=64.  The conv+mel frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, 1280).  Decoder-only decode
shapes attach a 32k self-attention cache (the assigned shape, beyond the
model's native 448-token decoder context — shapes are exercised as given).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab_size=51866,
        layer_pattern=("attn",), mlp_kind="dense",
        is_encoder_decoder=True, n_encoder_layers=32, encoder_seq=1500,
        frontend="audio_stub", remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke", family="encdec",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        layer_pattern=("attn",), mlp_kind="dense",
        is_encoder_decoder=True, n_encoder_layers=2, encoder_seq=16,
        frontend="audio_stub",
    )
