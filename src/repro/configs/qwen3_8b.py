"""qwen3-8b [dense] — qk-norm GQA [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936; head_dim=128;
per-head RMS qk-norm (the Qwen3 hallmark).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=12288, vocab_size=151936,
        qk_norm=True, layer_pattern=("attn",), mlp_kind="dense", remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        qk_norm=True, layer_pattern=("attn",), mlp_kind="dense",
    )
