"""chameleon-34b [vlm] — early-fusion VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  The VQ image
tokenizer is a stub: image tokens share the 65536-entry vocabulary
(frontend.vq_stub_tokens); the backbone is a dense decoder with qk-norm
(Chameleon's training-stability fix).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=65536,
        qk_norm=True, layer_pattern=("attn",), mlp_kind="dense",
        frontend="vq_stub", remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke", family="vlm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        qk_norm=True, layer_pattern=("attn",), mlp_kind="dense",
        frontend="vq_stub",
    )
