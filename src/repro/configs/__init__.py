from .registry import (ARCHS, LONG_CONTEXT_ARCHS, SHAPES, ShapeSpec,
                       get_config, reduced_config, runnable_cells,
                       skipped_cells, token_specs)

__all__ = ["ARCHS", "LONG_CONTEXT_ARCHS", "SHAPES", "ShapeSpec", "get_config",
           "reduced_config", "runnable_cells", "skipped_cells", "token_specs"]
