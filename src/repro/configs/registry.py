"""Architecture registry: ``--arch <id>`` resolution, the four assigned input
shapes, long-context applicability, and abstract ``input_specs`` for dry-runs.

Shapes (assignment):
    train_4k     seq=4096    global_batch=256   (training, lowers train_step)
    prefill_32k  seq=32768   global_batch=32    (inference prefill)
    decode_32k   seq=32768   global_batch=128   (one-token decode vs 32k cache)
    long_500k    seq=524288  global_batch=1     (long-context decode;
                 sub-quadratic archs only — skips documented in DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

from . import (chameleon_34b, deepseek_v2_lite_16b, gemma3_27b,
               llama4_scout_17b_a16e, mistral_large_123b, mistral_nemo_12b,
               qwen3_8b, recurrentgemma_9b, rwkv6_1_6b, whisper_large_v3)

_MODULES = {
    "chameleon-34b": chameleon_34b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "gemma3-27b": gemma3_27b,
    "mistral-large-123b": mistral_large_123b,
    "qwen3-8b": qwen3_8b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "whisper-large-v3": whisper_large_v3,
    "rwkv6-1.6b": rwkv6_1_6b,
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Sub-quadratic architectures that run long_500k (DESIGN.md §4): windowed /
# recurrent layers dominate; the rest are pure full-attention — skipped.
LONG_CONTEXT_ARCHS = ("rwkv6-1.6b", "recurrentgemma-9b", "gemma3-27b")


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return _MODULES[arch].config()


def reduced_config(arch: str) -> ModelConfig:
    return _MODULES[arch].reduced()


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule."""
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            cells.append((arch, shape))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in ARCHS:
        if arch not in LONG_CONTEXT_ARCHS:
            out.append((arch, "long_500k",
                        "pure full attention — O(S^2)/O(S·cache) at 500k; "
                        "sub-quadratic requirement not met (DESIGN.md §4)"))
    return out


# ------------------------------ input specs ---------------------------------------

def token_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract inputs for the given entry point (ShapeDtypeStruct only)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        d = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
             "labels": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode
        d = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
             "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.is_encoder_decoder:
        if shape.kind == "decode":
            # Decode consumes the precomputed encoder output.
            d["enc_out"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                                cfg.dtype)
        else:
            d["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                               cfg.dtype)
    return d
