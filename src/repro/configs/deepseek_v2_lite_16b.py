"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff_expert=1408 vocab=102400; MLA kv_lora=512
(qk_nope=128, qk_rope=64, v_head=128); 2 shared + 64 routed experts, top-6;
layer 0 uses a dense MLP (d_ff=10944), per the HF config.

NOTE: the assignment line reads "2 shared+160 routed"; 160 routed belongs to
full DeepSeek-V2 — the Lite model (and the same line's "MoE 64e top-6") has
64 routed experts [hf:deepseek-ai/DeepSeek-V2-Lite].  We implement 64
(documented in DESIGN.md §Arch-applicability).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab_size=102400,
        layer_pattern=("mla",), mlp_kind="moe",
        use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
        first_layer_dense=True, d_ff_first=10944, remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        layer_pattern=("mla",), mlp_kind="moe",
        use_mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        n_experts=8, n_shared_experts=2, top_k=2, d_ff_expert=64,
        first_layer_dense=True, d_ff_first=256,
    )
