"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427 (Griffin)].

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000; sliding window 2048.
Sub-quadratic (runs long_500k): recurrence is O(1)-state, attention is
windowed.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab_size=256000,
        layer_pattern=("rglru", "rglru", "local_attn"), mlp_kind="dense",
        local_window=2048, rglru_width=4096, conv_width=4, remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512,
        layer_pattern=("rglru", "rglru", "local_attn"), mlp_kind="dense",
        local_window=16, rglru_width=64, conv_width=4,
    )
