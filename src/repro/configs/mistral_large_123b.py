"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768; head_dim=128.
The largest assigned dense model — the FSDP/TP stress test.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=28672, vocab_size=32768,
        layer_pattern=("attn",), mlp_kind="dense", remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=512,
        layer_pattern=("attn",), mlp_kind="dense",
    )
