"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay
[arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536; WKV head_dim=64 (32 heads).
Attention-free: O(1)-state decode, runs long_500k natively.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536,
        layer_pattern=("rwkv",), mlp_kind="rwkv",
        rwkv_head_dim=64, remat="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        layer_pattern=("rwkv",), mlp_kind="rwkv",
        rwkv_head_dim=16,
    )
