from .rules import (ShardingRules, batch_axes, cache_pspec_tree, make_rules,
                    param_pspec_tree, validate_divisibility)

__all__ = ["ShardingRules", "batch_axes", "cache_pspec_tree", "make_rules",
           "param_pspec_tree", "validate_divisibility"]
