"""Logical-axis -> mesh-axis sharding rules (MaxText-style, one table).

Parallelism map (single-pod mesh (data=16, model=16); multi-pod adds a
leading "pod" axis used as outer data parallelism by default):

  * TP  (model): attention heads, kv heads, d_ff columns, experts, vocab.
  * FSDP (data [+pod]): every weight's `embed` dimension — parameters and
    optimizer state shard across the data axis; XLA inserts the per-layer
    all-gathers (one per scan step under scan-over-layers).
  * EP  (model): MoE experts (explicit all_to_all inside shard_map).
  * SP  (model): optional sequence sharding of boundary activations
    (Megatron-SP; a §Perf hillclimb lever — `seq_shard=True`).

Divisibility: any rule whose mesh-axis product does not divide the tensor
dimension is dropped for that leaf (e.g. whisper's 20 heads on a 16-way model
axis -> replicated heads; its vocab 51866 -> replicated vocab).  This is
what lets one table serve all 10 architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as PS

from repro.models.common import P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: dict[str, Any]                  # logical axis -> mesh axis (or tuple)
    batch: Any                             # mesh axes for the batch dimension
    seq_shard: bool = False                # Megatron-SP activation sharding

    def act_spec(self) -> PS:
        """Boundary activation (B, S, D) spec."""
        if self.seq_shard:
            return PS(self.batch, "model", None)
        return PS(self.batch, None, None)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(mesh: Mesh, *, seq_shard: bool = False,
               fsdp: bool = True) -> ShardingRules:
    fs = (("pod", "data") if "pod" in mesh.axis_names else "data") if fsdp else None
    rules = {
        "embed": fs,            # FSDP dimension
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "expert_mlp": None,
        "experts": "model",     # EP
        "kv_lora": None,
        "layers": None,         # scan axis — never sharded
    }
    return ShardingRules(mesh=mesh, rules=rules, batch=batch_axes(mesh),
                         seq_shard=seq_shard)


def _axis_size(mesh: Mesh, assignment) -> int:
    if assignment is None:
        return 1
    if isinstance(assignment, (tuple, list)):
        n = 1
        for a in assignment:
            n *= mesh.shape[a]
        return n
    return mesh.shape[assignment]


def _spec_for(decl: P, sr: ShardingRules) -> PS:
    entries = []
    for dim, axis in zip(decl.shape, decl.axes):
        assignment = sr.rules.get(axis) if axis is not None else None
        if assignment is not None and dim % _axis_size(sr.mesh, assignment) != 0:
            assignment = None              # divisibility fallback: replicate
        entries.append(assignment)
    return PS(*entries)


def param_pspec_tree(skeleton: Any, sr: ShardingRules) -> Any:
    """P-declaration tree -> PartitionSpec tree under the rules table."""
    return jax.tree.map(lambda d: _spec_for(d, sr), skeleton,
                        is_leaf=lambda x: isinstance(x, P))


def validate_divisibility(skeleton: Any, sr: ShardingRules) -> list[str]:
    """Report leaves where a rule was dropped (for DESIGN/EXPERIMENTS notes)."""
    notes = []

    def visit(path, decl):
        for dim, axis in zip(decl.shape, decl.axes):
            assignment = sr.rules.get(axis) if axis is not None else None
            if assignment is not None and dim % _axis_size(sr.mesh, assignment) != 0:
                notes.append(f"{'/'.join(map(str, path))}: {axis}={dim} not "
                             f"divisible by {assignment} -> replicated")

    jax.tree_util.tree_map_with_path(
        lambda p, d: visit([getattr(k, 'key', getattr(k, 'idx', k)) for k in p], d),
        skeleton, is_leaf=lambda x: isinstance(x, P))
    return notes


# ------------------------------ cache sharding -------------------------------------

def cache_pspec_tree(cfg, cache_shapes: Any, sr: ShardingRules,
                     decode_tp: bool = False) -> Any:
    """PartitionSpecs for decode caches.

    Policy (DESIGN.md §5):
      * batch dim -> data axes when divisible (decode_32k B=128), else
        replicated (long_500k B=1, where seq picks up the data axes too);
      * KV-head dim -> model when divisible (gemma3 kv=16), else the
        *sequence* dim shards over model (kv=8/20/1 cases) — the cache is the
        decode memory hog and must not be replicated on the model axis;
      * recurrent states (small) -> batch over data only.
    """
    mesh = sr.mesh
    model_n = mesh.shape["model"]
    data_n = _axis_size(mesh, sr.batch)

    # Cache kinds are identified structurally by their tree path:
    #   KV cache:  (units?, B, S, KVH, hd);  MLA: (units?, B, S, r|rope);
    #   recurrent states: (units?, B, ...) — small, batch-sharded only.
    def visit(path, leaf):
        shape = leaf.shape
        names = [getattr(k, 'key', None) or getattr(k, 'name', '') or str(getattr(k, 'idx', ''))
                 for k in path]
        joined = "/".join(str(n) for n in names)
        stacked = "scan" in joined
        off = 1 if stacked else 0
        batch_ok = (shape[off] % data_n == 0 if len(shape) > off else False) \
            and not decode_tp
        b_axis = sr.batch if batch_ok else None
        if "kv" in joined or "mla" in joined:
            # (units?, B, S, ...) tensors
            entries = [None] * len(shape)
            if len(shape) > off:
                entries[off] = b_axis
            if len(shape) > off + 1:
                seq_entries = []
                if not batch_ok:
                    seq_entries.extend(sr.batch if isinstance(sr.batch, tuple)
                                       else (sr.batch,))
                kvh_ok = (len(shape) == off + 4 and shape[off + 2] % model_n == 0
                          and not decode_tp)
                if kvh_ok:
                    entries[off + 2] = "model"
                else:
                    seq_entries.append("model")
                seq_assign = tuple(seq_entries) if seq_entries else None
                if seq_assign is not None and shape[off + 1] % _axis_size(
                        mesh, seq_assign) != 0:
                    # fall back to progressively fewer axes
                    for cand in (("model",), None):
                        if cand is None or shape[off + 1] % _axis_size(
                                mesh, cand) == 0:
                            seq_assign = cand
                            break
                entries[off + 1] = seq_assign
            return PS(*entries)
        # recurrent / small states: batch over data when divisible
        entries = [None] * len(shape)
        if len(shape) > off:
            entries[off] = b_axis
        return PS(*entries)

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)
