"""MLP blocks: SwiGLU (dense), RWKV channel-mix, and ScLinear — the paper's
stochastic-computing arithmetic as an approximate-matmul mode inside the LM.

ScLinear modes (cfg.sc_mode):
  * ``off``      — exact matmul (baseline; all full-size dry-runs).
  * ``analytic`` — exact mean + the *closed-form* SC sampling noise of
    popcount(AND)/BL estimation: for unipolar operands p = a*w per product,
    Var = p(1-p)/BL, independent across k ⇒
        Var[y] = (|a|@|w| - (a*w)^2-sum) / BL        (derived below)
    Scales to full configs (no bitstream materialization): this is how the
    paper's technique rides along in large-scale dry-runs.
  * ``exact``    — packed-bitstream kernels (kernels/sc_matmul): bit-identical
    to the Pallas path; smoke scale only (BL/32 words per product).

Signed values use the bipolar decomposition x = x⁺ - x⁻ (four unipolar
matmuls), with per-tensor max-abs scaling into [0, 1] — the same
unipolar-encoding restriction the paper's applications live under.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import P, ModelConfig, ein


def mlp_params(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "w_in": P((d, f), ("embed", "mlp")),
        "w_gate": P((d, f), ("embed", "mlp")),
        "w_out": P((f, d), ("mlp", "embed")),
    }


def rwkv_channel_mix_params(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "mu_k": P((d,), ("embed",), init="zeros"),
        "mu_r": P((d,), ("embed",), init="zeros"),
        "w_k": P((d, f), ("embed", "mlp")),
        "w_r": P((d, d), ("embed", "mlp")),
        "w_v": P((f, d), ("mlp", "embed")),
    }


# ------------------------------- ScLinear ----------------------------------------

def _sc_unipolar_matmul_analytic(a: jax.Array, w: jax.Array, bl: int,
                                 key: jax.Array) -> jax.Array:
    """E + noise model of popcount(AND)/BL for unipolar a, w in [0,1]."""
    mean = a @ w
    # Var[popcount/BL] per product p=a_k w_k is p(1-p)/BL; sum over k:
    #   sum_k a_k w_k - sum_k (a_k w_k)^2
    var = jnp.maximum(mean - (a * a) @ (w * w), 0.0) / bl
    noise = jax.random.normal(key, mean.shape, mean.dtype) * jnp.sqrt(var)
    return mean + noise


def sc_linear(x: jax.Array, w: jax.Array, cfg: ModelConfig,
              key: jax.Array | None = None, seed: int = 0) -> jax.Array:
    """Stochastic-computing linear layer: x (..., K) @ w (K, N).

    Bipolar decomposition into four unipolar matmuls, each estimated by the
    SC AND/popcount scheme at cfg.sc_bitstream_length.
    """
    if cfg.sc_mode == "off":
        return x @ w

    orig_shape = x.shape
    xm = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    wm = w.astype(jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(xm)), 1e-6)
    sw = jnp.maximum(jnp.max(jnp.abs(wm)), 1e-6)
    xp, xn = jnp.maximum(xm, 0) / sx, jnp.maximum(-xm, 0) / sx
    wp, wn = jnp.maximum(wm, 0) / sw, jnp.maximum(-wm, 0) / sw
    bl = cfg.sc_bitstream_length

    if cfg.sc_mode == "analytic":
        assert key is not None, "analytic sc_mode needs an rng key"
        ks = jax.random.split(key, 4)
        pp = _sc_unipolar_matmul_analytic(xp, wp, bl, ks[0])
        nn = _sc_unipolar_matmul_analytic(xn, wn, bl, ks[1])
        pn = _sc_unipolar_matmul_analytic(xp, wn, bl, ks[2])
        np_ = _sc_unipolar_matmul_analytic(xn, wp, bl, ks[3])
    elif cfg.sc_mode == "exact":
        from repro.kernels import ops
        pp = ops.sc_matmul(xp, wp, bl, seed=4 * seed + 0)
        nn = ops.sc_matmul(xn, wn, bl, seed=4 * seed + 1)
        pn = ops.sc_matmul(xp, wn, bl, seed=4 * seed + 2)
        np_ = ops.sc_matmul(xn, wp, bl, seed=4 * seed + 3)
    else:
        raise ValueError(cfg.sc_mode)
    y = (pp + nn - pn - np_) * (sx * sw)
    return y.reshape(orig_shape[:-1] + (w.shape[-1],)).astype(x.dtype)


# ------------------------------- blocks ------------------------------------------

def mlp_fwd(cfg: ModelConfig, p: dict, x: jax.Array,
            sc_key: jax.Array | None = None) -> jax.Array:
    """SwiGLU MLP; when sc_mode != off the in/gate projections run through the
    stochastic-computing path (the down-projection stays exact — it consumes
    signed activations with large dynamic range, the worst case for unipolar
    SC; documented in DESIGN.md §4)."""
    dt = x.dtype
    w_in, w_gate, w_out = (p["w_in"].astype(dt), p["w_gate"].astype(dt),
                           p["w_out"].astype(dt))
    if cfg.sc_mode == "off":
        h = ein("bsd,df->bsf", x, w_in)
        g = ein("bsd,df->bsf", x, w_gate)
    else:
        k1, k2 = (jax.random.split(sc_key) if sc_key is not None else (None, None))
        h = sc_linear(x, w_in, cfg, k1, seed=0)
        g = sc_linear(x, w_gate, cfg, k2, seed=1)
    return ein("bsf,fd->bsd", jax.nn.silu(g) * h, w_out)


def rwkv_channel_mix_fwd(cfg: ModelConfig, p: dict, x: jax.Array,
                         x_prev: jax.Array) -> jax.Array:
    """RWKV-6 channel mix: token-shifted squared-ReLU MLP with a receptance
    gate.  x, x_prev: (B, S, D) (x_prev = x shifted right by one token)."""
    dt = x.dtype
    mk = x + (x_prev - x) * p["mu_k"].astype(dt)
    mr = x + (x_prev - x) * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(mk @ p["w_k"].astype(dt)))
    r = jax.nn.sigmoid(mr @ p["w_r"].astype(dt))
    return r * (k @ p["w_v"].astype(dt))
