"""Mixture-of-Experts with expert parallelism (DeepSeek-V2 / Llama-4 style).

Two execution paths with identical math:
  * ``moe_dense`` — reference path (no mesh): every expert computes every
    token, masked combine.  Used for single-device smoke tests and as the
    numerical oracle for the EP path.
  * ``moe_ep``    — production path: capacity-bucketed token dispatch inside
    ``shard_map``, experts sharded over the ``model`` mesh axis, with explicit
    ``all_to_all`` dispatch/combine collectives (the pattern the multi-pod
    dry-run must exhibit for MoE architectures).

Routing: softmax router, top-k token choice, optional shared experts
(always-on dense experts, DeepSeek).  Capacity: C = ceil(T_local * k / E * cf);
overflowed tokens are dropped (their combine weight is zero) — standard
GShard semantics; the load-balance auxiliary loss discourages overflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # jax < 0.6 exposes it under jax.experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS

from .common import P, ModelConfig


def moe_params(cfg: ModelConfig) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": P((d, e), ("embed", None)),
        "w_in": P((e, d, fe), ("experts", "embed", "expert_mlp")),
        "w_gate": P((e, d, fe), ("experts", "embed", "expert_mlp")),
        "w_out": P((e, fe, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        p["shared_in"] = P((d, fs), ("embed", "mlp"))
        p["shared_gate"] = P((d, fs), ("embed", "mlp"))
        p["shared_out"] = P((fs, d), ("mlp", "embed"))
    return p


def _expert_ffn(w_in, w_gate, w_out, x):
    """SwiGLU expert: x (E, C, d) with per-expert weights (E, d, f)."""
    h = jnp.einsum("ecd,edf->ecf", x, w_in)
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)


def _route(cfg: ModelConfig, router_w, x_tokens):
    """x_tokens (T, d) -> (weights (T,k), experts (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x_tokens.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Load-balance loss (Switch): E * sum_e f_e * P_e
    e = cfg.n_experts
    onehot = jax.nn.one_hot(experts[:, 0], e)           # primary assignment
    f = onehot.mean(0)
    p_mean = probs.mean(0)
    aux = e * jnp.sum(f * p_mean)
    return weights, experts, aux


def moe_dense(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference/smoke path: all experts on all tokens, masked combine."""
    b, s, d = x.shape
    t = x.reshape(b * s, d)
    weights, experts, aux = _route(cfg, p["router"], t)
    dt = x.dtype
    h = jnp.einsum("td,edf->etf", t, p["w_in"].astype(dt))
    g = jnp.einsum("td,edf->etf", t, p["w_gate"].astype(dt))
    y_all = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * h, p["w_out"].astype(dt))
    combine = jnp.zeros((t.shape[0], cfg.n_experts), jnp.float32)
    for j in range(cfg.top_k):
        combine = combine + weights[:, j:j + 1] * jax.nn.one_hot(experts[:, j],
                                                                 cfg.n_experts)
    y = jnp.einsum("etd,te->td", y_all.astype(jnp.float32), combine)
    y = y.astype(dt) + _shared(cfg, p, t)
    return y.reshape(b, s, d), aux


def _shared(cfg: ModelConfig, p: dict, t: jax.Array) -> jax.Array:
    if not cfg.n_shared_experts:
        return jnp.zeros_like(t)
    h = t @ p["shared_in"].astype(t.dtype)
    g = t @ p["shared_gate"].astype(t.dtype)
    return (jax.nn.silu(g) * h) @ p["shared_out"].astype(t.dtype)


def moe_ep(cfg: ModelConfig, p: dict, x: jax.Array, mesh: Mesh,
           data_axes: tuple[str, ...], model_axis: str = "model"
           ) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map + all_to_all.

    x is sharded (batch over ``data_axes``); expert weights are sharded over
    ``model_axis``.  Inside the per-device block:
      route -> capacity-bucket by expert -> all_to_all (tokens to expert
      owners) -> local expert FFN -> all_to_all back -> weighted combine.
    """
    ep = mesh.shape[model_axis]
    e_total = cfg.n_experts
    assert e_total % ep == 0, (e_total, ep)
    batch_spec = PS(data_axes, None, None)

    e_local = e_total // ep

    def block(router_w, w_in, w_gate, w_out, x_local):
        bl, s, d = x_local.shape
        t = x_local.reshape(bl * s, d)
        n_tok = t.shape[0]
        weights, experts, aux = _route(cfg, router_w, t)
        cap = int(n_tok * cfg.top_k * cfg.capacity_factor / e_total) + 1

        # Flatten (token, k) assignments, bucket by expert with capacity.
        flat_e = experts.reshape(-1)                       # (T*k,)
        flat_t = jnp.repeat(jnp.arange(n_tok), cfg.top_k)
        flat_w = weights.reshape(-1)
        order = jnp.argsort(flat_e)                        # stable
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        # Position of each assignment within its expert bucket.
        pos_in_e = jnp.arange(se.shape[0]) - jnp.searchsorted(se, se, side="left")
        keep = pos_in_e < cap
        slot = jnp.where(keep, se * cap + pos_in_e, e_total * cap)  # overflow bin
        # Gather tokens into (E*cap, d) buffer (+1 overflow row, dropped).
        buf = jnp.zeros((e_total * cap + 1, d), t.dtype).at[slot].set(t[st])
        buf = buf[:-1].reshape(ep, e_local, cap, d)

        # Dispatch all_to_all (tiled): device j keeps its e_local experts and
        # receives cap slots from every source device -> (e_local, cap*ep, d).
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=2,
                                 tiled=True)[0]
        y_loc = _expert_ffn(w_in, w_gate, w_out, buf)      # local expert shard
        # Combine all_to_all: route each cap-block back to its source device.
        y = jax.lax.all_to_all(y_loc[None], model_axis, split_axis=2,
                               concat_axis=0, tiled=True)  # (ep, e_local, cap, d)
        y_buf = y.reshape(e_total * cap, d)
        y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)], 0)

        # Scatter back: each kept assignment contributes weight * expert-out.
        contrib = y_buf[slot].astype(jnp.float32) * (sw * keep)[:, None]
        y = jnp.zeros((n_tok, d), jnp.float32).at[st].add(contrib)
        y = y.astype(t.dtype)
        if cfg.n_shared_experts:
            y = y + _shared(cfg, {"shared_in": shared_in,
                                  "shared_gate": shared_gate,
                                  "shared_out": shared_out}, t)
        aux = jax.lax.pmean(aux, data_axes + (model_axis,))
        return y.reshape(bl, s, d), aux

    # Shared-expert weights ride along when present.
    if cfg.n_shared_experts:
        shared_in, shared_gate, shared_out = (p["shared_in"], p["shared_gate"],
                                              p["shared_out"])
    else:
        shared_in = shared_gate = shared_out = None

    fn = shard_map(
        block, mesh=mesh,
        in_specs=(PS(), PS(model_axis), PS(model_axis), PS(model_axis),
                  batch_spec),
        out_specs=(batch_spec, PS()),
        check_vma=False,
    )
    return fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], x)
