"""Model assembly: embedding -> layer stack -> head, for all 10 assigned
architectures, with three lowered entry points per model:

  * ``forward``       — full-sequence training/prefill forward (causal)
  * ``prefill``       — forward + KV/recurrent cache construction
  * ``decode_step``   — one-token step against caches

Layer stacking (MaxText-style): the layer pattern (e.g. gemma3's 5 local : 1
global) is grouped into *units*; parameters of all full units are stacked on
a leading axis and applied with ``jax.lax.scan`` — HLO stays compact for
62-layer full-size configs.  A prefix (deepseek's dense layer 0) and the
pattern remainder are unrolled.

Caches mirror the parameter structure: per unit position, stacked over units.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS

from . import attention as att
from . import mlp as mlpmod
from . import moe as moemod
from . import recurrent as rec
from .common import P, ModelConfig, materialize, rms_norm, shard

MIXER_KINDS = ("attn", "local_attn", "mla", "rglru", "rwkv")


# ------------------------------- param skeleton -----------------------------------

def _mixer_params(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("attn", "local_attn"):
        return att.attn_params(cfg)
    if kind == "mla":
        return att.mla_params(cfg)
    if kind == "rglru":
        return rec.rglru_params(cfg)
    if kind == "rwkv":
        return rec.rwkv_params(cfg)
    raise ValueError(kind)


def _mlp_params(cfg: ModelConfig, *, dense_ff: int | None = None,
                force_dense: bool = False) -> dict:
    if cfg.mlp_kind == "rwkv":
        return mlpmod.rwkv_channel_mix_params(cfg)
    if cfg.mlp_kind == "moe" and not force_dense:
        p = moemod.moe_params(cfg)
        p["ln"] = P((cfg.d_model,), ("embed",), init="zeros")
        return p
    return mlpmod.mlp_params(cfg, dense_ff)


def layer_params(cfg: ModelConfig, kind: str, *, force_dense_mlp: bool = False,
                 dense_ff: int | None = None, cross_attn: bool = False) -> dict:
    p = {"mixer": _mixer_params(cfg, kind),
         "mlp": _mlp_params(cfg, dense_ff=dense_ff, force_dense=force_dense_mlp)}
    if cross_attn:
        cp = att.cross_attn_params(cfg)
        cp["ln"] = P((cfg.d_model,), ("embed",), init="zeros")
        p["cross"] = cp
    return p


def _stack_decl(tree: Any, n: int) -> Any:
    """Prepend a stacked `layers` axis to every P declaration."""
    return jax.tree.map(
        lambda d: P((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        tree, is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass(frozen=True)
class StackPlan:
    """How cfg.pattern_layers() maps onto scan units + unrolled layers."""

    prefix: tuple[str, ...]          # unrolled leading layers (kinds)
    pattern: tuple[str, ...]         # one scan unit
    n_units: int
    suffix: tuple[str, ...]          # unrolled trailing layers (kinds)


def stack_plan(cfg: ModelConfig) -> StackPlan:
    kinds = cfg.pattern_layers()
    prefix: tuple[str, ...] = ()
    if cfg.first_layer_dense:                     # deepseek: layer 0 dense MLP
        prefix = (kinds[0],)
        kinds = kinds[1:]
    plen = len(cfg.layer_pattern)
    n_units = len(kinds) // plen
    suffix = tuple(kinds[n_units * plen:])
    return StackPlan(prefix, tuple(cfg.layer_pattern), n_units, suffix)


def model_params(cfg: ModelConfig) -> dict:
    """Skeleton parameter tree (P declarations) for the full model."""
    d, v = cfg.d_model, cfg.vocab_size
    plan = stack_plan(cfg)
    params: dict[str, Any] = {
        "embed": P((v, d), ("vocab", "embed"), scale=1.0),
        "head": P((d, v), ("embed", "vocab")),
        "final_ln": P((d,), ("embed",), init="zeros"),
        "layers": {
            "scan": _stack_decl(
                {f"p{j}": layer_params(cfg, k, cross_attn=cfg.is_encoder_decoder)
                 for j, k in enumerate(plan.pattern)}, plan.n_units),
            "prefix": [layer_params(cfg, k, force_dense_mlp=True,
                                    dense_ff=cfg.d_ff_first or cfg.d_ff,
                                    cross_attn=cfg.is_encoder_decoder)
                       for k in plan.prefix],
            "suffix": [layer_params(cfg, k, cross_attn=cfg.is_encoder_decoder)
                       for k in plan.suffix],
        },
    }
    if cfg.is_encoder_decoder:
        enc_cfg = cfg                                  # same dims (whisper)
        ne = cfg.n_encoder_layers
        params["encoder"] = {
            "pos_emb": P((cfg.encoder_seq, d), (None, "embed"), scale=0.02),
            "layers": _stack_decl({"p0": {
                "mixer": att.attn_params(enc_cfg),
                "mlp": mlpmod.mlp_params(enc_cfg)}}, ne),
            "final_ln": P((d,), ("embed",), init="zeros"),
        }
    return params


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return materialize(model_params(cfg), key, dtype=cfg.param_dtype)


# ------------------------------- context -------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Execution context: mesh + activation sharding specs (None = no mesh)."""

    mesh: Mesh | None = None
    act_spec: PS | None = None          # (batch, seq, d_model)
    use_ep: bool = False                # expert-parallel MoE path
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    rng: jax.Array | None = None        # for sc_mode=analytic


def _moe_fwd(cfg: ModelConfig, p: dict, x: jax.Array, ctx: RunCtx):
    if ctx.use_ep and ctx.mesh is not None:
        return moemod.moe_ep(cfg, p, x, ctx.mesh, ctx.data_axes, ctx.model_axis)
    return moemod.moe_dense(cfg, p, x)


def _mlp_fwd(cfg: ModelConfig, p: dict, x: jax.Array, ctx: RunCtx,
             x_prev: jax.Array | None = None, force_dense: bool = False):
    """Returns (y, aux_loss)."""
    if cfg.mlp_kind == "rwkv":
        xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1] if x_prev is None else x_prev
        return mlpmod.rwkv_channel_mix_fwd(cfg, p, x, xs), 0.0
    if cfg.mlp_kind == "moe" and not force_dense and "router" in p:
        return _moe_fwd(cfg, p, x, ctx)
    return mlpmod.mlp_fwd(cfg, p, x, sc_key=ctx.rng), 0.0


# ------------------------------- train-path blocks --------------------------------

def _mixer_train(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                 positions: jax.Array, ctx: RunCtx, *, causal: bool = True):
    if kind in ("attn", "local_attn"):
        return att.gqa_train(cfg, p, x, positions, is_local=(kind == "local_attn"),
                             causal=causal)
    if kind == "mla":
        return att.mla_train(cfg, p, x, positions)
    if kind == "rglru":
        return rec.rglru_train(cfg, p, x)
    if kind == "rwkv":
        return rec.rwkv_train(cfg, p, x)
    raise ValueError(kind)


def block_train(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                positions: jax.Array, ctx: RunCtx, *, causal: bool = True,
                enc_kv=None, force_dense_mlp: bool = False):
    """Pre-norm residual block; returns (x, aux_loss)."""
    h = rms_norm(x, p["mixer"]["ln"])
    x = x + _mixer_train(cfg, kind, p["mixer"], h, positions, ctx, causal=causal)
    x = shard(x, ctx.act_spec)
    if enc_kv is not None and "cross" in p:
        h = rms_norm(x, p["cross"]["ln"])
        x = x + att.cross_attend(cfg, p["cross"], h, enc_kv)
    h = rms_norm(x, p["mlp"]["ln"])
    y, aux = _mlp_fwd(cfg, p["mlp"], h, ctx, force_dense=force_dense_mlp)
    x = shard(x + y, ctx.act_spec)
    return x, aux


def _maybe_remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def decoder_stack(cfg: ModelConfig, params: dict, x: jax.Array,
                  positions: jax.Array, ctx: RunCtx, enc_kv=None):
    """Apply prefix -> scanned units -> suffix.  Returns (x, aux_loss)."""
    plan = stack_plan(cfg)
    lp = params["layers"]
    aux_total = 0.0

    for kind, p in zip(plan.prefix, lp["prefix"]):
        x, aux = block_train(cfg, kind, p, x, positions, ctx, enc_kv=enc_kv,
                             force_dense_mlp=True)
        aux_total += aux

    if plan.n_units > 0:
        def unit(x, unit_p):
            aux_u = 0.0
            for j, kind in enumerate(plan.pattern):
                x, aux = block_train(cfg, kind, unit_p[f"p{j}"], x, positions,
                                     ctx, enc_kv=enc_kv)
                aux_u += aux
            return x, aux_u

        unit = _maybe_remat(cfg, unit)

        def scan_body(x, unit_p):
            x, aux_u = unit(x, unit_p)
            return x, aux_u

        x, aux_units = jax.lax.scan(scan_body, x, lp["scan"])
        aux_total += jnp.sum(aux_units) if plan.n_units else 0.0

    for kind, p in zip(plan.suffix, lp["suffix"]):
        x, aux = block_train(cfg, kind, p, x, positions, ctx, enc_kv=enc_kv)
        aux_total += aux
    return x, aux_total


def encoder_stack(cfg: ModelConfig, params: dict, frames: jax.Array,
                  ctx: RunCtx) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (B, T, D)."""
    enc = params["encoder"]
    x = frames + enc["pos_emb"][None, : frames.shape[1]].astype(frames.dtype)
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(x, p):
        pp = p["p0"]
        h = rms_norm(x, pp["mixer"]["ln"])
        x = x + att.gqa_train(cfg, pp["mixer"], h, positions, is_local=False,
                              causal=False)
        h = rms_norm(x, pp["mlp"]["ln"])
        x = shard(x + mlpmod.mlp_fwd(cfg, pp["mlp"], h), ctx.act_spec)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, lambda x, p: body(x, p)), x, enc["layers"])
    return rms_norm(x, enc["final_ln"])


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            ctx: RunCtx = RunCtx(), frames: jax.Array | None = None):
    """Training forward: tokens (B, S) -> logits (B, S, V); returns aux loss.

    For encoder-decoder models ``frames`` are the stub frontend embeddings.
    """
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens] * jnp.asarray(
        cfg.d_model ** 0.5, cfg.dtype)
    x = shard(x, ctx.act_spec)
    positions = jnp.arange(s, dtype=jnp.int32)

    enc_kv = None
    if cfg.is_encoder_decoder:
        assert frames is not None, "enc-dec model needs frontend frames"
        enc_out = encoder_stack(cfg, params, frames.astype(cfg.dtype), ctx)
        # Precompute the cross K/V once; all decoder layers share dims but
        # have their own cross projections, so pass enc_out down instead.
        enc_kv = enc_out

    if cfg.is_encoder_decoder:
        x, aux = _encdec_decoder(cfg, params, x, positions, ctx, enc_kv)
    else:
        x, aux = decoder_stack(cfg, params, x, positions, ctx)
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.dtype))
    return logits, aux


def _encdec_decoder(cfg, params, x, positions, ctx, enc_out):
    """Decoder stack for enc-dec: per-layer cross-attention K/V from enc_out."""
    plan = stack_plan(cfg)
    lp = params["layers"]

    def unit(x, unit_p):
        for j, kind in enumerate(plan.pattern):
            p = unit_p[f"p{j}"]
            ekv = att.encode_cross_kv(cfg, p["cross"], enc_out)
            x, _ = block_train(cfg, kind, p, x, positions, ctx, enc_kv=ekv)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, unit), x, lp["scan"])
    for kind, p in zip(plan.suffix, lp["suffix"]):
        ekv = att.encode_cross_kv(cfg, p["cross"], enc_out)
        x, _ = block_train(cfg, kind, p, x, positions, ctx, enc_kv=ekv)
    return x, 0.0


# ------------------------------- decode path --------------------------------------

def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, seq: int,
                     dtype=jnp.bfloat16) -> dict:
    if kind in ("attn", "local_attn"):
        c = att.init_kv_cache(cfg, batch, seq, is_local=(kind == "local_attn"),
                              dtype=dtype)
        return {"kv": c}
    if kind == "mla":
        return {"mla": att.init_mla_cache(cfg, batch, seq, dtype=dtype)}
    if kind == "rglru":
        return {"rec": rec.init_rglru_state(cfg, batch)}
    if kind == "rwkv":
        return {"rec": rec.init_rwkv_state(cfg, batch),
                "cm_prev": jnp.zeros((batch, cfg.d_model), dtype)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> dict:
    """Stacked cache tree mirroring the parameter layout."""
    plan = stack_plan(cfg)

    def stacked(kind):
        one = init_layer_cache(cfg, kind, batch, seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (plan.n_units,) + a.shape), one)

    return {
        "scan": {f"p{j}": stacked(k) for j, k in enumerate(plan.pattern)},
        "prefix": [init_layer_cache(cfg, k, batch, seq, dtype)
                   for k in plan.prefix],
        "suffix": [init_layer_cache(cfg, k, batch, seq, dtype)
                   for k in plan.suffix],
    }


def _mixer_decode(cfg, kind, p, x, pos, cache, ctx):
    if kind in ("attn", "local_attn"):
        y, kv = att.gqa_decode(cfg, p, x, pos, cache["kv"],
                               is_local=(kind == "local_attn"))
        return y, {"kv": kv}
    if kind == "mla":
        y, c = att.mla_decode(cfg, p, x, pos, cache["mla"])
        return y, {"mla": c}
    if kind == "rglru":
        y, st = rec.rglru_decode(cfg, p, x, cache["rec"])
        return y, {"rec": st}
    if kind == "rwkv":
        y, st = rec.rwkv_decode(cfg, p, x, cache["rec"])
        return y, {"rec": st, "cm_prev": cache["cm_prev"]}
    raise ValueError(kind)


def block_decode(cfg, kind, p, x, pos, cache, ctx, enc_out=None,
                 force_dense_mlp=False):
    h = rms_norm(x, p["mixer"]["ln"])
    y, new_cache = _mixer_decode(cfg, kind, p["mixer"], h, pos, cache, ctx)
    x = shard(x + y, ctx.act_spec)
    if enc_out is not None and "cross" in p:
        h = rms_norm(x, p["cross"]["ln"])
        ekv = att.encode_cross_kv(cfg, p["cross"], enc_out)
        x = x + att.cross_attend(cfg, p["cross"], h, ekv)
    h = rms_norm(x, p["mlp"]["ln"])
    if cfg.mlp_kind == "rwkv":
        y, _ = _mlp_fwd(cfg, p["mlp"], h, ctx, x_prev=new_cache["cm_prev"][:, None])
        new_cache = dict(new_cache, cm_prev=h[:, 0])
    else:
        y, _ = _mlp_fwd(cfg, p["mlp"], h, ctx, force_dense=force_dense_mlp)
    return shard(x + y, ctx.act_spec), new_cache


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array,
                pos: jax.Array, cache: dict, ctx: RunCtx = RunCtx(),
                enc_out: jax.Array | None = None):
    """One decode step: tokens (B, 1), pos scalar -> logits (B, 1, V), cache."""
    plan = stack_plan(cfg)
    lp = params["layers"]
    x = params["embed"].astype(cfg.dtype)[tokens] * jnp.asarray(
        cfg.d_model ** 0.5, cfg.dtype)

    for kind, p, i in zip(plan.prefix, lp["prefix"], range(len(plan.prefix))):
        x, cache["prefix"][i] = block_decode(cfg, kind, p, x, pos,
                                             cache["prefix"][i], ctx, enc_out,
                                             force_dense_mlp=True)

    if plan.n_units > 0:
        def scan_body(x, xs):
            unit_p, unit_c = xs
            new_c = {}
            for j, kind in enumerate(plan.pattern):
                x, new_c[f"p{j}"] = block_decode(cfg, kind, unit_p[f"p{j}"], x,
                                                 pos, unit_c[f"p{j}"], ctx,
                                                 enc_out)
            return x, new_c

        x, new_scan = jax.lax.scan(scan_body, x, (lp["scan"], cache["scan"]))
        cache = dict(cache, scan=new_scan)

    for off, (kind, p) in enumerate(zip(plan.suffix, lp["suffix"])):
        x, cache["suffix"][off] = block_decode(cfg, kind, p, x, pos,
                                               cache["suffix"][off], ctx, enc_out)

    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.dtype))
    return logits, cache


# ------------------------------- prefill path -------------------------------------

def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            ctx: RunCtx = RunCtx(), frames: jax.Array | None = None):
    """Full-prompt forward that returns (last-token logits, filled caches).

    Implemented as the train-path forward with per-layer cache extraction —
    the caches come back sized to the prompt length (the decode entry point
    then appends within the same buffers).
    """
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens] * jnp.asarray(
        cfg.d_model ** 0.5, cfg.dtype)
    x = shard(x, ctx.act_spec)
    positions = jnp.arange(s, dtype=jnp.int32)
    plan = stack_plan(cfg)
    lp = params["layers"]

    enc_out = None
    if cfg.is_encoder_decoder:
        assert frames is not None
        enc_out = encoder_stack(cfg, params, frames.astype(cfg.dtype), ctx)

    def fill_block(kind, p, x, force_dense_mlp=False):
        h = rms_norm(x, p["mixer"]["ln"])
        y, c = _mixer_prefill(cfg, kind, p["mixer"], h, positions, ctx)
        x = shard(x + y, ctx.act_spec)
        if enc_out is not None and "cross" in p:
            hh = rms_norm(x, p["cross"]["ln"])
            ekv = att.encode_cross_kv(cfg, p["cross"], enc_out)
            x = x + att.cross_attend(cfg, p["cross"], hh, ekv)
        h = rms_norm(x, p["mlp"]["ln"])
        if cfg.mlp_kind == "rwkv":
            y, _ = _mlp_fwd(cfg, p["mlp"], h, ctx)
            c = dict(c, cm_prev=h[:, -1])
        else:
            y, _ = _mlp_fwd(cfg, p["mlp"], h, ctx, force_dense=force_dense_mlp)
        return shard(x + y, ctx.act_spec), c

    cache: dict[str, Any] = {"prefix": [], "suffix": []}
    for kind, p in zip(plan.prefix, lp["prefix"]):
        x, c = fill_block(kind, p, x, force_dense_mlp=True)
        cache["prefix"].append(c)

    if plan.n_units > 0:
        def scan_body(x, unit_p):
            cs = {}
            for j, kind in enumerate(plan.pattern):
                x, cs[f"p{j}"] = fill_block(kind, unit_p[f"p{j}"], x)
            return x, cs

        x, cache["scan"] = jax.lax.scan(scan_body, x, lp["scan"])
    else:
        cache["scan"] = {}

    for kind, p in zip(plan.suffix, lp["suffix"]):
        x, c = fill_block(kind, p, x)
        cache["suffix"].append(c)

    x = rms_norm(x, params["final_ln"][None] if False else params["final_ln"])
    last = x[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", last, params["head"].astype(cfg.dtype))
    return logits, cache


def _mixer_prefill(cfg, kind, p, x, positions, ctx):
    """Mixer forward over the prompt + cache extraction."""
    s = x.shape[1]
    if kind in ("attn", "local_attn"):
        is_local = kind == "local_attn"
        q, k, v = att.qkv_proj(cfg, p, x, positions)
        window = cfg.local_window if is_local else None
        y = att.attend_chunked(q, k, v, positions, positions, causal=True,
                               window=window, softmax_scale=cfg.qk_head_dim ** -0.5)
        y = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(x.dtype))
        if is_local:
            w = min(cfg.local_window, s)
            k_r = jnp.roll(k[:, s - w:], s % w if w else 0, axis=1)
            v_r = jnp.roll(v[:, s - w:], s % w if w else 0, axis=1)
            return y, {"kv": att.KVCache(k_r.astype(jnp.bfloat16),
                                         v_r.astype(jnp.bfloat16))}
        return y, {"kv": att.KVCache(k.astype(jnp.bfloat16),
                                     v.astype(jnp.bfloat16))}
    if kind == "mla":
        # Recompute the compressed stream (cheap) for the cache.
        y = att.mla_train(cfg, p, x, positions)
        ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
        c_kv = rms_norm(ckv[..., :cfg.kv_lora_rank], p["kv_ln"])
        from .common import rope as _rope
        k_rope = _rope(ckv[..., None, cfg.kv_lora_rank:], positions,
                       cfg.rope_theta)[:, :, 0]
        return y, {"mla": att.MLACache(c_kv.astype(jnp.bfloat16),
                                       k_rope.astype(jnp.bfloat16))}
    if kind == "rglru":
        y, st = rec.rglru_prefill(cfg, p, x)
        return y, {"rec": st}
    if kind == "rwkv":
        y, st = rec.rwkv_prefill(cfg, p, x)
        return y, {"rec": st}
    raise ValueError(kind)
