"""Attention layers: GQA (+qk-norm, sliding window), MLA, cross-attention.

Three execution paths, all sharing weights:
  * ``attend_train`` — full-sequence causal (or bidirectional) attention with
    chunked online softmax over KV blocks (memory O(S * chunk), required for
    the 32k prefill shapes);
  * ``decode_step``   — one-token attention against a KV cache
    (full cache for global layers, ring-buffer cache for local layers);
  * MLA variants cache the compressed c_kv (+ shared k_rope) only, with the
    absorbed-projection decode trick (DeepSeek-V2).

Shapes: x (B, S, D); q/k/v (B, S, H, hd); caches (B, S_max, KVH, hd).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import P, ModelConfig, ein, rms_norm, rope

NEG_INF = -1e30


# ------------------------------- params -------------------------------------------

def attn_params(cfg: ModelConfig) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.qk_head_dim
    h = cfg.pad_heads or h
    p = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
        "ln": P((d,), ("embed",), init="zeros"),
    }
    if cfg.qk_norm:
        p["q_norm"] = P((hd,), ("head_dim",), init="zeros")
        p["k_norm"] = P((hd,), ("head_dim",), init="zeros")
    return p


def mla_params(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r, nd, rd, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq": P((d, h, nd + rd), ("embed", "heads", "head_dim")),
        "w_dkv": P((d, r + rd), ("embed", "kv_lora")),
        "kv_ln": P((r,), ("kv_lora",), init="zeros"),
        "w_uk": P((r, h, nd), ("kv_lora", "heads", "head_dim")),
        "w_uv": P((r, h, vd), ("kv_lora", "heads", "head_dim")),
        "wo": P((h, vd, d), ("heads", "head_dim", "embed")),
        "ln": P((d,), ("embed",), init="zeros"),
    }


def cross_attn_params(cfg: ModelConfig) -> dict:
    p = attn_params(cfg)
    p.pop("q_norm", None)
    p.pop("k_norm", None)
    return p


# ------------------------------ core attention ------------------------------------

def _gqa_scores(q, k):
    """q (B,Sq,H,hd), k (B,Sk,KVH,hd) -> scores (B, H, Sq, Sk) with grouping."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, hd)
    s = ein("bqkgd,bskd->bkgqs", qg, k)
    return s.reshape(b, h, sq, k.shape[1])


def _gqa_out(weights, v):
    """weights (B,H,Sq,Sk), v (B,Sk,KVH,hd) -> (B,Sq,H,hd)."""
    b, h, sq, sk = weights.shape
    kvh = v.shape[2]
    group = h // kvh
    wg = weights.reshape(b, kvh, group, sq, sk)
    o = ein("bkgqs,bskd->bqkgd", wg, v)
    return o.reshape(b, sq, h, v.shape[-1])


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
                   window: int | None, kv_chunk: int = 1024,
                   softmax_scale: float) -> jax.Array:
    """Online-softmax attention over KV chunks (flash-attention structure).

    Memory O(Sq * kv_chunk) instead of O(Sq * Sk).  ``window``: sliding-window
    masking for local layers (None = global).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kv_chunk = min(kv_chunk, sk)
    n_chunks = (sk + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, pad),), constant_values=jnp.iinfo(jnp.int32).max)
    k = k.reshape(b, n_chunks, kv_chunk, *k.shape[2:])
    v = v.reshape(b, n_chunks, kv_chunk, *v.shape[2:])
    kv_pos = kv_pos.reshape(n_chunks, kv_chunk)

    q32 = q.astype(jnp.float32) * softmax_scale

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, pc = xs
        s = _gqa_scores(q32, kc.astype(jnp.float32))        # (B,H,Sq,kc)
        mask = jnp.ones((sq, kv_chunk), bool)
        if causal:
            mask &= pc[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= pc[None, :] > q_pos[:, None] - window
        mask &= pc[None, :] < jnp.iinfo(jnp.int32).max      # padding
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1)
        pv = _gqa_out(p, vc.astype(jnp.float32))            # (B,Sq,H,hd)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, h, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ------------------------------- GQA layer -----------------------------------------

class KVCache(NamedTuple):
    k: jax.Array            # (B, S_cache, KVH, hd)
    v: jax.Array
    # ring caches track writes via (pos % size); global caches use pos directly


def qkv_proj(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
             rope_on: bool = True):
    q = ein("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = ein("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = ein("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_train(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
              *, is_local: bool, causal: bool = True,
              kv_chunk: int = 1024) -> jax.Array:
    q, k, v = qkv_proj(cfg, p, x, positions)
    window = cfg.local_window if is_local else None
    out = attend_chunked(q, k, v, positions, positions, causal=causal,
                         window=window, kv_chunk=kv_chunk,
                         softmax_scale=cfg.qk_head_dim ** -0.5)
    return ein("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
               cache: KVCache, *, is_local: bool) -> tuple[jax.Array, KVCache]:
    """One-token decode. x (B,1,D); pos scalar int32 (current position).

    Local layers use a ring-buffer cache of size ``local_window``; global
    layers a full-length cache.
    """
    q, k_new, v_new = qkv_proj(cfg, p, x, pos[None].astype(jnp.int32))
    s_cache = cache.k.shape[1]
    slot = (pos % s_cache) if is_local else pos
    # One-hot masked cache write instead of dynamic_update_slice: DUS on a
    # seq-SHARDED cache makes GSPMD all-gather the whole cache per layer
    # (~17 GB/step at 123B/32k); the masked select is elementwise over the
    # sharded dim and fuses into the attention read (§Perf decode lever).
    hit = (jnp.arange(s_cache) == slot)[None, :, None, None]
    k = jnp.where(hit, k_new.astype(cache.k.dtype), cache.k)
    v = jnp.where(hit, v_new.astype(cache.v.dtype), cache.v)
    # Validity: global -> positions <= pos; ring -> age < written count.
    idx = jnp.arange(s_cache)
    if is_local:
        valid = ((slot - idx) % s_cache) < jnp.minimum(pos + 1, s_cache)
    else:
        valid = idx <= pos
    scores = _gqa_scores(q.astype(jnp.float32) * cfg.qk_head_dim ** -0.5,
                         k.astype(jnp.float32))          # (B,H,1,S)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(w, v.astype(jnp.float32)).astype(x.dtype)
    y = ein("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, KVCache(k, v)


def init_kv_cache(cfg: ModelConfig, batch: int, seq: int, *, is_local: bool,
                  dtype=jnp.bfloat16) -> KVCache:
    s = min(seq, cfg.local_window) if is_local else seq
    shape = (batch, s, cfg.n_kv_heads, cfg.qk_head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# ------------------------------- MLA layer -----------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array          # (B, S, kv_lora)
    k_rope: jax.Array        # (B, S, rope_dim)


def mla_train(cfg: ModelConfig, p: dict, x: jax.Array,
              positions: jax.Array, kv_chunk: int = 1024) -> jax.Array:
    b, s, d = x.shape
    h, nd, rd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = ein("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = ein("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    c_kv, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_ln"])
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    k_nope = ein("bsr,rhk->bshk", c_kv, p["w_uk"].astype(x.dtype))
    v = ein("bsr,rhk->bshk", c_kv, p["w_uv"].astype(x.dtype))
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rd))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    out = attend_chunked(q_full, k_full, v, positions, positions, causal=True,
                         window=None, kv_chunk=kv_chunk,
                         softmax_scale=(nd + rd) ** -0.5)
    return ein("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
               cache: MLACache) -> tuple[jax.Array, MLACache]:
    """Absorbed-projection decode: scores in compressed space, cache = c_kv."""
    b = x.shape[0]
    h, nd, rd, r = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.kv_lora_rank
    q = ein("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, pos[None].astype(jnp.int32), cfg.rope_theta)

    ckv = ein("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    c_new, kr_new = ckv[..., :r], ckv[..., r:]
    c_new = rms_norm(c_new, p["kv_ln"])
    kr_new = rope(kr_new[:, :, None, :], pos[None].astype(jnp.int32),
                  cfg.rope_theta)[:, :, 0]
    # Masked write (not DUS): keeps the seq-sharded cache local (see
    # gqa_decode for the rationale).
    hit = (jnp.arange(cache.c_kv.shape[1]) == pos)[None, :, None]
    c_kv = jnp.where(hit, c_new.astype(cache.c_kv.dtype), cache.c_kv)
    k_rope = jnp.where(hit, kr_new.astype(cache.k_rope.dtype), cache.k_rope)
    # Absorb W_uk into q: q_c (B,1,H,r)
    q_c = ein("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))
    s_c = ein("bshr,btr->bhst", q_c.astype(jnp.float32),
                     c_kv.astype(jnp.float32))
    s_r = ein("bshk,btk->bhst", q_rope.astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    scores = (s_c + s_r) * (nd + rd) ** -0.5
    valid = jnp.arange(c_kv.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = ein("bhst,btr->bshr", w, c_kv.astype(jnp.float32))
    out = ein("bshr,rhk->bshk", ctx, p["w_uv"].astype(jnp.float32))
    y = ein("bshk,hkd->bsd", out.astype(x.dtype), p["wo"].astype(x.dtype))
    return y, MLACache(c_kv, k_rope)


def init_mla_cache(cfg: ModelConfig, batch: int, seq: int,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
                    jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype))


# ---------------------------- cross-attention (whisper) ----------------------------

def cross_attend(cfg: ModelConfig, p: dict, x: jax.Array,
                 enc_kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = ein("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k, v = enc_kv
    scores = _gqa_scores(q.astype(jnp.float32) * cfg.qk_head_dim ** -0.5,
                         k.astype(jnp.float32))
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(w, v.astype(jnp.float32)).astype(x.dtype)
    return ein("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encode_cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    k = ein("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = ein("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v
