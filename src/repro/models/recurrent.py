"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and RWKV-6.

Both are implemented with *parallel* training paths (associative scan for the
RG-LRU's diagonal linear recurrence; stable chunked matmul form for RWKV-6's
data-dependent-decay WKV) and O(1)-state decode paths — these are the
sub-quadratic architectures that run the long_500k shape (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import P, ModelConfig

RGLRU_C = 8.0
RWKV_CHUNK = 32
RWKV_LOGW_CLIP = 0.45   # bounds per-chunk exp range: C * clip < 15 (fp32-safe)
RWKV_LORA_DIM = 64


# ================================= RG-LRU ==========================================

class RGLRUState(NamedTuple):
    h: jax.Array            # (B, d_rnn) recurrent state
    conv: jax.Array         # (B, conv_width-1, d_rnn) conv tail


def rglru_params(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dr = cfg.rglru_width or d
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "w_gelu": P((d, dr), ("embed", "mlp")),
        "w_rec": P((d, dr), ("embed", "mlp")),
        "conv_w": P((cfg.conv_width, dr), (None, "mlp")),
        "conv_b": P((dr,), ("mlp",), init="zeros"),
        "w_a": P((dr, dr), (None, "mlp")),
        "b_a": P((dr,), ("mlp",), init="zeros"),
        "w_i": P((dr, dr), (None, "mlp")),
        "b_i": P((dr,), ("mlp",), init="zeros"),
        "lam": P((dr,), ("mlp",), init="rglru_a"),
        "w_out": P((dr, d), ("mlp", "embed")),
    }


def _rglru_core(p: dict, u: jax.Array, h0: jax.Array | None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + sqrt(1-a^2) (i_t * u_t).

    u: (B, S, dr).  Parallelized with an associative scan over S.
    """
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, p["w_a"].astype(u.dtype))
                       + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, p["w_i"].astype(u.dtype))
                       + p["b_i"].astype(u.dtype))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u).astype(jnp.float32)
    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None].astype(jnp.float32), gated], axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(u.dtype)


def rglru_train(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Griffin recurrent block: GeLU branch x (conv -> RG-LRU) branch."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gelu"].astype(x.dtype)))
    u = jnp.einsum("bsd,de->bse", x, p["w_rec"].astype(x.dtype))
    # Depthwise causal conv, width cfg.conv_width.
    kw = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (kw - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
               for i in range(kw)) + p["conv_b"].astype(u.dtype)
    h = _rglru_core(p, conv, None)
    return jnp.einsum("bse,ed->bsd", gate * h, p["w_out"].astype(x.dtype))


def rglru_prefill(cfg: ModelConfig, p: dict, x: jax.Array
                  ) -> tuple[jax.Array, RGLRUState]:
    """Train-path forward + final recurrent state (for decode continuation)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gelu"].astype(x.dtype)))
    u = jnp.einsum("bsd,de->bse", x, p["w_rec"].astype(x.dtype))
    kw = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (kw - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
               for i in range(kw)) + p["conv_b"].astype(u.dtype)
    h = _rglru_core(p, conv, None)
    y = jnp.einsum("bse,ed->bsd", gate * h, p["w_out"].astype(x.dtype))
    state = RGLRUState(h[:, -1].astype(jnp.float32),
                       u[:, -(kw - 1):].astype(jnp.float32))
    return y, state


def rglru_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 state: RGLRUState) -> tuple[jax.Array, RGLRUState]:
    """One-token step. x (B,1,D)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gelu"].astype(x.dtype)))
    u = jnp.einsum("bsd,de->bse", x, p["w_rec"].astype(x.dtype))[:, 0]
    kw = p["conv_w"].shape[0]
    window = jnp.concatenate([state.conv, u[:, None]], axis=1)  # (B, kw, dr)
    conv = (sum(window[:, i] * p["conv_w"][i].astype(u.dtype) for i in range(kw))
            + p["conv_b"].astype(u.dtype))
    r = jax.nn.sigmoid(conv @ p["w_a"].astype(u.dtype) + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(conv @ p["w_i"].astype(u.dtype) + p["b_i"].astype(u.dtype))
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
                * r.astype(jnp.float32))
    h = a * state.h.astype(jnp.float32) + \
        jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i * conv).astype(jnp.float32)
    y = jnp.einsum("be,ed->bd", (gate[:, 0] * h.astype(x.dtype)),
                   p["w_out"].astype(x.dtype))
    return y[:, None], RGLRUState(h.astype(state.h.dtype), window[:, 1:])


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    dr = cfg.rglru_width or cfg.d_model
    return RGLRUState(jnp.zeros((batch, dr), dtype),
                      jnp.zeros((batch, cfg.conv_width - 1, dr), dtype))


# ================================= RWKV-6 ==========================================

class RWKVState(NamedTuple):
    x_prev: jax.Array        # (B, D) previous token embedding (token shift)
    s: jax.Array             # (B, H, dk, dv) WKV state


def rwkv_params(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "mu_r": P((d,), ("embed",), init="zeros"),
        "mu_k": P((d,), ("embed",), init="zeros"),
        "mu_v": P((d,), ("embed",), init="zeros"),
        "mu_w": P((d,), ("embed",), init="zeros"),
        "mu_g": P((d,), ("embed",), init="zeros"),
        "w_r": P((d, h, hd), ("embed", "heads", "head_dim")),
        "w_k": P((d, h, hd), ("embed", "heads", "head_dim")),
        "w_v": P((d, h, hd), ("embed", "heads", "head_dim")),
        "w_g": P((d, d), ("embed", "mlp")),
        "w0": P((d,), ("embed",), init="zeros", scale=0.1),
        "w_lora_a": P((d, RWKV_LORA_DIM), ("embed", None)),
        "w_lora_b": P((RWKV_LORA_DIM, d), (None, "embed")),
        "u": P((h, hd), ("heads", "head_dim"), scale=0.5),
        "gn": P((d,), ("embed",), init="zeros"),
        "w_o": P((d, d), ("mlp", "embed")),
    }


def _rwkv_proj(cfg: ModelConfig, p: dict, x: jax.Array, x_shift: jax.Array):
    """Token-shifted projections.  x, x_shift: (B, S, D)."""
    def mix(mu):
        m = p[mu].astype(x.dtype)
        return x + (x_shift - x) * m

    hd = cfg.rwkv_head_dim
    h = cfg.d_model // hd
    r = jnp.einsum("bsd,dhk->bshk", mix("mu_r"), p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", mix("mu_k"), p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", mix("mu_v"), p["w_v"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix("mu_g"), p["w_g"].astype(x.dtype)))
    # Data-dependent decay (the Finch hallmark): w = exp(-exp(raw)), clipped
    # for chunked fp32 stability (RWKV_LOGW_CLIP, see module docstring).
    raw = (p["w0"].astype(jnp.float32)
           + jnp.tanh(jnp.einsum("bsd,dl->bsl", mix("mu_w").astype(jnp.float32),
                                 p["w_lora_a"].astype(jnp.float32)))
           @ p["w_lora_b"].astype(jnp.float32))
    log_w = -jnp.exp(jnp.clip(raw, -8.0, RWKV_LOGW_CLIP))   # (B,S,D) negative
    log_w = log_w.reshape(log_w.shape[:2] + (h, hd))
    return r, k, v, g, log_w


def rwkv_train(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    y, _ = rwkv_prefill(cfg, p, x)
    return y


def rwkv_prefill(cfg: ModelConfig, p: dict, x: jax.Array
                 ) -> tuple[jax.Array, RWKVState]:
    """Chunked-parallel WKV over the full sequence.

    out_t = r_t @ (S_{t-1}) + (r_t . u . k_t) v_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Within a chunk of C tokens, with L_t = cumsum(log w) (L_0 = 0):
      q~_t = r_t * exp(L_{t-1}) ; k~_s = k_s * exp(-L_s)
      intra = strict_lower(q~ K~^T) V + diag(sum(r*u*k)) V
      carry: S' = exp(L_C) * (S + k~^T V) ... per dk-channel row scale.
    """
    b, s_orig, d = x.shape
    x_shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, log_w = _rwkv_proj(cfg, p, x, x_shift)
    hd = cfg.rwkv_head_dim
    h = d // hd
    c = min(RWKV_CHUNK, s_orig)
    # Pad to a chunk multiple: pad tokens get k=0 (no state contribution) and
    # log_w=0 (no decay), so the carried state is exact at position s_orig.
    pad = (-s_orig) % c
    if pad:
        pw = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, pw)
        k = jnp.pad(k, pw)
        v = jnp.pad(v, pw)
        log_w = jnp.pad(log_w.reshape(b, s_orig, h, hd), pw)
        log_w = log_w.reshape(b, s_orig + pad, h, hd)
    s = s_orig + pad
    nc = s // c

    def resh(t):  # (B,S,H,hd) -> (nc, B, H, C, hd)
        return t.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4)

    r_, k_, v_ = (resh(r.astype(jnp.float32)), resh(k.astype(jnp.float32)),
                  resh(v.astype(jnp.float32)))
    lw = resh(log_w)
    u = p["u"].astype(jnp.float32)

    big_l = jnp.cumsum(lw, axis=-2)                     # inclusive (.., C, hd)
    l_prev = big_l - lw                                 # exclusive
    q_t = r_ * jnp.exp(l_prev)
    k_t = k_ * jnp.exp(-big_l)
    bonus = jnp.einsum("nbhck,hk,nbhck->nbhc", r_, u, k_)
    tri = jnp.tril(jnp.ones((c, c), bool), -1)

    def chunk_step(s_state, xs):
        q_c, k_c, v_c, kt_c, lC, bon, r_c = xs
        inter = jnp.einsum("bhck,bhkv->bhcv", q_c, s_state)
        scores = jnp.einsum("bhck,bhsk->bhcs", q_c, kt_c)
        scores = jnp.where(tri[None, None], scores, 0.0)
        intra = jnp.einsum("bhcs,bhsv->bhcv", scores, v_c)
        out_c = inter + intra + bon[..., None] * v_c
        s_new = jnp.exp(lC)[..., :, None] * (
            s_state + jnp.einsum("bhsk,bhsv->bhkv", kt_c, v_c))
        return s_new, out_c

    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    s_final, outs = jax.lax.scan(
        chunk_step, s0,
        (q_t, k_, v_, k_t, big_l[..., -1, :], bonus, r_))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)[:, :s_orig]
    out = _rwkv_groupnorm(cfg, p, out)
    out = out.reshape(b, s_orig, d) * g.astype(jnp.float32)
    y = jnp.einsum("bsd,de->bse", out.astype(x.dtype), p["w_o"].astype(x.dtype))
    return y, RWKVState(x[:, -1], s_final.astype(jnp.float32))


def _rwkv_groupnorm(cfg: ModelConfig, p: dict, out: jax.Array) -> jax.Array:
    """Per-head group norm on the WKV output."""
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    normed = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    gamma = (1.0 + p["gn"].astype(jnp.float32)).reshape(
        1, 1, out.shape[-2], out.shape[-1])
    return normed * gamma


def rwkv_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                state: RWKVState) -> tuple[jax.Array, RWKVState]:
    """One-token WKV step. x (B,1,D)."""
    b, _, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    r, k, v, g, log_w = _rwkv_proj(cfg, p, x, state.x_prev[:, None])
    r_, k_, v_ = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(log_w[:, 0].astype(jnp.float32))               # (B,H,hd)
    u = p["u"].astype(jnp.float32)
    s = state.s.astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", k_, v_)
    out = jnp.einsum("bhk,bhkv->bhv", r_, s + u[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    out = _rwkv_groupnorm(cfg, p, out.reshape(b, 1, h, hd))
    out = out.reshape(b, 1, d) * g.astype(jnp.float32)
    y = jnp.einsum("bsd,de->bse", out.astype(x.dtype), p["w_o"].astype(x.dtype))
    return y, RWKVState(x[:, 0], s_new.astype(state.s.dtype))


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    hd = cfg.rwkv_head_dim
    h = cfg.d_model // hd
    return RWKVState(jnp.zeros((batch, cfg.d_model), dtype),
                     jnp.zeros((batch, h, hd, hd), dtype))
