"""Model substrate: config, declarative params with logical sharding axes,
norms, RoPE, init.

Params are declared as ``P(shape, axes)`` skeletons; ``materialize`` turns a
skeleton tree into arrays, ``pspec_tree`` turns the same tree into
``PartitionSpec``s via the sharding rules — one source of truth for both
(MaxText-style logical axis names).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


# ------------------------------- configuration -----------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int | None = None      # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    # attention variants
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 1024          # sliding window for local layers
    layer_pattern: tuple[str, ...] = ("attn",)   # repeating kinds
    # pattern kinds: attn | local_attn | rglru | rwkv | moe-suffixed kinds use
    # the mlp_kind field instead.
    mlp_kind: str = "dense"           # dense | moe
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    first_layer_dense: bool = False   # deepseek: layer 0 dense
    d_ff_first: int = 0
    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500           # precomputed frames (frontend stub)
    # recurrent
    rglru_width: int = 0              # RG-LRU recurrence width (d_rnn)
    conv_width: int = 4
    rwkv_head_dim: int = 64
    # stochastic-computing integration (the paper's technique)
    sc_mode: str = "off"              # off | analytic | exact
    sc_bitstream_length: int = 256
    # TP head padding (§Perf lever): pad n_heads up to a multiple of the
    # model axis so attention weights shard instead of replicating (llama4's
    # 40 heads on a 16-way axis).  Extra heads' wo rows are zero-initialized
    # -> identical function, ~heads_pad/heads extra attention compute.
    pad_heads: int | None = None
    # numerics
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: str = "none"               # none | full | dots
    # modality frontend stubs
    frontend: str = "none"            # none | audio_stub | vq_stub

    @property
    def qk_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def v_dim(self) -> int:
        if self.use_mla:
            return self.v_head_dim
        return self.head_dim or (self.d_model // self.n_heads)

    def pattern_layers(self) -> list[str]:
        """Expand layer_pattern to n_layers kinds (pattern repeats + remainder)."""
        kinds: list[str] = []
        while len(kinds) < self.n_layers:
            kinds.extend(self.layer_pattern)
        return kinds[: self.n_layers]


# ------------------------------ param declarations -------------------------------

@dataclasses.dataclass(frozen=True)
class P:
    """A parameter declaration: shape + logical axes (+ init)."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"              # normal | zeros | ones | rglru_a
    scale: float | None = None        # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(tree: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Turn a skeleton tree of P into arrays (split keys deterministically)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for decl, k in zip(leaves, keys):
        if decl.init == "zeros":
            arr = jnp.zeros(decl.shape, dtype)
        elif decl.init == "ones":
            arr = jnp.ones(decl.shape, dtype)
        elif decl.init == "rglru_a":
            # RG-LRU a-parameter: softplus-inv spread so a^c in ~(0.9, 0.999)
            u = jax.random.uniform(k, decl.shape, jnp.float32, 0.9, 0.999)
            arr = jnp.log(jnp.exp(-jnp.log(u)) - 1.0).astype(dtype)  # softplus^-1(-log u)
        else:
            fan_in = decl.shape[-2] if len(decl.shape) >= 2 else decl.shape[-1]
            std = decl.scale if decl.scale is not None else 1.0 / math.sqrt(fan_in)
            arr = (jax.random.normal(k, decl.shape, jnp.float32) * std).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def pspec_tree(tree: Any, rules: dict[str, Any]) -> Any:
    """Map each P's logical axes through the rules table to PartitionSpecs."""
    def to_spec(decl: P) -> PartitionSpec:
        return PartitionSpec(*[rules.get(a) if a is not None else None
                               for a in decl.axes])
    return jax.tree.map(to_spec, tree, is_leaf=lambda x: isinstance(x, P))


def abstract_tree(tree: Any, dtype=jnp.float32) -> Any:
    """Skeleton -> ShapeDtypeStruct tree (for dry-run lowering)."""
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------- layers ----------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float,
         rotary_dim: int | None = None) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    freqs = theta ** (-jnp.arange(0, rd, 2, dtype=jnp.float32) / rd)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, rd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0:rd:2]
    x2 = x[..., 1:rd:2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rot = jnp.stack([r1, r2], axis=-1).reshape(x[..., :rd].shape)
    return jnp.concatenate([rot.astype(x.dtype), x[..., rd:]], axis=-1)


def shard(x: jax.Array, spec: PartitionSpec | None) -> jax.Array:
    """Sharding-constraint helper (no-op when spec is None)."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# §Perf lever (off for the faithful baseline; enabled by dryrun --bf16_acc):
# JAX dots on bf16 inputs request an f32 accumulator, so the dot OUTPUT —
# where GSPMD inserts the TP psum, in both forward and transpose — is f32 and
# every (B, S, D)-sized partial-sum all-reduce moves 4 B/elem.  Requesting a
# bf16 dot output halves those collectives; on TPU the MXU still accumulates
# in f32 internally (this is the standard Megatron partial-sum-in-bf16
# configuration), only the cross-shard combine sees bf16 rounding.
ACC_DTYPE: list[Any] = [None]          # None = JAX default (f32 accumulation)


def set_bf16_matmul_accum(on: bool) -> None:
    ACC_DTYPE[0] = jnp.bfloat16 if on else None


def ein(eq: str, *xs: jax.Array) -> jax.Array:
    """einsum with the configured accumulator/output dtype."""
    if ACC_DTYPE[0] is not None and all(x.dtype == ACC_DTYPE[0] for x in xs):
        return jnp.einsum(eq, *xs, preferred_element_type=ACC_DTYPE[0])
    return jnp.einsum(eq, *xs)
