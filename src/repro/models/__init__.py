"""LM model stack: 10-architecture substrate with the paper's SC technique
available as an approximate-matmul mode (mlp.sc_linear / cfg.sc_mode)."""
from . import attention, common, frontend, mlp, model, moe, recurrent
from .common import ModelConfig, P
from .model import (RunCtx, decode_step, forward, init_cache, init_params,
                    model_params, prefill, stack_plan)

__all__ = [
    "attention", "common", "frontend", "mlp", "model", "moe", "recurrent",
    "ModelConfig", "P", "RunCtx", "decode_step", "forward", "init_cache",
    "init_params", "model_params", "prefill", "stack_plan",
]
