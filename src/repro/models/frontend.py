"""Modality frontend STUBS (per assignment: [audio]/[vlm] entries specify the
transformer backbone only; ``input_specs()`` provides precomputed frame/patch
embeddings).

* whisper-large-v3: the conv+mel frontend is replaced by precomputed frame
  embeddings (B, 1500, d_model) — the encoder consumes them directly.
* chameleon-34b / llama4-scout: early-fusion VQ image tokens share the text
  vocabulary, so the "frontend" is the identity on token ids; a helper below
  synthesizes mixed text+image-token streams for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig


def audio_stub_frames(cfg: ModelConfig, batch: int, key: jax.Array,
                      dtype=jnp.bfloat16) -> jax.Array:
    """Precomputed mel->conv frame embeddings stand-in: (B, T_enc, d_model)."""
    return jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model), dtype) * 0.02


def audio_stub_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), dtype)


def vq_stub_tokens(cfg: ModelConfig, batch: int, seq: int, key: jax.Array,
                   image_fraction: float = 0.25) -> jax.Array:
    """Early-fusion token stream: text ids interleaved with VQ image-token ids
    (the top of the vocabulary models the VQ codebook, as in Chameleon)."""
    k1, k2, k3 = jax.random.split(key, 3)
    codebook = cfg.vocab_size // 4
    text = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size - codebook)
    image = jax.random.randint(k2, (batch, seq), cfg.vocab_size - codebook,
                               cfg.vocab_size)
    is_img = jax.random.uniform(k3, (batch, seq)) < image_fraction
    return jnp.where(is_img, image, text).astype(jnp.int32)
