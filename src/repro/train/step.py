"""Training step: next-token loss, microbatch gradient accumulation, AdamW.

Distribution notes (DESIGN.md §5):
  * the step is written in the global view and jit-compiled with
    in/out shardings from sharding.rules — GSPMD inserts the FSDP
    all-gathers, TP collectives and the gradient reduce-scatters;
  * microbatch accumulation (``accum_steps``) bounds activation memory:
    grads are accumulated in fp32 across a ``lax.scan`` over microbatches;
  * optional SC-inspired stochastic gradient compression with error feedback
    (optim.compress) narrows the cross-pod gradient payload.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import RunCtx, forward
from repro.models.common import ModelConfig
from repro.optim import adamw_init, adamw_update, compress_decompress


class TrainState(NamedTuple):
    params: Any
    opt: Any
    rng: jax.Array
    compress_err: Any | None = None


def train_state_init(cfg: ModelConfig, params: Any, seed: int = 0) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params),
                      rng=jax.random.key(seed), compress_err=None)


def loss_fn(cfg: ModelConfig, params: Any, tokens: jax.Array,
            labels: jax.Array, ctx: RunCtx, frames=None) -> jax.Array:
    logits, aux = forward(cfg, params, tokens, ctx, frames=frames)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + 0.01 * aux


def make_train_step(cfg: ModelConfig, ctx: RunCtx, *, accum_steps: int = 1,
                    lr: float = 3e-4, compress_bits: int = 0,
                    cast_bf16_gather: bool = False,
                    gather_shardings=None,
                    pod_axis: str | None = None) -> Callable:
    """Build the jittable train_step(state, batch) -> (state, metrics).

    ``cast_bf16_gather``: cast the fp32 parameter shards to bf16 ONCE per
    step, outside the microbatch scan — the per-layer FSDP all-gathers then
    move bf16, halving weight-collective bytes (beyond-paper §Perf lever).

    ``pod_axis``: with compress_bits > 0, gradients are synchronized across
    pods by an int8 stochastically-quantized all-gather inside shard_map
    (the paper's SC-rounding insight applied to the slowest link) instead of
    an fp32 all-reduce — set FSDP to intra-pod axes only so the backward
    pass doesn't already reduce over pods.
    """

    def prepare(params):
        """ZeRO-1 gather + optional bf16 cast, ONCE per step (outside the
        microbatch scan).  gather_shardings are TP-only specs: the
        sharding-constraint transpose gives the gradient reduce-scatter back
        to the FSDP layout for free."""
        use = params
        if cast_bf16_gather:
            use = jax.tree.map(
                lambda p: p.astype(cfg.dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, use)
        if gather_shardings is not None:
            use = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(p, s)
                if s is not None else p, use, gather_shardings)
        return use

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        tokens, labels = batch["tokens"], batch["labels"]
        frames = batch.get("frames")
        # ONCE per step, outside the microbatch scan: the gathered/cast copy
        # is a loop constant, so XLA materializes it before the while loop —
        # ZeRO-1's "gathers per step, not per microbatch x layer".
        use = prepare(state.params)

        def grads_of(params_use, tokens, labels, frames):
            return jax.value_and_grad(
                lambda u: loss_fn(cfg, u, tokens, labels, ctx, frames)
            )(params_use)

        if accum_steps == 1:
            loss, grads = grads_of(use, tokens, labels, frames)
        else:
            b = tokens.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            mb = b // accum_steps
            resh = lambda t: t.reshape((accum_steps, mb) + t.shape[1:])
            mts, mls = resh(tokens), resh(labels)
            mfr = resh(frames) if frames is not None else None

            def acc_body(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs[0], xs[1]
                f = xs[2] if mfr is not None else None
                loss, g = grads_of(use, t, l, f)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32) / accum_steps,
                    g_acc, g)
                return (loss_acc + loss / accum_steps, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), use)
            xs = (mts, mls, mfr) if mfr is not None else (mts, mls)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, g0), xs)

        rng, sub = jax.random.split(state.rng)
        compress_err = state.compress_err
        if compress_bits > 0:
            grads, compress_err = compress_decompress(
                grads, sub, compress_bits, compress_err)

        params, opt = adamw_update(grads, state.opt, state.params, lr=lr)
        metrics = {"loss": loss, "grad_norm": jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))}
        return TrainState(params, opt, rng, compress_err), metrics

    return train_step
