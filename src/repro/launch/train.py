"""End-to-end training driver with checkpoint/restart fault tolerance.

Runs on whatever devices exist (CPU smoke, TPU pod when available):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 200 --ckpt_dir /tmp/ckpt --ckpt_every 50

Fault tolerance exercised here (README §Operations):
  * auto-resume: restarts continue from the latest atomic checkpoint;
  * deterministic data: batch t is a pure function of (seed, t) — no data
    state to replay;
  * elastic restore: checkpoints are mesh-shape-agnostic (global arrays).
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config, reduced_config
from repro.data import SyntheticLM
from repro.models import RunCtx, init_params
from repro.sharding import make_rules
from repro.train import make_train_step, train_state_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config sized for CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt_dir", default=None)
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--compress_bits", type=int, default=0)
    ap.add_argument("--log_every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    rules = make_rules(mesh)
    ctx = RunCtx(mesh=mesh, act_spec=NamedSharding(mesh, rules.act_spec()),
                 use_ep=False, data_axes=("data",))

    params = init_params(cfg, jax.random.key(0))
    state = train_state_init(cfg, params)
    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore(args.ckpt_dir, last, state)
            start = last
            print(f"[train] resumed from step {last}")

    pipe = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    step_fn = jax.jit(make_train_step(cfg, ctx, accum_steps=args.accum,
                                      lr=args.lr,
                                      compress_bits=args.compress_bits),
                      donate_argnums=(0,))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = pipe.batch(step)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({time.time() - t0:.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = save(args.ckpt_dir, step + 1, state)
            print(f"[train] checkpoint -> {path}")
    print("[train] done")
    return state


if __name__ == "__main__":
    main()
