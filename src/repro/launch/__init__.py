# Launch layer. NOTE: importing submodules here would initialize jax before
# dryrun.py can set XLA_FLAGS — keep this package __init__ empty.
