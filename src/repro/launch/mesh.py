"""Production mesh construction (assignment: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state (device count is locked at first jax init — dryrun.py sets the
512-device XLA flag before importing anything).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod (256 chips) or (2, 16, 16) two-pod (512 chips).

    Axes: ``data`` = FSDP/data-parallel, ``model`` = TP/EP; ``pod`` = outer
    data-parallel across pods (repurposable as a pipeline axis — DESIGN.md §5).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over however many (host) devices tests run with."""
    return jax.make_mesh((data, model), ("data", "model"))
