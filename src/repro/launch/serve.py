"""Serving driver: batched prefill + decode on local devices.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt_len 32 --new 16

Production path: the decode step is the same function the multi-pod dry-run
lowers for decode_32k/long_500k (launch/dryrun.py --decode_tp for the
weight-stationary 2D-TP serving layout).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced_config
from repro.models import RunCtx, init_params
from repro.models.frontend import audio_stub_frames
from repro.serve.engine import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    frames = (audio_stub_frames(cfg, args.batch, jax.random.key(2))
              if cfg.is_encoder_decoder else None)
    t0 = time.time()
    out = greedy_generate(cfg, params, prompt, args.new, RunCtx(),
                          frames=frames)
    dt = time.time() - t0
    tok_s = args.batch * args.new / dt
    print(f"[serve] arch={cfg.name} batch={args.batch} new={args.new} "
          f"{dt:.1f}s ({tok_s:.1f} tok/s incl. compile)")
    return out


if __name__ == "__main__":
    main()
