"""While-aware HLO analysis: FLOPs, memory traffic and collective bytes with
loop trip counts multiplied in.

Why: ``compiled.cost_analysis()`` counts every computation ONCE — a
scan-over-layers (or microbatch/attention-chunk scan) lowers to a ``while``
whose body executes ``trip_count`` times, so XLA's numbers undercount by the
product of enclosing trip counts (~140x for a 36-layer x 16-microbatch
train step).  This module parses ``compiled.as_text()`` and:

  * splits the module into computations, building a per-computation symbol
    table (instruction name -> shape) so operand shapes resolve;
  * counts dot FLOPs (2 x prod(result dims) x prod(contracting dims)),
    convolutions approximated the same way;
  * estimates memory traffic as sum(operand bytes + result bytes) of
    *top-level* (post-fusion) instructions — fusion boundaries are what
    actually materializes on TPU/CPU;
  * sums collective bytes per kind (with ring-traffic effective factors);
  * multiplies everything by enclosing ``while`` trip counts, detected from
    the loop condition's compare-against-constant pattern;
  * recurses through fusion/call/conditional/while bodies with memoization.

Validated against an unrolled jit module in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_CFG = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^(\([^)]*\)|[\w]+\[[\d,]*\](?:{[^}]*})?)\s*(.*)$")
_OPNAME = re.compile(r"^([\w\-]+)\(")
_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CALLS = re.compile(r"(?:calls|body|condition|branch_computations)"
                    r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                  "all-to-all": 1.0, "collective-permute": 1.0,
                  "ragged-all-to-all": 1.0}


def _split_top(s: str) -> list[str]:
    """Split on commas at bracket depth 0 — shapes embed commas both in dims
    (``f32[64,128]``) and in layout annotations (``{1,0}``, printed by newer
    XLA versions), so a naive ``split(",")`` corrupts operand names."""
    parts: list[str] = []
    depth = 0
    cur: list[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_TOK.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str
    operands: list[str]


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult

    @property
    def effective_collective_bytes(self) -> float:
        return sum(v * TRAFFIC_FACTOR.get(k, 1.0)
                   for k, v in self.collective_bytes.items())


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._memo: dict[str, Totals] = {}

    # ------------------------------ parsing --------------------------------------
    def _parse(self, text: str):
        cur: list[Instr] | None = None
        cur_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if cur is None:
                m = _COMP_HDR.match(stripped)
                if m and stripped.endswith("{"):
                    cur_name = m.group(1)
                    cur = []
                    if stripped.startswith("ENTRY"):
                        self.entry = cur_name
                continue
            if stripped == "}":
                self.computations[cur_name] = cur
                cur = None
                continue
            mi = _INSTR.match(stripped)
            if not mi:
                continue
            name, rhs = mi.group(1), mi.group(2)
            ms = _SHAPE.match(rhs)
            if not ms:
                continue
            shape, rest = ms.group(1), ms.group(2)
            mo = _OPNAME.match(rest)
            op = mo.group(1) if mo else rest.split("(")[0].strip()
            opm = _OPERANDS.search(rest)
            operands = []
            if opm:
                for tok in _split_top(opm.group(1)):
                    tok = tok.strip().lstrip("%")
                    if tok and not tok[0].isdigit():
                        operands.append(tok.split(" ")[-1].lstrip("%"))
            cur.append(Instr(name, shape, op, rest, operands))

    # ------------------------------ analysis -------------------------------------
    def _symtab(self, comp: list[Instr]) -> dict[str, str]:
        return {i.name: i.shape for i in comp}

    def _trip_count(self, cond_name: str) -> float:
        """Trip count heuristic: largest integer constant in the condition."""
        comp = self.computations.get(cond_name, [])
        best = 1
        for i in comp:
            for c in _CONST_INT.findall(i.rest):
                best = max(best, int(c))
        return float(best)

    def _dot_flops(self, instr: Instr, symtab: dict[str, str]) -> float:
        out_elems = _shape_elems(instr.shape)
        contract = 1
        m = _CONTRACT.search(instr.rest)
        if m and instr.operands:
            lhs_shape = symtab.get(instr.operands[0], "")
            ms = _SHAPE_TOK.search(lhs_shape)
            if ms:
                dims = [int(d) for d in ms.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def totals_of(self, comp_name: str) -> Totals:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Totals()      # cycle guard
        comp = self.computations.get(comp_name, [])
        symtab = self._symtab(comp)
        t = Totals()
        for instr in comp:
            op = instr.op
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", instr.rest)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                # Preferred: XLA's own known_trip_count backend_config;
                # fallback: largest constant in the loop condition.
                mt = _TRIP_CFG.search(instr.rest)
                if mt:
                    trips = float(mt.group(1))
                else:
                    trips = self._trip_count(cond) if cond else 1.0
                if body:
                    t.add(self.totals_of(body), trips)
                continue
            if op in ("fusion", "call", "async-start"):
                mcalls = _CALLS.search(instr.rest) or _TO_APPLY.search(instr.rest)
                if mcalls:
                    for callee in mcalls.group(1).replace("%", "").split(","):
                        t.add(self.totals_of(callee.strip()))
                # fusion boundary = materialization: operands + result traffic
                t.traffic_bytes += self._io_bytes(instr, symtab)
                continue
            if op == "conditional":
                mcalls = _CALLS.search(instr.rest)
                if mcalls:
                    branches = [self.totals_of(c.strip().lstrip("%"))
                                for c in mcalls.group(1).split(",")]
                    if branches:
                        # charge the most expensive branch
                        t.add(max(branches, key=lambda b: b.flops))
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = _shape_bytes(instr.shape)
                t.collective_bytes[base] = t.collective_bytes.get(base, 0.0) + b
                t.collective_counts[base] = t.collective_counts.get(base, 0.0) + 1
                t.traffic_bytes += self._io_bytes(instr, symtab)
                continue
            if op in ("dot", "convolution"):
                t.flops += self._dot_flops(instr, symtab)
                t.traffic_bytes += self._io_bytes(instr, symtab)
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "custom-call"):
                if op == "custom-call":
                    t.traffic_bytes += self._io_bytes(instr, symtab)
                continue
            # other top-level ops (copy, broadcast outside fusions, etc.)
            t.traffic_bytes += self._io_bytes(instr, symtab)
        self._memo[comp_name] = t
        return t

    def _io_bytes(self, instr: Instr, symtab: dict[str, str]) -> float:
        b = float(_shape_bytes(instr.shape))
        for o in instr.operands:
            if o in symtab:
                b += _shape_bytes(symtab[o])
        return b

    def entry_totals(self) -> Totals:
        assert self.entry, "no ENTRY computation found"
        return self.totals_of(self.entry)


def analyze(hlo_text: str) -> Totals:
    return HloModule(hlo_text).entry_totals()


def top_collectives(hlo_text: str, k: int = 12) -> list[tuple[float, str, str]]:
    """(bytes*trips, kind, shape) of the heaviest collective ops — the §Perf
    profiling view: which tensors dominate the collective roofline term."""
    mod = HloModule(hlo_text)

    # Pre-compute trip multiplier per computation by walking from entry.
    mult: dict[str, float] = {mod.entry: 1.0}
    order = [mod.entry]
    seen = {mod.entry}
    while order:
        name = order.pop()
        m = mult[name]
        for instr in mod.computations.get(name, []):
            trips = 1.0
            callees: list[str] = []
            if instr.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                mt = _TRIP_CFG.search(instr.rest)
                trips = float(mt.group(1)) if mt else 1.0
                if mb:
                    callees = [mb.group(1)]
            elif instr.op in ("fusion", "call", "conditional"):
                mc = _CALLS.search(instr.rest) or _TO_APPLY.search(instr.rest)
                if mc:
                    callees = [c.strip().lstrip("%")
                               for c in mc.group(1).split(",")]
            for c in callees:
                mult[c] = max(mult.get(c, 0.0), m * trips)
                if c not in seen:
                    seen.add(c)
                    order.append(c)

    out = []
    for name, comp in mod.computations.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for instr in comp:
            base = instr.op.replace("-start", "")
            if base in COLLECTIVES:
                b = _shape_bytes(instr.shape) * m * TRAFFIC_FACTOR.get(base, 1.0)
                out.append((b, base, f"{instr.shape} x{m:.0f}"))
    out.sort(reverse=True)
    return out[:k]
