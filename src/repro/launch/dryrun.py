import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles every (architecture × input shape) cell against the
production meshes — (16, 16) single-pod and (2, 16, 16) multi-pod — and
records memory_analysis / cost_analysis / collective stats + the three
roofline terms to JSON (EXPERIMENTS.md §Dry-run / §Roofline read from it).

NOTE the two lines above MUST run before any jax import: jax locks the
device count at first initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out dryrun.json
  ... --multi_pod           # 2-pod mesh
  ... --seq_shard           # Megatron-SP activation sharding (perf lever)
  ... --compress_bits 8     # SC gradient compression on the pod all-reduce
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import SHAPES, get_config, runnable_cells, token_specs
from repro.data import batch_specs
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import RunCtx, init_cache, model_params
from repro.models.common import ModelConfig, abstract_tree
from repro.serve import make_decode_step, make_prefill
from repro.sharding import (cache_pspec_tree, make_rules, param_pspec_tree,
                            validate_divisibility)
from repro.train import make_train_step, train_state_init


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PS) else s,
        spec_tree, is_leaf=lambda s: isinstance(s, PS) or s is None)


def _accum_steps(cfg: ModelConfig, seq: int, batch_local: int) -> int:
    """Microbatch count: keep boundary activations per device under ~2 GB.

    napkin: bytes ~ layers * mb * seq * d_model * 2 (bf16 boundaries under
    scan remat).  Solve for mb.
    """
    budget = 2e9
    per_row = cfg.n_layers * seq * cfg.d_model * 2
    mb = max(int(budget // max(per_row, 1)), 1)
    accum = max(batch_local // mb, 1)
    while batch_local % accum:
        accum += 1
    return accum


def build_cell(cfg: ModelConfig, shape_name: str, mesh, *, seq_shard=False,
               compress_bits=0, accum_override=None, donate=True,
               cast_bf16=False, decode_tp=False, zero1=False):
    """Returns (jitted_fn, example_args) ready to .lower()."""
    shape = SHAPES[shape_name]
    rules = make_rules(mesh, seq_shard=seq_shard)
    skeleton = model_params(cfg)
    pspecs = param_pspec_tree(skeleton, rules)
    p_shard = _named(mesh, pspecs)
    ctx = RunCtx(mesh=mesh, act_spec=NamedSharding(mesh, rules.act_spec()),
                 use_ep=(cfg.mlp_kind == "moe"),
                 data_axes=rules.batch if isinstance(rules.batch, tuple)
                 else (rules.batch,))
    params_abs = abstract_tree(skeleton, dtype=cfg.param_dtype)
    batch_axes = rules.batch
    tok_specs = token_specs(cfg, shape)

    n_data = 1
    for a in (batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)):
        n_data *= mesh.shape[a]

    if shape.kind == "train":
        accum = accum_override or _accum_steps(cfg, shape.seq_len,
                                               shape.global_batch // n_data)
        gather_shardings = None
        if zero1:
            # ZeRO-1: optimizer state + master weights stay FSDP-sharded;
            # the compute copy is gathered to TP-only sharding once per step.
            # Selective: expert weights keep FSDP (EP already shards them over
            # `model`; gathering their embed dim would add E*d*f/16 ~ 24 GB at
            # llama4 scale), and if the gathered dense copy itself exceeds the
            # HBM budget (mistral-large: 31 GB fp32 at TP16) ZeRO-1 falls back
            # to ZeRO-3 wholesale.
            from repro.models.common import P as Pdecl
            tp_rules = make_rules(mesh, seq_shard=seq_shard, fsdp=False)
            fsdp_specs = pspecs
            tp_specs = param_pspec_tree(skeleton, tp_rules)
            model_n = mesh.shape["model"]
            gathered_bytes = 0.0
            for decl in jax.tree.leaves(
                    skeleton, is_leaf=lambda x: isinstance(x, Pdecl)):
                if "experts" in decl.axes:
                    continue
                n = 1
                for dim in decl.shape:
                    n *= dim
                shard_n = model_n if any(a in ("heads", "kv_heads", "mlp",
                                               "vocab") for a in decl.axes) else 1
                gathered_bytes += n * 4.0 / shard_n
            if gathered_bytes < 8e9:
                gather_specs_tree = jax.tree.map(
                    lambda d, fs, ts: fs if "experts" in d.axes else ts,
                    skeleton, fsdp_specs, tp_specs,
                    is_leaf=lambda x: isinstance(x, Pdecl))
                gather_shardings = _named(mesh, gather_specs_tree)
            else:
                print(f"   [zero1] gathered copy {gathered_bytes/1e9:.1f} GB "
                      f"> budget; keeping ZeRO-3 for this arch")
        step = make_train_step(cfg, ctx, accum_steps=accum,
                               compress_bits=compress_bits,
                               cast_bf16_gather=cast_bf16,
                               gather_shardings=gather_shardings)
        state_abs = jax.eval_shape(
            lambda p: train_state_init(cfg, p), params_abs)
        state_shard = type(state_abs)(
            params=p_shard,
            opt=type(state_abs.opt)(
                step=NamedSharding(mesh, PS()), m=p_shard, v=p_shard),
            rng=NamedSharding(mesh, PS()),
            compress_err=None if state_abs.compress_err is None else p_shard)
        bspec = {"tokens": NamedSharding(mesh, PS(batch_axes, None)),
                 "labels": NamedSharding(mesh, PS(batch_axes, None))}
        batch_abs = dict(batch_specs(cfg, shape.seq_len, shape.global_batch))
        if "frames" in tok_specs:
            batch_abs["frames"] = tok_specs["frames"]
            bspec["frames"] = NamedSharding(mesh, PS(batch_axes, None, None))
        fn = jax.jit(step, in_shardings=(state_shard, bspec),
                     donate_argnums=(0,) if donate else ())
        return fn, (state_abs, batch_abs), ctx, accum

    if shape.kind == "prefill":
        fn0 = make_prefill(cfg, ctx)
        args = [params_abs, tok_specs["tokens"]]
        shards = [p_shard, NamedSharding(mesh, PS(batch_axes, None))]
        if "frames" in tok_specs:
            args.append(tok_specs["frames"])
            shards.append(NamedSharding(mesh, PS(batch_axes, None, None)))
        fn = jax.jit(fn0, in_shardings=tuple(shards))
        return fn, tuple(args), ctx, 1

    # decode
    if decode_tp:
        # Weight-stationary 2D-TP decode: batch replicated over data, the
        # embed dim of every weight contraction-sharded over data (psum of
        # small activations replaces per-token weight all-gathers), serving
        # weights in bf16 (§Perf decode lever).  The activation's d_model is
        # ALSO sharded over data so the contraction dims line up and GSPMD
        # partial-sums instead of gathering the weights.
        params_abs = abstract_tree(skeleton, dtype=cfg.dtype)
        ctx = dataclasses.replace(
            ctx, act_spec=NamedSharding(mesh, PS(None, None, "data")))
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cache_specs = cache_pspec_tree(cfg, cache_abs, rules, decode_tp=decode_tp)
    cache_shard = _named(mesh, cache_specs)
    step_fn = make_decode_step(cfg, ctx)
    batch_shardable = shape.global_batch % n_data == 0 and not decode_tp
    args = [params_abs, tok_specs["tokens"], tok_specs["pos"], cache_abs]
    shards = [p_shard,
              NamedSharding(mesh, PS(batch_axes if batch_shardable else None,
                                     None)),
              NamedSharding(mesh, PS()), cache_shard]
    if "enc_out" in tok_specs:
        args.append(tok_specs["enc_out"])
        shards.append(NamedSharding(
            mesh, PS(batch_axes if batch_shardable else None, None, None)))
    fn = jax.jit(step_fn, in_shardings=tuple(shards),
                 donate_argnums=(3,) if donate else ())
    return fn, tuple(args), ctx, 1


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, seq_shard=False,
             compress_bits=0, verbose=True, cast_bf16=False, decode_tp=False,
             accum_override=None, bf16_acc=False, pad_heads=None,
             zero1=False) -> dict:
    from repro.models.common import set_bf16_matmul_accum
    set_bf16_matmul_accum(bf16_acc)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if pad_heads:
        cfg = dataclasses.replace(cfg, pad_heads=pad_heads)
    shape = SHAPES[shape_name]
    t0 = time.time()
    fn, args, ctx, accum = build_cell(cfg, shape_name, mesh,
                                      seq_shard=seq_shard,
                                      compress_bits=compress_bits,
                                      cast_bf16=cast_bf16, decode_tp=decode_tp,
                                      accum_override=accum_override,
                                      zero1=zero1)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    skeleton = model_params(cfg)
    mf = rl.model_flops_for(cfg, shape.kind, shape.seq_len, shape.global_batch,
                            skeleton)
    n_dev = mesh.devices.size
    ana_bytes = rl.analytic_traffic(cfg, shape.kind, shape.seq_len,
                                    shape.global_batch, n_dev, accum, skeleton)
    roof = rl.derive(cost, hlo, mf, n_dev, analytic_bytes=ana_bytes)
    from repro.launch import hlo_analysis
    totals = hlo_analysis.analyze(hlo)
    xla_flops, xla_bytes = rl.flops_and_bytes(cost)
    total_p, active_p = rl.param_counts(cfg, skeleton)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "accum_steps": accum,
        "seq_shard": seq_shard, "compress_bits": compress_bits,
        "cast_bf16": cast_bf16, "decode_tp": decode_tp, "bf16_acc": bf16_acc,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params_total": total_p, "params_active": active_p,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes,
                              "note": "loop bodies counted once by XLA"},
        "collectives": {"counts": totals.collective_counts,
                        "bytes_by_kind": {k: float(v) for k, v in
                                          totals.collective_bytes.items()},
                        "effective_bytes": totals.effective_collective_bytes},
        "roofline": roof.to_json(),
        "sharding_fallbacks": validate_divisibility(
            skeleton, make_rules(mesh, seq_shard=seq_shard)),
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {rec['mesh']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"   memory_analysis: {mem}")
        print(f"   hlo (while-aware): flops={roof.hlo_flops_per_device:.3e} "
              f"bytes={roof.hlo_bytes_per_device:.3e} | xla cost_analysis "
              f"(bodies once): flops={xla_flops:.3e}")
        print(f"   collectives: {totals.collective_counts} "
              f"eff_bytes={totals.effective_collective_bytes:.3e}")
        print(f"   roofline: compute={roof.compute_s:.4f}s "
              f"memory={roof.memory_s:.4f}s collective={roof.collective_s:.4f}s "
              f"dominant={roof.dominant} frac={roof.roofline_fraction:.3f}")
    return rec


def pod_sync_study(arch: str, bits: int, out: str | None):
    """§Perf cell 3: SC stochastically-quantized cross-pod parameter sync
    (local-SGD style) vs fp32 pmean — measure HLO collective bytes on the
    2x16x16 mesh."""
    from repro.launch import hlo_analysis
    from repro.optim.compress import make_pod_sync, make_pod_sync_uncompressed

    mesh = make_production_mesh(multi_pod=True)
    cfg = get_config(arch)
    rules = make_rules(mesh)
    # FSDP within the pod only: the pod axis syncs via the compressed path.
    rules = dataclasses.replace(rules, rules=dict(rules.rules, embed="data"))
    skeleton = model_params(cfg)
    pspecs = param_pspec_tree(skeleton, rules)
    params_abs = abstract_tree(skeleton, dtype=cfg.param_dtype)
    flat_p = jax.tree.leaves(params_abs)

    sync_c = make_pod_sync(mesh, pspecs, bits=bits)
    sync_u = make_pod_sync_uncompressed(mesh, pspecs)

    def lower_and_measure(fn, args, label):
        t0 = time.time()
        compiled = jax.jit(fn).lower(*args).compile()
        totals = hlo_analysis.analyze(compiled.as_text())
        print(f"  {label}: collectives={totals.collective_counts} "
              f"eff_bytes={totals.effective_collective_bytes:.4e} "
              f"(compile {time.time() - t0:.0f}s)")
        return totals

    print(f"== pod-sync study: {arch}, int{bits} + error feedback vs fp32 ==")
    tc = lower_and_measure(lambda p, a, e: sync_c(p, a, e, 0),
                           (params_abs, params_abs, params_abs), f"int{bits}+EF")
    tu = lower_and_measure(sync_u, (params_abs,), "fp32 pmean")
    ratio = tu.effective_collective_bytes / max(tc.effective_collective_bytes, 1)
    print(f"  cross-pod byte reduction: {ratio:.2f}x "
          f"(theory ~{2 * 32 / bits:.0f}x: AR moves 2x, int{bits} AG moves "
          f"{bits}/32 of fp32)")
    if out:
        with open(out, "w") as f:
            json.dump({
                "arch": arch, "bits": bits,
                "compressed": {"counts": tc.collective_counts,
                               "eff_bytes": tc.effective_collective_bytes},
                "fp32": {"counts": tu.collective_counts,
                         "eff_bytes": tu.effective_collective_bytes},
                "reduction_x": ratio}, f, indent=1)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--seq_shard", action="store_true")
    ap.add_argument("--compress_bits", type=int, default=0)
    ap.add_argument("--cast_bf16", action="store_true")
    ap.add_argument("--bf16_acc", action="store_true")
    ap.add_argument("--decode_tp", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--pad_heads", type=int, default=None)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--pod_sync_study", action="store_true",
                    help="lower compressed vs fp32 pod param-sync and "
                         "compare collective bytes (multi-pod mesh)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--start", type=int, default=0, help="cell index offset")
    ap.add_argument("--count", type=int, default=10_000)
    args = ap.parse_args(argv)

    if args.pod_sync_study:
        return pod_sync_study(args.arch or "qwen3-8b",
                              args.compress_bits or 8, args.out)

    cells = (runnable_cells()[args.start:args.start + args.count]
             if args.all else [(args.arch, args.shape)])
    results, failures = [], []
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                    seq_shard=args.seq_shard,
                                    compress_bits=args.compress_bits,
                                    cast_bf16=args.cast_bf16,
                                    decode_tp=args.decode_tp,
                                    accum_override=args.accum,
                                    bf16_acc=args.bf16_acc,
                                    pad_heads=args.pad_heads,
                                    zero1=args.zero1))
        except Exception as e:              # noqa: BLE001 — record and continue
            traceback.print_exc()
            failures.append({"arch": arch, "shape": shape, "error": repr(e)})
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("FAILED:", f_["arch"], f_["shape"], f_["error"][:200])
        sys.exit(1)


if __name__ == "__main__":
    main()
