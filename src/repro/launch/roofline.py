"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (assignment §ROOFLINE):

    compute    = HLO_FLOPs_per_device / 197e12            (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9             (HBM bandwidth)
    collective = effective_collective_bytes / 50e9        (ICI per link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the compiled module
is the per-device program).  Collective bytes are NOT in cost_analysis: we
parse the post-SPMD HLO for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops and sum operand sizes, with per-type
effective-traffic factors (ring all-reduce moves ~2x its operand; AG/RS/A2A
move ~(N-1)/N ~ 1x).

MODEL_FLOPS uses the 6·N·D (train) / 2·N·D (inference) convention with
N_active for MoE — the ratio MODEL_FLOPS / (HLO_FLOPs × devices) exposes
remat recompute and padding waste.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (assignment).
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# effective bytes-on-the-wire multiplier per collective kind (ring algorithms)
_TRAFFIC_FACTOR = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"ragged-all-to-all)(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    effective_bytes: float

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape sizes of every collective op in the optimized HLO."""
    counts: dict[str, int] = {}
    bytes_by_kind: dict[str, float] = {}
    eff = 0.0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + b
        eff += b * _TRAFFIC_FACTOR[kind]
    return CollectiveStats(counts, bytes_by_kind, eff)


def flops_and_bytes(cost: dict | None) -> tuple[float, float]:
    """Extract per-device flops / bytes-accessed from cost_analysis output."""
    if not cost:
        return 0.0, 0.0
    c = cost[0] if isinstance(cost, (list, tuple)) else cost
    flops = float(c.get("flops", 0.0))
    byts = float(c.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(v for k, v in c.items()
                   if isinstance(v, (int, float)) and "bytes accessed" in k)
    return flops, byts


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_device: float
    hlo_bytes_per_device: float          # CPU-fusion-boundary upper bound
    analytic_bytes_per_device: float     # TPU-realistic floor (memory term)
    collective_bytes: float
    model_flops: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step estimate: max of the three (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-at-peak time / roofline step time (the perf score)."""
        ideal = self.model_flops / (self.n_devices * PEAK_FLOPS)
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def to_json(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "step_time_s": self.step_time_s,
                "useful_flops_ratio": self.useful_flops_ratio,
                "roofline_fraction": self.roofline_fraction}


def derive(cost: dict | None, hlo_text: str, model_flops: float,
           n_devices: int, analytic_bytes: float = 0.0) -> Roofline:
    """Roofline terms from the compiled per-device HLO.

    * compute / collective terms use the while-aware analyzer
      (hlo_analysis): scan-over-layers / microbatch / attention-chunk loop
      bodies are multiplied by their trip counts — XLA's cost_analysis
      counts loop bodies once and undercounts deep-scanned models by orders
      of magnitude (validated in tests/test_hlo_analysis.py);
    * the memory term uses the analytic TPU-traffic floor when provided
      (CPU fusion boundaries + loop-carry copies make the HLO-derived
      number a loose upper bound — both are recorded).
    """
    from . import hlo_analysis
    totals = hlo_analysis.analyze(hlo_text)
    mem_bytes = analytic_bytes if analytic_bytes > 0 else totals.traffic_bytes
    return Roofline(
        compute_s=totals.flops / PEAK_FLOPS,
        memory_s=mem_bytes / HBM_BW,
        collective_s=totals.effective_collective_bytes / ICI_BW,
        hlo_flops_per_device=totals.flops,
        hlo_bytes_per_device=totals.traffic_bytes,
        analytic_bytes_per_device=analytic_bytes,
        collective_bytes=totals.effective_collective_bytes,
        model_flops=model_flops,
        n_devices=n_devices,
    )


# --------------------------- MODEL_FLOPS helpers -----------------------------------

def param_counts(cfg, skeleton) -> tuple[float, float]:
    """(total_params, active_params): MoE experts count at top_k/E activity."""
    import jax
    from repro.models.common import P

    total = active = 0.0
    def visit(path, decl):
        nonlocal total, active
        n = 1.0
        for d in decl.shape:
            n *= d
        total += n
        if "experts" in decl.axes:
            active += n * (cfg.top_k / max(cfg.n_experts, 1))
        else:
            active += n

    jax.tree_util.tree_map_with_path(visit, skeleton,
                                     is_leaf=lambda x: isinstance(x, P))
    return total, active


def model_flops_for(cfg, shape_kind: str, seq: int, batch: int,
                    skeleton) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference) with N_active for MoE,
    PLUS the attention quadratic term (PaLM-style MFU accounting) — without
    it the 'useful flops' ratio misreads attention-heavy cells (MLA at 4k)
    as waste.  Windowed layers use min(S, window) context; recurrent layers
    (rglru/rwkv) have no quadratic term.
    """
    _, n_active = param_counts(cfg, skeleton)
    tokens = batch * (seq if shape_kind in ("train", "prefill") else 1)
    per_token = 6.0 if shape_kind == "train" else 2.0
    param_flops = per_token * n_active * tokens

    # Attention quadratic term: per token, per attention layer,
    #   fwd ~ 2 * ctx * H * (qk_dim + v_dim)   (scores + weighted sum)
    # with ctx = avg causal context; train multiplies by 3 (fwd+bwd).
    kinds = cfg.pattern_layers()
    fwd_mult = 3.0 if shape_kind == "train" else 1.0
    attn = 0.0
    for kind in kinds:
        if kind in ("attn", "mla"):
            ctx = (seq / 2) if shape_kind in ("train", "prefill") else seq
        elif kind == "local_attn":
            ctx = min(seq, cfg.local_window)
        else:
            continue  # rglru / rwkv: linear in seq, inside param_flops
        qk = cfg.qk_head_dim
        v = cfg.v_dim
        attn += 2.0 * ctx * cfg.n_heads * (qk + v)
    if cfg.is_encoder_decoder:
        # encoder self-attention (bidirectional, ctx = encoder_seq) applies
        # to encoder tokens; cross-attention context = encoder_seq.
        enc_tokens = batch * cfg.encoder_seq
        attn_enc = (2.0 * cfg.encoder_seq * cfg.n_heads * 2 * cfg.qk_head_dim
                    * cfg.n_encoder_layers)
        param_flops += fwd_mult * attn_enc * enc_tokens
        attn += 2.0 * cfg.encoder_seq * cfg.n_heads * 2 * cfg.qk_head_dim \
            * len(kinds)
    return param_flops + fwd_mult * attn * tokens


def analytic_traffic(cfg, shape_kind: str, seq: int, batch: int, n_devices: int,
                     accum: int, skeleton) -> float:
    """TPU-realistic per-device HBM-traffic floor (bytes per step).

    The HLO-derived traffic (hlo_analysis) reflects *CPU* fusion boundaries
    and loop-carry copies, which overstate what a TPU executes; this analytic
    floor is what §Roofline reports as the memory term, with the HLO number
    recorded alongside as an upper bound.  Terms:
      * weights: fp32 reads per microbatch (fwd + bwd + remat recompute),
        gradient write + optimizer read/modify/write (3 states);
      * boundary activations: bf16 write (fwd) + read (bwd) per layer;
      * logits: bf16 write + fp32 softmax read/write;
      * decode: KV-cache read (+ one-slot write) + weight read.
    """
    total_p, _ = param_counts(cfg, skeleton)
    p_loc = total_p / n_devices * 4.0                      # fp32 shards
    d, l, v = cfg.d_model, cfg.n_layers, cfg.vocab_size
    if shape_kind == "train":
        remat_reads = 1 if cfg.remat != "none" else 0
        w = p_loc * (accum * (2 + remat_reads) + 7)        # fwd/bwd/remat + opt
        mb_rows = max(batch // n_devices, 1) / accum
        acts = accum * 2 * l * mb_rows * seq * d * 2.0
        return w + acts
    if shape_kind == "prefill":
        rows = max(batch / n_devices, 1 / 16)
        acts = 2 * l * rows * seq * d * 2.0
        cache_w = l * rows * seq * 2 * cfg.n_kv_heads * cfg.qk_head_dim * 2.0
        return p_loc + acts + cache_w
    # decode: read the whole local cache shard + the weights once
    if cfg.use_mla:
        cache = l * batch * seq * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0
    else:
        per_layer = {"attn": seq, "local_attn": min(seq, cfg.local_window)}
        cache = 0.0
        for kind in cfg.pattern_layers():
            s_eff = per_layer.get(kind)
            if s_eff is None:
                cache += batch * d * 64 * 4.0                # small rec state
            else:
                cache += batch * s_eff * 2 * cfg.n_kv_heads * cfg.qk_head_dim * 2.0
    return p_loc + cache / n_devices
