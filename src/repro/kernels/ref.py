"""Pure-jnp oracles for every Pallas kernel (bit-identical RNG).

Each function mirrors the corresponding kernel's semantics exactly — same
counter-based RNG, same accumulation order class — so tests can assert exact
equality in interpret mode and tight statistical agreement against the
float-exact result.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (WORD_BITS, gen_packed_bits, gen_packed_bits_seeded,
                     hash_u32, mix_seed, popcount, threshold_u32)


def sc_eltwise_ref(op: str, *args: jax.Array) -> jax.Array:
    """Packed bitwise gate ops over uint32 words."""
    a = args[0]
    if op == "not":
        return ~a
    b = args[1]
    if op == "and":
        return a & b
    if op == "nand":
        return ~(a & b)
    if op == "or":
        return a | b
    if op == "nor":
        return ~(a | b)
    if op == "xor":
        return a ^ b
    if op == "mux":
        s = args[2]
        return (a & s) | (b & ~s)
    raise ValueError(op)


def popcount_hier_ref(words: jax.Array, group: int) -> jax.Array:
    """Hierarchical StoB popcount: (N, W) packed -> (N,) int32 counts.

    Sums per-word popcounts in two levels (groups of ``group`` words, then
    across groups) — the local/global accumulator structure of Fig. 8.  The
    result is exact regardless of grouping.
    """
    n, w = words.shape
    pad = (-w) % group
    padded = jnp.pad(words, ((0, 0), (0, pad)))
    per_word = popcount(padded).reshape(n, -1, group)
    local = per_word.sum(axis=-1)          # local accumulators (per group)
    return local.sum(axis=-1)              # global accumulator


def sc_matmul_ref(a: jax.Array, w: jax.Array, bitstream_length: int,
                  seed: int = 0) -> jax.Array:
    """SC matrix multiply oracle: popcount(AND) over on-the-fly bitstreams.

    a: (M, K) in [0,1];  w: (K, N) in [0,1];  result approximates a @ w with
    per-product Bernoulli sampling noise of variance p(1-p)/BL.

    Bit t of the stream for a[m, k] uses counter (m*K + k)*BL + t with seed
    ``seed``; w[k, n] uses counter (k*N + n)*BL + t with seed ``seed+1`` —
    identical to the kernel, so kernel output == ref output bit-for-bit.
    """
    m_dim, k_dim = a.shape
    _, n_dim = w.shape
    n_words = bitstream_length // WORD_BITS
    seed_a = jnp.uint32(seed)
    seed_w = jnp.uint32(seed + 1)

    out = jnp.zeros((m_dim, n_dim), jnp.int32)
    for wi in range(n_words):
        a_idx = ((jnp.arange(m_dim)[:, None] * k_dim + jnp.arange(k_dim)[None, :])
                 .astype(jnp.uint32) * jnp.uint32(bitstream_length)
                 + jnp.uint32(wi * WORD_BITS))
        w_idx = ((jnp.arange(k_dim)[:, None] * n_dim + jnp.arange(n_dim)[None, :])
                 .astype(jnp.uint32) * jnp.uint32(bitstream_length)
                 + jnp.uint32(wi * WORD_BITS))
        a_bits = gen_packed_bits(seed_a, a_idx, a)          # (M, K) uint32
        w_bits = gen_packed_bits(seed_w, w_idx, w)          # (K, N) uint32
        anded = a_bits[:, :, None] & w_bits[None, :, :]     # (M, K, N)
        out = out + popcount(anded).sum(axis=1)
    return out.astype(jnp.float32) / jnp.float32(bitstream_length)


def sng_words_ref(row_seeds: jax.Array, thr: jax.Array, n_words: int,
                  word_offset: jax.Array | None = None,
                  total_words: int | None = None) -> jax.Array:
    """Batched SNG oracle over a stream table: (N, B) thresholds -> (N, B, W).

    ``row_seeds``: (N,) pre-mixed per-row seeds (``common.mix_seed``); rows
    with equal seed share their uniforms (correlation groups).  ``thr``:
    (N, B) uint32 compare thresholds.  Bit ``t`` of word ``w`` of element
    ``b`` is 1 iff hash((b*W + w)*32 + t ^ row_seed) < thr — the counter runs
    over *bit space* per element, so output is independent of how rows are
    stacked or batches are tiled.

    ``word_offset``/``total_words`` generate a *window*: words
    ``[word_offset, word_offset + n_words)`` of a conceptual
    ``total_words``-long stream.  Because the counter is the absolute bit
    index, the window is bit-identical to the same slice of a whole-stream
    call — the chunked streaming executor relies on this exactness.
    ``word_offset`` may be traced (a ``lax.scan`` chunk index).

    Packs by compare-and-accumulate over the 32 lane shifts: only packed-size
    (N, B, W) tensors are ever materialized, never the (N, B, W, 32) unpacked
    bit tensor — mirroring the Pallas kernel's in-register accumulation.
    """
    b = thr.shape[-1]
    total = jnp.uint32(n_words if total_words is None else total_words)
    word_idx = jnp.arange(n_words, dtype=jnp.uint32)
    if word_offset is not None:
        word_idx = word_idx + jnp.asarray(word_offset, jnp.uint32)
    base = ((jnp.arange(b, dtype=jnp.uint32)[:, None] * total
             + word_idx[None, :])
            * jnp.uint32(WORD_BITS))                       # (B, W) bit counters
    acc = jnp.zeros(thr.shape + (n_words,), jnp.uint32)
    seeds = row_seeds[:, None, None]
    for t in range(WORD_BITS):
        r = hash_u32((base[None] + jnp.uint32(t)) ^ seeds)
        acc = acc | ((r < thr[..., None]).astype(jnp.uint32) << jnp.uint32(t))
    return acc


def sng_pack_ref(p: jax.Array, bitstream_length: int, seed: int = 0) -> jax.Array:
    """Stochastic number generation oracle: p (...,) -> packed (..., BL//32).

    Single-row degenerate case of the stream-table discipline: every element
    of ``p`` is one batch element of row 0 (key lane 0), with bit counters
    ``elem * BL + bit``.
    """
    n_words = bitstream_length // WORD_BITS
    flat = p.reshape(-1)
    idx = (jnp.arange(flat.shape[0], dtype=jnp.uint32)[:, None]
           * jnp.uint32(bitstream_length)
           + (jnp.arange(n_words, dtype=jnp.uint32) * WORD_BITS)[None, :])
    mixed = jnp.broadcast_to(mix_seed(jnp.uint32(seed), jnp.uint32(0)), idx.shape)
    words = gen_packed_bits_seeded(mixed, idx, threshold_u32(flat)[:, None])
    return words.reshape(p.shape + (n_words,))
