"""Public jit'd entry points for the SC kernels.

``use_pallas`` selects the Pallas path (interpret mode on CPU, compiled on
TPU); the ref path is the pure-jnp oracle.  Both compute bit-identical
results (same counter-based RNG), so the switch is purely an execution-
strategy choice.
"""
from __future__ import annotations

import jax

from . import ref
from .common import on_tpu as _on_tpu
from .packed_logic import packed_logic
from .popcount_tree import popcount_hier
from .sc_matmul import sc_matmul as _sc_matmul_pallas
from .sng import sng_pack as _sng_pallas, sng_words as _sng_words


def sc_matmul(a: jax.Array, w: jax.Array, bitstream_length: int = 256,
              seed: int = 0, use_pallas: bool = True, bm: int = 8,
              bn: int = 128, bk: int = 128) -> jax.Array:
    if use_pallas:
        return _sc_matmul_pallas(a, w, bitstream_length, seed, bm=bm, bn=bn,
                                 bk=bk, interpret=not _on_tpu())
    return ref.sc_matmul_ref(a, w, bitstream_length, seed)


def sng(p: jax.Array, bitstream_length: int = 256, seed: int = 0,
        use_pallas: bool = True) -> jax.Array:
    if use_pallas:
        flat = p.reshape(-1)
        out = _sng_pallas(flat, bitstream_length, seed, interpret=not _on_tpu())
        return out.reshape(p.shape + (bitstream_length // 32,))
    return ref.sng_pack_ref(p, bitstream_length, seed)


def sng_table(row_seeds: jax.Array, thr: jax.Array, bitstream_length: int = 256,
              use_pallas: bool = True) -> jax.Array:
    """Batched stream-table SNG: (N,) seeds + (N, B) thresholds -> (N, B, W)."""
    if bitstream_length % 32 != 0:
        raise ValueError(f"bitstream length {bitstream_length} must be a "
                         "multiple of 32")
    # sng_words routes to the ref oracle itself when use_pallas=False and
    # auto-selects interpret mode off-TPU otherwise.
    return _sng_words(row_seeds, thr, bitstream_length // 32,
                      use_pallas=use_pallas)


def logic(op: str, *args: jax.Array, use_pallas: bool = True) -> jax.Array:
    if use_pallas:
        return packed_logic(op, *args, interpret=not _on_tpu())
    return ref.sc_eltwise_ref(op, *args)


def stob_counts(words: jax.Array, use_pallas: bool = True) -> jax.Array:
    if use_pallas:
        return popcount_hier(words, interpret=not _on_tpu())
    return ref.popcount_hier_ref(words, group=16)
