"""Pallas kernel: chunked RWKV-6 WKV recurrence (the attn-free hot loop).

    out_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ);   S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

TPU mapping: one grid program per (batch, head); r/k/v/log_w chunks stream
through VMEM while the (hd, hd) state lives in a VMEM scratch accumulator —
the same state-stays-resident structure as the paper's in-memory divider
wavefront (state cells persist across bit steps, DESIGN.md §7(d)).  Within a
chunk the recurrence is evaluated in the cumulative-decay matrix form
(intra-chunk attention-like matmul on the MXU + rank-C state update), so the
sequential dependency is only chunk-to-chunk.

Validated against ref.wkv_ref (same chunk order, allclose) and against the
models/recurrent.py production path in tests/test_wkv_kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
            chunk: int, seq: int):
    hd = r_ref.shape[-1]
    state_ref[...] = jnp.zeros((hd, hd), jnp.float32)
    u = u_ref[...]                                     # (hd,)
    n_chunks = seq // chunk
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    def body(ci, _):
        sl = pl.dslice(ci * chunk, chunk)
        r = r_ref[sl, :]                               # (C, hd)
        k = k_ref[sl, :]
        v = v_ref[sl, :]
        lw = lw_ref[sl, :]
        big_l = jnp.cumsum(lw, axis=0)                 # inclusive decay
        l_prev = big_l - lw
        q_t = r * jnp.exp(l_prev)
        k_t = k * jnp.exp(-big_l)
        s = state_ref[...]
        inter = q_t @ s                                # (C, hd)
        scores = (q_t @ k_t.T) * tri                   # strictly causal
        intra = scores @ v
        bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
        o_ref[sl, :] = inter + intra + bonus
        state_ref[...] = jnp.exp(big_l[-1])[:, None] * (s + k_t.T @ v)
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
        u: jax.Array, chunk: int = 32, interpret: bool = True) -> jax.Array:
    """r/k/v/log_w: (B, S, H, hd) fp32; u: (H, hd).  Returns (B, S, H, hd).

    chunk must divide S; hd should be a multiple of 8 (vreg sublanes) and
    ideally 128 lanes on real TPU.
    """
    b, s, h, hd = r.shape
    assert s % chunk == 0, (s, chunk)

    def bh(t):  # (B,S,H,hd) -> (B*H, S, hd)
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    kern = functools.partial(_kernel, chunk=chunk, seq=s)
    spec = pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0))

    out = pl.pallas_call(
        lambda r_, k_, v_, lw_, u_, o_, st: kern(
            r_.at[0], k_.at[0], v_.at[0], lw_.at[0], u_.at[0], o_.at[0], st),
        grid=(b * h,),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda i: (i % h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(bh(r.astype(jnp.float32)), bh(k.astype(jnp.float32)),
      bh(v.astype(jnp.float32)), bh(log_w.astype(jnp.float32)),
      u.astype(jnp.float32))
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
