"""Pallas kernel: hierarchical StoB popcount (the accumulator tree of Fig. 8).

Stochastic-to-binary conversion counts the ones of each output bitstream.
Stoch-IMC does this hierarchically: m local accumulators per group feed one
global accumulator — n+m steps instead of n*m.  The TPU mapping: per-word
``lax.population_count`` (the local accumulator: 32 bits folded at once),
an in-tile sum over a word group, then a cross-tile accumulation over the
word-block grid axis (the global accumulator).

Grid: (row_blocks, word_blocks); the word-block axis accumulates into the
same output block (revisiting pattern), mirroring group-by-group global
accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, o_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    words = a_ref[...]                                   # (bm, bw) uint32
    local = jax.lax.population_count(words).astype(jnp.int32)
    o_ref[...] += local.sum(axis=1)                      # global accumulate


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words",
                                             "interpret"))
def popcount_hier(words: jax.Array, block_rows: int = 8, block_words: int = 128,
                  interpret: bool = True) -> jax.Array:
    """(N, W) packed uint32 -> (N,) int32 set-bit counts."""
    n, w = words.shape
    bm = min(block_rows, n)
    bw = min(block_words, w)
    grid = (pl.cdiv(n, bm), pl.cdiv(w, bw))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(words)
