"""Pure-jnp oracle for the WKV kernel (same chunk order as the kernel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, log_w, u, chunk: int = 32) -> jax.Array:
    """Reference chunked WKV: r/k/v/log_w (B,S,H,hd) fp32, u (H,hd)."""
    b, s, h, hd = r.shape
    assert s % chunk == 0
    nc = s // chunk

    def resh(t):
        return (t.astype(jnp.float32).transpose(0, 2, 1, 3)
                .reshape(b * h, nc, chunk, hd))

    r_, k_, v_, lw = resh(r), resh(k), resh(v), resh(log_w)
    u_ = jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, hd)
                          ).reshape(b * h, hd)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    big_l = jnp.cumsum(lw, axis=2)
    l_prev = big_l - lw
    q_t = r_ * jnp.exp(l_prev)
    k_t = k_ * jnp.exp(-big_l)
    bonus = jnp.sum(r_ * u_[:, None, None, :] * k_, axis=-1, keepdims=True) * v_

    def step(state, xs):
        q_c, kc, vc, kt_c, lC, bon = xs
        inter = jnp.einsum("nck,nkv->ncv", q_c, state)
        scores = jnp.einsum("nck,nsk->ncs", q_c, kt_c) * tri[None]
        intra = jnp.einsum("ncs,nsv->ncv", scores, vc)
        new_state = jnp.exp(lC)[:, :, None] * (
            state + jnp.einsum("nsk,nsv->nkv", kt_c, vc))
        return new_state, inter + intra + bon

    s0 = jnp.zeros((b * h, hd, hd), jnp.float32)
    _, outs = jax.lax.scan(
        step, s0, (q_t.transpose(1, 0, 2, 3), k_.transpose(1, 0, 2, 3),
                   v_.transpose(1, 0, 2, 3), k_t.transpose(1, 0, 2, 3),
                   big_l[:, :, -1].transpose(1, 0, 2),
                   bonus.transpose(1, 0, 2, 3)))
    out = outs.transpose(1, 0, 2, 3).reshape(b * h, s, hd)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
