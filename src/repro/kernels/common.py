"""Shared kernel utilities: in-kernel counter-based RNG and packing.

The MTJ's intrinsic stochastic switching generates bitstream bits *in place*,
fused with computation (paper Section 4-1).  The TPU analogue is a
counter-based hash RNG evaluated inside the kernel (VMEM-resident, no HBM
traffic for randomness).  We use the murmur3/splitmix finalizer — statistical
quality is ample for SC (independence across counters is what matters), and
keeping it in plain jnp means the Pallas kernel and the ref.py oracle compute
*bit-identical* streams, enabling exact equality tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def on_tpu() -> bool:
    """Whether the default backend is a real TPU (Pallas compiles natively);
    everywhere else the kernels run in interpret mode."""
    return jax.default_backend() == "tpu"


def hash_u32(x: jax.Array) -> jax.Array:
    """Murmur3 finalizer: uint32 -> well-mixed uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def threshold_u32(p: jax.Array) -> jax.Array:
    """Probability in [0,1] -> uint32 compare threshold (the BtoS LUT analogue).

    Clamped on the integer side: float32 cannot represent 2^32 - 1 (it rounds
    to 2^32), so a float-side minimum is a no-op and the out-of-range
    float->uint32 cast it was meant to prevent is undefined across XLA
    backends.  Anything that rounds to >= 2^32 maps to 0xFFFFFFFF instead
    (p=1.0 covers all but one value in 2^32 — the same convention as
    ``core.bitstream._threshold_u32``).
    """
    scaled = jnp.round(jnp.clip(p, 0.0, 1.0).astype(jnp.float32) * 4294967296.0)
    return jnp.where(scaled >= jnp.float32(4294967296.0), jnp.uint32(0xFFFFFFFF),
                     scaled.astype(jnp.uint32))


def mix_seed(seed: jax.Array, lane: jax.Array) -> jax.Array:
    """Derive a per-stream-row mixed seed from (seed, key-lane index).

    Rows with equal lane share their uniforms (correlation groups); rows with
    distinct lanes are statistically independent.  The mix is applied once
    outside the generation loop, so the hot path hashes only the bit counter.
    """
    return hash_u32(hash_u32(seed.astype(jnp.uint32)) ^ lane.astype(jnp.uint32))


def gen_packed_bits_seeded(mixed_seed: jax.Array, base_index: jax.Array,
                           thr: jax.Array) -> jax.Array:
    """Generate one packed uint32 word of Bernoulli bits per element.

    ``mixed_seed``: pre-mixed per-row seed (see ``mix_seed``), broadcastable
    against ``base_index``.  ``base_index``: uint32 tensor of *bit-space* base
    counters (flat element index * 32).  ``thr``: uint32 compare thresholds
    (``threshold_u32``), broadcastable against ``base_index``.  Bit ``t`` of
    the output word is 1 iff hash(base+t ^ seed) < thr.
    """
    lanes = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    ctr = base_index[..., None] + lanes          # (..., 32)
    r = hash_u32(ctr ^ mixed_seed[..., None])
    bits = (r < thr[..., None]).astype(jnp.uint32)
    return jnp.sum(bits << lanes, axis=-1, dtype=jnp.uint32)


def gen_packed_bits(seed: jax.Array, base_index: jax.Array, p: jax.Array) -> jax.Array:
    """Generate one packed uint32 word of Bernoulli(p) bits per element.

    ``base_index``: uint32 tensor of *bit-space* base counters (flat element
    index * 32), broadcastable against ``p``.  Bit ``t`` of the output word is
    1 with probability ``p``, independently across (seed, counter) pairs.
    """
    mixed = jnp.broadcast_to(hash_u32(seed.astype(jnp.uint32)), base_index.shape)
    return gen_packed_bits_seeded(mixed, base_index, threshold_u32(p))


def popcount(words: jax.Array) -> jax.Array:
    return jax.lax.population_count(words).astype(jnp.int32)
