"""Shared kernel utilities: in-kernel counter-based RNG and packing.

The MTJ's intrinsic stochastic switching generates bitstream bits *in place*,
fused with computation (paper Section 4-1).  The TPU analogue is a
counter-based hash RNG evaluated inside the kernel (VMEM-resident, no HBM
traffic for randomness).  We use the murmur3/splitmix finalizer — statistical
quality is ample for SC (independence across counters is what matters), and
keeping it in plain jnp means the Pallas kernel and the ref.py oracle compute
*bit-identical* streams, enabling exact equality tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def hash_u32(x: jax.Array) -> jax.Array:
    """Murmur3 finalizer: uint32 -> well-mixed uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def threshold_u32(p: jax.Array) -> jax.Array:
    """Probability in [0,1] -> uint32 compare threshold (the BtoS LUT analogue)."""
    scaled = jnp.round(jnp.clip(p, 0.0, 1.0).astype(jnp.float32) * 4294967296.0)
    return jnp.minimum(scaled, 4294967295.0).astype(jnp.uint32)


def gen_packed_bits(seed: jax.Array, base_index: jax.Array, p: jax.Array) -> jax.Array:
    """Generate one packed uint32 word of Bernoulli(p) bits per element.

    ``base_index``: uint32 tensor of *bit-space* base counters (flat element
    index * 32), broadcastable against ``p``.  Bit ``t`` of the output word is
    1 with probability ``p``, independently across (seed, counter) pairs.
    """
    lanes = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    ctr = base_index[..., None] + lanes          # (..., 32)
    r = hash_u32(ctr ^ hash_u32(seed.astype(jnp.uint32)))
    bits = (r < threshold_u32(p)[..., None]).astype(jnp.uint32)
    return jnp.sum(bits << lanes, axis=-1, dtype=jnp.uint32)


def popcount(words: jax.Array) -> jax.Array:
    return jax.lax.population_count(words).astype(jnp.int32)
