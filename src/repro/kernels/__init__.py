"""Pallas TPU kernels for the compute hot-spots the paper optimizes, each
with a pure-jnp oracle (exact-equality or allclose tests in tests/):

  sng            fused stochastic number generation (the in-memory BtoS step)
  packed_logic   bit-parallel boolean algebra over packed uint32 lanes
  netlist_exec   fused execution of compiled netlist plans (core/plan.py)
  popcount_tree  hierarchical StoB accumulation (Fig. 8's local/global tree)
  sc_matmul      popcount(AND) stochastic matrix multiply w/ in-kernel SNG
  wkv            chunked RWKV-6 WKV recurrence (the attn-free arch hot loop)
"""
from . import common, netlist_exec, ops, ref, ref_wkv
from .packed_logic import packed_logic
from .popcount_tree import popcount_hier
from .sc_matmul import sc_matmul
from .sng import lane_seeds, sng_pack, sng_words
from .wkv import wkv

__all__ = ["common", "netlist_exec", "ops", "ref", "ref_wkv", "packed_logic",
           "popcount_hier", "sc_matmul", "lane_seeds", "sng_pack", "sng_words",
           "wkv"]
