"""Whole-plan Pallas megakernel: an ExecutionPlan as ONE fused kernel.

The per-pass path (``netlist_exec``) issues one kernel per fused pass, so
every intermediate node stream round-trips through HBM-equivalent buffers
between passes.  This module lowers an entire combinational plan (or the
combinational body of a sequential plan's scan step) into a single
``pallas_call`` gridded over ``(row_tiles, word_tiles)``:

  * each tile's PI streams load once into a VMEM scratch *pool* sized by the
    liveness stage's ``plan.max_live`` — NOT by node count — and every
    level's bitwise passes run without the tile ever leaving VMEM;
  * per-input complement masks (``CompiledOp.neg``) fold into the in-register
    reads, and the fused MUX/XOR/AND plan-level ops execute as single
    expressions;
  * only the plan's declared outputs (and state drivers) write back.

This is the TPU analogue of the paper's intra-subarray residency: a gate
level's operands and results stay inside the array (here: VMEM) instead of
streaming in and out per gate pass.  Exact, not approximate — combinational
SC streams are word-parallel, every op is bitwise, and the scratch assignment
never recycles a slot while its node is still live (``stages.assign_liveness``
releases a pass's dying inputs only after the pass, so batched gates cannot
clobber a sibling's operand).  Off-TPU the kernel runs in interpret mode,
bit-identical to the jnp per-pass path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import bitstream as bs
from ..core.plan import FUSED_MUX, ExecutionPlan
from .common import on_tpu

#: program instruction: (op, neg, in_slot_rows, out_slots) — all static.


def _plan_program(plan: ExecutionPlan):
    """Compile the plan's levels into a static slot program.

    Returns ``(program, slot_of)`` where ``slot_of`` maps every materialized
    node name to its scratch slot, or ``None`` when the plan carries no
    liveness assignment (pre-liveness plans have empty ``pi_slots``).
    """
    if len(plan.pi_slots) != len(plan.pis):
        return None
    slot_of = {pi.name: s for pi, s in zip(plan.pis, plan.pi_slots) if s >= 0}
    program = []
    for level in plan.levels:
        for cop in level:
            if len(cop.slots) != len(cop.outputs):
                return None
            in_rows = tuple(tuple(slot_of[nm] for nm in row)
                            for row in cop.inputs)
            neg = cop.neg if cop.neg else (False,) * len(cop.inputs)
            program.append((cop.op, neg, in_rows, cop.slots))
            for nm, s in zip(cop.outputs, cop.slots):
                slot_of[nm] = s
    return program, slot_of


def _apply_op(op: str, args: list[jax.Array]) -> jax.Array:
    if op == FUSED_MUX:
        return bs.mux(*args)
    return bs.GATE_FNS[op](*args)


def _kernel(program, pi_slots, out_slots, pi_ref, out_ref, scratch):
    # Load this tile's PI streams into their scratch slots.
    for k, s in enumerate(pi_slots):
        scratch[s] = pi_ref[k]
    # Every level's passes, gate by gate — static Python loops, fully
    # unrolled at trace time; slots recycle per the liveness assignment.
    for op, neg, in_rows, slots in program:
        for g, out_slot in enumerate(slots):
            args = []
            for row, nb in zip(in_rows, neg):
                v = scratch[row[g]]
                args.append(~v if nb else v)
            scratch[out_slot] = _apply_op(op, args)
    # Only declared outputs leave VMEM.
    for k, s in enumerate(out_slots):
        out_ref[k] = scratch[s]


def combinational_megakernel(plan: ExecutionPlan,
                             env: dict[str, jax.Array], *,
                             block_rows: int = 8, block_words: int = 128,
                             interpret: bool | None = None,
                             ) -> dict[str, jax.Array] | None:
    """Run a combinational plan as one fused Pallas kernel.

    ``env`` maps every stream/state PI name to its packed words (any common
    shape; the kernel flattens to (rows, words)).  Returns the plan's
    observable streams — outputs and state drivers, aliases resolved — or
    ``None`` when the plan cannot lower (no liveness info, or heterogeneous
    PI shapes, as in a merged bank serving mixed batch shapes); the caller
    then falls back to the per-pass path.
    """
    prog = _plan_program(plan)
    if prog is None:
        return None
    program, slot_of = prog

    alias = dict(plan.aliases)
    out_names: list[str] = []
    for nm in (*plan.outputs, *plan.state_drivers):
        r = alias.get(nm, nm)
        if r not in out_names:
            out_names.append(r)
    if not out_names:
        return {}

    pi_names = [pi.name for pi, s in zip(plan.pis, plan.pi_slots) if s >= 0]
    shapes = {env[nm].shape for nm in pi_names}
    if len(shapes) != 1:
        return None
    (shape,) = shapes
    if plan.max_live == 0 or not pi_names:
        return None

    words = shape[-1] if len(shape) >= 1 else 1
    rows = 1
    for d in shape[:-1]:
        rows *= d
    stacked = jnp.stack([env[nm].reshape(rows, words) for nm in pi_names])
    out = _megakernel_call(
        plan, tuple(slot_of[nm] for nm in pi_names),
        tuple(slot_of[nm] for nm in out_names), len(out_names),
        stacked, block_rows, block_words, interpret)
    return {nm: out[out_names.index(alias.get(nm, nm))].reshape(shape)
            for nm in (*plan.outputs, *plan.state_drivers)}


@functools.partial(jax.jit, static_argnames=(
    "plan", "pi_slots", "out_slots", "n_out", "block_rows", "block_words",
    "interpret"))
def _megakernel_call(plan: ExecutionPlan, pi_slots, out_slots, n_out: int,
                     stacked: jax.Array, block_rows: int, block_words: int,
                     interpret: bool | None) -> jax.Array:
    """The jitted pallas_call: (P, rows, words) PI stack -> (O, rows, words).

    The plan is a static arg (interned, identity-hashed), so the slot program
    rebuilds only per plan per shape — one trace, one kernel.
    """
    if interpret is None:
        interpret = not on_tpu()
    program, _ = _plan_program(plan)
    p, rows, words = stacked.shape
    bm = min(block_rows, rows)
    bw = min(block_words, words)
    grid = (pl.cdiv(rows, bm), pl.cdiv(words, bw))
    kernel = functools.partial(_kernel, program, pi_slots, out_slots)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((p, bm, bw), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((n_out, bm, bw), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, rows, words), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((plan.max_live, bm, bw), jnp.uint32)],
        interpret=interpret,
    )(stacked)
