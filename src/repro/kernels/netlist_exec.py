"""Fused bit-parallel execution of compiled netlist plans.

Executes ``core.plan.ExecutionPlan``s over packed uint32 bitstream words.
Each ``CompiledOp`` — all same-type gates of one topological level — becomes
ONE bitwise pass over stacked words, the TPU analogue of the paper's
intra-subarray SIMD gate execution (a whole gate level fires in one VPU
pass, like all rows of a subarray firing in one cycle).  Two backends per
pass:

  * pure jnp bitwise ops (default): XLA fuses the whole plan into a single
    kernel under jit;
  * the Pallas packed-logic kernel (``use_pallas=True``): routes 1/2/3-input
    passes through ``packed_logic.py``'s VMEM-tiled kernel, including the
    fused 4-gate MUX path.

Sequential (stateful) netlists — the Gaines-divider class — run as a
``lax.scan`` over *words* with an inner 32-step bit loop, so the feedback
wavefront never materializes the eager time-major (BL, ...) bit tensor the
interpreter builds (32x less live memory at BL=1024, and the whole recurrence
stays inside one jit).

Everything here is bit-identical to the gate-by-gate interpreter: fused ops
are boolean identities and per-gate fault injection uses the same per-gate
key assignment (see ``core/executor.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import bitstream as bs
from ..core import faults as _faults
from ..core.plan import FUSED_MUX, ExecutionPlan
from .packed_logic import packed_logic

# Plan op -> packed_logic op name (ops the Pallas kernel implements).
_PALLAS_OPS = {"NOT": "not", "AND": "and", "NAND": "nand", "OR": "or",
               "NOR": "nor", "XOR": "xor", FUSED_MUX: "mux"}


def _apply_pass(op: str, ins: list[jax.Array], use_pallas: bool,
                neg: tuple[bool, ...] = (),
                interpret: bool | None = None) -> jax.Array:
    """One fused pass over stacked packed words (any leading batch shape).

    ``neg[j]`` complements input ``j`` first — the absorbed-lone-NOT form of
    ``core/plan.py``'s NOT fusion (an exact identity: complementing inside
    the pass equals materializing the NOT's output stream).  On the Pallas
    path the mask folds into the kernel itself (an in-register read), so no
    separate full-tensor complement op ever materializes; ``interpret``
    forwards to ``packed_logic`` (None = auto-detect off-TPU).
    """
    if use_pallas and op in _PALLAS_OPS and ins[0].ndim >= 2:
        shape = ins[0].shape
        flat = [x.reshape(-1, shape[-1]) for x in ins]
        return packed_logic(_PALLAS_OPS[op], *flat, neg=tuple(neg),
                            interpret=interpret).reshape(shape)
    if any(neg):
        ins = [~x if nb else x for x, nb in zip(ins, neg)]
    if op == "BUFF":
        return ins[0]
    if op == FUSED_MUX:
        return bs.mux(*ins)
    return bs.GATE_FNS[op](*ins)


def run_combinational(plan: ExecutionPlan, env: dict[str, jax.Array],
                      gate_fkeys: jax.Array | None = None,
                      bitflip_rate: float = 0.0,
                      use_pallas: bool = False,
                      fault_model=None,
                      megakernel: bool = False,
                      interpret: bool | None = None) -> dict[str, jax.Array]:
    """Evaluate the plan's levels in-place over ``env`` (node -> words).

    ``gate_fkeys``: per-gate fault keys indexed by original gate id; when
    given (with ``bitflip_rate > 0`` or a non-null ``fault_model``) every
    pass output is faulted with its gate's own key — matching the
    interpreter's injection points, which requires an unfused plan
    (``compile_plan(net, fuse_mux=False)``).  ``fault_model`` generalizes
    the flat rate to the STT-MRAM taxonomy (``core/faults.py``): each gate's
    output stream occupies its own array rows, so its stuck/dead masks
    derive from that gate's key.

    ``megakernel=True`` lowers the whole plan into ONE Pallas kernel
    (``plan_megakernel``) when it can — homogeneous PI shapes and a
    liveness-annotated plan — silently falling back to the per-pass path
    otherwise.  Fault injection faults individual pass outputs, which the
    fused kernel never materializes, so the combination is rejected.

    The per-pass path releases dead intermediates as it goes: after each
    pass, every node in ``cop.free_after`` (computed by the compiler's
    liveness stage) is dropped from ``env``, bounding eager/interpret
    residency at ``plan.max_live`` streams instead of one per node.
    """
    inject = gate_fkeys is not None and \
        _faults.injecting(bitflip_rate, fault_model)
    if inject and plan.fused:
        raise ValueError("per-gate fault injection requires an unfused plan")
    if megakernel:
        if inject:
            raise ValueError(
                "megakernel execution cannot inject per-gate faults: "
                "intermediate pass outputs never leave the kernel")
        from .plan_megakernel import combinational_megakernel
        res = combinational_megakernel(plan, env, interpret=interpret)
        if res is not None:
            env.update(res)
            return env
    for level in plan.levels:
        for cop in level:
            k = cop.n_batched
            if k == 1:
                ins = [env[names[0]] for names in cop.inputs]
                outs = [_apply_pass(cop.op, ins, use_pallas, cop.neg,
                                    interpret)]
            else:
                outs = _batched_pass(cop, env, use_pallas, interpret)
            if inject:
                outs = [_faults.apply_faults(gate_fkeys[gid], o,
                                             bitflip_rate, fault_model)
                        for gid, o in zip(cop.gids, outs)]
            for name, o in zip(cop.outputs, outs):
                env[name] = o
            for name in cop.free_after:
                env.pop(name, None)
    # Re-expose nodes elided by BUFF elision / CSE: each aliases the surviving
    # node computing the identical stream, so outputs and state drivers that
    # were deduplicated away stay readable (zero extra passes).
    for src, dst in plan.aliases:
        env[src] = env[dst]
    return env


def _batched_pass(cop, env: dict[str, jax.Array], use_pallas: bool,
                  interpret: bool | None = None) -> list[jax.Array]:
    """Execute one multi-gate CompiledOp, allowing heterogeneous batch shapes.

    Bank-merged plans batch gates from different member netlists into one op,
    and members may carry different batch shapes (one member serves a (8,)
    request while another serves a scalar).  Gates are grouped by input-shape
    signature; each group stacks into one fused pass, so same-shape members
    still share a single pass while differently-shaped ones keep their native
    shapes — no broadcasting, which keeps every node's stream (and therefore
    fault injection and decode) bit-identical to a per-member run.
    """
    k = cop.n_batched
    rows = [[env[n] for n in names] for names in cop.inputs]   # arity x k
    groups: dict[tuple, list[int]] = {}
    for i in range(k):
        sig = tuple(row[i].shape for row in rows)
        groups.setdefault(sig, []).append(i)

    outs: list[jax.Array | None] = [None] * k
    for idxs in groups.values():
        if len(idxs) == 1:
            i = idxs[0]
            outs[i] = _apply_pass(cop.op, [row[i] for row in rows], use_pallas,
                                  cop.neg, interpret)
            continue
        ins = [jnp.stack([row[i] for i in idxs]) for row in rows]
        stacked = _apply_pass(cop.op, ins, use_pallas, cop.neg, interpret)
        for j, i in enumerate(idxs):
            outs[i] = stacked[j]
    return outs


def run_sequential(plan: ExecutionPlan, pi_words: dict[str, jax.Array],
                   use_pallas: bool = False,
                   n_words: int | None = None,
                   batch_shape: tuple[int, ...] | None = None,
                   megakernel: bool = False,
                   interpret: bool | None = None) -> dict[str, jax.Array]:
    """Run a stateful plan as scan-over-words with an inner 32-bit loop.

    ``pi_words``: packed streams for every non-state PI, shape (..., W).
    Returns packed output streams of the same shape.  State cells are carried
    across bits (the paper's wavefront across subarrays); bit ``t`` of the
    output is the circuit's emission at time step ``t``, with state read
    *before* update — exactly the interpreter's scan semantics.

    Members of a bank-merged sequential plan may carry different (broadcast-
    compatible) batch shapes; the scan then runs at the common shape and the
    caller restricts each member's outputs back to its native shape (exact:
    every op is elementwise, so restriction commutes with the recurrence).
    Plans with zero stream PIs (state-only recurrences, e.g. a NOT-feedback
    oscillator) have nothing to stack — ``n_words`` then supplies the scan
    length that is otherwise read off the stacked words, and ``batch_shape``
    the batch shape that is otherwise read off the stacked words' leading
    dims (without it a batched request would silently collapse to scalar
    state and outputs).

    ``megakernel``/``interpret`` forward to the per-bit combinational body.
    """
    names = plan.stream_pi_names()
    if names:
        shapes = {pi_words[n].shape for n in names}
        if len(shapes) > 1:
            common = jnp.broadcast_shapes(*shapes)
            stacked = jnp.stack([jnp.broadcast_to(pi_words[n], common)
                                 for n in names])              # (P, ..., W)
        else:
            stacked = jnp.stack([pi_words[n] for n in names])  # (P, ..., W)
        batch = stacked.shape[1:-1]
        xs = jnp.moveaxis(stacked, -1, 0)                      # (W, P, ...)
    else:
        if n_words is None:
            raise ValueError(
                f"plan {plan.name} has no stream PIs; pass n_words "
                "(= bitstream_length // 32) to size the scan")
        batch = tuple(batch_shape) if batch_shape else ()
        xs = jnp.zeros((n_words, 0), jnp.uint32)               # (W, 0)

    state0 = tuple(jnp.full(batch, jnp.uint32(round(init)))
                   for init in plan.state_inits)
    n_out = len(plan.outputs)

    def word_step(state, word):                                # word: (P, ...)
        zeros = tuple(jnp.zeros(batch, jnp.uint32) for _ in range(n_out))

        def bit_step(i, carry):
            state, out_words = carry
            sh = jnp.uint32(i)
            env = {n: (word[j] >> sh) & jnp.uint32(1)
                   for j, n in enumerate(names)}
            for s_name, s_val in zip(plan.state_pis, state):
                env[s_name] = s_val
            run_combinational(plan, env, use_pallas=use_pallas,
                              megakernel=megakernel, interpret=interpret)
            new_state = tuple(env[d] for d in plan.state_drivers)
            # Mask to bit 0 before packing: inverting gates (~x) carry
            # garbage in bits 1..31 of the per-bit env values.
            out_words = tuple(w | ((env[o] & jnp.uint32(1)) << sh)
                              for w, o in zip(out_words, plan.outputs))
            return new_state, out_words

        state, out_words = jax.lax.fori_loop(0, bs.WORD_BITS, bit_step,
                                             (state, zeros))
        return state, out_words

    _, ys = jax.lax.scan(word_step, state0, xs)                # each: (W, ...)
    return {o: jnp.moveaxis(y, 0, -1) for o, y in zip(plan.outputs, ys)}
