"""Pallas kernel: batched stochastic number generation over a stream table.

The BtoS step of the paper writes *all* operand streams into subarray rows in
bulk before any gate pass runs (Sec. 2-3 / Fig. 8) — and for in-memory SC it
is stream generation, not the logic passes, that dominates end-to-end cost
(Khatamifard et al.; Razi et al.).  This kernel is the TPU translation of
that bulk write: ONE fused threshold+pack pass generates every primary-input
stream of a compiled plan (or a whole bank of plans) from a stacked
threshold table, instead of one dispatch per stream.

Layout: the *stream table* (``core.plan.StreamTable``) stacks the plan's
non-state PIs into rows.  Row ``i`` carries a pre-mixed per-row seed
(``common.mix_seed(seed, lane_i)``); rows with equal key-lane index share
their uniforms — that is how correlation groups (XOR = |a-b|, Fig. 4(c))
ride through the same batched pass as the independent streams.

The kernel packs by compare-and-accumulate over the 32 lane shifts: the
(…, W, 32) unpacked bit tensor is never materialized (32x less live memory
than the threshold-then-pack formulation).  Counters derive from global
(element, bit) indices, so output is tiling-independent and bit-identical to
``ref.sng_words_ref`` — the jnp fallback the executor uses by default
(``use_pallas`` opts into the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import WORD_BITS, hash_u32, mix_seed, on_tpu, threshold_u32


def lane_seeds(seed: jax.Array, lanes: jax.Array) -> jax.Array:
    """Per-row mixed seeds for a stream table: (N,) lanes -> (N,) seeds."""
    return mix_seed(jnp.asarray(seed, jnp.uint32),
                    jnp.asarray(lanes, jnp.uint32))


def _kernel(seed_ref, thr_ref, o_ref, *, n_words: int, be: int):
    j = pl.program_id(1)
    s = seed_ref[0]                                       # mixed per-row seed
    thr = thr_ref[0]                                      # (be,)
    elem = (j * be + jnp.arange(be, dtype=jnp.uint32))    # global element ids
    base = (elem[:, None] * jnp.uint32(n_words)
            + jnp.arange(n_words, dtype=jnp.uint32)[None, :]) * jnp.uint32(
                WORD_BITS)                                # (be, W) bit counters
    acc = jnp.zeros((be, n_words), jnp.uint32)
    for t in range(WORD_BITS):
        r = hash_u32((base + jnp.uint32(t)) ^ s)
        acc = acc | ((r < thr[:, None]).astype(jnp.uint32) << jnp.uint32(t))
    o_ref[...] = acc[None]


@functools.partial(jax.jit, static_argnames=("n_words", "use_pallas",
                                             "block_elems", "interpret",
                                             "total_words"))
def sng_words(row_seeds: jax.Array, thr: jax.Array, n_words: int,
              use_pallas: bool = False, block_elems: int = 256,
              interpret: bool | None = None,
              word_offset: jax.Array | None = None,
              total_words: int | None = None) -> jax.Array:
    """Batched SNG over a stream table: (N, B) thresholds -> (N, B, W) words.

    ``row_seeds``: (N,) pre-mixed per-row seeds (``lane_seeds``); rows with
    equal seed share their uniforms (correlation groups decode exact |a-b|
    under XOR).  ``thr``: (N, B) uint32 compare thresholds.  The jnp fallback
    (``use_pallas=False``, the executor default) and the Pallas kernel are
    bit-identical; ``interpret=None`` auto-selects interpret mode off-TPU.

    ``word_offset``/``total_words`` request a word *window* of a conceptual
    ``total_words``-long stream (see ``ref.sng_words_ref``) — exact because
    the counter is the absolute bit index.  Windowed generation always runs
    the jnp path: ``word_offset`` is typically a traced scan index, which the
    grid-blocked Pallas kernel cannot take as a static.
    """
    total = n_words if total_words is None else total_words
    if thr.shape[-1] * total * WORD_BITS > 1 << 32:
        # Bit counters are uint32 per (row, element, bit): past 2^32 bits per
        # row they wrap, silently duplicating uniforms between far-apart
        # elements (streams assumed independent become perfectly correlated).
        # The legacy threefry discipline has no such cliff, so refuse loudly.
        raise ValueError(
            f"batched SNG counter space exhausted: {thr.shape[-1]} elements x "
            f"{total * WORD_BITS} bits > 2^32 bits per stream row; shard "
            "the batch across keys or use key_mode='legacy'")
    windowed = word_offset is not None or total != n_words
    if not use_pallas or windowed:
        return ref.sng_words_ref(row_seeds, thr, n_words,
                                 word_offset=word_offset, total_words=total)
    n, b = thr.shape
    be = min(block_elems, b)
    kernel = functools.partial(_kernel, n_words=n_words, be=be)
    return pl.pallas_call(
        kernel,
        grid=(n, pl.cdiv(b, be)),
        in_specs=[pl.BlockSpec((1,), lambda i, j: (i,)),
                  pl.BlockSpec((1, be), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, be, n_words), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b, n_words), jnp.uint32),
        interpret=not on_tpu() if interpret is None else interpret,
    )(row_seeds.astype(jnp.uint32), thr)


@functools.partial(jax.jit, static_argnames=("bitstream_length", "seed",
                                             "block", "interpret"))
def sng_pack(p: jax.Array, bitstream_length: int = 256, seed: int = 0,
             block: int = 256, interpret: bool = True) -> jax.Array:
    """p: (N,) float in [0,1] -> (N, BL//32) packed uint32 bitstreams.

    Single-row degenerate case of ``sng_words`` (one table row, key lane 0,
    every element of ``p`` a batch element) — equals ``ref.sng_pack_ref``.
    """
    n_words = bitstream_length // WORD_BITS
    seeds = lane_seeds(jnp.uint32(seed), jnp.zeros((1,), jnp.uint32))
    thr = threshold_u32(p.astype(jnp.float32))[None, :]
    return sng_words(seeds, thr, n_words, use_pallas=True, block_elems=block,
                     interpret=interpret)[0]
