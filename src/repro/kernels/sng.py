"""Pallas kernel: stochastic number generation (the BtoS step as a kernel).

Maps a tensor of probabilities to packed Bernoulli bitstreams, entirely in
VMEM — the TPU analogue of the pulse-programmed MTJ stochastic write
(Eqs. (1)-(2) / Fig. 8's BtoS memory).  Counters derive from global element
indices, so output is tiling-independent and equals ref.sng_pack_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import WORD_BITS, gen_packed_bits


def _kernel(p_ref, o_ref, *, bl: int, n_words: int, bn: int, seed: int):
    i = pl.program_id(0)
    p = p_ref[...]                                        # (bn,)
    gi = i * bn + jnp.arange(bn, dtype=jnp.uint32)        # global element ids
    base = gi[:, None] * jnp.uint32(bl) + (
        jnp.arange(n_words, dtype=jnp.uint32) * WORD_BITS)[None, :]
    o_ref[...] = gen_packed_bits(jnp.uint32(seed), base, p[:, None])


@functools.partial(jax.jit, static_argnames=("bitstream_length", "seed",
                                             "block", "interpret"))
def sng_pack(p: jax.Array, bitstream_length: int = 256, seed: int = 0,
             block: int = 256, interpret: bool = True) -> jax.Array:
    """p: (N,) float in [0,1] -> (N, BL//32) packed uint32 bitstreams."""
    n = p.shape[0]
    n_words = bitstream_length // WORD_BITS
    bn = min(block, n)
    kernel = functools.partial(_kernel, bl=bitstream_length, n_words=n_words,
                               bn=bn, seed=seed)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(n, bn),),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn, n_words), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_words), jnp.uint32),
        interpret=interpret,
    )(p.astype(jnp.float32))
