"""Pallas kernel: bit-parallel packed logic over uint32 bitstream words.

TPU mapping of the paper's intra-subarray SIMD gate execution: one VPU
bitwise op processes a whole VMEM tile = (rows x words x 32) bitstream bits —
the "subarray" of DESIGN.md §2.  The MUX (scaled addition) fuses 4 gates
(NOT + 2xNAND + NAND) into one pass, where the 2T-1MTJ method takes 4 cycles;
fusion is the beyond-paper win available on TPU (no per-gate cell writes).

Block shapes: (BM, BW) words; BM a multiple of 8 rows, BW a multiple of 128
lanes to match the (8, 128) vreg tiling for 32-bit types.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OPS1 = {"not"}
_OPS2 = {"and", "nand", "or", "nor", "xor"}
_OPS3 = {"mux"}


def _kernel1(op, a_ref, o_ref):
    a = a_ref[...]
    o_ref[...] = ~a


def _kernel2(op, a_ref, b_ref, o_ref):
    a, b = a_ref[...], b_ref[...]
    if op == "and":
        o_ref[...] = a & b
    elif op == "nand":
        o_ref[...] = ~(a & b)
    elif op == "or":
        o_ref[...] = a | b
    elif op == "nor":
        o_ref[...] = ~(a | b)
    elif op == "xor":
        o_ref[...] = a ^ b


def _kernel3(op, a_ref, b_ref, s_ref, o_ref):
    a, b, s = a_ref[...], b_ref[...], s_ref[...]
    o_ref[...] = (a & s) | (b & ~s)  # fused scaled addition


@functools.partial(jax.jit, static_argnames=("op", "block_rows", "block_words",
                                             "interpret"))
def packed_logic(op: str, *args: jax.Array, block_rows: int = 8,
                 block_words: int = 128, interpret: bool = True) -> jax.Array:
    """Apply a packed logic op over (rows, words) uint32 tensors."""
    a = args[0]
    rows, words = a.shape
    bm = min(block_rows, rows)
    bw = min(block_words, words)
    grid = (pl.cdiv(rows, bm), pl.cdiv(words, bw))
    spec = pl.BlockSpec((bm, bw), lambda i, j: (i, j))

    if op in _OPS1:
        kernel, n_in = functools.partial(_kernel1, op), 1
    elif op in _OPS2:
        kernel, n_in = functools.partial(_kernel2, op), 2
    elif op in _OPS3:
        kernel, n_in = functools.partial(_kernel3, op), 3
    else:
        raise ValueError(op)
    if len(args) != n_in:
        raise ValueError(f"{op} expects {n_in} operands")

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, words), jnp.uint32),
        interpret=interpret,
    )(*args)
