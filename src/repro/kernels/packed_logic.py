"""Pallas kernel: bit-parallel packed logic over uint32 bitstream words.

TPU mapping of the paper's intra-subarray SIMD gate execution: one VPU
bitwise op processes a whole VMEM tile = (rows x words x 32) bitstream bits —
the "subarray" of DESIGN.md §2.  The MUX (scaled addition) fuses 4 gates
(NOT + 2xNAND + NAND) into one pass, where the 2T-1MTJ method takes 4 cycles;
fusion is the beyond-paper win available on TPU (no per-gate cell writes).

Per-input complement masks (``neg``) are folded into the kernel itself: an
absorbed lone NOT costs zero extra passes AND zero extra XLA ops — the
complement happens on the VMEM-resident tile, not as a separate full-tensor
pass before the pallas_call.

Block shapes: (BM, BW) words; BM a multiple of 8 rows, BW a multiple of 128
lanes to match the (8, 128) vreg tiling for 32-bit types.  ``interpret=None``
(the default) auto-selects: compiled on TPU, interpret mode everywhere else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import on_tpu

_OPS1 = {"not"}
_OPS2 = {"and", "nand", "or", "nor", "xor"}
_OPS3 = {"mux"}


def _load(ref, nb: bool) -> jax.Array:
    """Read a tile, complementing in-register when its neg-mask bit is set."""
    x = ref[...]
    return ~x if nb else x


def _kernel1(op, neg, a_ref, o_ref):
    a = _load(a_ref, neg[0])
    o_ref[...] = ~a


def _kernel2(op, neg, a_ref, b_ref, o_ref):
    a, b = _load(a_ref, neg[0]), _load(b_ref, neg[1])
    if op == "and":
        o_ref[...] = a & b
    elif op == "nand":
        o_ref[...] = ~(a & b)
    elif op == "or":
        o_ref[...] = a | b
    elif op == "nor":
        o_ref[...] = ~(a | b)
    elif op == "xor":
        o_ref[...] = a ^ b


def _kernel3(op, neg, a_ref, b_ref, s_ref, o_ref):
    a, b = _load(a_ref, neg[0]), _load(b_ref, neg[1])
    s = _load(s_ref, neg[2])
    o_ref[...] = (a & s) | (b & ~s)  # fused scaled addition


@functools.partial(jax.jit, static_argnames=("op", "block_rows", "block_words",
                                             "interpret", "neg"))
def packed_logic(op: str, *args: jax.Array, block_rows: int = 8,
                 block_words: int = 128, interpret: bool | None = None,
                 neg: tuple[bool, ...] = ()) -> jax.Array:
    """Apply a packed logic op over (rows, words) uint32 tensors.

    ``neg[j]`` complements operand ``j`` inside the kernel before the op
    (``CompiledOp.neg``, the absorbed-lone-NOT mask); ``()`` means none.
    ``interpret=None`` resolves to interpret mode unless running on a real
    TPU (``common.on_tpu``).
    """
    if interpret is None:
        interpret = not on_tpu()
    a = args[0]
    rows, words = a.shape
    bm = min(block_rows, rows)
    bw = min(block_words, words)
    grid = (pl.cdiv(rows, bm), pl.cdiv(words, bw))
    spec = pl.BlockSpec((bm, bw), lambda i, j: (i, j))

    if op in _OPS1:
        kernel, n_in = _kernel1, 1
    elif op in _OPS2:
        kernel, n_in = _kernel2, 2
    elif op in _OPS3:
        kernel, n_in = _kernel3, 3
    else:
        raise ValueError(op)
    if len(args) != n_in:
        raise ValueError(f"{op} expects {n_in} operands")
    if neg and len(neg) != n_in:
        raise ValueError(f"{op} neg mask has {len(neg)} entries "
                         f"for {n_in} operands")
    full_neg = tuple(neg) if neg else (False,) * n_in

    return pl.pallas_call(
        functools.partial(kernel, op, full_neg),
        grid=grid,
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, words), jnp.uint32),
        interpret=interpret,
    )(*args)
