"""Pallas kernel: stochastic-computing matrix multiply with fused SNG.

The paper's flagship integration point (DESIGN.md §2/§6): every scalar
product a*w is estimated as popcount(AND(bits_a, bits_w)) / BL over
Bernoulli bitstreams *generated inside the kernel* — the TPU analogue of the
MTJ intrinsic-stochasticity SNG fused with the logic step (no separate RNG
pass, no randomness traffic from HBM).

    out[m, n] = (1/BL) * sum_k popcount(bits(a[m,k]) & bits(w[k,n]))
    E[out] = a @ w,  Var ~ sum_k p(1-p)/BL

Tiling: grid (M/bm, N/bn, K/bk); the K axis revisits the same output block
(accumulation pattern).  Inside, a fori_loop walks the BL/32 bitstream words;
per word, the (bm,bk)x(bk,bn) AND+popcount contraction is evaluated on the
VPU.  Counters are derived from *global* element indices so results are
independent of the tiling — the kernel equals ref.sc_matmul_ref bit-for-bit.

Arithmetic-intensity note (recorded in EXPERIMENTS.md §Perf): on TPU this
costs ~2*BL/32 integer ops per MAC versus 1 MXU MAC for exact matmul, so SC
matmul is a *fault-tolerance/approximation feature*, not a speed win — the
paper's latency win is specific to in-memory hardware where binary
multipliers cost hundreds of array cycles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import WORD_BITS, gen_packed_bits, popcount


def _kernel(a_ref, w_ref, o_ref, *, bl: int, bk: int, k_dim: int, n_dim: int,
            seed: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    j = pl.program_id(1)
    a = a_ref[...]                     # (bm, bk) float32 in [0,1]
    w = w_ref[...]                     # (bk, bn) float32 in [0,1]
    bm, _ = a.shape
    _, bn = w.shape

    # Global element indices -> bit-space counters (tiling-independent RNG).
    gm = i * bm + jnp.arange(bm, dtype=jnp.uint32)[:, None]       # (bm, 1)
    gk_a = kb * bk + jnp.arange(bk, dtype=jnp.uint32)[None, :]    # (1, bk)
    gk_w = kb * bk + jnp.arange(bk, dtype=jnp.uint32)[:, None]    # (bk, 1)
    gn = j * bn + jnp.arange(bn, dtype=jnp.uint32)[None, :]       # (1, bn)
    a_base = (gm * jnp.uint32(k_dim) + gk_a) * jnp.uint32(bl)     # (bm, bk)
    w_base = (gk_w * jnp.uint32(n_dim) + gn) * jnp.uint32(bl)     # (bk, bn)
    seed_a = jnp.uint32(seed)
    seed_w = jnp.uint32(seed + 1)

    def word_step(wi, acc):
        off = jnp.uint32(wi * WORD_BITS)
        a_bits = gen_packed_bits(seed_a, a_base + off, a)          # (bm, bk)
        w_bits = gen_packed_bits(seed_w, w_base + off, w)          # (bk, bn)
        anded = a_bits[:, :, None] & w_bits[None, :, :]            # (bm,bk,bn)
        return acc + popcount(anded).sum(axis=1)

    acc = jax.lax.fori_loop(0, bl // WORD_BITS, word_step,
                            jnp.zeros((bm, bn), jnp.int32))
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bitstream_length", "seed", "bm",
                                             "bn", "bk", "interpret"))
def sc_matmul(a: jax.Array, w: jax.Array, bitstream_length: int = 256,
              seed: int = 0, bm: int = 8, bn: int = 128, bk: int = 128,
              interpret: bool = True) -> jax.Array:
    """Stochastic matmul: a (M,K) x w (K,N), values in [0,1] -> float32 (M,N)."""
    m_dim, k_dim = a.shape
    k2, n_dim = w.shape
    assert k_dim == k2
    bm = min(bm, m_dim)
    bn = min(bn, n_dim)
    bk = min(bk, k_dim)
    grid = (pl.cdiv(m_dim, bm), pl.cdiv(n_dim, bn), pl.cdiv(k_dim, bk))
    kernel = functools.partial(_kernel, bl=bitstream_length, bk=bk, k_dim=k_dim,
                               n_dim=n_dim, seed=seed)
    counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.int32),
        interpret=interpret,
    )(a.astype(jnp.float32), w.astype(jnp.float32))
    return counts.astype(jnp.float32) / jnp.float32(bitstream_length)
