"""Deterministic, resumable, sharded synthetic token pipeline.

Design for 1000+-node fleets (README §Operations):
  * STATELESS: batch ``t`` is a pure function of (seed, t) — resume after a
    failure needs only the step counter from the checkpoint; no iterator
    state, no data-server coordination.
  * SHARDED: each data-parallel host materializes only its slice
    (process_index-derived), then device_put's to the global sharding; on the
    single-process dry-run we materialize globally.
  * LEARNABLE: tokens follow a k-order Markov-ish recurrence so a real
    training run shows decreasing loss (examples/train_lm.py), not noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        """Global batch for ``step`` (pure function — resumable)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2 = jax.random.split(key)
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        # Structured stream: x_{t} = (a * x_{t-1} + c + noise) mod v with
        # per-sequence (a, c) — predictable given context, so loss can fall.
        a = jax.random.randint(k1, (b, 1), 2, 8)
        c = jax.random.randint(k2, (b, 1), 0, v)
        t = jnp.arange(s + 1)
        x0 = jax.random.randint(key, (b, 1), 0, v)
        seq = (x0 * (a ** 0) + c * t[None, :]) % v          # affine stream
        seq = seq.astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def host_batch(self, step: int, host_id: int, n_hosts: int) -> dict:
        """This host's slice of the global batch (multi-host pipelines)."""
        full = self.batch(step)
        assert self.global_batch % n_hosts == 0
        mb = self.global_batch // n_hosts
        sl = slice(host_id * mb, (host_id + 1) * mb)
        return {k: v[sl] for k, v in full.items()}


def batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int) -> dict:
    return {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)}
