"""Atomic, elastic checkpointing.

Fault-tolerance contract (README §Operations):
  * ATOMIC: writes go to ``step_<n>.tmp-<pid>`` then ``os.replace`` to
    ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint;
  * MANIFEST: every leaf is a .npy plus a JSON manifest with tree structure,
    shapes, dtypes and a content checksum — restore verifies integrity;
  * ELASTIC: arrays are saved in the *global* (unsharded) view and re-placed
    under whatever sharding the restoring mesh provides — restore onto a
    different mesh shape (shrink-and-continue after node loss) needs no
    conversion step;
  * AUTO-RESUME: ``latest_step`` scans the directory; launch/train.py resumes
    from it by default.

At true fleet scale this module's single-writer global view is the fallback
path; per-shard parallel IO would slot in behind the same manifest format.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(_key_str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(directory: str, step: int, tree: Any) -> str:
    """Atomically write ``tree`` as checkpoint ``step``; returns final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        is_key = hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
            leaf.dtype, jax.dtypes.prng_key)
        if is_key:
            arr = np.asarray(jax.device_get(jax.random.key_data(leaf)))
        else:
            arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256_16": digest,
            "prng_key": bool(is_key),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and "tmp" not in d]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any | None = None, verify: bool = True) -> Any:
    """Restore checkpoint ``step`` into the structure of ``like``.

    ``shardings``: optional tree of jax.sharding.Sharding — arrays are
    device_put under it (elastic resharding: the saving mesh's shape is
    irrelevant).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        entry = by_path[p]
        arr = np.load(os.path.join(path, entry["file"]))
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if digest != entry["sha256_16"]:
                raise IOError(f"checksum mismatch for {p} in {path}")
        if entry.get("prng_key"):
            out.append(jax.random.wrap_key_data(jax.numpy.asarray(arr)))
        elif sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
