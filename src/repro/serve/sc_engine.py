"""Dynamic bank serving: admit SC requests, execute bucketed padded banks.

The bank compiler (core/plan.py) and executor (execute_many) serve a *fixed,
ahead-of-time* member list: every distinct request multiset costs a fresh
BankPlan merge and a fresh jit trace.  Real traffic — the ROADMAP's "heavy
heterogeneous traffic" north star, and the regime the memory-level-
parallelism literature targets — changes its member set every arrival, so a
naive execute_many server recompiles constantly and the accelerator starves.

``BankServer`` closes that gap with three mechanisms:

  * **admission queue** — ``submit()`` enqueues a request and returns a
    ``Ticket``; batches launch when ``max_slots`` requests of one execution
    group (same bitstream length / bitflip rate) are waiting, when the oldest
    waiting request exceeds the batching window, or on explicit ``flush()``
    / ``Ticket.result()`` (the engine is synchronous: time-based flushes are
    evaluated at submit/result boundaries, not by a background thread).
  * **bucketed, padded bank templates** — each batch maps to the canonical
    template of its member multiset (``plan.compile_bank_template``):
    structures in deterministic order, per-structure slot counts padded to
    powers of two, identity members topping up the total.  Requests bind to
    slots (stable order: plan serial, then value shapes) and unbound slots
    are masked out (``executor.execute_bank(active=...)``), so any request
    set that fits a bucket reuses ONE BankPlan and ONE jit program.
  * **per-request key threading** — every request carries its own PRNG key
    (and flip key under fault injection), and the executor draws slot
    streams exactly as standalone ``execute`` would: results are
    **bit-identical** per request to an unbatched run with the same key and
    ``key_mode``, regardless of which bucket or slot served it (pinned by
    tests/test_serve.py).

``stats()`` reports the serving health signals: bucket hit rate (how warm
the template/jit caches run), padding waste (masked slots per executed
slot), p50/p99 request latency, and throughput.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, defaultdict, deque
from typing import Any

import jax
import numpy as np

from ..core import executor
from ..core.gates import Netlist
from ..core.plan import compile_bank_template, compile_plan


@dataclasses.dataclass
class SCRequest:
    """One admitted stochastic-computation request.

    ``net`` is the circuit (structure-equal netlists intern to one compiled
    plan — reuse built netlist objects across requests to keep the plan memo
    warm, e.g. via ``repro.serve.apps``); ``values`` its PI values; ``key``
    the request's own PRNG key (the bit-identity anchor).  ``batch_shape``
    declares the stream batch shape when values alone cannot (all-const
    PIs).  ``bitflip_rate``/``flip_key`` inject per-request faults.
    """

    net: Netlist
    values: dict[str, Any]
    key: Any
    bitstream_length: int = 256
    batch_shape: "tuple[int, ...] | None" = None
    bitflip_rate: float = 0.0
    flip_key: Any = None


class Ticket:
    """Completion handle for a submitted request."""

    __slots__ = ("_server", "_result", "_done", "submitted_at", "latency_s")

    def __init__(self, server: "BankServer"):
        self._server = server
        self._result = None
        self._done = False
        self.submitted_at = time.perf_counter()
        self.latency_s: float | None = None

    def done(self) -> bool:
        return self._done

    def result(self):
        """The request's output dict; flushes the server if still pending."""
        if not self._done:
            self._server.flush()
        if not self._done:                      # pragma: no cover - safety
            raise RuntimeError("ticket unresolved after flush")
        return self._result

    def _fulfil(self, result, t_done: float) -> None:
        self._result = result
        self._done = True
        self.latency_s = t_done - self.submitted_at


@dataclasses.dataclass
class _Pending:
    req: SCRequest
    ticket: Ticket


def _key_data_host(k) -> "np.ndarray":
    # The public unwrap (jax.random.key_data) dispatches an XLA op per key —
    # at serving rates that is the single largest per-batch host cost.  The
    # raw buffer is directly reachable on current jax; fall back to the
    # public path if the internal layout ever changes.
    base = getattr(k, "_base_array", None)
    if base is not None:
        return np.asarray(base)
    return np.asarray(jax.random.key_data(k))


def _stack_keys(keys: list):
    """Stack per-slot PRNG keys into one (n,) key array, host-side.

    ``jnp.stack`` over typed keys dispatches one expand_dims per slot plus a
    concatenate; staging the raw key data through numpy collapses that to
    ONE device put, bit-identical to the stacked keys (same key data, same
    impl).  Repeated slot keys (the unbound-slot placeholder) unwrap once.
    """
    try:
        memo: dict[int, np.ndarray] = {}
        rows = []
        for k in keys:
            d = memo.get(id(k))
            if d is None:
                d = memo[id(k)] = _key_data_host(k)
            rows.append(d)
        return jax.random.wrap_key_data(jax.numpy.asarray(np.stack(rows)),
                                        impl=jax.random.key_impl(keys[0]))
    except (TypeError, AttributeError):
        return jax.numpy.stack(keys)


def _percentile(sorted_xs: "list[float]", q: float) -> float:
    if not sorted_xs:
        return 0.0
    idx = min(len(sorted_xs) - 1, max(0, round(q * (len(sorted_xs) - 1))))
    return sorted_xs[idx]


#: Sliding window for latency percentiles (bounds a long-running server's
#: memory; counters stay exact).
LATENCY_WINDOW = 4096
#: LRU caps on the server's own memo/signature state — like the plan/bank
#: caches, serving many bucket shapes must not grow them without bound.
_TEMPLATE_MEMO_CAP = 256
_SIGNATURE_CAP = 4096


@dataclasses.dataclass
class BankServerStats:
    """Cumulative serving counters (reset with ``BankServer.reset_stats``).

    Latencies are kept in a sliding window of the most recent
    ``LATENCY_WINDOW`` requests — p50/p99/mean describe recent traffic, the
    integer counters the server's whole life.
    """

    n_requests: int = 0
    n_batches: int = 0
    bucket_hits: int = 0          # batches whose full exec signature was warm
    bucket_misses: int = 0
    slots_total: int = 0          # executed template slots (incl. padding)
    active_slots: int = 0         # slots bound to requests
    identity_slots: int = 0       # no-op identity padding slots
    exec_s: float = 0.0           # wall time inside batch execution
    latencies_s: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))

    def as_dict(self) -> dict:
        lat = sorted(self.latencies_s)
        total_batches = max(self.n_batches, 1)
        return {
            "n_requests": self.n_requests,
            "n_batches": self.n_batches,
            "bucket_hits": self.bucket_hits,
            "bucket_misses": self.bucket_misses,
            "bucket_hit_rate": self.bucket_hits / total_batches,
            "padding_waste": (self.slots_total - self.active_slots)
            / max(self.slots_total, 1),
            "identity_slots": self.identity_slots,
            "p50_ms": _percentile(lat, 0.50) * 1e3,
            "p99_ms": _percentile(lat, 0.99) * 1e3,
            "mean_ms": (sum(lat) / len(lat) * 1e3) if lat else 0.0,
            "throughput_rps": self.n_requests / max(self.exec_s, 1e-9),
            "exec_s": self.exec_s,
        }


class BankServer:
    """Traffic-driven serving engine over bucketed, padded BankPlans.

    Parameters
    ----------
    max_slots:
        Admission threshold and per-batch request cap: a batch launches as
        soon as ``max_slots`` requests of one execution group are queued.
    window_s:
        Batching window — on submit, if the oldest queued request has waited
        at least this long, the queue flushes.  ``None`` (default) disables
        the time trigger: batches launch on ``max_slots``, ``flush()``, or
        ``Ticket.result()`` only.  The engine is synchronous, so the window
        is evaluated at submit/result/flush calls, not by a background
        thread (0.0 therefore means "never let a request wait behind a
        second submit").
    pad_counts:
        Pad each structure's slot count to a power of two (bucket key space
        shrinks from per-count to per-log-count).
    pad_total:
        Pad the template's total slot count to a power of two with identity
        members.
    key_mode / backend / decode:
        Threaded to ``executor.execute_bank``; ``decode=True`` (default)
        returns decoded output values per request, else packed streams.

    Results are bit-identical per request to standalone
    ``executor.execute[_value]`` with the same key — see module docstring.
    """

    def __init__(self, *, max_slots: int = 8,
                 window_s: "float | None" = None,
                 pad_counts: bool = True, pad_total: bool = True,
                 key_mode: str | None = None, backend: str | None = None,
                 decode: bool = True):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self.window_s = window_s
        self.pad_counts = pad_counts
        self.pad_total = pad_total
        self.key_mode = key_mode
        self.backend = backend
        self.decode = decode
        self._queue: "list[_Pending]" = []
        # Both maps are LRU-bounded: heterogeneous traffic mints new plan
        # tuples / exec signatures indefinitely, and the memo's strong
        # template references must not defeat plan.py's bank-cache cap.
        self._seen_signatures: OrderedDict = OrderedDict()
        # Canonical plan tuple -> compiled template: front-runs the plan-level
        # bank cache (which must hash member tuples) with an id-keyed lookup.
        self._template_memo: OrderedDict = OrderedDict()
        self._stats = BankServerStats()

    # ------------------------------ admission ------------------------------------

    def submit(self, req: SCRequest) -> Ticket:
        """Admit one request; may trigger a flush per the batching policy."""
        if req.bitflip_rate > 0.0 and req.flip_key is None:
            raise ValueError("bitflip_rate > 0 requires flip_key")
        ticket = Ticket(self)
        self._queue.append(_Pending(req, ticket))
        group = self._group_key(req)
        n_group = sum(1 for p in self._queue
                      if self._group_key(p.req) == group)
        if n_group >= self.max_slots:
            # Only the group that filled launches — other groups keep
            # accumulating toward their own max_slots/window triggers.
            self._flush_group(group)
        elif self.window_s is not None and self._queue:
            if time.perf_counter() - self._queue[0].ticket.submitted_at \
                    >= self.window_s:
                self.flush()
        return ticket

    def serve(self, requests: "list[SCRequest]") -> list:
        """Submit a burst and return its results in request order."""
        tickets = [self.submit(r) for r in requests]
        self.flush()
        return [t.result() for t in tickets]

    def flush(self) -> int:
        """Drain the admission queue; returns the number of batches run."""
        n_batches = 0
        while self._queue:
            self._flush_group(self._group_key(self._queue[0].req))
            n_batches += 1
        return n_batches

    def _flush_group(self, group: tuple) -> None:
        """Execute one batch of up to ``max_slots`` requests of ``group``."""
        take = [p for p in self._queue
                if self._group_key(p.req) == group][:self.max_slots]
        taken = set(map(id, take))
        self._queue = [p for p in self._queue if id(p) not in taken]
        self._execute_batch(take)

    # ------------------------------ execution ------------------------------------

    @staticmethod
    def _group_key(req: SCRequest) -> tuple:
        # Static execution parameters that cannot share one bank dispatch.
        return (req.bitstream_length, float(req.bitflip_rate))

    @staticmethod
    def _shape_sig(req: SCRequest) -> tuple:
        vs = tuple(sorted((k, tuple(jax.numpy.shape(v)))
                          for k, v in req.values.items()))
        # Encode "no declared batch shape" as a comparable value: signatures
        # are sort keys, and None does not order against tuples.
        if req.batch_shape is None:
            return ((False, ()), vs)
        return ((True, tuple(req.batch_shape)), vs)

    def _execute_batch(self, pendings: "list[_Pending]") -> None:
        t0 = time.perf_counter()
        bl, rate = self._group_key(pendings[0].req)
        fuse = rate == 0.0
        plans = [compile_plan(p.req.net,
                              fuse_mux=fuse or p.req.net.is_sequential)
                 for p in pendings]
        # Canonical request order (plan serial, then value shapes): identical
        # traffic mixes bind identically, so the jit signature repeats even
        # when arrival order shuffles.
        sigs = [self._shape_sig(p.req) for p in pendings]
        order = sorted(range(len(pendings)),
                       key=lambda i: (plans[i].serial, sigs[i]))
        ordered_plans = tuple(plans[i] for i in order)
        template = self._template_memo.get(ordered_plans)
        if template is None:
            template = compile_bank_template(list(ordered_plans),
                                             pad_counts=self.pad_counts,
                                             pad_total=self.pad_total)
            self._template_memo[ordered_plans] = template
            while len(self._template_memo) > _TEMPLATE_MEMO_CAP:
                self._template_memo.popitem(last=False)
        else:
            self._template_memo.move_to_end(ordered_plans)

        free: "dict[int, deque]" = defaultdict(deque)
        for s, m in enumerate(template.members):
            free[id(m)].append(s)
        n = template.n_members
        dummy_key = pendings[0].req.key
        fk0 = pendings[0].req.flip_key
        values_seq: list = [{} for _ in range(n)]
        key_rows: list = [dummy_key] * n
        flip_rows: list = [fk0 if fk0 is not None else dummy_key] * n
        batch_shapes: list = [None] * n
        active = [False] * n
        slot_of: "dict[int, int]" = {}                  # request idx -> slot
        for ri in order:
            req = pendings[ri].req
            s = free[id(plans[ri])].popleft()
            slot_of[ri] = s
            values_seq[s] = req.values
            key_rows[s] = req.key
            batch_shapes[s] = req.batch_shape
            active[s] = True
            if rate > 0.0:
                flip_rows[s] = req.flip_key

        # template.serial (a monotone build stamp) — never id(), which can
        # alias a garbage-collected template after cache eviction and
        # misreport cold batches as bucket hits.
        signature = (template.serial, bl, rate, tuple(active),
                     tuple(sigs[i] for i in order))
        hit = signature in self._seen_signatures
        self._seen_signatures[signature] = None
        self._seen_signatures.move_to_end(signature)
        while len(self._seen_signatures) > _SIGNATURE_CAP:
            self._seen_signatures.popitem(last=False)

        outs = executor.execute_bank(
            template, values_seq, _stack_keys(key_rows), bl, active=active,
            bitflip_rate=rate,
            flip_keys=_stack_keys(flip_rows) if rate > 0.0 else None,
            backend=self.backend, key_mode=self.key_mode,
            batch_shapes=batch_shapes, decode=self.decode)
        jax.block_until_ready([outs[s] for s in slot_of.values()])
        t_done = time.perf_counter()

        for ri, s in slot_of.items():
            pendings[ri].ticket._fulfil(outs[s], t_done)
        st = self._stats
        st.n_requests += len(pendings)
        st.n_batches += 1
        st.bucket_hits += int(hit)
        st.bucket_misses += int(not hit)
        st.slots_total += n
        st.active_slots += len(pendings)
        st.identity_slots += template.n_identity_members
        st.exec_s += t_done - t0
        st.latencies_s.extend(p.ticket.latency_s for p in pendings)

    # -------------------------------- stats --------------------------------------

    def stats(self) -> dict:
        return self._stats.as_dict()

    def reset_stats(self) -> None:
        """Zero the counters; keeps the bucket/jit caches warm (for
        measuring steady-state serving after a warmup pass)."""
        self._stats = BankServerStats()
