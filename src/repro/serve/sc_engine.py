"""Multi-bank async serving: device-sharded, pipelined bank dispatch.

The bank compiler (core/plan.py) and executor serve a *fixed, ahead-of-time*
member list: every distinct request multiset costs a fresh BankPlan merge and
a fresh jit trace.  Real traffic — the ROADMAP's "heavy heterogeneous
traffic" north star, and the regime the memory-level-parallelism literature
targets — changes its member set every arrival, so a naive execute_many
server recompiles constantly and the accelerator starves.  The paper's
headline speedup additionally rests on *bank-level* parallelism: independent
subarrays computing concurrently.  ``BankServer`` models both axes:

  * **admission queue** — ``submit()`` enqueues a request and returns a
    ``Ticket``; batches form when ``max_slots`` requests of one execution
    group (same bitstream length / bitflip rate) are waiting, when the oldest
    waiting request exceeds the batching window, or on explicit ``flush()``
    / ``Ticket.result()`` (the engine is synchronous: time-based triggers are
    evaluated at submit/result boundaries, not by a background thread).
  * **bucketed, padded bank templates** — each batch maps to the canonical
    template of its member multiset (structures in deterministic order,
    per-structure slot counts padded to powers of two, identity members
    topping up the total).  Requests bind to slots (stable order: plan
    serial, then value shapes) and unbound slots are masked out, so any
    request set that fits a bucket reuses ONE BankPlan and ONE jit program.
  * **continuous batching** — a formed batch is *staged* before dispatch;
    requests arriving while it waits bind into its free (padding) slots
    instead of seeding a second batch (``stats()["joined_requests"]``).
  * **device sharding + async dispatch** — staged batches launch onto the
    least-loaded / round-robin / bank-affine JAX device (one bank per
    device, ``executor.run(..., device=...)``) and the server does NOT block
    on results: JAX async dispatch keeps up to ``max_inflight`` batches per
    device in flight while admission continues.  Tickets resolve to async
    arrays at dispatch; ``Ticket.result()`` waits (with optional timeout)
    and surfaces any execution failure on every ticket of the batch.
  * **per-request key threading** — every request carries its own PRNG key
    (and flip key under fault injection) and the executor draws slot streams
    exactly as standalone ``execute`` would: results are **bit-identical**
    per request to an unbatched run with the same key and ``key_mode``,
    regardless of device, bucket, or slot (pinned by tests/test_serve.py and
    tests/test_serve_multibank.py).

Reliability (fault-tolerant serving):

  * **bounded admission** — ``max_queue`` caps the waiting queue; overload
    either rejects the *new* request or sheds the *oldest* queued one
    (``overload="reject" | "shed_oldest"``), failing its ticket with a
    typed :class:`RequestShed` — no unbounded memory growth behind a
    stalled device.
  * **deadlines** — ``ExecOptions.deadline_ms`` bounds a request's total
    wall time (queue + retries + device); a passed deadline fails the
    ticket with :class:`DeadlineExceeded` (permanent — distinct from the
    retryable ``TimeoutError`` of ``Ticket.result(timeout=)``).
  * **bounded retry** — ``max_retries`` re-admits a failed batch's requests
    with exponential backoff (``retry_backoff_s * 2**attempt``); the
    request (and its keys) is unchanged, so a successful retry is
    **bit-identical** to a clean first-shot run.
  * **per-device circuit breaker** — ``quarantine_after`` consecutive batch
    failures on one device quarantine it for ``quarantine_s`` (doubling on
    repeated failure); its in-flight batches re-dispatch to healthy devices
    (without consuming retry budget), a health probe re-admits it, and the
    last healthy device is never quarantined.
  * **shutdown** — ``close()`` / context manager: drain mode resolves every
    queued/in-flight ticket (retries included); non-drain fails undispatched
    tickets with :class:`ServerClosed` and finalizes in-flight work.  The
    engine is synchronous (no threads), so close can never leak one.
  * **chaos hook** — ``fault_injector`` is called before every batch launch
    (and during health probes); raising simulates a device failure —
    the harness ``benchmarks/fault_campaign.py`` drives device kills
    through it.

``stats()`` reports serving health: bucket hit rate (how warm the
template/jit caches run), padding waste, join count, p50/p99 request
latency, throughput, per-device batch/request counts, and the reliability
counters (``shed_requests`` / ``retries`` / ``quarantines`` /
``redispatched_requests`` / ``deadline_exceeded``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, defaultdict, deque
from typing import Any

import jax
import numpy as np

from ..core import executor, obs
from ..core.arch import _plan_schedule_cycles
from ..core.dispatch import _check_fault_args
from ..core.executor import ExecOptions, ExecRequest
from ..core.faults import injecting, normalize_fault_model
from ..core.gates import Netlist
from ..core.plan import compile_bank_members, compile_plan, template_members


class DeadlineExceeded(Exception):
    """The request's ``deadline_ms`` passed before its result was delivered.

    Permanent: the ticket is failed and will not be retried.  Deliberately
    NOT a ``TimeoutError`` subclass — ``Ticket.result(timeout=)`` raises
    ``TimeoutError`` for a *retryable* bounded wait, while a deadline is a
    property of the request itself.
    """


class RequestShed(Exception):
    """The request was shed by admission backpressure (queue full)."""


class ServerClosed(Exception):
    """The server was closed before this request could be served."""


def _layout_sig_of(req: ExecRequest) -> tuple:
    """Batching-layout signature: PI names + shapes + declared batch shape.

    Requests with equal signatures occupy interchangeable bank slots, so
    the server sorts on this to canonicalize batch layouts (template-cache
    hits) and to match continuous-batching joins."""
    vs = tuple(sorted((k, tuple(v.shape) if hasattr(v, "shape")
                       else tuple(jax.numpy.shape(v)))
                      for k, v in req.values.items()))
    # Encode "no declared batch shape" as a comparable value: signatures
    # are sort keys, and None does not order against tuples.
    if req.batch_shape is None:
        return ((False, ()), vs)
    return ((True, tuple(req.batch_shape)), vs)


class SCRequest(ExecRequest):
    """One admitted stochastic-computation request.

    A thin subclass of :class:`repro.core.executor.ExecRequest` keeping the
    historical flat constructor: per-request execution parameters are folded
    into ``ExecOptions`` under the hood.  ``net`` is the circuit
    (structure-equal netlists intern to one compiled plan — reuse built
    netlist objects across requests to keep the plan memo warm, e.g. via
    ``repro.serve.apps``); ``values`` its PI values; ``key`` the request's
    own PRNG key (the bit-identity anchor).  ``batch_shape`` declares the
    stream batch shape when values alone cannot (all-const PIs).
    ``bitflip_rate``/``flip_key`` inject per-request transient faults;
    ``fault_model`` is the full STT-MRAM fault description
    (:class:`repro.core.faults.FaultModel` — subsumes ``bitflip_rate``).
    ``deadline_ms`` bounds the request's total wall time in the server
    (queue + retries + device); past it the ticket fails with
    :class:`DeadlineExceeded`.

    Values are canonicalized to *host* float32 at admission: a request is
    dispatched exactly once but its leaves are touched on every hot-path
    pass (signature, bind, bank call), so paying the dtype conversion here
    — once, at construction — keeps the dispatch loop cheap.  Host scalars
    are what ``execute_bank`` packs into one vector per slot at the jit
    boundary; jax-array values pass through untouched (forcing them to
    host would block on the device).
    """

    def __init__(self, net: Netlist, values: dict[str, Any], key: Any,
                 bitstream_length: int = 256,
                 batch_shape: "tuple[int, ...] | None" = None,
                 bitflip_rate: float = 0.0, flip_key: Any = None,
                 fault_model=None, deadline_ms: "float | None" = None,
                 options: "ExecOptions | None" = None):
        if options is None:
            options = ExecOptions(
                bitstream_length=bitstream_length,
                batch_shape=(tuple(batch_shape)
                             if batch_shape is not None else None),
                bitflip_rate=float(bitflip_rate), flip_key=flip_key,
                fault_model=fault_model, deadline_ms=deadline_ms)
        values = {k: v if isinstance(v, jax.Array)
                  else np.asarray(v, np.float32)
                  for k, v in values.items()}
        super().__init__(net=net, values=values, key=key, options=options)
        self._layout_sig = _layout_sig_of(self)


class Ticket:
    """Completion handle for a submitted request.

    ``done()`` turns True once the request's batch has been *dispatched*
    (results are then async jax arrays, possibly still computing) or failed.
    ``result()`` forces the wait and raises the batch's exception, if any.
    A retried request's ticket transiently drops back to not-done while it
    re-queues; ``result()`` drives the server until it settles.
    """

    __slots__ = ("_server", "_result", "_error", "_batch", "_done",
                 "submitted_at", "latency_s", "deadline_at")

    def __init__(self, server: "BankServer"):
        self._server = server
        self._result = None
        self._error: "BaseException | None" = None
        self._batch: "_Batch | None" = None
        self._done = False
        self.submitted_at = time.perf_counter()
        self.latency_s: float | None = None
        self.deadline_at: "float | None" = None

    def done(self) -> bool:
        return self._done

    def result(self, timeout: "float | None" = None):
        """The request's output dict; drives the server until it settles.

        ``timeout`` (seconds) bounds this *call*: raises ``TimeoutError``
        if the result has not landed in time (retryable — the ticket stays
        valid, call ``result()`` again).  The request's own ``deadline_ms``
        instead fails the ticket *permanently* with
        :class:`DeadlineExceeded`.  If the batch failed (after exhausting
        any retry budget), the original execution exception re-raises on
        every ticket of that batch.
        """
        srv = self._server
        t_end = None if timeout is None else time.perf_counter() + timeout
        while True:
            if not self._done:
                srv._drive()
            if self._done:
                if self._error is not None:
                    raise self._error
                batch = self._batch
                if batch is not None and not batch.finalized:
                    limit = t_end
                    if self.deadline_at is not None:
                        limit = self.deadline_at if limit is None \
                            else min(limit, self.deadline_at)
                    if limit is None:
                        srv._finalize(batch)
                    else:
                        try:
                            srv._wait_batch(
                                batch,
                                max(0.0, limit - time.perf_counter()))
                        except TimeoutError:
                            if self.deadline_at is not None and \
                                    time.perf_counter() >= self.deadline_at:
                                srv._stats.deadline_exceeded += 1
                                self._fail(DeadlineExceeded(
                                    "deadline passed while the batch was "
                                    "still in flight"))
                                raise self._error from None
                            raise
                    # Finalize may have failed or re-queued (retry) this
                    # very ticket — re-examine from the top.
                    continue
                if self._error is not None:
                    raise self._error
                return self._result
            # Not done: queued (possibly backing off for retry) or staged.
            now = time.perf_counter()
            if self.deadline_at is not None and now >= self.deadline_at:
                srv._expire_deadlines()
                if not self._done:
                    srv._stats.deadline_exceeded += 1
                    self._fail(DeadlineExceeded(
                        "deadline passed before dispatch"))
                continue
            if t_end is not None and now >= t_end:
                raise TimeoutError(
                    f"Ticket.result timed out after {timeout:g}s; request "
                    f"still queued for dispatch")
            time.sleep(2.5e-4)

    def _fulfil(self, result, batch: "_Batch") -> None:
        self._result = result
        self._batch = batch
        self._done = True

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._done = True

    def _reset(self) -> None:
        """Return to not-done for a retry / re-dispatch (keeps
        ``submitted_at`` — latency and deadline measure from admission)."""
        self._result = None
        self._error = None
        self._batch = None
        self._done = False


@dataclasses.dataclass
class _Pending:
    req: SCRequest
    ticket: Ticket
    sig: tuple = ()     # shape signature, computed once at admission
    retries: int = 0    # failed-dispatch retries consumed
    not_before: float = 0.0   # earliest re-dispatch time (retry backoff)
    seq: int = -1             # admission serial (trace track identity)
    staged_at: float = 0.0    # last bind into a batch (perf_counter)
    launched_at: float = 0.0  # last dispatch to a device (perf_counter)


class _Batch:
    """One formed bank batch: a template member layout plus bound requests.

    Lives through three states: *staged* (formed, accepting joins into free
    padding slots), *in flight* (dispatched to a device, results async), and
    *finalized* (results ready or failed, tickets resolved)."""

    __slots__ = ("group", "members", "pendings", "slots", "free",
                 "device", "outs", "dispatched_at", "finalized")

    def __init__(self, group: tuple, members: tuple):
        self.group = group
        self.members = members                  # slot -> member ExecutionPlan
        self.pendings: "list[_Pending]" = []
        self.slots: "list[int]" = []            # parallel to pendings
        self.free: "dict[int, deque]" = defaultdict(deque)
        for s, m in enumerate(members):
            self.free[id(m)].append(s)
        self.device = None
        self.outs: "list | None" = None         # per-pending async out dicts
        self.dispatched_at: "float | None" = None
        self.finalized = False

    def bind(self, pending: _Pending, plan) -> bool:
        """Bind ``pending`` (compiled to ``plan``) to a free compatible slot."""
        dq = self.free.get(id(plan))
        if not dq:
            return False
        self.slots.append(dq.popleft())
        self.pendings.append(pending)
        pending.staged_at = time.perf_counter()
        return True

    def unbind(self, idx: int) -> _Pending:
        """Release bound request ``idx`` (staged batches only): its slot
        returns to the free pool as a padding slot."""
        slot = self.slots.pop(idx)
        self.free[id(self.members[slot])].append(slot)
        return self.pendings.pop(idx)

    def ready(self) -> bool:
        """Non-blocking: have all this batch's device results landed?"""
        return all(a.is_ready() for out in self.outs
                   for a in jax.tree_util.tree_leaves(out))


def _percentile(sorted_xs: "list[float]", q: float) -> float:
    if not sorted_xs:
        return 0.0
    idx = min(len(sorted_xs) - 1, max(0, round(q * (len(sorted_xs) - 1))))
    return sorted_xs[idx]


#: Sliding window for latency percentiles (bounds a long-running server's
#: memory; counters stay exact).
LATENCY_WINDOW = 4096
#: LRU caps on the server's own memo/signature state — like the plan/bank
#: caches, serving many bucket shapes must not grow them without bound.
_TEMPLATE_MEMO_CAP = 256
_SIGNATURE_CAP = 4096


@dataclasses.dataclass
class BankServerStats:
    """Cumulative serving counters (reset with ``BankServer.reset_stats``).

    Latencies are kept in a sliding window of the most recent
    ``LATENCY_WINDOW`` requests — p50/p99/mean describe recent traffic, the
    integer counters the server's whole life.  ``exec_s`` is busy wall time:
    the union of intervals during which at least one batch was in flight.
    """

    n_requests: int = 0
    n_batches: int = 0
    bucket_hits: int = 0          # batches whose full exec signature was warm
    bucket_misses: int = 0
    joined_requests: int = 0      # requests continuous-batched into a staged bank
    slots_total: int = 0          # executed template slots (incl. padding)
    active_slots: int = 0         # slots bound to requests
    identity_slots: int = 0       # no-op identity padding slots
    # Compiler-pipeline provenance, summed over every launched batch's bank
    # (per-pass counters the pipeline stages attach to each ExecutionPlan).
    passes_merged: int = 0        # fused passes actually driven (merged bank)
    passes_looped_equiv: int = 0  # passes a per-member loop would have driven
    schedule_cycles: int = 0      # Algorithm-1 scheduled cycles (merged bank)
    passes_fused_away: int = 0    # MUX/XOR/AND fusions + NOT absorptions
    nodes_elided: int = 0         # BUFF elisions + CSE merges
    max_live_peak: int = 0        # peak liveness (scratch slots) over all
    #                               launched banks' group plans
    naive_live_peak: int = 0      # one-row-per-node peak it replaces
    # Reliability counters.
    shed_requests: int = 0        # rejected/shed by admission backpressure
    retries: int = 0              # failed-batch requests re-queued w/ backoff
    quarantines: int = 0          # device circuit-breaker trips
    redispatched_requests: int = 0  # in-flight requests moved off a
    #                                 quarantined device (no retry budget)
    deadline_exceeded: int = 0    # tickets failed by their deadline_ms
    exec_s: float = 0.0           # busy wall time (>=1 batch in flight)
    latencies_s: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))

    def as_dict(self) -> dict:
        lat = sorted(self.latencies_s)
        total_batches = max(self.n_batches, 1)
        return {
            "n_requests": self.n_requests,
            "n_batches": self.n_batches,
            "bucket_hits": self.bucket_hits,
            "bucket_misses": self.bucket_misses,
            "bucket_hit_rate": self.bucket_hits / total_batches,
            "joined_requests": self.joined_requests,
            "padding_waste": (self.slots_total - self.active_slots)
            / max(self.slots_total, 1),
            "identity_slots": self.identity_slots,
            "passes_merged": self.passes_merged,
            "passes_looped_equiv": self.passes_looped_equiv,
            "pass_savings_rate": (self.passes_looped_equiv
                                  - self.passes_merged)
            / max(self.passes_looped_equiv, 1),
            "schedule_cycles": self.schedule_cycles,
            "passes_fused_away": self.passes_fused_away,
            "nodes_elided": self.nodes_elided,
            "max_live_peak": self.max_live_peak,
            "naive_live_peak": self.naive_live_peak,
            "shed_requests": self.shed_requests,
            "retries": self.retries,
            "quarantines": self.quarantines,
            "redispatched_requests": self.redispatched_requests,
            "deadline_exceeded": self.deadline_exceeded,
            "p50_ms": _percentile(lat, 0.50) * 1e3,
            "p99_ms": _percentile(lat, 0.99) * 1e3,
            "mean_ms": (sum(lat) / len(lat) * 1e3) if lat else 0.0,
            "throughput_rps": self.n_requests / max(self.exec_s, 1e-9),
            "exec_s": self.exec_s,
        }


_PLACEMENTS = ("affinity", "round_robin", "least_loaded")


class BankServer:
    """Traffic-driven serving engine over bucketed, padded BankPlans.

    Parameters
    ----------
    max_slots:
        Admission threshold: a batch forms as soon as ``max_slots`` requests
        of one execution group are queued.  Joins may bind further requests
        into the batch's padding slots while it is staged.
    window_s:
        Batching window — on submit, if the oldest queued request has waited
        at least this long, the whole queue forms into batches.  ``None``
        (default) disables the time trigger.  The engine is synchronous, so
        the window is evaluated at submit/result/flush calls, not by a
        background thread (0.0 therefore means "never let a request wait
        behind a second submit").
    pad_counts / pad_total:
        Template padding policy (power-of-two slot counts / total).
    key_mode / backend / decode:
        Threaded to the executor; ``decode=True`` (default) returns decoded
        output values per request, else packed streams.
    devices:
        JAX devices to shard batches across (default: all of
        ``jax.devices()``).  Run CPU tests with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to get
        several host devices.
    max_inflight:
        Per-device cap on concurrently in-flight batches (JAX async
        dispatch).  ``0`` degenerates to the synchronous flush-and-wait
        engine of PR-4: every batch blocks before the next dispatch.
    placement:
        ``"affinity"`` (default) prefers devices already warm for the
        batch's member layout, spilling to the least-loaded cold device when
        the warm ones are busy; ``"round_robin"`` cycles; ``"least_loaded"``
        picks the smallest in-flight queue.
    donate:
        Donate the per-batch key buffers to XLA (best-effort; see
        ``executor.execute_bank``).
    max_queue:
        Admission-queue bound (``None`` = unbounded, the historic
        behavior).  At the bound, ``overload`` decides: ``"reject"`` fails
        the *new* request's ticket with :class:`RequestShed` (submit does
        not raise — the shed notice is delivered through the ticket);
        ``"shed_oldest"`` fails the oldest queued request and admits the
        new one.
    max_retries:
        Failed-batch retry budget per request.  A batch failure re-queues
        its requests with exponential backoff
        (``retry_backoff_s * 2**attempt``); past the budget the *original*
        exception fails the ticket.  Retries re-run the identical request
        (same keys), so a successful retry is bit-identical to a clean
        first-shot run.  Default 0: failures propagate immediately.
    quarantine_after / quarantine_s:
        Per-device circuit breaker: after ``quarantine_after`` consecutive
        batch failures on one device it is quarantined for
        ``quarantine_s`` seconds (doubling while it keeps failing its
        health probe).  Its in-flight batches re-dispatch to healthy
        devices without consuming retry budget.  The last healthy device
        is never quarantined.
    fault_injector:
        Chaos hook ``fn(device, batch_or_None)`` called immediately before
        every batch launch (batch) and during health probes (None);
        raising makes the launch/probe fail.  Used by the chaos harness to
        kill devices mid-run.
    trace:
        Observability switch (default None = off, zero overhead on the hot
        path).  Pass a ``core.obs.Trace`` — or ``True`` to have the server
        create one, reachable as ``server.trace`` — and the engine records
        per-request lifecycle spans (``request`` with nested
        ``request.queued`` / ``request.staged`` / ``request.inflight``,
        partitioning admit → stage → launch → reap exactly), ``serve.launch``
        host spans with the executor/compiler spans nested inside, instant
        events for retry / quarantine / re-dispatch / shed / deadline, and
        mirrors the reliability counters into ``trace.metrics`` (folded
        into :meth:`stats` as ``"metrics"``).  Tracing never perturbs
        results — bit-identity on/off is pinned by tests.

    Results are bit-identical per request to standalone
    ``executor.execute[_value]`` with the same key — see module docstring.
    """

    def __init__(self, *, max_slots: int = 8,
                 window_s: "float | None" = None,
                 pad_counts: bool = True, pad_total: bool = True,
                 key_mode: str | None = None, backend: str | None = None,
                 decode: bool = True,
                 devices: "list | None" = None, max_inflight: int = 2,
                 placement: str = "affinity", donate: bool = False,
                 max_queue: "int | None" = None, overload: str = "reject",
                 max_retries: int = 0, retry_backoff_s: float = 0.02,
                 quarantine_after: int = 3, quarantine_s: float = 0.5,
                 fault_injector=None, trace=None):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if max_inflight < 0:
            raise ValueError("max_inflight must be >= 0")
        if placement not in _PLACEMENTS:
            raise ValueError(f"placement must be one of {_PLACEMENTS}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        if overload not in ("reject", "shed_oldest"):
            raise ValueError("overload must be 'reject' or 'shed_oldest'")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if quarantine_after < 1:
            raise ValueError("quarantine_after must be >= 1")
        self.max_slots = max_slots
        self.window_s = window_s
        self.pad_counts = pad_counts
        self.pad_total = pad_total
        self.key_mode = key_mode
        self.backend = backend
        self.decode = decode
        self.devices = tuple(devices) if devices is not None \
            else tuple(jax.devices())
        if not self.devices:
            raise ValueError("need at least one device")
        self.max_inflight = max_inflight
        self.placement = placement
        self.donate = donate
        self.max_queue = max_queue
        self.overload = overload
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.quarantine_after = quarantine_after
        self.quarantine_s = quarantine_s
        self.fault_injector = fault_injector
        self.trace = obs.Trace("bank-server") if trace is True else trace
        self._req_seq = 0
        # jax's own default placement: when a batch lands here anyway,
        # skipping the explicit commit avoids the committed-argument
        # bookkeeping jit pays per input leaf (measurably slower than the
        # uncommitted fast path, for an identical outcome).
        self._default_device = jax.devices()[0]
        self._queue: "list[_Pending]" = []
        self._staged: "list[_Batch]" = []
        self._inflight: "dict[Any, deque[_Batch]]" = \
            {d: deque() for d in self.devices}
        self._rr = 0
        self._held = False
        self._busy_since: "float | None" = None
        self._closed = False
        self._accepting = True          # False: close() disabled retries
        self._consec_failures: "dict[Any, int]" = {}
        self._quarantined: "dict[Any, float]" = {}   # device -> retest time
        self._quarantine_backoff: "dict[Any, float]" = {}
        # All three maps are LRU-bounded: heterogeneous traffic mints new
        # plan tuples / exec signatures indefinitely, and strong references
        # here must not defeat plan.py's bank-cache cap.
        self._seen_signatures: OrderedDict = OrderedDict()
        # Canonical plan tuple -> padded member layout (plain tuple, cheap):
        # the compiled per-device bank comes from plan.compile_bank_members'
        # own cache at dispatch time.
        self._layout_memo: OrderedDict = OrderedDict()
        # Member layout -> set of devices that have executed it (jit warm).
        self._warm: OrderedDict = OrderedDict()
        self._stats = BankServerStats()
        self._dev_stats = {d: {"n_batches": 0, "n_requests": 0,
                               "quarantines": 0}
                           for d in self.devices}

    # ------------------------------ admission ------------------------------------

    def submit(self, req: SCRequest) -> Ticket:
        """Admit one request; returns immediately with a :class:`Ticket`.

        Batch formation/dispatch runs opportunistically inside the call
        (there is no background thread), but dispatched work proceeds
        asynchronously on its device.  Raises :class:`ServerClosed` after
        ``close()``; under ``max_queue`` backpressure a shed request's
        ticket is returned already failed with :class:`RequestShed`.

        Example::

            import jax
            from repro.core import circuits
            from repro.serve import BankServer, circuit_request
            net = circuits.sc_multiply()
            with BankServer(max_slots=4) as server:
                t = server.submit(circuit_request(
                    net, {"a": 0.5, "b": 0.5}, jax.random.key(0), bl=256))
                out = t.result()           # {"out": ~0.25}
        """
        if self._closed:
            raise ServerClosed("submit() on a closed BankServer")
        _check_fault_args(req.bitflip_rate, req.fault_model, req.flip_key)
        tr = self.trace
        ticket = Ticket(self)
        if req.deadline_ms is not None:
            ticket.deadline_at = \
                ticket.submitted_at + float(req.deadline_ms) / 1e3
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._pump()        # formation may drain the queue into batches
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._stats.shed_requests += 1
            if tr is not None:
                tr.metrics.inc("serve.shed_requests")
            if self.overload == "reject":
                if tr is not None:
                    tr.event("serve.shed", policy="reject")
                ticket._fail(RequestShed(
                    f"admission queue full (max_queue={self.max_queue})"))
                return ticket
            oldest = self._queue.pop(0)
            if tr is not None:
                tr.event("serve.shed", policy="shed_oldest", seq=oldest.seq)
            oldest.ticket._fail(RequestShed(
                f"shed by a newer arrival (max_queue={self.max_queue})"))
        p = _Pending(req, ticket, self._shape_sig(req), seq=self._req_seq)
        self._req_seq += 1
        if tr is not None:
            tr.metrics.inc("serve.requests_admitted")
        self._queue.append(p)
        self._pump()
        return ticket

    def serve(self, requests: "list[SCRequest]") -> list:
        """Submit a burst and return its results in request order."""
        tickets = [self.submit(r) for r in requests]
        self.flush()
        return [t.result() for t in tickets]

    def hold(self) -> None:
        """Pause dispatch: batches still form and stage (and keep accepting
        continuous-batching joins) but do not launch until ``release()`` or
        an explicit ``flush()``."""
        self._held = True

    def release(self) -> None:
        """Resume dispatch after :meth:`hold`."""
        self._held = False
        self._pump()

    def flush(self) -> int:
        """Form and dispatch everything queued; returns batches dispatched.

        Does NOT block on results — tickets resolve to async arrays and
        ``Ticket.result()`` performs the wait.  Dispatches even while
        ``hold()`` is in effect.  Requests backing off for retry are left
        queued until their backoff expires."""
        n0 = self._stats.n_batches
        self._expire_deadlines()
        self._check_quarantine()
        self._reap()
        self._join_staged()
        self._form_all()
        while self._staged:
            batch = self._staged.pop(0)
            device = self._pick_device(batch)
            while device is None:
                # Every device is at max_inflight: retire the oldest
                # in-flight batch to free a slot, then place.
                oldest = min((dq[0] for dq in self._inflight.values() if dq),
                             key=lambda b: b.dispatched_at)
                self._finalize(oldest)
                device = self._pick_device(batch)
            self._launch(batch, device)
        return self._stats.n_batches - n0

    def _drive(self) -> None:
        """One blocking-wait scheduler step (Ticket.result's engine)."""
        self.flush()

    # ------------------------------ scheduling -----------------------------------

    def _pump(self) -> None:
        """One scheduler step: reap ready work, join queued requests into
        staged batches, form newly-triggered batches, dispatch while device
        capacity allows.  Called at submit/release boundaries."""
        self._expire_deadlines()
        self._check_quarantine()
        self._reap()
        self._join_staged()
        if self.window_s is not None and self._queue and \
                time.perf_counter() - self._queue[0].ticket.submitted_at \
                >= self.window_s:
            self._form_all()
        else:
            self._form_triggered()
        if not self._held:
            self._dispatch_staged()

    @staticmethod
    def _group_key(req: SCRequest) -> tuple:
        # Static execution parameters that cannot share one bank dispatch.
        # The fault model is normalized (null -> None) so a no-op model
        # batches with clean traffic on the clean jit program.
        return (req.bitstream_length, float(req.bitflip_rate),
                normalize_fault_model(req.fault_model))

    @staticmethod
    def _shape_sig(req: SCRequest) -> tuple:
        # Computed once per request (eagerly by SCRequest, lazily here for
        # plain ExecRequests) — the per-leaf walk is measurable at high
        # admission rates.
        sig = getattr(req, "_layout_sig", None)
        if sig is None:
            sig = _layout_sig_of(req)
            try:
                req._layout_sig = sig
            except AttributeError:
                pass
        return sig

    def _plan_of(self, req: SCRequest, group: tuple):
        # Gate-level fault injection needs the unfused plan (per-gate fkeys).
        return compile_plan(req.net,
                            fuse_mux=not injecting(group[1], group[2])
                            or req.net.is_sequential)

    def _form_triggered(self) -> None:
        # A group that accumulates max_slots waiting requests launches alone —
        # other groups keep building toward their own triggers.
        now = time.perf_counter()
        while True:
            counts: "dict[tuple, int]" = defaultdict(int)
            trigger = None
            for p in self._queue:
                if p.not_before > now:
                    continue
                g = self._group_key(p.req)
                counts[g] += 1
                if counts[g] >= self.max_slots:
                    trigger = g
                    break
            if trigger is None:
                return
            self._form_group(trigger, now)

    def _form_all(self) -> None:
        now = time.perf_counter()
        while True:
            ready = next((p for p in self._queue if p.not_before <= now),
                         None)
            if ready is None:
                return
            self._form_group(self._group_key(ready.req), now)

    def _form_group(self, group: tuple, now: "float | None" = None) -> None:
        if now is None:
            now = time.perf_counter()
        take = [p for p in self._queue if p.not_before <= now
                and self._group_key(p.req) == group][:self.max_slots]
        taken = set(map(id, take))
        self._queue = [p for p in self._queue if id(p) not in taken]
        self._staged.append(self._make_batch(group, take))

    def _make_batch(self, group: tuple, take: "list[_Pending]") -> _Batch:
        plans = [self._plan_of(p.req, group) for p in take]
        # Canonical request order (plan serial, then value shapes): identical
        # traffic mixes bind identically, so the jit signature repeats even
        # when arrival order shuffles.
        order = sorted(range(len(take)),
                       key=lambda i: (plans[i].serial, take[i].sig))
        ordered_plans = tuple(plans[i] for i in order)
        members = self._layout_memo.get(ordered_plans)
        if members is None:
            members = tuple(template_members(list(ordered_plans),
                                             pad_counts=self.pad_counts,
                                             pad_total=self.pad_total))
            self._layout_memo[ordered_plans] = members
            while len(self._layout_memo) > _TEMPLATE_MEMO_CAP:
                self._layout_memo.popitem(last=False)
        else:
            self._layout_memo.move_to_end(ordered_plans)
        batch = _Batch(group, members)
        for i in order:
            bound = batch.bind(take[i], plans[i])
            assert bound, "canonical member layout must fit its own batch"
        return batch

    def _join_staged(self) -> None:
        """Continuous batching: bind queued requests into free padding slots
        of staged (formed, not yet dispatched) batches of the same group."""
        if not self._queue or not self._staged:
            return
        now = time.perf_counter()
        keep: "list[_Pending]" = []
        for p in self._queue:
            if p.not_before > now:      # still backing off: may not join
                keep.append(p)
                continue
            g = self._group_key(p.req)
            plan = None
            for b in self._staged:
                if b.group != g:
                    continue
                if plan is None:
                    plan = self._plan_of(p.req, g)
                if b.bind(p, plan):
                    self._stats.joined_requests += 1
                    break
            else:
                keep.append(p)
        self._queue = keep

    # ------------------------------ placement ------------------------------------

    def _capacity(self, device) -> bool:
        # max_inflight == 0 is the synchronous mode: each launch blocks, so
        # every device is always free by the time placement runs.
        return self.max_inflight == 0 or \
            len(self._inflight[device]) < self.max_inflight

    def _pick_device(self, batch: _Batch):
        """A healthy device with in-flight capacity for ``batch``, or None."""
        devs = self.devices
        if self._quarantined:
            healthy = tuple(d for d in devs if d not in self._quarantined)
            if healthy:         # safety: never strand traffic entirely
                devs = healthy
        if len(devs) == 1:
            return devs[0] if self._capacity(devs[0]) else None
        if self.placement == "round_robin":
            for k in range(len(devs)):
                d = devs[(self._rr + k) % len(devs)]
                if self._capacity(d):
                    self._rr = (self._rr + k + 1) % len(devs)
                    return d
            return None
        cands = [d for d in devs if self._capacity(d)]
        if not cands:
            return None
        if self.placement == "affinity":
            warm = self._warm.get(batch.members)
            warm_free = [d for d in cands if warm and d in warm]
            if warm_free:
                cands = warm_free
        return min(cands, key=lambda d: (len(self._inflight[d]),
                                         devs.index(d)))

    # ------------------------------ execution ------------------------------------

    def _dispatch_staged(self) -> None:
        while self._staged:
            device = self._pick_device(self._staged[0])
            if device is None:
                return
            self._launch(self._staged.pop(0), device)

    def _launch(self, batch: _Batch, device) -> None:
        """Dispatch one batch asynchronously; resolve its tickets.

        Dispatch-time failures (bad request values, trace errors) and
        device-side failures (surfacing at finalize/``result()``) both run
        the retry/circuit-breaker policy via ``_on_batch_failure``."""
        tr = self.trace
        if tr is None:
            self._launch_impl(batch, device)
            return
        # Making the server's trace current for the launch lets the
        # compiler's per-stage spans and the executor's pack/transfer/
        # dispatch spans nest under this host-side launch span.
        with obs.tracing(tr), tr.span("serve.launch", device=str(device),
                                      n_requests=len(batch.pendings),
                                      slots=len(batch.members)):
            self._launch_impl(batch, device)
        tr.metrics.inc("serve.batches_launched")

    def _launch_impl(self, batch: _Batch, device) -> None:
        bl, rate, model = batch.group
        multi = len(self.devices) > 1
        # Per-device template scope partitions the bank cache so each
        # device's jit executable stays keyed to its own bank identity.
        bank = compile_bank_members(batch.members,
                                    scope=device if multi else None)
        n = bank.n_members
        slot_reqs: "list[SCRequest | None]" = [None] * n
        for p, s in zip(batch.pendings, batch.slots):
            slot_reqs[s] = p.req
        active = [r is not None for r in slot_reqs]
        shared = ExecOptions(backend=self.backend, key_mode=self.key_mode,
                             bitstream_length=bl, bitflip_rate=rate,
                             fault_model=model, decode=self.decode)
        sig_order = sorted(range(len(batch.pendings)),
                           key=lambda i: batch.slots[i])
        signature = (bank.serial, bl, rate, model, tuple(active),
                     tuple(batch.pendings[i].sig for i in sig_order))
        hit = signature in self._seen_signatures
        self._seen_signatures[signature] = None
        self._seen_signatures.move_to_end(signature)
        while len(self._seen_signatures) > _SIGNATURE_CAP:
            self._seen_signatures.popitem(last=False)

        t0 = time.perf_counter()
        for p in batch.pendings:
            p.launched_at = t0
        st = self._stats
        st.n_requests += len(batch.pendings)
        st.n_batches += 1
        st.bucket_hits += int(hit)
        st.bucket_misses += int(not hit)
        st.slots_total += n
        st.active_slots += len(batch.pendings)
        st.identity_slots += bank.n_identity_members
        st.passes_merged += bank.n_passes
        st.passes_looped_equiv += bank.n_passes_looped
        st.schedule_cycles += sum(
            _plan_schedule_cycles(g) for g in (bank.comb, bank.seq)
            if g is not None)
        for g in (bank.comb, bank.seq):
            if g is None:
                continue
            st.passes_fused_away += (g.n_fused_mux + g.n_fused_xor
                                     + g.n_fused_and + g.n_not_absorbed)
            st.nodes_elided += g.n_elided
            st.max_live_peak = max(st.max_live_peak, g.max_live)
            st.naive_live_peak = max(st.naive_live_peak, g.naive_live)
        dev_arg = device if multi and device is not self._default_device \
            else None
        try:
            if self.fault_injector is not None:
                self.fault_injector(device, batch)
            outs = executor.run(slot_reqs, template=bank, active=active,
                                device=dev_arg,
                                donate=self.donate, options=shared)
        except Exception as exc:
            self._on_batch_failure(batch, exc, device)
            return
        batch.device = device
        batch.dispatched_at = t0
        batch.outs = [outs[s] for s in batch.slots]
        for p, out in zip(batch.pendings, batch.outs):
            p.ticket._fulfil(out, batch)
        if self._busy_since is None:
            self._busy_since = t0
        self._inflight[device].append(batch)
        warm = self._warm.setdefault(batch.members, set())
        warm.add(device)
        self._warm.move_to_end(batch.members)
        while len(self._warm) > _TEMPLATE_MEMO_CAP:
            self._warm.popitem(last=False)
        ds = self._dev_stats[device]
        ds["n_batches"] += 1
        ds["n_requests"] += len(batch.pendings)
        if self.max_inflight == 0:
            self._finalize(batch)

    def _reap(self) -> None:
        """Retire in-flight batches whose results have landed (non-blocking)."""
        for dq in self._inflight.values():
            while dq and dq[0].ready():
                self._finalize(dq[0])

    def _finalize(self, batch: _Batch) -> None:
        """Wait out one in-flight batch; record latencies, or run the
        failure policy (retry / circuit breaker) on its requests."""
        if batch.finalized:
            return
        batch.finalized = True
        err: "BaseException | None" = None
        try:
            jax.block_until_ready(batch.outs)
        except Exception as exc:
            err = exc
        t_done = time.perf_counter()
        dq = self._inflight[batch.device]
        try:
            dq.remove(batch)
        except ValueError:                      # pragma: no cover - safety
            pass
        tr = self.trace
        if err is not None:
            self._on_batch_failure(batch, err, batch.device)
        else:
            self._consec_failures[batch.device] = 0
            for p in batch.pendings:
                t = p.ticket
                if t._error is not None:
                    continue    # already settled (deadline hit mid-flight)
                if t.deadline_at is not None and t_done >= t.deadline_at:
                    self._stats.deadline_exceeded += 1
                    if tr is not None:
                        tr.metrics.inc("serve.deadline_exceeded")
                        tr.event("serve.deadline_exceeded", seq=p.seq,
                                 where="inflight")
                    t._fail(DeadlineExceeded(
                        f"deadline_ms={p.req.deadline_ms:g} passed before "
                        f"the batch completed"))
                    continue
                t.latency_s = t_done - t.submitted_at
                self._stats.latencies_s.append(t.latency_s)
                if tr is not None:
                    self._emit_request_trace(tr, p, t_done, batch)
        if self._busy_since is not None and \
                not any(self._inflight.values()):
            self._stats.exec_s += t_done - self._busy_since
            self._busy_since = None

    def _emit_request_trace(self, tr, p: _Pending, t_done: float,
                            batch: _Batch) -> None:
        """Retroactive lifecycle spans for one reaped request.

        The child spans partition the root exactly — queued (admit → last
        bind), staged (bind → launch), inflight (launch → reap) — so their
        total always accounts for 100% of the request's wall-clock.  Each
        request renders on its own virtual chrome-trace track."""
        t = p.ticket
        t_sub = t.submitted_at
        t_staged = min(max(p.staged_at, t_sub), t_done)
        t_launch = min(max(p.launched_at, t_staged), t_done)
        tid = tr.virtual_tid(f"request-{p.seq}")
        root = tr.add_span("request", t_sub, t_done, tid=tid, seq=p.seq,
                           retries=p.retries, device=str(batch.device))
        tr.add_span("request.queued", t_sub, t_staged, parent=root, tid=tid)
        tr.add_span("request.staged", t_staged, t_launch, parent=root,
                    tid=tid)
        tr.add_span("request.inflight", t_launch, t_done, parent=root,
                    tid=tid)
        m = tr.metrics
        m.inc("serve.requests_completed")
        m.observe("serve.latency_ms", (t_done - t_sub) * 1e3)
        m.observe("serve.queued_ms", (t_staged - t_sub) * 1e3)
        m.observe("serve.staged_ms", (t_launch - t_staged) * 1e3)
        m.observe("serve.inflight_ms", (t_done - t_launch) * 1e3)

    def _wait_batch(self, batch: _Batch, timeout: "float | None") -> None:
        if batch.finalized:
            return
        if timeout is None:
            self._finalize(batch)
            return
        deadline = time.perf_counter() + timeout
        while not batch.ready():
            now = time.perf_counter()
            if now >= deadline:
                raise TimeoutError(
                    f"Ticket.result timed out after {timeout:g}s; batch of "
                    f"{len(batch.pendings)} request(s) still in flight on "
                    f"{batch.device}")
            time.sleep(min(5e-4, deadline - now))
        self._finalize(batch)

    # ------------------------------ reliability ----------------------------------

    @staticmethod
    def _note_exception(exc: BaseException, batch: _Batch, device) -> None:
        # Attach serving context to the ORIGINAL exception (PEP 678) so the
        # user sees both where it failed and what it was doing — without
        # wrapping (isinstance checks and tracebacks stay intact).
        if getattr(exc, "_bankserver_noted", False):
            return
        note = (f"[BankServer] raised while executing a bank batch of "
                f"{len(batch.pendings)} request(s) on {device}")
        try:
            if hasattr(exc, "add_note"):        # Python >= 3.11
                exc.add_note(note)
            else:                               # emulate PEP 678 storage
                notes = getattr(exc, "__notes__", None)
                if notes is None:
                    notes = []
                    exc.__notes__ = notes
                notes.append(note)
            exc._bankserver_noted = True
        except Exception:                       # pragma: no cover - safety
            pass

    def _on_batch_failure(self, batch: _Batch, exc: BaseException,
                          device) -> None:
        """Failure policy for one failed batch: note the device failure
        (circuit breaker input) and retry or fail each request."""
        batch.finalized = True
        self._note_exception(exc, batch, device)
        self._note_device_failure(device)
        now = time.perf_counter()
        for p in batch.pendings:
            self._retry_or_fail(p, exc, now)

    def _retry_or_fail(self, p: _Pending, exc: BaseException,
                       now: float) -> None:
        t = p.ticket
        if t._error is not None:
            return              # already settled (deadline hit mid-flight)
        if self._accepting and p.retries < self.max_retries:
            backoff = self.retry_backoff_s * (2.0 ** p.retries)
            if t.deadline_at is None or now + backoff < t.deadline_at:
                p.retries += 1
                p.not_before = now + backoff
                t._reset()
                self._queue.append(p)
                self._stats.retries += 1
                if self.trace is not None:
                    self.trace.metrics.inc("serve.retries")
                    self.trace.event("serve.retry", seq=p.seq,
                                     attempt=p.retries)
                return
        if self.trace is not None:
            self.trace.event("serve.request_failed", seq=p.seq,
                             error=type(exc).__name__)
        t._fail(exc)

    def _note_device_failure(self, device) -> None:
        n = self._consec_failures.get(device, 0) + 1
        self._consec_failures[device] = n
        if n >= self.quarantine_after and device not in self._quarantined:
            healthy = [d for d in self.devices
                       if d not in self._quarantined]
            if len(healthy) > 1:    # never quarantine the last device
                self._quarantine(device)

    def _quarantine(self, device) -> None:
        """Trip the circuit breaker: stop placing batches on ``device`` and
        re-dispatch its in-flight work to healthy devices (no retry budget
        consumed — the requests did nothing wrong)."""
        backoff = self._quarantine_backoff.get(device, self.quarantine_s)
        self._quarantined[device] = time.perf_counter() + backoff
        self._quarantine_backoff[device] = backoff * 2.0
        self._stats.quarantines += 1
        self._dev_stats[device]["quarantines"] += 1
        tr = self.trace
        if tr is not None:
            tr.metrics.inc("serve.quarantines")
            tr.event("serve.quarantine", device=str(device),
                     backoff_s=backoff)
        dq = self._inflight[device]
        while dq:
            b = dq.popleft()
            if b.finalized:                     # pragma: no cover - safety
                continue
            b.finalized = True
            for p in b.pendings:
                if p.ticket._error is not None:
                    continue    # already settled (deadline hit mid-flight)
                p.ticket._reset()
                p.not_before = 0.0
                self._queue.append(p)
                self._stats.redispatched_requests += 1
                if tr is not None:
                    tr.metrics.inc("serve.redispatched_requests")
                    tr.event("serve.redispatch", seq=p.seq,
                             device=str(device))
        if self._busy_since is not None and \
                not any(self._inflight.values()):
            self._stats.exec_s += time.perf_counter() - self._busy_since
            self._busy_since = None

    def _check_quarantine(self) -> None:
        """Health-check quarantined devices whose retest time has come:
        re-admit on a passing probe, else double the quarantine."""
        if not self._quarantined:
            return
        now = time.perf_counter()
        for device, until in list(self._quarantined.items()):
            if now < until:
                continue
            if self._probe(device):
                del self._quarantined[device]
                self._consec_failures[device] = 0
                self._quarantine_backoff.pop(device, None)
            else:
                backoff = self._quarantine_backoff.get(
                    device, self.quarantine_s)
                self._quarantined[device] = now + backoff
                self._quarantine_backoff[device] = backoff * 2.0

    def _probe(self, device) -> bool:
        """One round-trip health check (tiny transfer) on ``device``."""
        try:
            if self.fault_injector is not None:
                self.fault_injector(device, None)
            jax.block_until_ready(jax.device_put(np.uint32(0), device))
            return True
        except Exception:
            return False

    def _expire_deadlines(self) -> None:
        """Fail queued/staged requests whose deadline already passed —
        don't waste a device on work nobody can use."""
        now = time.perf_counter()
        if self._queue and any(
                p.ticket.deadline_at is not None
                and now >= p.ticket.deadline_at for p in self._queue):
            keep: "list[_Pending]" = []
            for p in self._queue:
                dl = p.ticket.deadline_at
                if dl is not None and now >= dl:
                    self._stats.deadline_exceeded += 1
                    if self.trace is not None:
                        self.trace.metrics.inc("serve.deadline_exceeded")
                        self.trace.event("serve.deadline_exceeded",
                                         seq=p.seq, where="queued")
                    p.ticket._fail(DeadlineExceeded(
                        f"deadline_ms={p.req.deadline_ms:g} passed while "
                        f"queued"))
                else:
                    keep.append(p)
            self._queue = keep
        drop = False
        for b in self._staged:
            for i in range(len(b.pendings) - 1, -1, -1):
                t = b.pendings[i].ticket
                if t.deadline_at is not None and now >= t.deadline_at:
                    p = b.unbind(i)
                    self._stats.deadline_exceeded += 1
                    if self.trace is not None:
                        self.trace.metrics.inc("serve.deadline_exceeded")
                        self.trace.event("serve.deadline_exceeded",
                                         seq=p.seq, where="staged")
                    p.ticket._fail(DeadlineExceeded(
                        f"deadline_ms={p.req.deadline_ms:g} passed while "
                        f"staged"))
                    drop = drop or not b.pendings
        if drop:
            self._staged = [b for b in self._staged if b.pendings]

    # ------------------------------ shutdown -------------------------------------

    def close(self, drain: bool = True,
              timeout: "float | None" = None) -> None:
        """Shut the server down; every outstanding ticket settles.

        ``drain=True`` (default) keeps dispatching until every queued,
        staged and in-flight request has a result or a typed error (retries
        and quarantine recovery included; ``timeout`` bounds the drain,
        after which it degrades to the fast path).  ``drain=False`` fails
        undispatched tickets with :class:`ServerClosed`, disables retries,
        and finalizes in-flight batches.  Idempotent; the engine has no
        threads, so nothing can leak."""
        if self._closed:
            return
        self._closed = True
        t_end = None if timeout is None else time.perf_counter() + timeout
        if drain:
            while self._queue or self._staged or \
                    any(self._inflight.values()):
                if t_end is not None and time.perf_counter() >= t_end:
                    drain = False
                    break
                self._drive()
                for dq in list(self._inflight.values()):
                    while dq:
                        self._finalize(dq[0])
                if self._queue and not self._staged:
                    # Everything left is backing off — wait it out.
                    time.sleep(5e-4)
        if not drain:
            self._accepting = False     # no further retries
            for p in self._queue:
                if not p.ticket._done:
                    p.ticket._fail(ServerClosed(
                        "server closed before dispatch"))
            self._queue.clear()
            for b in self._staged:
                for p in b.pendings:
                    if not p.ticket._done:
                        p.ticket._fail(ServerClosed(
                            "server closed before dispatch"))
            self._staged.clear()
            for dq in list(self._inflight.values()):
                while dq:
                    self._finalize(dq[0])

    def __enter__(self) -> "BankServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Exiting on an exception still drains: tickets must never dangle.
        self.close(drain=True)

    # -------------------------------- stats --------------------------------------

    def stats(self) -> dict:
        """Serving-health snapshot (plain dict, json-serializable).

        Fields are documented exhaustively in ``docs/OBSERVABILITY.md``:
        provenance counters (``n_requests`` / ``n_batches`` / bucket
        hits / joins / padding waste / pass-merge savings), latency
        aggregates (``p50_ms`` / ``p99_ms`` / ``mean_ms`` /
        ``throughput_rps`` over the most recent window), reliability
        counters (``shed_requests`` / ``retries`` / ``quarantines`` /
        ``redispatched_requests`` / ``deadline_exceeded``) and a
        per-device breakdown.  When the server was built with ``trace=``,
        a ``"metrics"`` key carries ``trace.metrics.snapshot()``.

        Example::

            server = BankServer(max_slots=4, trace=True)
            # ... traffic ...
            s = server.stats()
            s["bucket_hit_rate"], s["p99_ms"], s["metrics"]["counters"]
        """
        d = self._stats.as_dict()
        d["n_devices"] = len(self.devices)
        d["devices"] = [{"device": str(dev), **dict(st),
                         "quarantined": dev in self._quarantined}
                        for dev, st in self._dev_stats.items()]
        if self.trace is not None:
            d["metrics"] = self.trace.metrics.snapshot()
        return d

    def reset_stats(self) -> None:
        """Zero the counters; keeps the bucket/jit caches warm (for
        measuring steady-state serving after a warmup pass)."""
        self._stats = BankServerStats()
        self._dev_stats = {d: {"n_batches": 0, "n_requests": 0,
                               "quarantines": 0}
                           for d in self.devices}
        self._busy_since = None
