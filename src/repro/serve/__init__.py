"""Serving subsystems.

``sc_engine`` / ``apps`` — the dynamic SC bank server (request admission,
bucketed padded BankPlans, per-request key threading).  The LM serving entry
points (``make_prefill`` / ``make_decode_step`` / ``greedy_generate``) load
lazily: they pull in the whole ``repro.models`` stack, which the SC serving
path does not need.
"""
from ..core.executor import ExecOptions, ExecRequest
from ..core.faults import FaultModel
from .apps import app_netlist, app_request, circuit_request
from .sc_engine import (BankServer, BankServerStats, DeadlineExceeded,
                        RequestShed, SCRequest, ServerClosed, Ticket)

__all__ = [
    "BankServer", "BankServerStats", "DeadlineExceeded", "ExecOptions",
    "ExecRequest", "FaultModel", "RequestShed", "SCRequest", "ServerClosed",
    "Ticket",
    "app_netlist", "app_request", "circuit_request",
    "make_decode_step", "make_prefill", "greedy_generate",
]

_LM_EXPORTS = ("make_decode_step", "make_prefill", "greedy_generate")


def __getattr__(name):
    if name in _LM_EXPORTS:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
