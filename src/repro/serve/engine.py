"""Serving entry points: prefill and single-token decode, in the shapes the
assignment's inference cells lower (prefill_32k lowers ``prefill``;
decode_32k / long_500k lower ``decode_step`` against a seq_len-sized cache).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import RunCtx, decode_step, prefill
from repro.models.common import ModelConfig


def make_prefill(cfg: ModelConfig, ctx: RunCtx) -> Callable:
    def prefill_step(params, tokens, frames=None):
        logits, cache = prefill(cfg, params, tokens, ctx, frames=frames)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: RunCtx) -> Callable:
    def step(params, tokens, pos, cache, enc_out=None):
        logits, cache = decode_step(cfg, params, tokens, pos, cache, ctx,
                                    enc_out=enc_out)
        return logits, cache
    return step


def greedy_generate(cfg: ModelConfig, params: Any, prompt: jax.Array,
                    n_new: int, ctx: RunCtx = RunCtx(),
                    frames: jax.Array | None = None) -> jax.Array:
    """Reference batched greedy decoding loop (examples/serve_lm.py)."""
    b, s = prompt.shape
    _, cache = prefill(cfg, params, prompt, ctx, frames=frames)
    # Grow prompt-sized caches to s + n_new capacity.
    from repro.models.attention import KVCache, MLACache

    def grow(c):
        if isinstance(c, dict):
            return {k: grow(v) for k, v in c.items()}
        if isinstance(c, list):
            return [grow(v) for v in c]
        if isinstance(c, KVCache):
            ax = c.k.ndim - 3
            if c.k.shape[ax] == min(cfg.local_window, s):
                return c                      # ring cache: fixed size
            pad = [(0, 0)] * c.k.ndim
            pad[ax] = (0, n_new)
            return KVCache(jnp.pad(c.k, pad), jnp.pad(c.v, pad))
        if isinstance(c, MLACache):
            ax = c.c_kv.ndim - 2
            pad = [(0, 0)] * c.c_kv.ndim
            pad[ax] = (0, n_new)
            pad_r = [(0, 0)] * c.k_rope.ndim
            pad_r[ax] = (0, n_new)
            return MLACache(jnp.pad(c.c_kv, pad), jnp.pad(c.k_rope, pad_r))
        return c

    cache = grow(cache)
    enc_out = None
    if cfg.is_encoder_decoder:
        from repro.models.model import encoder_stack
        enc_out = encoder_stack(cfg, params, frames.astype(cfg.dtype), ctx)

    step = jax.jit(make_decode_step(cfg, ctx))
    # Prefill logits are for position s-1 -> they predict token s.
    logits, _ = prefill(cfg, params, prompt, ctx, frames=frames)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [prompt, tok]
    for i in range(n_new - 1):
        logits, cache = step(params, tok, jnp.int32(s + i), cache, enc_out)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
