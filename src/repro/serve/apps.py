"""Request construction for serving the paper's applications (Section 5-3).

Builders for ``BankServer`` requests over the composed per-bit application
netlists (LIT / OL / HDP / KDE) and over raw Table-2 circuits.  Both return
``SCRequest`` — the canonical ``executor.ExecRequest`` with per-request
execution parameters folded into ``ExecOptions`` — so a built request can be
submitted to a server OR handed directly to ``executor.run``.  Application
netlists are built ONCE per process and reused across requests: appnet node
names are uniquified per build, so a fresh build per request would defeat
the plan memo and the bank-template bucketing (every request would look like
a new structure).
"""
from __future__ import annotations

from typing import Any

from ..core import apps as core_apps
from ..core.gates import Netlist
from .sc_engine import SCRequest

_APP_NETS: dict[str, Netlist] = {}


def app_netlist(app: str) -> Netlist:
    """Process-wide cached build of an application netlist.

    Reusing one build per app keeps structure identity stable: every request
    for the same app interns to the same compiled plan, which is what makes
    bank-template buckets (and the jit cache behind them) hit.
    """
    if app not in _APP_NETS:
        from ..core.appnet import APP_NETLISTS
        _APP_NETS[app] = APP_NETLISTS[app]()
    return _APP_NETS[app]


def app_request(app: str, key, bl: int = 256, *,
                batch_shape: "tuple[int, ...] | None" = None,
                bitflip_rate: float = 0.0, flip_key=None,
                fault_model=None, deadline_ms: "float | None" = None,
                **inputs: Any) -> SCRequest:
    """Build a BankServer request for one application evaluation.

    ``inputs`` are the app-level keyword inputs of ``apps.appnet_inputs``
    (``lit``: ``a`` (..., 81); ``ol``: ``p`` (..., 16, 6); ``hdp``: ``v``
    dict; ``kde``: ``x_t``, ``hist``).  ``key`` is the request's PRNG key —
    the served result is bit-identical to ``appnet_stochastic`` with the
    same key and netlist.
    """
    return SCRequest(net=app_netlist(app),
                     values=core_apps.appnet_inputs(app, **inputs),
                     key=key, bitstream_length=bl, batch_shape=batch_shape,
                     bitflip_rate=bitflip_rate, flip_key=flip_key,
                     fault_model=fault_model, deadline_ms=deadline_ms)


def circuit_request(net: Netlist, values: dict, key, bl: int = 256, *,
                    batch_shape: "tuple[int, ...] | None" = None,
                    bitflip_rate: float = 0.0, flip_key=None,
                    fault_model=None,
                    deadline_ms: "float | None" = None) -> SCRequest:
    """Build a BankServer request for a raw circuit netlist.

    Reuse the same ``net`` object across requests of equal structure (e.g.
    one ``circuits.sc_multiply()`` instance for all multiply traffic) so the
    template buckets stay warm.
    """
    return SCRequest(net=net, values=values, key=key, bitstream_length=bl,
                     batch_shape=batch_shape, bitflip_rate=bitflip_rate,
                     flip_key=flip_key, fault_model=fault_model,
                     deadline_ms=deadline_ms)
