"""Sharded AdamW: optimizer state trees mirror parameter sharding (FSDP —
m/v shard exactly like their parameter), global-norm clipping, decoupled
weight decay, bias correction.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    m: Any                   # tree like params
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: float | jax.Array = 3e-4, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0
                 ) -> tuple[Any, AdamWState]:
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    # out is a tree of 3-tuples; split it back into three trees.
    is_triplet = lambda x: isinstance(x, tuple) and len(x) == 3 and not \
        isinstance(x[0], tuple)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_triplet)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_triplet)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_triplet)
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
