from .adamw import AdamWState, adamw_init, adamw_update
from .compress import compress_decompress, error_feedback_update

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "compress_decompress", "error_feedback_update"]
