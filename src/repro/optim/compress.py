"""SC-inspired stochastically-quantized gradient compression with error
feedback (beyond-paper application of the paper's stochastic-rounding
insight, DESIGN.md §6).

The paper generates Bernoulli(p) bits from analog values via MTJ pulse
programming; the gradient-compression analogue quantizes each gradient to
``bits`` levels with *stochastic rounding* (unbiased, like the SC encoding),
all-reduces the narrow representation, and keeps the quantization residual
as local error feedback so the bias telescopes away across steps.

In-framework use: train/train_step applies compress->psum->decompress to the
gradient tree when cfg.grad_compress_bits > 0.  On a real fleet this shrinks
the all-reduce payload by 32/bits; the dry-run records the collective-byte
reduction in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _stochastic_quantize(g: jax.Array, key: jax.Array, bits: int
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """g -> (q_int, scale, residual); unbiased stochastic rounding."""
    levels = (1 << (bits - 1)) - 1                      # signed range
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scaled = g / amax * levels                           # [-levels, levels]
    floor = jnp.floor(scaled)
    frac = scaled - floor
    rnd = jax.random.uniform(key, g.shape, g.dtype)
    q = floor + (rnd < frac)                             # stochastic round
    q = jnp.clip(q, -levels - 1, levels)
    deq = q * amax / levels
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32), amax / levels, g - deq


def compress_decompress(grads: Any, key: jax.Array, bits: int,
                        errors: Any | None = None) -> tuple[Any, Any]:
    """Quantize (+error feedback in) each leaf; returns (dequantized, new_errors).

    The dequantized tree is what enters the (narrow) all-reduce in
    train_step; ``new_errors`` must be carried to the next step.
    """
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(errors) if errors is not None else [None] * len(leaves)
    keys = jax.random.split(key, max(len(leaves), 1))
    outs, new_errs = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, scale, resid = _stochastic_quantize(g32, k, bits)
        outs.append((q.astype(jnp.float32) * scale).astype(g.dtype))
        new_errs.append(resid)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_errs)


def error_feedback_update(errors: Any | None, grads: Any) -> Any:
    """Initialize the error-feedback tree lazily (zeros like grads)."""
    if errors is not None:
        return errors
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compressed_bytes_ratio(bits: int) -> float:
    """Payload shrink factor vs fp32 all-reduce."""
    return bits / 32.0


# ----------------------- cross-pod compressed parameter sync ----------------------
#
# The pod axis is the slow link (DCN between pods, vs ICI within a pod) —
# exactly where the paper's stochastic-rounding insight pays: synchronize
# parameter DELTAS as int8 stochastically-rounded values with error
# feedback, local-SGD style (each pod runs synchronous FSDP/TP internally;
# every K steps pods exchange quantized deltas).  The sync runs OUTSIDE
# autodiff as its own jitted shard_map, so the all-gather on the wire is
# genuinely int8 — the dry-run measures the byte reduction in HLO.

def make_pod_sync(mesh, pspecs, bits: int = 8, pod_axis: str = "pod"):
    """Returns sync(params, anchor, err, seed) -> (new_params, new_err).

    ``pspecs``: the parameter PartitionSpec tree (pod axis unmentioned —
    parameters are replicated across pods, sharded FSDP/TP within a pod).
    """
    try:
        from jax import shard_map
    except ImportError:  # jax < 0.6 exposes it under jax.experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    n_pods = mesh.shape[pod_axis]
    levels = (1 << (bits - 1)) - 1

    def body(seed, *flat):
        k = len(flat) // 3
        params, anchor, err = flat[:k], flat[k:2 * k], flat[2 * k:]
        new_p, new_e = [], []
        for i, (p, a, e) in enumerate(zip(params, anchor, err)):
            delta = (p - a).astype(jnp.float32) + e
            amax = jnp.maximum(jnp.max(jnp.abs(delta)), 1e-12)
            scaled = delta / amax * levels
            rnd = jax.random.uniform(
                jax.random.fold_in(jax.random.key(seed[0]), i), p.shape)
            q = jnp.clip(jnp.floor(scaled) + (rnd < scaled - jnp.floor(scaled)),
                         -levels - 1, levels).astype(jnp.int8)
            deq_local = q.astype(jnp.float32) * (amax / levels)
            new_e.append(delta - deq_local)
            # int8 all-gather across pods (the only cross-pod traffic) +
            # per-pod scales, then average the dequantized deltas locally.
            qs = jax.lax.all_gather(q, pod_axis)                 # (pods, ...)
            scales = jax.lax.all_gather(amax / levels, pod_axis)  # (pods,)
            mean_delta = jnp.tensordot(scales, qs.astype(jnp.float32), axes=1) \
                / n_pods
            new_p.append((a.astype(jnp.float32) + mean_delta).astype(p.dtype))
        return tuple(new_p) + tuple(new_e)

    flat_specs, treedef = jax.tree_util.tree_flatten(pspecs)
    in_specs = (PS(),) + tuple(flat_specs) * 3
    out_specs = tuple(flat_specs) * 2

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)

    def sync(params, anchor, err, seed: int):
        flat_p, _ = jax.tree_util.tree_flatten(params)
        flat_a, _ = jax.tree_util.tree_flatten(anchor)
        flat_e, _ = jax.tree_util.tree_flatten(err)
        out = fn(jnp.asarray([seed], jnp.uint32), *flat_p, *flat_a, *flat_e)
        k = len(flat_p)
        new_p = jax.tree_util.tree_unflatten(treedef, out[:k])
        new_e = jax.tree_util.tree_unflatten(treedef, out[k:])
        return new_p, new_e

    return sync


def make_pod_sync_uncompressed(mesh, pspecs, pod_axis: str = "pod"):
    """fp32 pmean baseline for the same sync (the all-reduce we replace)."""
    try:
        from jax import shard_map
    except ImportError:  # jax < 0.6 exposes it under jax.experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    def body(*flat):
        return tuple(jax.lax.pmean(p.astype(jnp.float32), pod_axis).astype(p.dtype)
                     for p in flat)

    flat_specs, treedef = jax.tree_util.tree_flatten(pspecs)
    fn = shard_map(body, mesh=mesh, in_specs=tuple(flat_specs),
                   out_specs=tuple(flat_specs), check_vma=False)

    def sync(params):
        flat_p, _ = jax.tree_util.tree_flatten(params)
        return jax.tree_util.tree_unflatten(treedef, fn(*flat_p))

    return sync
