"""Zero-dependency tracing + metrics for the compile/exec/serve stack.

The paper's headline claims are *phase* claims — SNG cycles vs. computation
cycles vs. readout (Table 8) — so the reproduction needs a way to attribute
wall-clock the same way: where did a served request's 40 ms go across
admission, batching, stream generation, and pass execution?  This module is
that window.  It is deliberately dependency-free (no jax import) so every
layer from ``compiler/pipeline.py`` down to ``serve/sc_engine.py`` can use
it without cycles.

Three pieces:

* ``Trace`` — an in-memory span collector.  ``trace.span(name, **attrs)``
  is a context manager producing nested spans with monotonic timestamps;
  nesting is tracked per thread (a thread-local stack on the trace), so one
  ``Trace`` can be shared across worker threads and each thread gets its
  own correct parent chain.  ``trace.add_span(...)`` records a span
  retroactively from timestamps stamped earlier (the serve engine uses this
  to emit a request's queued/staged/inflight phases at reap time), and
  ``trace.event(...)`` records instant events (retry, quarantine, shed).
  Exporters: ``to_chrome_json()`` (load in chrome://tracing or Perfetto)
  and ``summary()`` (flat per-span-name totals).

* ``MetricsRegistry`` — named counters / gauges / histograms behind one
  lock.  Every ``Trace`` owns one (``trace.metrics``); a process-wide
  ``REGISTRY`` exists for code with no trace in hand.

* A current-trace context: ``tracing(trace)`` sets a contextvar for the
  dynamic extent of a block, ``install(trace)`` sets a process-wide
  fallback (what ``REPRO_TRACE=1`` does at import), and ``span(...)`` /
  ``event(...)`` module-level helpers no-op cheaply when neither is set —
  the disabled path is one contextvar read, so instrumented hot paths cost
  nothing measurable when tracing is off.

Example::

    from repro.core import obs
    tr = obs.Trace("demo")
    with obs.tracing(tr):
        with obs.span("outer", step=1):
            with obs.span("inner"):
                pass
    print(tr.summary()["spans"]["outer"]["count"])  # 1
    open("/tmp/trace.json", "w").write(tr.to_chrome_json())
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Iterator

__all__ = [
    "Span", "Trace", "MetricsRegistry", "REGISTRY",
    "current_trace", "tracing", "install", "span", "event", "span_on",
]


class Span:
    """One timed region: ``name``, perf_counter start/end, attrs, parent.

    ``tid`` is the chrome-trace track the span renders on — the recording
    thread's ident for live spans, or a virtual track id for retroactive
    spans (the serve engine gives each request its own track so its
    queued → staged → inflight children nest visibly).
    """

    __slots__ = ("name", "t0", "t1", "tid", "parent", "attrs")

    def __init__(self, name: str, t0: float, t1: "float | None",
                 tid: int, parent: "Span | None", attrs: dict):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.parent = parent
        self.attrs = attrs

    @property
    def duration_ms(self) -> float:
        return 0.0 if self.t1 is None else (self.t1 - self.t0) * 1e3

    def set(self, key: str, value: Any) -> None:
        """Attach/overwrite an attribute while the span is open."""
        self.attrs[key] = value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Span({self.name!r}, {self.duration_ms:.3f} ms)"


class _NullSpan:
    """Inert stand-in returned by ``span(...)`` when tracing is disabled."""

    __slots__ = ()
    name = "<disabled>"
    attrs: dict = {}
    duration_ms = 0.0

    def set(self, key: str, value: Any) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Bounded-sample histogram: exact count/sum, percentiles from the
    most recent ``cap`` observations (enough for latency distributions)."""

    __slots__ = ("count", "total", "vmin", "vmax", "_samples", "_cap")

    def __init__(self, cap: int = 4096):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._samples: list[float] = []
        self._cap = cap

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if len(self._samples) >= self._cap:
            self._samples.pop(0)
        self._samples.append(v)

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        return s[min(len(s) - 1, int(q * len(s)))]

    def as_dict(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {"count": self.count,
                "sum": round(self.total, 6),
                "mean": round(self.total / self.count, 6),
                "min": round(self.vmin, 6), "max": round(self.vmax, 6),
                "p50": round(self.percentile(0.50), 6),
                "p99": round(self.percentile(0.99), 6)}


class MetricsRegistry:
    """Process- or trace-scoped named counters/gauges/histograms.

    Accessors create on first use; all mutation goes through one lock, so
    the registry is safe to share across the server's caller threads.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            return h

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def snapshot(self) -> dict:
        """Point-in-time dict: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count/sum/mean/min/max/p50/p99}}}``."""
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.as_dict() for k, h in self._hists.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


#: Process-wide registry for call sites with no Trace in hand.
REGISTRY = MetricsRegistry()


class Trace:
    """An in-memory collection of spans + instant events + metrics.

    Safe to share across threads: completed spans append under a lock, and
    the open-span stack used for parent inference is thread-local, so spans
    opened on different threads never corrupt each other's nesting.
    """

    def __init__(self, name: str = "trace"):
        self.name = name
        self.metrics = MetricsRegistry()
        self.t_origin = time.perf_counter()
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._events: list[dict] = []
        self._tls = threading.local()
        self._vtids: dict[str, int] = {}

    # -- recording ---------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[Span]:
        """Open a live nested span; closed (and recorded) on exit."""
        st = self._stack()
        sp = Span(name, time.perf_counter(), None, threading.get_ident(),
                  st[-1] if st else None, attrs)
        st.append(sp)
        try:
            yield sp
        finally:
            st.pop()
            sp.t1 = time.perf_counter()
            with self._lock:
                self._spans.append(sp)

    def add_span(self, name: str, t0: float, t1: float, *,
                 parent: "Span | None" = None, tid: "int | None" = None,
                 **attrs: Any) -> Span:
        """Record a span retroactively from perf_counter timestamps.

        Used where the interesting interval was stamped earlier than it can
        be attributed (the serve engine stamps admission/stage/launch times
        on the pending request and emits the spans at reap).  Pass the
        returned span as ``parent=`` to nest children under it.
        """
        sp = Span(name, t0, t1, threading.get_ident() if tid is None else tid,
                  parent, attrs)
        with self._lock:
            self._spans.append(sp)
        return sp

    def event(self, name: str, *, t: "float | None" = None,
              tid: "int | None" = None, **attrs: Any) -> None:
        """Record an instant event (chrome-trace ``ph: "i"``)."""
        ev = {"name": name,
              "t": time.perf_counter() if t is None else t,
              "tid": threading.get_ident() if tid is None else tid,
              "attrs": attrs}
        with self._lock:
            self._events.append(ev)

    def virtual_tid(self, label: str) -> int:
        """Stable synthetic track id for ``label`` (named in the export).

        Virtual tracks keep overlapping retroactive spans (e.g. concurrent
        requests) from stacking on one thread's row in chrome://tracing.
        """
        with self._lock:
            tid = self._vtids.get(label)
            if tid is None:
                tid = self._vtids[label] = 1_000_000 + len(self._vtids)
            return tid

    # -- inspection --------------------------------------------------------

    def spans(self) -> "list[Span]":
        with self._lock:
            return list(self._spans)

    def events(self) -> "list[dict]":
        with self._lock:
            return list(self._events)

    # -- exporters ---------------------------------------------------------

    def to_chrome_json(self, indent: "int | None" = None) -> str:
        """Serialize to the chrome://tracing / Perfetto JSON array format.

        Spans become complete (``"ph": "X"``) events with microsecond
        ``ts``/``dur`` relative to trace creation; instant events become
        ``"ph": "i"``; virtual tracks get ``thread_name`` metadata so the
        viewer labels them.
        """
        pid = os.getpid()
        out: list[dict] = [{"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": self.name}}]
        with self._lock:
            spans, events = list(self._spans), list(self._events)
            vtids = dict(self._vtids)
        for label, tid in sorted(vtids.items(), key=lambda kv: kv[1]):
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": label}})
        for sp in spans:
            t1 = sp.t1 if sp.t1 is not None else sp.t0
            out.append({"name": sp.name, "ph": "X", "pid": pid, "tid": sp.tid,
                        "ts": round((sp.t0 - self.t_origin) * 1e6, 3),
                        "dur": round((t1 - sp.t0) * 1e6, 3),
                        "args": _jsonable(sp.attrs)})
        for ev in events:
            out.append({"name": ev["name"], "ph": "i", "s": "t", "pid": pid,
                        "tid": ev["tid"],
                        "ts": round((ev["t"] - self.t_origin) * 1e6, 3),
                        "args": _jsonable(ev["attrs"])})
        return json.dumps({"traceEvents": out, "displayTimeUnit": "ms"},
                          indent=indent)

    def summary(self) -> dict:
        """Flat aggregation: per-span-name count/total/mean/max ms, event
        counts, and the trace's metrics snapshot."""
        spans, events = self.spans(), self.events()
        agg: dict[str, dict] = {}
        for sp in spans:
            a = agg.setdefault(sp.name, {"count": 0, "total_ms": 0.0,
                                         "max_ms": 0.0})
            a["count"] += 1
            a["total_ms"] += sp.duration_ms
            a["max_ms"] = max(a["max_ms"], sp.duration_ms)
        for a in agg.values():
            a["mean_ms"] = round(a["total_ms"] / a["count"], 4)
            a["total_ms"] = round(a["total_ms"], 4)
            a["max_ms"] = round(a["max_ms"], 4)
        ev_counts: dict[str, int] = {}
        for ev in events:
            ev_counts[ev["name"]] = ev_counts.get(ev["name"], 0) + 1
        end = max([sp.t1 or sp.t0 for sp in spans]
                  + [ev["t"] for ev in events] + [self.t_origin])
        return {"name": self.name,
                "wall_ms": round((end - self.t_origin) * 1e3, 4),
                "n_spans": len(spans), "n_events": len(events),
                "spans": agg, "events": ev_counts,
                "metrics": self.metrics.snapshot()}


def _jsonable(attrs: dict) -> dict:
    return {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                else str(v))
            for k, v in attrs.items()}


# -- current-trace context -------------------------------------------------

_current: "contextvars.ContextVar[Trace | None]" = contextvars.ContextVar(
    "repro_obs_trace", default=None)
_installed: "Trace | None" = None


def current_trace() -> "Trace | None":
    """The active trace: context-local if set, else the installed global."""
    tr = _current.get()
    return tr if tr is not None else _installed


def install(trace: "Trace | None") -> "Trace | None":
    """Set (or clear, with None) the process-wide fallback trace.

    Unlike the contextvar set by :func:`tracing`, the installed trace is
    visible from *every* thread — which is what lets ``REPRO_TRACE=1``
    capture spans from server caller threads without plumbing.
    """
    global _installed
    _installed = trace
    return trace


@contextmanager
def tracing(trace: Trace) -> Iterator[Trace]:
    """Make ``trace`` the current trace for the dynamic extent of a block."""
    token = _current.set(trace)
    try:
        yield trace
    finally:
        _current.reset(token)


def span(name: str, **attrs: Any):
    """Span on the current trace, or an inert no-op when tracing is off."""
    tr = current_trace()
    if tr is None:
        return NULL_SPAN
    return tr.span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    """Instant event on the current trace; no-op when tracing is off."""
    tr = current_trace()
    if tr is not None:
        tr.event(name, **attrs)


def span_on(trace: "Trace | None", name: str, **attrs: Any):
    """Span on an explicit trace handle (None → no-op) — for call sites
    like the serve engine that hold their own trace reference."""
    if trace is None:
        return NULL_SPAN
    return trace.span(name, **attrs)


if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    install(Trace("REPRO_TRACE"))
