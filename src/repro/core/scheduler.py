"""Algorithm 1 — in-memory co-scheduling and mapping for the 2T-1MTJ method.

Reproduces the paper's scheduling/mapping heuristic with the hardware validity
rules implied by the worked examples of Fig. 7 (derivation in DESIGN.md §7):

* **one logic operation per row per cycle** — the row's logic line (LL) drives
  one intra-row current path at a time.  A SIMD gate (ALL_ROWS node span —
  e.g. every bit of a stochastic stream in rows 0..q-1 of one column,
  Fig. 7(b)) occupies *all* rows for its cycle: one V_SL drive pattern fires
  the same gate in every row simultaneously.  That is the intra-subarray
  parallelism Algorithm 1 exploits (and why stochastic scaled addition takes
  4 cycles regardless of bitstream length).
* **no shared fan-in within a cycle** — Algorithm 1's "gates must not have
  same input": a cell can source current for only one operation per cycle.
* a cross-row move is a BUFF via the bit lines and occupies both source and
  target rows (the carry copies of Fig. 7(a)).  Non-BUFF gates need their
  operands resident in their own row; the scheduler auto-inserts BUFF copies
  (Algorithm 1 lines 15-22).
* ready gates are prioritized by inverse topological order (distance to the
  primary outputs — Algorithm 1 lines 12-13), then construction order.
* every gate output is mapped to the next available column of its row
  (Algorithm 1 line 27); PIs map one-column-each first (lines 4-8).

``strict_same_type=True`` additionally forbids mixing gate types within a
cycle — the most conservative reading of the pseudocode's "identical gate
type" subset rule.  The default packing reproduces Fig. 7(a) exactly
(9 cycles for the 4-bit binary ripple-carry adder, mixed-type cycles like its
t5 = {NOT, BUFF, MAJ3}) and Fig. 7(b) (4 cycles for stochastic scaled
addition); see tests/test_scheduler.py.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from .gates import ALL_ROWS, Gate, Netlist, PIKind


@dataclasses.dataclass(frozen=True)
class Placement:
    row: int            # ALL_ROWS for SIMD nodes
    col: int


@dataclasses.dataclass(frozen=True)
class ScheduledOp:
    gtype: str
    cycle: int
    row: int                     # executing row (ALL_ROWS for SIMD)
    src_row: int                 # != row only for cross-row BUFF moves
    in_cols: tuple[int, ...]
    out_col: int
    is_copy: bool = False
    rows_spanned: int = 1        # lanes written (SIMD gates span all lanes)


@dataclasses.dataclass
class Schedule:
    """Result of Algorithm 1 plus the accounting needed by Eqs. (3)-(4)/(11)."""

    netlist_name: str
    logic_cycles: int
    ops: list[ScheduledOp]
    placements: dict[str, Placement]
    n_lanes: int                      # SIMD lane count (Algorithm 1's q)
    n_rows: int                       # rows actually used
    n_cols: int                       # columns actually used (max over rows)
    n_copies: int                     # auto-inserted BUFF copies
    cells_used: int                   # distinct (row, col) cells occupied
    gate_exec_counts: dict[str, int]  # per gate type, x lanes (for Eq. (4))
    preset_count: int                 # output-cell presets, x lanes
    input_cells: int                  # PI cells (x lanes)
    stochastic_input_cells: int       # subset written via SBG pulses
    cell_writes: int                  # total cell write events (Eq. (11))

    def total_cycles(self, init_cycles: int = 0) -> int:
        # Output-cell presets overlap with consecutive logic ops except the
        # first one (Section 5.3.2 accounting).
        return self.logic_cycles + 1 + init_cycles


class _Row:
    __slots__ = ("next_col",)

    def __init__(self) -> None:
        self.next_col = 0


def schedule(net: Netlist, n_lanes: int = 1, strict_same_type: bool = False,
             r_available: int = 256, c_available: int = 256) -> Schedule:
    """Run Algorithm 1 on ``net``.

    ``n_lanes`` = rows spanned by each ALL_ROWS (SIMD) node: sub-bitstream
    bits and/or batched circuit instances (Algorithm 1's ``q``).  Row-local
    nodes (binary bit lanes) use their declared row index.
    """
    net.validate()
    inv_topo = net.inverse_topological_order()

    placements: dict[str, Placement] = {}
    rows: dict[int, _Row] = defaultdict(_Row)
    explicit_rows = [p.row for p in net.pis if p.row != ALL_ROWS] + \
                    [g.row for g in net.gates if g.row != ALL_ROWS]
    max_explicit = max(explicit_rows, default=-1)
    n_rows = max(max_explicit + 1, n_lanes)
    if n_rows > r_available:
        raise ValueError(f"{net.name}: needs {n_rows} rows > subarray {r_available}")

    def alloc_col(row: int) -> int:
        if row == ALL_ROWS:
            col = max((rows[r].next_col for r in range(n_rows)), default=0)
            for r in range(n_rows):
                rows[r].next_col = col + 1
            return col
        col = rows[row].next_col
        rows[row].next_col = col + 1
        return col

    # --- PI mapping (lines 4-8) -------------------------------------------------
    stochastic_inputs = 0
    input_cells = 0
    for pi in net.pis:
        col = alloc_col(pi.row)
        placements[pi.name] = Placement(pi.row, col)
        span = n_lanes if pi.row == ALL_ROWS else 1
        input_cells += span
        if pi.kind in (PIKind.STOCHASTIC, PIKind.CONSTANT, PIKind.STATE):
            stochastic_inputs += span

    # --- list scheduling ---------------------------------------------------------
    pending: list[Gate] = list(net.gates)
    done: set[str] = {p.name for p in net.pis}
    ops: list[ScheduledOp] = []
    gate_exec_counts: dict[str, int] = defaultdict(int)
    copies: dict[tuple[str, int], Placement] = {}  # (node, row) -> copy placement
    n_copies = 0
    cycle = 0
    cell_writes = input_cells
    preset_count = 0

    def lanes_of(row: int) -> int:
        return n_lanes if row == ALL_ROWS else 1

    def resolved(name: str, target_row: int) -> Placement | None:
        p = placements[name]
        if p.row == ALL_ROWS or p.row == target_row or target_row == ALL_ROWS:
            return p
        return copies.get((name, target_row))

    while pending:
        cycle += 1
        busy_rows: set[int] = set()
        fanin_used: set[str] = set()
        types_used: set[str] = set()
        progressed = False

        def rows_free(needed: set[int]) -> bool:
            if ALL_ROWS in needed:
                return not busy_rows
            return ALL_ROWS not in busy_rows and not (needed & busy_rows)

        def type_ok(gtype: str) -> bool:
            return not strict_same_type or not types_used or types_used == {gtype}

        def commit(gtype: str, row: int, src_row: int, in_cols: tuple[int, ...],
                   out_col: int, in_nodes: tuple[str, ...], is_copy: bool) -> None:
            nonlocal n_copies, cell_writes, preset_count, progressed
            span = lanes_of(row)
            ops.append(ScheduledOp(gtype, cycle, row, src_row, in_cols, out_col,
                                   is_copy, span))
            needed = {row} if row == src_row else {row, src_row}
            busy_rows.update(needed if ALL_ROWS not in needed else {ALL_ROWS})
            fanin_used.update(in_nodes)
            types_used.add(gtype)
            gate_exec_counts[gtype] += span
            preset_count += span
            cell_writes += 2 * span  # output preset + logic-result write
            if is_copy:
                n_copies += 1
            progressed = True

        ready = [g for g in pending if all(i in done for i in g.inputs)]
        ready.sort(key=lambda g: (-inv_topo[g.gid], g.gid))

        for g in ready:
            target = g.row
            miss: str | None = None
            places: list[Placement] = []
            for name in g.inputs:
                p = resolved(name, target)
                if p is None:
                    miss = name
                    break
                places.append(p)

            if miss is not None:
                src = placements[miss]
                if g.gtype == "BUFF":
                    # The gate itself is the cross-row mover (Fig. 7(a) carries).
                    needed = {target, src.row} if src.row != ALL_ROWS else {target}
                    if rows_free(needed) and miss not in fanin_used and type_ok("BUFF"):
                        out_col = alloc_col(target)
                        placements[g.output] = Placement(target, out_col)
                        commit("BUFF", target, src.row, (src.col,), out_col,
                               (miss,), False)
                        pending.remove(g)
                        done.add(g.output)
                    continue
                # Auto-insert a copy (Algorithm 1 lines 16-21).
                needed = {target, src.row} if src.row != ALL_ROWS else {target}
                if rows_free(needed) and miss not in fanin_used and type_ok("BUFF"):
                    out_col = alloc_col(target)
                    copies[(miss, target)] = Placement(target, out_col)
                    commit("BUFF", target, src.row, (src.col,), out_col,
                           (miss,), True)
                continue

            needed = {target}
            if not rows_free(needed):
                continue
            if any(name in fanin_used for name in g.inputs):
                continue
            if not type_ok(g.gtype):
                continue
            in_cols = tuple(p.col for p in places)
            out_col = alloc_col(target)
            placements[g.output] = Placement(target, out_col)
            commit(g.gtype, target, target, in_cols, out_col, tuple(g.inputs), False)
            pending.remove(g)
            done.add(g.output)

        if not progressed:
            raise RuntimeError(f"scheduler deadlock in {net.name} at cycle {cycle}")

    n_cols = max((rows[r].next_col for r in rows), default=0)
    if n_cols > c_available:
        raise ValueError(f"{net.name}: needs {n_cols} cols > subarray {c_available}")
    # Cells: each row index holds one cell per column its allocator issued;
    # SIMD lanes were materialized as rows 0..n_lanes-1, so the per-row sum
    # is exact for both row-local and SIMD nodes.
    cells_used = sum((rows[r].next_col if r in rows else 0) for r in range(n_rows))

    return Schedule(
        netlist_name=net.name,
        logic_cycles=cycle,
        ops=ops,
        placements=placements,
        n_lanes=n_lanes,
        n_rows=n_rows,
        n_cols=n_cols,
        n_copies=n_copies,
        cells_used=cells_used,
        gate_exec_counts=dict(gate_exec_counts),
        preset_count=preset_count,
        input_cells=input_cells,
        stochastic_input_cells=stochastic_inputs,
        cell_writes=cell_writes,
    )


def input_init_cycles(net: Netlist) -> int:
    """Cycles for the input-initialization step (DESIGN.md §7 accounting).

    SIMD (ALL_ROWS) stochastic/constant streams: 1 preset + 1 SBG pulse —
    all rows of a PI column share the pulse amplitude (fused in-memory SNG).
    Row-local stochastic PIs (instance-per-row app netlists): different
    values per row serialize on the word lines — 1 preset + one SBG cycle
    per occupied row (all columns of a row pulse together).
    Binary operands: 1 preset + one write cycle per occupied row.
    """
    stoch_kinds = {PIKind.STOCHASTIC, PIKind.CONSTANT, PIKind.STATE}
    simd_stoch = any(p.kind in stoch_kinds and p.row == ALL_ROWS
                     for p in net.pis)
    local_rows = {p.row for p in net.pis
                  if p.kind in stoch_kinds and p.row != ALL_ROWS}
    cycles = 0
    if simd_stoch or local_rows:
        cycles = 1 + (1 if simd_stoch else 0) + len(local_rows)
    if any(p.kind == PIKind.BINARY for p in net.pis):
        rows = {p.row for p in net.pis if p.kind == PIKind.BINARY}
        cycles += 1 + max(len(rows), 1)
    return cycles
