"""The paper's four evaluation applications (Section 5-3, Fig. 9).

  LIT — local image thresholding (Sauvola), Eq. (5)-(6), 9x9 window
  OL  — Bayesian object location, Eq. (7), 64x64 grid, 3 sensors
  HDP — Bayesian heart-disaster prediction, Eq. (8)-(9)
  KDE — kernel density estimation, Eq. (10), N-frame history

Each application provides:
  * ``exact(...)``       — float reference
  * ``stochastic(...)``  — the SC accuracy path on packed bitstreams, with
                           optional bitflip injection (Table 4)
  * ``binary8(...)``     — the 8-bit fixed-point binary-IMC accuracy path,
                           with optional bitflip injection (Table 4)
  * ``cost_stages()``    — netlist stages (circuit, instance count) feeding
                           Algorithm 1 + the architecture model (Table 3)

Reconstruction notes (figure images unavailable): DESIGN.md §7.  The SC mean
over k operands uses a uniform-select multiplexer (unbiased k-way scaled
addition); its netlist form is the balanced MUX tree of circuits.sc_mux_tree.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import bitstream as bs
from . import circuits, executor, faults, sc_ops
from .gates import Netlist


# ------------------------------------------------------------------ helpers ----

def mean_select_stream(key: jax.Array, leaves: jax.Array, bl: int) -> jax.Array:
    """Unbiased SC mean of k streams: per bit, select one leaf uniformly.

    ``leaves``: (..., k, W) packed.  Returns (..., W) packed with value
    mean_k(values).  The hardware realization is the MUX tree (cost path);
    a uniform k-way select is its unbiased generalization.
    """
    k = leaves.shape[-2]
    bits = bs.unpack_bits(leaves)                     # (..., k, W, 32)
    sel = jax.random.randint(key, (bits.shape[-2], bs.WORD_BITS), 0, k)  # (W,32)
    sel = jnp.broadcast_to(sel, bits.shape[:-3] + sel.shape)[..., None, :, :]
    picked = jnp.take_along_axis(bits, sel, axis=-3)[..., 0, :, :]
    return bs.pack_bits(picked)


def _flip(key, words, rate, model=None):
    """Fault injection on one stored intermediate (Table-4 checkpoints).

    Each call site models one STT-MRAM array holding the stage's streams:
    transient flips under the legacy ``rate``, or the full ``FaultModel``
    (stuck-at cells, dead rows, wear) — each site draws its own masks from
    its own key, so distinct arrays fail independently."""
    if not faults.injecting(rate, model):
        return words
    return faults.apply_faults(key, words, rate, model)


def _app_fault_model(rate: float, model):
    """Normalize/validate the (bitflip_rate, fault_model) pair of one app."""
    model = faults.normalize_fault_model(model)
    if model is not None and rate > 0.0:
        raise ValueError("pass bitflip_rate or fault_model, not both "
                         "(FaultModel(flip_rate=...) subsumes bitflip_rate)")
    return model


def _value_stream(key: jax.Array, value: jax.Array, bl: int) -> jax.Array:
    return bs.generate(key, value, bl)


# Fixed-point helpers for the binary-IMC accuracy path (8-bit, Table 4).

def _q8(x: np.ndarray) -> np.ndarray:
    return np.clip(np.round(np.asarray(x) * 255.0), 0, 255).astype(np.int64)


def _dq8(x: np.ndarray) -> np.ndarray:
    return x.astype(np.float64) / 255.0


def _flip8(rng: np.random.Generator, x: np.ndarray, rate: float,
           bits: int = 8) -> np.ndarray:
    """Flip each of the low ``bits`` bits independently with prob ``rate``."""
    if rate <= 0:
        return x
    masks = rng.random(x.shape + (bits,)) < rate
    flip = (masks * (1 << np.arange(bits))).sum(axis=-1).astype(np.int64)
    return x ^ flip


# ================================ LIT ============================================

WINDOW = 9  # 9x9 window (Section 5.3.2)


def lit_exact(a: np.ndarray) -> np.ndarray:
    """Eq. (5)-(6): a has shape (..., 81) of pixel intensities in [0,1]."""
    m = a.mean(-1)
    m2 = (a * a).mean(-1)
    sigma = np.sqrt(np.abs(m2 - m * m))
    return m * (sigma + 1.0) / 2.0


def lit_stochastic(key: jax.Array, a: jax.Array, bl: int = 256,
                   bitflip_rate: float = 0.0, fault_model=None) -> jax.Array:
    """SC accuracy path for LIT.  a: (..., 81) in [0,1]; returns T estimates."""
    fault_model = _app_fault_model(bitflip_rate, fault_model)
    ks = jax.random.split(key, 16)
    a = jnp.asarray(a, jnp.float32)
    A1 = _flip(ks[10], bs.generate(ks[0], a, bl), bitflip_rate,
               fault_model)                                       # (...,81,W)
    A2 = _flip(ks[11], bs.generate(ks[1], a, bl), bitflip_rate, fault_model)

    squares = A1 & A2                                             # value a^2
    squares = _flip(ks[12], squares, bitflip_rate, fault_model)
    mean_sq = mean_select_stream(ks[2], squares, bl)              # E[a^2]
    mean_a_x = mean_select_stream(ks[3], A1, bl)
    mean_a_y = mean_select_stream(ks[4], A2, bl)
    mean_sq_of_mean = mean_a_x & mean_a_y                         # E[a]^2
    mean_sq = _flip(ks[13], mean_sq, bitflip_rate, fault_model)
    mean_sq_of_mean = _flip(ks[14], mean_sq_of_mean, bitflip_rate,
                            fault_model)

    # Absolute difference needs correlated operands: regenerate correlated
    # streams at the decoded values (StoB->BtoS regeneration, DESIGN.md §7).
    v1 = bs.to_value(mean_sq, bl)
    v2 = bs.to_value(mean_sq_of_mean, bl)
    c1, c2 = bs.generate_correlated(ks[5], [v1, v2], bl)
    var_stream = c1 ^ c2                                          # |v1 - v2|

    # sqrt: value-faithful sampling (DESIGN.md §7(e)).
    sigma_v = jnp.sqrt(bs.to_value(var_stream, bl))
    sigma_stream = bs.generate(ks[6], sigma_v, bl)
    ones = bs.generate(ks[7], jnp.ones_like(sigma_v), bl)
    half = bs.generate(ks[8], jnp.full_like(sigma_v, 0.5), bl)
    scaled = sc_ops.scaled_add(sigma_stream, ones, half)          # (sigma+1)/2
    mean_a_z = mean_select_stream(ks[9], A1, bl)
    t_stream = mean_a_z & scaled
    t_stream = _flip(ks[15], t_stream, bitflip_rate, fault_model)
    return bs.to_value(t_stream, bl)


def lit_binary8(rng: np.random.Generator, a: np.ndarray,
                bitflip_rate: float = 0.0) -> np.ndarray:
    """8-bit fixed-point binary-IMC accuracy path with bitflip injection."""
    q = _flip8(rng, _q8(a), bitflip_rate)
    sq = _flip8(rng, (q * q) >> 8, bitflip_rate, bits=8)
    m2 = _flip8(rng, sq.mean(-1).astype(np.int64), bitflip_rate)
    m = _flip8(rng, q.mean(-1).astype(np.int64), bitflip_rate)
    msq = _flip8(rng, (m * m) >> 8, bitflip_rate)
    var = _flip8(rng, np.abs(m2 - msq), bitflip_rate)
    sigma = _flip8(rng, np.sqrt(var / 255.0 * 255.0 * 255.0).astype(np.int64) % 256,
                   bitflip_rate)
    t = _flip8(rng, (m * ((sigma + 255) >> 1)) >> 8, bitflip_rate)
    return _dq8(t)


@dataclasses.dataclass(frozen=True)
class CostStage:
    netlist: Netlist
    n_instances: int         # independent circuit instances in this stage
    q_lanes: int             # SIMD lanes per instance per subarray pass


def lit_cost_stages() -> list[CostStage]:
    """Netlist stages for one window evaluation (cost path, Table 3)."""
    stages = [CostStage(circuits.sc_multiply(), 81, 1)]           # squares
    # Three mean trees (A x2 for the squared mean, squares x1), level by level.
    for _tree in range(3):
        k = 81
        while k > 1:
            pairs = k // 2
            stages.append(CostStage(circuits.sc_scaled_add(), pairs, 1))
            k = pairs + (k % 2)
    stages += [
        CostStage(circuits.sc_multiply(), 1, 1),                  # mean(A)^2
        CostStage(circuits.sc_abs_sub(), 1, 1),
        CostStage(circuits.sc_sqrt(), 1, 1),
        CostStage(circuits.sc_scaled_add(), 1, 1),                # (sigma+1)/2
        CostStage(circuits.sc_multiply(), 1, 1),                  # T
    ]
    return stages


# ================================ OL =============================================

def ol_exact(p: np.ndarray) -> np.ndarray:
    """Eq. (7): p has shape (..., 6) of conditional probabilities."""
    return np.prod(np.asarray(p), axis=-1)


def ol_stochastic(key: jax.Array, p: jax.Array, bl: int = 256,
                  bitflip_rate: float = 0.0, fault_model=None) -> jax.Array:
    fault_model = _app_fault_model(bitflip_rate, fault_model)
    ks = jax.random.split(key, 3)
    p = jnp.asarray(p, jnp.float32)
    streams = bs.generate(ks[0], p, bl)            # (..., 6, W) independent
    streams = _flip(ks[1], streams, bitflip_rate, fault_model)
    out = streams[..., 0, :]
    for i in range(1, p.shape[-1]):
        out = out & streams[..., i, :]
    out = _flip(ks[2], out, bitflip_rate, fault_model)
    return bs.to_value(out, bl)


def ol_binary8(rng: np.random.Generator, p: np.ndarray,
               bitflip_rate: float = 0.0) -> np.ndarray:
    q = _flip8(rng, _q8(p), bitflip_rate)
    out = q[..., 0]
    for i in range(1, p.shape[-1]):
        out = _flip8(rng, (out * q[..., i]) >> 8, bitflip_rate)
    return _dq8(out)


def ol_cost_stages() -> list[CostStage]:
    """Product of 6 factors: 5 multiplies in a balanced tree (3+1+1)."""
    return [
        CostStage(circuits.sc_multiply(), 3, 1),
        CostStage(circuits.sc_multiply(), 1, 1),
        CostStage(circuits.sc_multiply(), 1, 1),
    ]


# ================================ HDP ============================================

HDP_KEYS = ("p_bp", "p_cp", "p_e", "p_d", "p_ed", "p_end", "p_ned", "p_nend")


def hdp_exact(v: dict[str, np.ndarray]) -> np.ndarray:
    """Eq. (8)-(9)."""
    p_hd_ed = ((v["p_ed"] * v["p_d"] + v["p_end"] * (1 - v["p_d"])) * v["p_e"]
               + (v["p_ned"] * v["p_d"] + v["p_nend"] * (1 - v["p_d"])) * (1 - v["p_e"]))
    num = v["p_bp"] * v["p_cp"] * p_hd_ed
    den = num + (1 - v["p_bp"]) * (1 - v["p_cp"]) * (1 - p_hd_ed)
    return num / den


def hdp_stochastic(key: jax.Array, v: dict[str, jax.Array], bl: int = 256,
                   bitflip_rate: float = 0.0, fault_model=None) -> jax.Array:
    fault_model = _app_fault_model(bitflip_rate, fault_model)
    ks = jax.random.split(key, 12)
    g = {k: bs.generate(ks[i], jnp.asarray(v[k], jnp.float32), bl)
         for i, k in enumerate(HDP_KEYS)}
    if faults.injecting(bitflip_rate, fault_model):
        fk = jax.random.split(ks[8], len(HDP_KEYS))
        g = {k: _flip(fk[i], s, bitflip_rate, fault_model)
             for i, (k, s) in enumerate(g.items())}
    # Eq. (9): nested MUXes with variable selects P(D), P(E).
    inner_e = sc_ops.scaled_add(g["p_ed"], g["p_end"], g["p_d"])
    inner_ne = sc_ops.scaled_add(g["p_ned"], g["p_nend"], g["p_d"])
    # Independent select stream instances for the outer MUX:
    p_e2 = bs.generate(ks[9], jnp.asarray(v["p_e"], jnp.float32), bl)
    p_hd_ed = sc_ops.scaled_add(inner_e, inner_ne, p_e2)
    p_hd_ed = _flip(ks[10], p_hd_ed, bitflip_rate, fault_model)
    # Eq. (8): numerator / (numerator + complement term) via the JK divider.
    num = g["p_bp"] & g["p_cp"] & p_hd_ed
    # Complement streams: NOT of independent regenerations (independence for
    # the product), matching Fig. 9(c)'s separately-generated inputs.
    nbp = ~bs.generate(ks[11], jnp.asarray(v["p_bp"], jnp.float32), bl)
    ncp = ~bs.generate(jax.random.fold_in(ks[0], 7), jnp.asarray(v["p_cp"], jnp.float32), bl)
    nhd = ~bs.generate(jax.random.fold_in(ks[1], 7),
                       bs.to_value(p_hd_ed, bl), bl)
    comp = nbp & ncp & nhd
    q = sc_ops.scaled_div(num, comp, bl, warmup=True)
    return bs.to_value(q, bl)


def hdp_binary8(rng: np.random.Generator, v: dict[str, np.ndarray],
                bitflip_rate: float = 0.0) -> np.ndarray:
    q = {k: _flip8(rng, _q8(v[k]), bitflip_rate) for k in HDP_KEYS}
    mul = lambda x, y: _flip8(rng, (x * y) >> 8, bitflip_rate)
    inv = lambda x: 255 - x
    inner_e = _flip8(rng, mul(q["p_ed"], q["p_d"]) + mul(q["p_end"], inv(q["p_d"])),
                     bitflip_rate)
    inner_ne = _flip8(rng, mul(q["p_ned"], q["p_d"]) + mul(q["p_nend"], inv(q["p_d"])),
                      bitflip_rate)
    p_hd = _flip8(rng, mul(inner_e, q["p_e"]) + mul(inner_ne, inv(q["p_e"])),
                  bitflip_rate)
    num = mul(mul(q["p_bp"], q["p_cp"]), p_hd)
    den = num + mul(mul(inv(q["p_bp"]), inv(q["p_cp"])), inv(p_hd))
    out = _flip8(rng, np.where(den > 0, (num * 255) // np.maximum(den, 1), 0),
                 bitflip_rate)
    return _dq8(out)


def hdp_cost_stages() -> list[CostStage]:
    return [
        CostStage(circuits.sc_scaled_add_var(), 2, 1),   # Eq. (9) inner MUXes
        CostStage(circuits.sc_scaled_add_var(), 1, 1),   # Eq. (9) outer MUX
        CostStage(circuits.sc_multiply(), 2, 1),         # numerator products
        CostStage(circuits.sc_multiply(), 2, 1),         # complement products
        CostStage(circuits.sc_scaled_div(), 1, 1),       # Eq. (8) divider
    ]


# ================================ KDE ============================================

KDE_N = 8      # history depth (paper does not print N; documented choice)
KDE_C = 4.0    # exp(-4 |x_t - x_i|), realized as five e^{-0.8 d} stages


def kde_exact(x_t: np.ndarray, hist: np.ndarray) -> np.ndarray:
    """Eq. (10): hist shape (..., N)."""
    d = np.abs(np.asarray(x_t)[..., None] - np.asarray(hist))
    return np.exp(-KDE_C * d).mean(-1)


def kde_stochastic(key: jax.Array, x_t: jax.Array, hist: jax.Array,
                   bl: int = 256, bitflip_rate: float = 0.0,
                   fault_model=None) -> jax.Array:
    """Five independent e^{-0.8 d} factors per history term, ANDed (paper:
    "five stages of e^{-4/5 x} multiplication"); unbiasedness needs fresh
    correlated (x_t, x_i) pairs and fresh Maclaurin input copies per factor."""
    fault_model = _app_fault_model(bitflip_rate, fault_model)
    x_t = jnp.asarray(x_t, jnp.float32)
    hist = jnp.asarray(hist, jnp.float32)
    n_hist = hist.shape[-1]
    n_factors, order = 5, 5
    keys = jax.random.split(key, n_hist * n_factors * (1 + order) + 2)
    ki = 0
    terms = []
    for i in range(n_hist):
        factor = None
        for f in range(n_factors):
            xa, xb = bs.generate_correlated(keys[ki], [x_t, hist[..., i]], bl)
            ki += 1
            d = xa ^ xb                                   # |x_t - x_i|
            d = _flip(jax.random.fold_in(keys[-1], ki), d, bitflip_rate,
                      fault_model)
            copies = []
            for _ in range(order):
                # independent copies of the diff for the Maclaurin ladder
                ca, cb = bs.generate_correlated(keys[ki], [x_t, hist[..., i]], bl)
                ki += 1
                copies.append(ca ^ cb)
            e = sc_ops.exp_neg(copies, KDE_C / n_factors,
                               jax.random.fold_in(keys[ki - 1], 3), bl)
            factor = e if factor is None else (factor & e)
        terms.append(factor)
    stacked = jnp.stack(terms, axis=-2)                   # (..., N, W)
    out = mean_select_stream(keys[-2], stacked, bl)
    out = _flip(keys[-1], out, bitflip_rate, fault_model)
    return bs.to_value(out, bl)


def kde_binary8(rng: np.random.Generator, x_t: np.ndarray, hist: np.ndarray,
                bitflip_rate: float = 0.0) -> np.ndarray:
    qx = _flip8(rng, _q8(x_t), bitflip_rate)
    qh = _flip8(rng, _q8(hist), bitflip_rate)
    d = _flip8(rng, np.abs(qx[..., None] - qh), bitflip_rate)
    # e^{-0.8 u} Maclaurin (5th order) in Q8, then 5 multiplies.
    u = d.astype(np.float64) / 255.0
    e1 = np.zeros_like(u)
    acc = np.ones_like(u)
    fact = 1.0
    for k in range(6):
        if k > 0:
            fact *= k
        e1 = e1 + ((-0.8 * u) ** k) / fact
    e1 = _flip8(rng, _q8(np.clip(e1, 0, 1)), bitflip_rate)
    out = e1
    for _ in range(4):
        out = _flip8(rng, (out * e1) >> 8, bitflip_rate)
    pdf = _flip8(rng, out.mean(-1).astype(np.int64), bitflip_rate)
    return _dq8(pdf)


def kde_cost_stages() -> list[CostStage]:
    stages = []
    n_factors = 5
    # Per history term: 5 factors x (1 abs-sub + 5 Maclaurin copies' abs-subs
    # + exp ladder) + 4 product ANDs; instances batched across the N terms.
    stages.append(CostStage(circuits.sc_abs_sub(), KDE_N * n_factors * 5, 1))
    stages.append(CostStage(circuits.sc_exp(KDE_C / n_factors), KDE_N * n_factors, 1))
    stages.append(CostStage(circuits.sc_multiply(), KDE_N * (n_factors - 1), 1))
    # Mean tree over N terms.
    k = KDE_N
    while k > 1:
        pairs = k // 2
        stages.append(CostStage(circuits.sc_scaled_add(), pairs, 1))
        k = pairs + (k % 2)
    return stages


# ================== composed per-bit netlist execution ===========================

def appnet_inputs(app: str, *, a=None, p=None, v=None, x_t=None,
                  hist=None) -> dict:
    """Map app-level inputs to the PI value keys of ``appnet.APP_NETLISTS``.

    Shapes (trailing dims consumed, leading dims broadcast as batch):
      lit: ``a`` (..., 81) window pixels      ol: ``p`` (..., 16, 6) pixel probs
      hdp: ``v`` dict over HDP_KEYS           kde: ``x_t`` (...), ``hist`` (..., N)

    Values stay *host* float32 (numpy): per-PI splats of an 81-pixel window
    would otherwise dispatch one device op per element, and host scalars are
    what the executor's bank path packs into a single per-slot vector at the
    jit boundary.  An input already on device is kept there and splats via
    device slices.
    """
    def _host(x):
        return x if isinstance(x, jax.Array) else np.asarray(x, np.float32)

    if app == "lit":
        a = _host(a)
        return {f"a{i}": a[..., i] for i in range(a.shape[-1])}
    if app == "ol":
        p = _host(p)
        return {f"p{r}_{j}": p[..., r, j]
                for r in range(p.shape[-2]) for j in range(p.shape[-1])}
    if app == "hdp":
        return {k: _host(v[k]) for k in HDP_KEYS}
    if app == "kde":
        hist = _host(hist)
        vals = {f"h{i}": hist[..., i] for i in range(hist.shape[-1])}
        vals["x_t"] = _host(x_t)
        return vals
    raise KeyError(app)


def appnet_stochastic(app: str, key: jax.Array, bl: int = 256,
                      backend: str | None = None, bitflip_rate: float = 0.0,
                      flip_key: jax.Array | None = None,
                      net: Netlist | None = None, fault_model=None,
                      **inputs) -> dict[str, jax.Array]:
    """Execute the composed per-bit application netlist end to end.

    This is the cost-path netlist (``appnet.APP_NETLISTS`` — the circuit
    Algorithm 1 actually schedules) *run* through the executor's compiled
    plan: every gate level becomes one fused bit-parallel pass, sequential
    state (HDP's divider) scans over words.  Returns decoded output values.

    Pass ``net`` to reuse a built netlist across calls (appnet node names are
    uniquified per build, so reuse keeps the plan/jit caches warm).
    """
    from .appnet import APP_NETLISTS
    if net is None:
        net = APP_NETLISTS[app]()
    values = appnet_inputs(app, **inputs)
    return executor.execute_value(net, values, key, bl,
                                  bitflip_rate=bitflip_rate, flip_key=flip_key,
                                  backend=backend, fault_model=fault_model)


def appnet_stochastic_many(requests, key, bl: int = 256,
                           backend: str | None = None,
                           bitflip_rate: float = 0.0, flip_keys=None,
                           nets: "list[Netlist] | None" = None) -> list:
    """Serve N concurrent app evaluations as ONE fused bank-level plan.

    ``requests``: sequence of ``(app, inputs)`` pairs — ``app`` one of
    ``APPS``, ``inputs`` the keyword dict ``appnet_inputs`` expects.  The
    member netlists (heterogeneous — e.g. 4 LIT windows + 2 OL tiles + an HDP
    query) merge into one bank plan (``core/plan.compile_bank_plan``): every
    gate level is type-batched *across* requests and the whole bank runs as a
    single jit dispatch instead of one ``execute`` per request — the paper's
    Fig. 8 bank-level SIMD, and the serving path for many concurrent app
    requests per device.  ``key`` may be one key (split N ways) or N keys;
    results are bit-identical to per-request ``appnet_stochastic`` calls with
    the same per-member keys.  Pass ``nets`` to reuse built netlists across
    calls (keeps the bank-plan/jit caches warm).  Returns one decoded-output
    dict per request, in request order.
    """
    from .appnet import APP_NETLISTS
    if nets is None:
        nets = [APP_NETLISTS[app]() for app, _ in requests]
    values = [appnet_inputs(app, **inp) for app, inp in requests]
    n = len(nets)
    keys = executor._normalize_keys(key, n)
    if bitflip_rate > 0.0:
        flip_keys = executor._normalize_keys(flip_keys, n, "flip_keys")
    shared = executor.ExecOptions(backend=backend, bitstream_length=bl,
                                  bitflip_rate=bitflip_rate, decode=True)
    return executor.run(
        [executor.ExecRequest(net, vals, keys[i],
                              dataclasses.replace(
                                  shared, flip_key=flip_keys[i])
                              if bitflip_rate > 0.0 else shared)
         for i, (net, vals) in enumerate(zip(nets, values))])


def cost_stage_netlists(app: str, max_instances: int | None = None) -> list:
    """Expand an app's ``cost_stages()`` into per-instance bank members.

    Every stage instance becomes one member (repeating the stage's netlist
    object — structure-equal members intern to one compiled plan), so
    ``compile_bank_plan(cost_stage_netlists(app))`` is the bank-level plan of
    the whole Table-3 application: all same-type gates of a level across all
    stage instances fire in one pass (``arch.evaluate_bank_plan`` maps the
    pass counts onto the [n, m] bank cycle model).
    """
    stages_fn = {"lit": lit_cost_stages, "ol": ol_cost_stages,
                 "hdp": hdp_cost_stages, "kde": kde_cost_stages}[app]
    nets = []
    for st in stages_fn():
        k = st.n_instances if max_instances is None \
            else min(st.n_instances, max_instances)
        nets.extend([st.netlist] * k)
    return nets


# ============================== registry =========================================

APPS = ("lit", "ol", "hdp", "kde")
