"""PI stream generation: the BtoS front of every execution path.

Bottom layer of the executor stack (``streams`` <- ``dispatch`` <-
``exec_api`` <- the ``executor`` facade): given a plan's PrimaryInputs and
their values, produce the packed uint32 stochastic streams the logic passes
consume.  Two key disciplines (``key_mode``), honored identically by every
backend so reference and compiled stay bit-for-bit interchangeable:

  * ``"batched"`` (default): ONE fused threshold+pack pass generates all
    streams from the plan's stream table (``bs.generate_batch``) —
    correlation groups share a key lane, singles get one lane each.  Bank
    execution extends this bank-wide: every member's stream-table rows stack
    into one threshold tensor per distinct batch shape
    (``_gen_bank_streams``), the paper's bulk BtoS pass.
  * ``"legacy"``: one PRNG split per correlation group / single PI, one
    ``bs.generate*`` dispatch each — bit-exactly the pre-batching behavior,
    kept for reproducibility pins.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitstream as bs
from . import obs
from .gates import PIKind
from .plan import BankPlan, StreamTable, build_stream_table

#: Default backend for execute()/execute_value()/execute_binary().
DEFAULT_BACKEND = "compiled"

_BACKENDS = ("compiled", "compiled_pallas", "compiled_megakernel", "reference")

#: Default key discipline for PI-stream generation (see ``_gen_pi_streams``).
DEFAULT_KEY_MODE = "batched"

_KEY_MODES = ("batched", "legacy")


def _pi_shape(values: dict[str, jax.Array],
              batch_shape: tuple[int, ...] | None) -> tuple[int, ...]:
    """Common broadcast shape of the PI streams.

    Derived from the supplied values AND the caller-declared ``batch_shape``
    — so a netlist whose stream PIs are all const-valued (empty ``values``)
    can still generate batched streams for batched downstream use instead of
    silently falling back to scalar shape ``()``.
    """
    shapes = [jnp.shape(jnp.asarray(v)) for v in values.values()]
    if batch_shape is not None:
        shapes.append(tuple(batch_shape))
    return jnp.broadcast_shapes(*shapes) if shapes else ()


def _stack_table_values(table: StreamTable, values: dict[str, jax.Array],
                        shape: tuple[int, ...]) -> jax.Array:
    """Stack the stream table's row values into one (n_rows, *shape) tensor."""
    rows = []
    for vk, const in zip(table.value_keys, table.const_values):
        v = values[vk] if vk is not None else const
        rows.append(jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape))
    return jnp.stack(rows)


def _gen_pi_streams(pis, values: dict[str, jax.Array], key: jax.Array,
                    bitstream_length: int, key_mode: str = DEFAULT_KEY_MODE,
                    batch_shape: tuple[int, ...] | None = None,
                    use_pallas: bool = False,
                    table: StreamTable | None = None,
                    word_window: tuple | None = None) -> dict[str, jax.Array]:
    """Generate packed streams for every PI, honoring correlation groups and
    independent-copy indices.  ``pis`` is any sequence of PrimaryInput.

    ``key_mode`` selects the key discipline (see module docstring).  The two
    modes differ bit-wise but are statistically equivalent (same Bernoulli
    marginals, same correlation structure).

    ``word_window=(start, n)`` (batched mode only) generates just words
    ``[start, start + n)`` of each stream — bit-identical to slicing the full
    streams, because the counter-based RNG indexes absolute bit positions.
    The chunked streaming executor regenerates each chunk's PI words this way
    instead of holding full-length streams live.  The legacy threefry
    discipline draws all words in one monolithic call and cannot window.
    """
    # Under the compiled backends this body runs at jit-trace time, so the
    # span measures lowering cost (a cache-miss-only host cost), not
    # steady-state runtime; on the reference backend it runs eagerly.
    with obs.span("streams.gen_pi", key_mode=key_mode, trace_time=True):
        return _gen_pi_streams_impl(pis, values, key, bitstream_length,
                                    key_mode, batch_shape, use_pallas, table,
                                    word_window)


def _gen_pi_streams_impl(pis, values, key, bitstream_length, key_mode,
                         batch_shape, use_pallas, table, word_window):
    shape = _pi_shape(values, batch_shape)
    if key_mode == "batched":
        if table is None:
            table = build_stream_table(pis)
        if not table.names:
            return {}
        ps = _stack_table_values(table, values, shape)
        words = bs.generate_batch(key, ps, bitstream_length,
                                  lanes=jnp.asarray(table.lanes, jnp.uint32),
                                  use_pallas=use_pallas,
                                  word_window=word_window)
        return {name: words[i] for i, name in enumerate(table.names)}
    if word_window is not None:
        raise ValueError("word_window requires key_mode='batched': legacy "
                         "threefry streams are not word-addressable")
    if key_mode != "legacy":
        raise ValueError(f"unknown key_mode {key_mode!r}; "
                         f"expected one of {_KEY_MODES}")

    streams: dict[str, jax.Array] = {}

    # Correlated groups share underlying uniforms.
    groups: dict[str, list] = {}
    singles: list = []
    for pi in pis:
        if pi.kind == PIKind.STATE:
            continue
        if pi.corr_group is not None:
            groups.setdefault(pi.corr_group, []).append(pi)
        else:
            singles.append(pi)

    n_keys = len(groups) + len(singles)
    keys = jax.random.split(key, max(n_keys, 1))
    ki = 0
    for gname, gpis in sorted(groups.items()):
        vals = []
        for pi in gpis:
            v = values[pi.value_key] if pi.value_key else pi.const_value
            vals.append(jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape))
        outs = bs.generate_correlated(keys[ki], vals, bitstream_length)
        ki += 1
        for pi, o in zip(gpis, outs):
            streams[pi.name] = o
    for pi in singles:
        v = values[pi.value_key] if pi.value_key is not None else pi.const_value
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape)
        streams[pi.name] = bs.generate(keys[ki], v, bitstream_length)
        ki += 1
    return streams


def _gen_bank_streams(bank: BankPlan, values_seq, keys, bitstream_length: int,
                      key_mode: str, use_pallas: bool,
                      batch_shapes, active=None) -> list[dict[str, jax.Array]]:
    """Per-member PI streams for a whole bank (list indexed by member).

    Batched key mode is the paper's bulk BtoS pass bank-wide: every member's
    stream-table rows stack into ONE threshold tensor per distinct batch
    shape and generate in one fused SNG pass — instead of one dispatch per
    PI per member.  Each row's randomness is keyed by (member key, fixed
    key-lane index), independent of the stacking, so a merged run stays
    bit-identical to a loop of per-member ``execute`` calls in the same mode.

    ``active`` (None = all) masks padded template slots: inactive members
    contribute NO rows to the fused SNG pass — their PI streams are zero
    words (value-0.0 constants, nearly free), just enough to keep the merged
    logic passes well-formed.  Active members' streams are untouched by the
    masking, so padded execution stays bit-identical per bound slot.
    """
    # Like _gen_pi_streams: under jit this span measures trace/lowering
    # cost (cache misses only), not per-call runtime.
    with obs.span("streams.gen_bank", bank=bank.name, key_mode=key_mode,
                  trace_time=True):
        return _gen_bank_streams_impl(bank, values_seq, keys,
                                      bitstream_length, key_mode, use_pallas,
                                      batch_shapes, active)


def _gen_bank_streams_impl(bank, values_seq, keys, bitstream_length,
                           key_mode, use_pallas, batch_shapes, active):
    n = bank.n_members
    streams: list[dict[str, jax.Array]] = [{} for _ in range(n)]
    w = bs.n_words(bitstream_length)

    def masked(i: int) -> bool:
        return active is not None and not active[i]

    def zero_fill(i: int) -> dict[str, jax.Array]:
        return {nm: jnp.zeros((w,), jnp.uint32)
                for nm in bank.members[i].stream_table.names}

    if key_mode != "batched":
        for i, plan in enumerate(bank.members):
            if masked(i):
                streams[i] = zero_fill(i)
                continue
            streams[i] = _gen_pi_streams(
                plan.pis, values_seq[i], keys[i], bitstream_length,
                key_mode=key_mode,
                batch_shape=batch_shapes[i] if batch_shapes else None)
        return streams

    # Group member tables by broadcast shape; one fused SNG pass per shape.
    buckets: dict[tuple[int, ...], list[tuple[int, jax.Array, jax.Array]]] = {}
    for i, plan in enumerate(bank.members):
        table = plan.stream_table
        if not table.names:
            continue
        if masked(i):
            streams[i] = zero_fill(i)
            continue
        shape = _pi_shape(values_seq[i],
                          batch_shapes[i] if batch_shapes else None)
        ps = _stack_table_values(table, values_seq[i], shape)
        seeds = bs.stream_row_seeds(keys[i],
                                    jnp.asarray(table.lanes, jnp.uint32))
        buckets.setdefault(shape, []).append((i, ps, seeds))
    for entries in buckets.values():
        ps = jnp.concatenate([e[1] for e in entries])
        seeds = jnp.concatenate([e[2] for e in entries])
        words = bs.generate_batch_seeded(seeds, ps, bitstream_length,
                                         use_pallas=use_pallas)
        off = 0
        for i, ps_i, _ in entries:
            names = bank.members[i].stream_table.names
            for k, nm in enumerate(names):
                streams[i][nm] = words[off + k]
            off += len(names)
    return streams
