"""MTJ device model for STT-MRAM stochastic switching (paper Eqs. (1)-(2)).

The MTJ switching probability under a voltage pulse of amplitude ``V_p`` and
duration ``t_p`` follows the thermally-activated model

    P_sw = 1 - exp(-t_p / tau)                      (1)
    tau  = tau_0 * exp(Delta * (1 - V_p / V_c0))    (2)

Constants are calibrated to the paper's Fig. 3 anchor point: a 310 mV / 4 ns
pulse switches with probability ~0.7.  Table 1 provides the cell parameters
(R_P = 12.7 kOhm, R_AP = 76.3 kOhm, I_c = 0.79 uA, t_switch = 1 ns).

The Binary-to-Stochastic (BtoS) LUT of the Stoch-IMC architecture maps a
binary input value to the (V_p, t_p) pulse pair that yields the desired
switching probability at minimum write energy E = V_p^2 * t_p / R_MTJ
(energy-optimal pulse selection per Section 5-1).
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import numpy as np

# --- Table 1 physical parameters -------------------------------------------------
R_P_OHM = 12.7e3        # parallel (logic '0') resistance
R_AP_OHM = 76.3e3       # anti-parallel (logic '1') resistance
I_C_A = 0.79e-6         # critical switching current
T_SWITCH_S = 1e-9       # deterministic switching time
TMR = 5.0               # tunneling magnetoresistance ratio (500%)

# --- Eq. (1)-(2) constants, calibrated to Fig. 3 (310mV, 4ns -> P_sw ~ 0.7) -------
DELTA = 40.0            # thermal stability factor
V_C0_V = 0.32           # critical switching voltage at 0K
TAU_0_S = 1e-9          # thermal attempt time

# Pulse-duration sweep range shown in Fig. 3.
T_P_MIN_S = 3e-9
T_P_MAX_S = 10e-9


def tau(v_p: float) -> float:
    """Thermal activation time constant, Eq. (2)."""
    return TAU_0_S * math.exp(DELTA * (1.0 - v_p / V_C0_V))


def switching_probability(v_p: float, t_p: float) -> float:
    """P_sw(V_p, t_p), Eq. (1)."""
    return 1.0 - math.exp(-t_p / tau(v_p))


def pulse_voltage_for(p_sw: float, t_p: float) -> float:
    """Invert Eqs. (1)-(2): the V_p achieving ``p_sw`` for a given ``t_p``."""
    p_sw = min(max(p_sw, 1e-12), 1.0 - 1e-12)
    tau_needed = -t_p / math.log1p(-p_sw)
    return V_C0_V * (1.0 - math.log(tau_needed / TAU_0_S) / DELTA)


# Calibration of the analytic pulse energy to the paper's SPICE scale.
# The raw V^2 t / R estimate (~tens of fJ for a 0.3 V / 4-10 ns pulse across
# 12.7 kOhm) sits ~3 orders above the paper's SPICE-extracted per-op energies
# (PRESET = 26.1 aJ -- and a preset *is* a deterministic write).  SPICE
# accounts for the actual switching-current path and pulse shaping that the
# analytic formula ignores, so we keep the formula's *relative* shape over
# (V_p, t_p) and normalize its absolute scale so a deterministic write
# (P_sw = 0.999) costs the paper's preset energy.
_PRESET_E_J = 26.1e-18


def _raw_energy(v_p: float, t_p: float, r_mtj: float = R_P_OHM) -> float:
    return v_p * v_p * t_p / r_mtj


def _write_cal() -> float:
    t_ref = T_P_MAX_S
    v_ref = pulse_voltage_for(0.999, t_ref)
    return _PRESET_E_J / _raw_energy(v_ref, t_ref)


def write_energy(v_p: float, t_p: float, r_mtj: float = R_P_OHM) -> float:
    """Joule energy of one stochastic write pulse: E = V^2 t / R (Section 5-1),
    normalized to the paper's SPICE energy scale (see _write_cal)."""
    return _raw_energy(v_p, t_p, r_mtj) * _write_cal()


@dataclasses.dataclass(frozen=True)
class PulseSpec:
    """One BtoS LUT entry: the pulse realizing probability ``p_sw``."""

    p_sw: float
    v_p: float
    t_p: float
    energy_j: float


def optimal_pulse(p_sw: float, n_grid: int = 64) -> PulseSpec:
    """Energy-optimal (V_p, t_p) pair for the target probability.

    Longer pulses admit lower voltages; energy V^2 t / R trades quadratically
    against linearly, so we sweep t_p over the Fig. 3 range and keep the min.
    """
    if p_sw <= 0.0:
        return PulseSpec(0.0, 0.0, 0.0, 0.0)
    best = None
    for t_p in np.linspace(T_P_MIN_S, T_P_MAX_S, n_grid):
        v_p = pulse_voltage_for(p_sw, float(t_p))
        if v_p <= 0.0:
            continue
        e = write_energy(v_p, float(t_p))
        if best is None or e < best.energy_j:
            best = PulseSpec(p_sw, v_p, float(t_p), e)
    assert best is not None
    return best


@lru_cache(maxsize=8)
def btos_lut(resolution_bits: int = 8) -> tuple[PulseSpec, ...]:
    """The 2^resolution-entry BtoS memory (Section 4-3).

    Entry ``k`` holds the pulse pair that writes a preset-'0' cell to '1'
    with probability k / 2^resolution.  For 8-bit resolution this is the
    256-byte BtoS memory of Fig. 8.
    """
    n = 1 << resolution_bits
    return tuple(optimal_pulse(k / n) for k in range(n))


def sbg_energy(p_sw: float = 0.5) -> float:
    """Energy of one stochastic bit generation (E_SBG in Eq. (4))."""
    return optimal_pulse(p_sw).energy_j


def lut_size_bytes(resolution_bits: int = 8) -> int:
    """BtoS memory footprint: 2^resolution bytes (paper: 256 B at 8-bit)."""
    return 1 << resolution_bits
