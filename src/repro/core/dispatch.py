"""Execution dispatch: the jit boundary, value packing, and backends.

Middle layer of the executor stack (``streams`` <- ``dispatch`` <-
``exec_api`` <- the ``executor`` facade).  Owns everything that crosses the
host/XLA boundary:

  * the jitted whole-plan / whole-bank programs (``_execute_compiled``,
    ``_execute_bank``) and their static-argument discipline;
  * host-side argument normalization (keys, batch shapes, active masks) and
    the slot-packed value layout ``_pack_values_seq`` — host scalars collapse
    to one f32 vector per slot and host arrays to one stacked leaf per
    (slot, shape) group, so the jit boundary flattens a handful of leaves
    per slot instead of one per PI;
  * the gate-by-gate reference interpreter (``_execute_reference``), the
    oracle the compiled path is tested against.

Fault keying mirrors the reference interpreter exactly (whatever the
``key_mode``): one fkey per sorted PI stream, then one per gate id
(combinational) / per sorted output (sequential).
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import bitstream as bs
from . import faults as _faults
from . import obs
from .faults import FaultModel
from .gates import Netlist
from .plan import BankPlan, ExecutionPlan, compile_bank_plan, compile_plan, member_prefix
from .streams import (_BACKENDS, _KEY_MODES, DEFAULT_BACKEND, DEFAULT_KEY_MODE,
                      _gen_bank_streams, _gen_pi_streams)

# ------------------------------ compiled backend ----------------------------------


@partial(jax.jit, static_argnames=("plan", "bitstream_length", "bitflip_rate",
                                   "use_pallas", "decode", "key_mode",
                                   "batch_shape", "fault_model", "word_chunk",
                                   "megakernel", "interpret"))
def _execute_compiled(plan: ExecutionPlan, values: dict[str, jax.Array],
                      key: jax.Array, flip_key, bitstream_length: int,
                      bitflip_rate: float, use_pallas: bool,
                      decode: bool = False,
                      key_mode: str = DEFAULT_KEY_MODE,
                      batch_shape: tuple[int, ...] | None = None,
                      fault_model: FaultModel | None = None,
                      word_chunk: int | None = None,
                      megakernel: bool = False,
                      interpret: bool | None = None) -> dict[str, jax.Array]:
    """Whole-netlist execution as one XLA program.

    Mirrors the reference interpreter's key discipline exactly (whatever the
    ``key_mode``): one fkey per sorted PI stream, then one per gate id
    (combinational) / per sorted output (sequential).  ``decode=True`` folds
    the StoB popcount decode into the same program (used by execute_value),
    leaving one dispatch per call.  In batched key mode the PI streams come
    from ONE fused SNG pass over the plan's stream table — generation, logic,
    fault injection and decode are all one XLA program either way.

    ``fault_model`` (static, pre-normalized) generalizes ``bitflip_rate``:
    its transient component consumes each injection point's raw fault key —
    the same split, the same key assignment — and its persistent/static
    masks stack on top (``core/faults.py``), so a transient-only model is
    bit-identical to the legacy rate path.  Static-only models (dead
    columns, explicit cell maps) need no ``flip_key``; a placeholder key
    feeds the (unconsumed) splits.

    ``word_chunk`` streams a combinational run ``word_chunk`` words at a
    time via ``lax.scan`` instead of materializing full-length node streams:
    peak live words drop from ``plan.naive_live * W`` to roughly
    ``plan.max_live * word_chunk``.  In batched key mode each chunk's PI
    words are *regenerated* in place (the counter-based SNG is
    word-addressable — see ``bs.generate_batch_seeded``); legacy mode
    generates once and slices, so only intermediate streams are bounded.
    Exact either way: chunks of an i.i.d. bitstream are independent, every
    op is word-local, and reassembly is a pure transpose.
    ``megakernel``/``interpret`` select the whole-plan Pallas kernel for the
    logic passes (``kernels/plan_megakernel``).
    """
    from ..kernels import netlist_exec

    inject = _faults.injecting(bitflip_rate, fault_model)
    if word_chunk is not None:
        if plan.is_sequential:
            raise ValueError(
                "word_chunk streams combinational plans only: a sequential "
                "plan's state recurrence already scans over words "
                "(kernels/netlist_exec.run_sequential) and cannot be "
                "re-chunked; drop word_chunk for this netlist")
        if inject:
            raise ValueError(
                "word_chunk cannot combine with fault injection: "
                "stuck/dead masks index absolute stream positions")
        w = bs.n_words(bitstream_length)
        if word_chunk <= 0 or w % word_chunk != 0:
            raise ValueError(
                f"word_chunk={word_chunk} must be positive and divide the "
                f"stream length in words ({w} for BL={bitstream_length})")
        if word_chunk != w:
            return _execute_chunked(plan, values, key, bitstream_length,
                                    use_pallas, decode, key_mode, batch_shape,
                                    word_chunk, megakernel, interpret)

    streams = _gen_pi_streams(plan.pis, values, key, bitstream_length,
                              key_mode=key_mode, batch_shape=batch_shape,
                              use_pallas=use_pallas, table=plan.stream_table)

    gate_fkeys = None
    if inject:
        fk = flip_key if flip_key is not None else jax.random.key(0)
        fkeys = jax.random.split(fk, len(streams) + plan.n_gates)
        for i, name in enumerate(sorted(streams)):
            streams[name] = _faults.apply_faults(fkeys[i], streams[name],
                                                 bitflip_rate, fault_model)
        gate_fkeys = fkeys[len(streams):]

    if not plan.is_sequential:
        env = dict(streams)
        netlist_exec.run_combinational(plan, env, gate_fkeys=gate_fkeys,
                                       bitflip_rate=bitflip_rate,
                                       fault_model=fault_model,
                                       use_pallas=use_pallas,
                                       megakernel=megakernel,
                                       interpret=interpret)
        packed_outs = {o: env[o] for o in plan.outputs}
    else:
        packed_outs = netlist_exec.run_sequential(
            plan, streams, use_pallas=use_pallas,
            n_words=bs.n_words(bitstream_length),
            batch_shape=batch_shape,
            megakernel=megakernel, interpret=interpret)
        if gate_fkeys is not None:
            for i, o in enumerate(sorted(packed_outs)):
                packed_outs[o] = _faults.apply_faults(gate_fkeys[i],
                                                      packed_outs[o],
                                                      bitflip_rate, fault_model)
    if decode:
        return {o: bs.to_value(w, bitstream_length)
                for o, w in packed_outs.items()}
    return packed_outs


def _execute_chunked(plan: ExecutionPlan, values, key, bitstream_length: int,
                     use_pallas: bool, decode: bool, key_mode: str,
                     batch_shape, word_chunk: int, megakernel: bool,
                     interpret: bool | None) -> dict[str, jax.Array]:
    """Word-tiled streaming execution of a combinational plan.

    One ``lax.scan`` over ``W / word_chunk`` chunks; each step holds at most
    ``plan.max_live`` streams of ``word_chunk`` words.  Batched key mode
    regenerates each chunk's PI words by absolute position
    (``word_window``); legacy threefry streams are not word-addressable, so
    that mode pre-generates once and the scan body slices (the live-words
    bound then covers intermediates only).  Chunk outputs stack on a leading
    axis and reassemble by a transpose — bit-identical to the one-shot run.
    """
    from ..kernels import netlist_exec

    w = bs.n_words(bitstream_length)
    n_chunks = w // word_chunk
    full = None
    if key_mode != "batched":
        full = _gen_pi_streams(plan.pis, values, key, bitstream_length,
                               key_mode=key_mode, batch_shape=batch_shape,
                               use_pallas=use_pallas, table=plan.stream_table)

    def body(carry, ci):
        if full is None:
            streams = _gen_pi_streams(
                plan.pis, values, key, bitstream_length, key_mode=key_mode,
                batch_shape=batch_shape, use_pallas=use_pallas,
                table=plan.stream_table,
                word_window=(ci * jnp.uint32(word_chunk), word_chunk))
        else:
            streams = {nm: jax.lax.dynamic_slice_in_dim(
                           v, ci * jnp.uint32(word_chunk), word_chunk, axis=-1)
                       for nm, v in full.items()}
        env = dict(streams)
        netlist_exec.run_combinational(plan, env, use_pallas=use_pallas,
                                       megakernel=megakernel,
                                       interpret=interpret)
        return carry, tuple(env[o] for o in plan.outputs)

    _, ys = jax.lax.scan(body, 0, jnp.arange(n_chunks, dtype=jnp.uint32))
    packed_outs = {}
    for o, y in zip(plan.outputs, ys):      # y: (n_chunks, *batch, word_chunk)
        y = jnp.moveaxis(y, 0, -2)
        packed_outs[o] = y.reshape(y.shape[:-2] + (w,))
    if decode:
        return {o: bs.to_value(v, bitstream_length)
                for o, v in packed_outs.items()}
    return packed_outs


def _binary_env(pis, operand_bits: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """PI env for a binary netlist: supplied operands + const-PI fills."""
    env: dict[str, jax.Array] = {}
    shape = next(iter(operand_bits.values())).shape
    for pi in pis:
        if pi.name in operand_bits:
            env[pi.name] = operand_bits[pi.name]
        elif pi.const_value is not None:
            c = float(pi.const_value)
            if c == 0.0:
                fill = jnp.uint32(0)
            elif c == 1.0:
                fill = jnp.uint32(0xFFFFFFFF)
            else:
                # A binary constant cell holds one bit; flooring 0 < c < 1 to
                # an all-zeros word would silently miscompute.
                raise ValueError(
                    f"binary PI {pi.name}: const_value must be 0.0 or 1.0, "
                    f"got {pi.const_value}")
            env[pi.name] = jnp.full(shape, fill)
        else:
            raise KeyError(f"missing binary operand {pi.name}")
    return env


@partial(jax.jit, static_argnames=("plan", "use_pallas"))
def _execute_binary_compiled(plan: ExecutionPlan,
                             operand_bits: dict[str, jax.Array],
                             use_pallas: bool) -> dict[str, jax.Array]:
    from ..kernels import netlist_exec

    env = _binary_env(plan.pis, operand_bits)
    netlist_exec.run_combinational(plan, env, use_pallas=use_pallas)
    return {o: env[o] for o in plan.outputs}


def _plan_for(net: Netlist, bitflip_rate: float,
              fault_model: FaultModel | None = None) -> ExecutionPlan:
    # Per-gate fault injection must observe the 4-gate MUX intermediates, so
    # the fused plan is only valid for clean combinational runs; sequential
    # runs inject at PI/output streams only (like the reference) and may fuse.
    fuse = not _faults.injecting(bitflip_rate, fault_model) \
        or net.is_sequential
    return compile_plan(net, fuse_mux=fuse)


def _check_fault_args(bitflip_rate: float, fault_model, flip_key,
                      what: str = "flip_key") -> "FaultModel | None":
    """Normalize/validate the fault arguments shared by every entry point.

    Returns the normalized model (null models collapse to ``None`` so the
    clean path — and its jit cache entry — is taken).  ``bitflip_rate`` and
    ``fault_model`` are mutually exclusive: the model's ``flip_rate`` *is*
    the transient rate, and letting both stack would silently double-inject.
    """
    fault_model = _faults.normalize_fault_model(fault_model)
    if fault_model is not None and bitflip_rate > 0.0:
        raise ValueError(
            "pass bitflip_rate or fault_model, not both "
            "(FaultModel(flip_rate=...) subsumes bitflip_rate)")
    if bitflip_rate > 0.0 and flip_key is None:
        raise ValueError(f"bitflip_rate > 0 requires {what}")
    if fault_model is not None and fault_model.needs_keys and flip_key is None:
        raise ValueError(
            f"fault_model with random components requires {what}")
    return fault_model


def _check_modes(backend: str | None, key_mode: str | None) -> tuple[str, str]:
    backend = backend or DEFAULT_BACKEND
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {_BACKENDS}")
    key_mode = key_mode or DEFAULT_KEY_MODE
    if key_mode not in _KEY_MODES:
        raise ValueError(f"unknown key_mode {key_mode!r}; "
                         f"expected one of {_KEY_MODES}")
    return backend, key_mode


def _dispatch(net: Netlist, values, key, bitstream_length: int,
              bitflip_rate: float, flip_key, backend: str | None,
              decode: bool, key_mode: str | None = None,
              batch_shape: tuple[int, ...] | None = None,
              fault_model: FaultModel | None = None,
              word_chunk: int | None = None,
              interpret: bool | None = None) -> dict[str, jax.Array]:
    backend, key_mode = _check_modes(backend, key_mode)
    if batch_shape is not None:
        batch_shape = tuple(batch_shape)   # hashable for the jit static arg
    fault_model = _check_fault_args(bitflip_rate, fault_model, flip_key)
    if backend == "reference":
        if word_chunk is not None:
            raise ValueError("word_chunk requires a compiled backend; the "
                             "reference interpreter always materializes "
                             "full streams")
        outs = _execute_reference(net, values, key, bitstream_length,
                                  bitflip_rate, flip_key, key_mode=key_mode,
                                  batch_shape=batch_shape,
                                  fault_model=fault_model)
        if decode:
            outs = {k: bs.to_value(v, bitstream_length) for k, v in outs.items()}
        return outs
    plan = _plan_for(net, bitflip_rate, fault_model)
    values = {k: jnp.asarray(v, jnp.float32) for k, v in values.items()}
    with obs.span("exec.dispatch", plan=plan.name,
                  bitstream_length=bitstream_length):
        return _execute_compiled(plan, values, key, flip_key, bitstream_length,
                                 float(bitflip_rate),
                                 backend == "compiled_pallas", decode=decode,
                                 key_mode=key_mode, batch_shape=batch_shape,
                                 fault_model=fault_model,
                                 word_chunk=word_chunk,
                                 megakernel=backend == "compiled_megakernel",
                                 interpret=interpret)


def _dispatch_binary(net: Netlist, operand_bits: dict[str, jax.Array],
                     backend: str | None) -> dict[str, jax.Array]:
    backend = backend or DEFAULT_BACKEND
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {_BACKENDS}")
    if backend == "reference":
        env = _binary_env(net.pis, operand_bits)
        for g in net.gates:
            env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
        return {o: env[o] for o in net.outputs}
    plan = compile_plan(net, fuse_mux=True)
    return _execute_binary_compiled(plan, dict(operand_bits),
                                    backend == "compiled_pallas")


# ----------------------------- bank-level execution -------------------------------

def _restrict(x: jax.Array, batch: tuple[int, ...]) -> jax.Array:
    """Undo a broadcast: restrict ``x`` of shape (*common, W) to (*batch, W).

    Exact, not approximate: a merged member's nodes only ever combine
    elementwise with that member's own (broadcast) streams, so the restricted
    entries equal the member's native computation bit for bit.
    """
    want = len(batch) + 1
    if x.ndim == want and x.shape[:-1] == batch:
        return x
    x = x[(0,) * (x.ndim - want)]
    for ax, d in enumerate(batch):
        if d == 1 and x.shape[ax] != 1:
            x = jax.lax.slice_in_dim(x, 0, 1, axis=ax)
    return x


@partial(jax.jit, static_argnames=("bank", "bitstream_length", "key_mode",
                                   "use_pallas", "batch_shapes", "active"))
def _generate_bank_streams_jit(bank: BankPlan, values_seq, keys,
                               bitstream_length: int, key_mode: str,
                               use_pallas: bool, batch_shapes, active=None):
    return _gen_bank_streams(bank, values_seq, keys, bitstream_length,
                             key_mode, use_pallas, batch_shapes, active=active)


def generate_bank_streams(bank: BankPlan, values_seq, keys,
                          bitstream_length: int,
                          key_mode: str = DEFAULT_KEY_MODE,
                          use_pallas: bool = False, batch_shapes=None,
                          active=None):
    """Generate (only) every member's PI streams — no logic passes.

    The stream-generation phase of ``_execute_bank`` as its own jitted entry
    point, used by the benchmarks to split bank wall-clock into gen vs pass
    time.  Accepts the same calling convention as ``execute_many`` (``keys``
    may be one key, split N ways; ``batch_shapes`` entries may be any
    sequence; ``active`` masks padded template slots down to zero-word
    fills).  Returns one ``{pi_name: packed words}`` dict per member.
    """
    values_seq = tuple(values_seq)
    if len(values_seq) != bank.n_members:
        raise ValueError(f"values: got {len(values_seq)} for "
                         f"{bank.n_members} members")
    keys = _normalize_keys(keys, bank.n_members)
    batch_shapes = _normalize_batch_shapes(batch_shapes, bank.n_members,
                                           "members")
    active = _normalize_active(active, bank.n_members)
    with obs.span("exec.stream_gen", bank=bank.name,
                  bitstream_length=bitstream_length):
        return _generate_bank_streams_jit(bank, values_seq, keys,
                                          bitstream_length, key_mode,
                                          use_pallas, batch_shapes, active)


def _unpack_values_seq(values_seq, scalar_names):
    """Trace-time inverse of ``_pack_values_seq``: rebuild per-slot dicts.

    The unpack slices are free after fusion, and the jit boundary sees a
    handful of leaves per slot instead of one per PI.
    """
    packed_seq, grouped_seq, rest_seq = values_seq
    out = []
    for i, (snames, gspecs) in enumerate(scalar_names):
        vals = {nm: packed_seq[i][j] for j, nm in enumerate(snames)}
        for (_, gnames), arr in zip(gspecs, grouped_seq[i]):
            for j, nm in enumerate(gnames):
                vals[nm] = arr[j]
        vals.update(rest_seq[i])
        out.append(vals)
    return tuple(out)


def _execute_bank_impl(bank: BankPlan, values_seq, keys, flip_keys,
                       bitstream_length: int, bitflip_rate: float,
                       use_pallas: bool, decode: bool,
                       key_mode: str = DEFAULT_KEY_MODE, batch_shapes=None,
                       active=None, scalar_names=None,
                       fault_model: FaultModel | None = None,
                       megakernel: bool = False,
                       interpret: bool | None = None):
    """Whole-bank execution of N member netlists as one XLA program.

    Stream generation and fault keying stay *per member*: member ``i``'s
    streams are drawn from ``keys[i]`` / ``flip_keys[i]`` exactly as a
    standalone ``execute`` call (same ``key_mode``) would draw them, so a
    merged run is bit-identical to a loop of per-member runs.  The logic
    merges — all combinational members execute through one merged plan
    (cross-member type-batched levels), all sequential members through one
    merged scan — and in batched key mode the stream generation merges too
    (one fused SNG pass per distinct member batch shape).

    ``active`` (static; None = all) is the padded-template slot mask: an
    inactive slot generates no real streams (zero-word fills), skips fault
    injection on its streams, and returns ``None`` instead of outputs.  Its
    *gate fault-key block* is still allocated when injecting — the merged
    plan's flat gid offsets cover every member — so active slots see exactly
    the keys a standalone run would.
    """
    from ..kernels import netlist_exec

    if scalar_names is not None:
        # Packed-slot layout (see _pack_values_seq): slot i's host-scalar PI
        # values arrive as one f32 vector and its host arrays as one stacked
        # leaf per shape group; rebuild the per-name dicts at trace time.
        values_seq = _unpack_values_seq(values_seq, scalar_names)

    comb_env: dict[str, jax.Array] = {}
    seq_words: dict[str, jax.Array] = {}
    comb_gate_fkeys: list[jax.Array] = []
    seq_out_fkeys: dict[int, jax.Array | None] = {}
    native_batch: dict[int, tuple[int, ...]] = {}
    member_streams = _gen_bank_streams(bank, values_seq, keys,
                                       bitstream_length, key_mode, use_pallas,
                                       batch_shapes, active=active)
    inject = _faults.injecting(bitflip_rate, fault_model)
    for i, plan in enumerate(bank.members):
        pre = member_prefix(i)
        streams = member_streams[i]
        masked = active is not None and not active[i]
        tail = None
        if inject and len(streams) + plan.n_gates > 0:
            fkeys = jax.random.split(flip_keys[i], len(streams) + plan.n_gates)
            if not masked:
                for j, nm in enumerate(sorted(streams)):
                    streams[nm] = _faults.apply_faults(fkeys[j], streams[nm],
                                                       bitflip_rate,
                                                       fault_model)
            tail = fkeys[len(streams):]
        native_batch[i] = (next(iter(streams.values())).shape[:-1]
                           if streams else ())
        target = seq_words if plan.is_sequential else comb_env
        for nm, v in streams.items():
            target[pre + nm] = v
        if plan.is_sequential:
            seq_out_fkeys[i] = tail
        elif tail is not None:
            # Flat per-gate key blocks in merge (= ascending member) order:
            # the merged plan's gids are offset to index this concatenation.
            comb_gate_fkeys.append(tail)

    outs: list = [None] * bank.n_members
    if bank.comb is not None:
        gf = jnp.concatenate(comb_gate_fkeys) if comb_gate_fkeys else None
        netlist_exec.run_combinational(bank.comb, comb_env, gate_fkeys=gf,
                                       bitflip_rate=bitflip_rate,
                                       fault_model=fault_model,
                                       use_pallas=use_pallas,
                                       megakernel=megakernel,
                                       interpret=interpret)
        for i in bank.comb_members:
            if active is not None and not active[i]:
                continue
            pre = member_prefix(i)
            outs[i] = {o: comb_env[pre + o] for o in bank.members[i].outputs}
    if bank.seq is not None:
        packed = netlist_exec.run_sequential(
            bank.seq, seq_words, use_pallas=use_pallas,
            n_words=bs.n_words(bitstream_length),
            megakernel=megakernel, interpret=interpret)
        for i in bank.seq_members:
            if active is not None and not active[i]:
                continue
            pre = member_prefix(i)
            m = {o: _restrict(packed[pre + o], native_batch[i])
                 for o in bank.members[i].outputs}
            if inject:
                tail = seq_out_fkeys[i]
                for j, o in enumerate(sorted(m)):
                    m[o] = _faults.apply_faults(tail[j], m[o], bitflip_rate,
                                                fault_model)
            outs[i] = m
    if decode:
        outs = [m if m is None else
                {o: bs.to_value(w, bitstream_length) for o, w in m.items()}
                for m in outs]
    return tuple(outs)


_BANK_STATIC = ("bank", "bitstream_length", "bitflip_rate", "use_pallas",
                "decode", "key_mode", "batch_shapes", "active",
                "scalar_names", "fault_model", "megakernel", "interpret")
_execute_bank = partial(jax.jit, static_argnames=_BANK_STATIC)(
    _execute_bank_impl)
#: Donating variant (its own jit cache): XLA reuses the stacked key rows'
#: buffers (argnums 2/3).  Only safe when the caller owns those arrays and
#: never reads them after the call — the serve engine's per-batch stacks.
#: Slot *values* are never donated: they may alias caller-held request
#: arrays.
_execute_bank_donating = partial(jax.jit, static_argnames=_BANK_STATIC,
                                 donate_argnums=(2, 3))(_execute_bank_impl)


#: type -> "is a jax.Array subclass" memo: ``isinstance(v, jax.Array)`` goes
#: through ABC registration machinery, which shows up at bank-dispatch rates
#: (thousands of value leaves per batch).
_IS_JAX_ARRAY: dict = {}


def _is_jax_array(v) -> bool:
    t = type(v)
    is_jax = _IS_JAX_ARRAY.get(t)
    if is_jax is None:
        is_jax = _IS_JAX_ARRAY.setdefault(t, isinstance(v, jax.Array))
    return is_jax


def _as_f32(v) -> jax.Array:
    """asarray(v, float32), skipping the (surprisingly costly) conversion
    machinery on the serving hot path when the caller already holds f32."""
    if _is_jax_array(v) and v.dtype == jnp.float32:
        return v
    return jnp.asarray(v, jnp.float32)


def _is_host_scalar(v) -> bool:
    return not _is_jax_array(v) and np.ndim(v) == 0


def _pack_values_seq(values_seq):
    """Slot-packed jit layout for bank dispatch:
    ``(packed, grouped, rest), names``.

    Each slot's *host scalar* PI values (python/numpy scalars — the serving
    admission format) collapse into one f32 vector, and its *host array*
    (batched, non-jax) values stack into one f32 leaf per distinct shape —
    so the jit boundary flattens/transfers a handful of leaves per slot
    instead of one per PI (a LIT slot alone carries 81 scalars; a batched OL
    slot a (16, 6) array per column group).  ``names[i]`` records slot i's
    layout — ``(scalar_names, ((shape, group_names), ...))``, both in sorted
    order — as a static jit argument; ``_unpack_values_seq`` rebuilds the
    dicts at trace time.  jax-array leaves are NOT packed — pulling them
    back to host would force a device sync — and flow through ``rest``
    unchanged.
    """
    packed, grouped, rest, names = [], [], [], []
    for vals in values_seq:
        scalars = []
        by_shape: dict[tuple[int, ...], list[str]] = {}
        jax_rest = {}
        for k, v in vals.items():
            if _is_jax_array(v):
                jax_rest[k] = _as_f32(v)
            elif np.ndim(v) == 0:
                scalars.append(k)
            else:
                by_shape.setdefault(np.shape(v), []).append(k)
        scalars.sort()
        gspecs, garrs = [], []
        for shape in sorted(by_shape):
            ks = sorted(by_shape[shape])
            gspecs.append((shape, tuple(ks)))
            garrs.append(np.stack([np.asarray(vals[k], np.float32)
                                   for k in ks]))
        packed.append(np.asarray([vals[k] for k in scalars], np.float32))
        grouped.append(tuple(garrs))
        rest.append(jax_rest)
        names.append((tuple(scalars), tuple(gspecs)))
    return (tuple(packed), tuple(grouped), tuple(rest)), tuple(names)


def _normalize_batch_shapes(batch_shapes, n: int, what: str = "netlists"):
    """Coerce per-member batch shapes to a hashable tuple-of-tuples (jit
    static arg) and validate the member count; None passes through."""
    if batch_shapes is None:
        return None
    batch_shapes = tuple(tuple(b) if b is not None else None
                         for b in batch_shapes)
    if len(batch_shapes) != n:
        raise ValueError(
            f"batch_shapes: got {len(batch_shapes)} for {n} {what}")
    return batch_shapes


def _normalize_active(active, n: int):
    """Coerce a slot-active mask to a hashable bool tuple (jit static arg).

    ``None`` and all-True both normalize to ``None`` — a fully-bound bank
    must share its jit trace with the mask-free ``execute_many`` path.
    """
    if active is None:
        return None
    active = tuple(bool(a) for a in active)
    if len(active) != n:
        raise ValueError(f"active: got {len(active)} for {n} slots")
    return None if all(active) else active


def _normalize_keys(keys, n: int, what: str = "keys") -> jax.Array:
    """Accept one key (split n ways), a key array, or a sequence of keys.

    Returns a stacked (n,) key array — members index it *inside* the jitted
    program, so the per-member key slicing costs no host dispatches.
    """
    if isinstance(keys, (list, tuple)):
        keys = jnp.stack(keys)
    elif jnp.ndim(keys) == 0:
        keys = jax.random.split(keys, n)
    if keys.shape[0] != n:
        raise ValueError(f"{what}: got {keys.shape[0]} for {n} netlists")
    return keys


def _fault_flip_keys(flip_keys, n: int, bitflip_rate: float,
                     fault_model: "FaultModel | None"):
    """Normalize per-member fault keys for a bank dispatch.

    When injecting, the bank impl splits a key per member unconditionally;
    a static-only model (no random components) may run keyless, so a
    deterministic placeholder fills in — its splits are never consumed.
    """
    if not _faults.injecting(bitflip_rate, fault_model):
        return None
    if flip_keys is None:
        return _normalize_keys(jax.random.key(0), n, "flip_keys")
    return _normalize_keys(flip_keys, n, "flip_keys")


def _dispatch_many(nets, values_seq, keys, bitstream_length: int,
                   bitflip_rate: float, flip_keys, backend: str | None,
                   decode: bool, key_mode: str | None = None,
                   batch_shapes=None,
                   fault_model: FaultModel | None = None) -> list:
    backend, key_mode = _check_modes(backend, key_mode)
    n = len(nets)
    if n == 0:
        raise ValueError("execute_many: need at least one netlist")
    if len(values_seq) != n:
        raise ValueError(f"values: got {len(values_seq)} for {n} netlists")
    batch_shapes = _normalize_batch_shapes(batch_shapes, n)
    keys = _normalize_keys(keys, n)
    fault_model = _check_fault_args(bitflip_rate, fault_model, flip_keys,
                                    "flip_keys")
    flip_keys = _fault_flip_keys(flip_keys, n, bitflip_rate, fault_model)
    if backend == "reference":
        return [_dispatch(net, dict(vals), keys[i], bitstream_length,
                          bitflip_rate,
                          flip_keys[i] if flip_keys is not None else None,
                          backend, decode, key_mode=key_mode,
                          batch_shape=batch_shapes[i] if batch_shapes else None,
                          fault_model=fault_model)
                for i, (net, vals) in enumerate(zip(nets, values_seq))]
    bank = compile_bank_plan(
        list(nets),
        fuse_mux=not _faults.injecting(bitflip_rate, fault_model))
    values_seq, scalar_names = _pack_values_seq(values_seq)
    outs = _execute_bank(bank, values_seq, keys, flip_keys, bitstream_length,
                         float(bitflip_rate), backend == "compiled_pallas",
                         decode, key_mode=key_mode, batch_shapes=batch_shapes,
                         scalar_names=scalar_names, fault_model=fault_model,
                         megakernel=backend == "compiled_megakernel")
    return list(outs)


def execute_bank(bank: BankPlan, values_seq, keys, bitstream_length: int,
                 *, active=None, bitflip_rate: float = 0.0, flip_keys=None,
                 backend: str | None = None, key_mode: str | None = None,
                 batch_shapes=None, decode: bool = False,
                 device=None, donate: bool = False,
                 fault_model: FaultModel | None = None,
                 interpret: bool | None = None) -> list:
    """Execute a prebuilt (possibly padded) BankPlan slot-wise.

    The serving-engine entry point (``repro.serve.sc_engine``): ``bank`` is
    typically a canonical template from ``plan.compile_bank_template`` whose
    slots outnumber the bound requests.  ``values_seq[i]`` / ``keys[i]`` /
    ``batch_shapes[i]`` / ``flip_keys[i]`` feed slot ``i``; ``active[i] =
    False`` masks slot ``i`` out — no streams are generated for it (zero-word
    fills keep the merged passes well-formed), and its entry in the returned
    list is ``None``.  Unbound slots' ``values_seq`` entries should be empty
    dicts; their key rows are placeholders (any same-dtype key).

    Every *bound* slot's outputs are bit-identical to a standalone
    ``execute`` of that member with the same key, ``key_mode`` and flip key —
    padding never perturbs active streams.  ``decode=True`` fuses the StoB
    decode into the program (the ``execute_value_many`` analogue).  Bank
    plans only execute on the compiled backends.

    ``device`` (a ``jax.Device``) commits the stacked key rows there before
    dispatch; jit places the whole bank execution with its committed
    argument, so the program runs on that device and the outputs live there
    — the multi-bank server's sharded placement.  Only the key arrays are
    committed (one buffer each): committing the per-slot values pytree
    leaf-by-leaf costs more host time than the dispatch itself, while
    uncommitted values follow the keys in one transfer.  Values already
    committed to a *different* device raise jax's colocation error — pass
    host/uncommitted values when sharding.  ``donate=True`` lets XLA consume
    the stacked key-row buffers (never the slot values, which may alias
    caller arrays); only pass it when the key rows are call-owned scratch,
    like the serve engine's per-batch stacks.
    """
    backend, key_mode = _check_modes(backend, key_mode)
    if backend == "reference":
        raise ValueError("execute_bank runs compiled BankPlans; use "
                         "execute()/execute_many() for the reference backend")
    n = bank.n_members
    if len(values_seq) != n:
        raise ValueError(f"values: got {len(values_seq)} for {n} slots")
    with obs.span("exec.pack_values", slots=n):
        values_seq, scalar_names = _pack_values_seq(values_seq)
    with obs.span("exec.stage_keys"):
        keys = _normalize_keys(keys, n)
        batch_shapes = _normalize_batch_shapes(batch_shapes, n, "slots")
        active = _normalize_active(active, n)
        fault_model = _check_fault_args(bitflip_rate, fault_model, flip_keys,
                                        "flip_keys")
        flip_keys = _fault_flip_keys(flip_keys, n, bitflip_rate, fault_model)
    if device is not None:
        with obs.span("exec.device_transfer", device=str(device)):
            keys = jax.device_put(keys, device)
            if flip_keys is not None:
                flip_keys = jax.device_put(flip_keys, device)
    args = (bank, values_seq, keys, flip_keys, bitstream_length,
            float(bitflip_rate), backend == "compiled_pallas", decode)
    kw = dict(key_mode=key_mode, batch_shapes=batch_shapes, active=active,
              scalar_names=scalar_names, fault_model=fault_model,
              megakernel=backend == "compiled_megakernel",
              interpret=interpret)
    # NOTE: the dispatch span measures host time to *enqueue* the jitted
    # program (plus trace/lower cost on a cache miss) — jax dispatch is
    # async, so device compute lands in the caller's block/reap interval.
    with obs.span("exec.dispatch", bank=bank.name, slots=n,
                  bitstream_length=bitstream_length):
        if donate:
            # Donation is best-effort: when no output can alias a key-row
            # buffer (the common case — outputs are packed words, not keys)
            # XLA ignores it and jax warns; that advisory is noise on a hot
            # serving path.
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore",
                                        message="Some donated buffers were not")
                outs = _execute_bank_donating(*args, **kw)
        else:
            outs = _execute_bank(*args, **kw)
    return list(outs)


# ---------------------------- host-side key staging --------------------------------

def _key_data_host(k) -> np.ndarray:
    # The public unwrap (jax.random.key_data) dispatches an XLA op per key —
    # at serving rates that is the single largest per-batch host cost.  The
    # raw buffer is directly reachable on current jax; fall back to the
    # public path if the internal layout ever changes.
    base = getattr(k, "_base_array", None)
    if base is not None:
        return np.asarray(base)
    return np.asarray(jax.random.key_data(k))


def _stack_keys(keys: list):
    """Stack per-slot PRNG keys into one (n,) key array, host-side.

    ``jnp.stack`` over typed keys dispatches one expand_dims per slot plus a
    concatenate; staging the raw key data through numpy collapses that to
    ONE device put, bit-identical to the stacked keys (same key data, same
    impl).  Repeated slot keys (the unbound-slot placeholder) unwrap once.
    """
    try:
        memo: dict[int, np.ndarray] = {}
        rows = []
        for k in keys:
            d = memo.get(id(k))
            if d is None:
                d = memo[id(k)] = _key_data_host(k)
            rows.append(d)
        return jax.random.wrap_key_data(jnp.asarray(np.stack(rows)),
                                        impl=jax.random.key_impl(keys[0]))
    except (TypeError, AttributeError):
        return jnp.stack(keys)


# ----------------------------- reference backend ----------------------------------

def _execute_reference(net: Netlist, values: dict[str, jax.Array],
                       key: jax.Array, bitstream_length: int,
                       bitflip_rate: float = 0.0,
                       flip_key: jax.Array | None = None,
                       key_mode: str = DEFAULT_KEY_MODE,
                       batch_shape: tuple[int, ...] | None = None,
                       fault_model: FaultModel | None = None) -> dict[str, jax.Array]:
    """Gate-by-gate interpreter: the oracle for the compiled plans.

    Stream generation honors the same ``key_mode`` as the compiled backends
    (the discipline lives in ``_gen_pi_streams``, upstream of interpretation),
    so reference and compiled outputs stay bit-for-bit comparable in either
    mode.  Fault injection (``bitflip_rate`` or its ``fault_model``
    generalization) applies at the same points with the same key splits as
    the compiled path."""
    streams = _gen_pi_streams(net.pis, values, key, bitstream_length,
                              key_mode=key_mode, batch_shape=batch_shape)

    fault_model = _check_fault_args(bitflip_rate, fault_model, flip_key)
    inject = _faults.injecting(bitflip_rate, fault_model)
    if inject:
        fk = flip_key if flip_key is not None else jax.random.key(0)
        fkeys = jax.random.split(fk, len(streams) + len(net.gates))
        for i, name in enumerate(sorted(streams)):
            streams[name] = _faults.apply_faults(fkeys[i], streams[name],
                                                 bitflip_rate, fault_model)

    if not net.is_sequential:
        # Snapshot the PI-stream count: gate outputs are appended to the env
        # below, and letting the flip-key index grow with it would silently
        # clamp past the end of ``fkeys`` and reuse the last key.
        n_streams = len(streams)
        for gi, g in enumerate(net.gates):
            out = bs.GATE_FNS[g.gtype](*[streams[i] for i in g.inputs])
            if inject:
                out = _faults.apply_faults(fkeys[n_streams + gi], out,
                                           bitflip_rate, fault_model)
            streams[g.output] = out
        return {o: streams[o] for o in net.outputs}

    # Sequential: iterate the combinational core over bitstream bits.
    state_pis = list(net.state_bindings.keys())
    # State-only recurrences have no streams to read the shape from.
    shape = (next(iter(streams.values())).shape if streams
             else (bitstream_length // bs.WORD_BITS,))  # (..., W)
    bl = bitstream_length

    def unpack_time_major(w):
        bits = bs.unpack_bits(w)                      # (..., W, 32)
        flat = bits.reshape(bits.shape[:-2] + (bl,))
        return jnp.moveaxis(flat, -1, 0)              # (BL, ...)

    time_streams = {k: unpack_time_major(v) for k, v in streams.items()}

    def step(state, xs):
        env = dict(xs) if xs is not None else {}
        for s_name in state_pis:
            env[s_name] = state[s_name]
        for g in net.gates:
            env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
        new_state = {s: env[net.state_bindings[s][0]] for s in state_pis}
        outs = {o: env[o] for o in net.outputs}
        return new_state, outs

    init = {s: jnp.full(shape[:-1], jnp.uint32(round(net.state_bindings[s][1])))
            for s in state_pis}
    _, out_seq = jax.lax.scan(step, init, time_streams or None,
                              length=None if time_streams else bl)
    packed_outs = {}
    for o, seq in out_seq.items():
        seq = jnp.moveaxis(seq, 0, -1)                # (..., BL)
        bits = seq.reshape(seq.shape[:-1] + (bl // 32, 32))
        # Mask to bit 0 before packing: inverting gates (~x) leave garbage
        # in bits 1..31 of the per-step values, which pack_bits would sum
        # into other bit positions of the word.
        packed_outs[o] = bs.pack_bits(bits & jnp.uint32(1))
    if inject:
        for i, o in enumerate(sorted(packed_outs)):
            packed_outs[o] = _faults.apply_faults(fkeys[len(streams) + i],
                                                  packed_outs[o],
                                                  bitflip_rate, fault_model)
    return packed_outs
