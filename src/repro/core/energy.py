"""Energy model — Eqs. (3)-(4) with the paper's SPICE-extracted gate energies.

    E_total = BL * E_computation + E_peripheral                       (3)
    E_computation = N_preset*E_preset + N_SBG*E_SBG + sum_g N_g*E_g   (4)

Per-gate energies (aJ) are the paper's SPICE values (Section 5-1).  AND/OR/
MUX built from the reliable subset decompose into those gates in the
netlists, so Eq. (4) applies directly to scheduler gate counts.

Peripheral terms: the paper extracts subarray-driver and BtoS-memory energy
from NVSim and accumulator energy from a 15nm Nangate synthesis; neither set
of absolute numbers is printed in the paper, so we use documented estimates
of the right scale (calibrated so the Fig. 10 breakdown shares are
qualitatively reproduced) — see DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import math

from . import mtj
from .scheduler import Schedule

ATTO = 1e-18
FEMTO = 1e-15

# --- paper's per-gate energies (aJ), Section 5-1 ---------------------------------
GATE_ENERGY_AJ = {
    "NOT": 30.7,
    "BUFF": 73.8,
    "NAND": 28.7,
    "NOR": 8.4,
    "NMAJ3": 7.6,
    "NMAJ5": 6.3,
    # Non-reliable-subset gates, modeled as their reliable decompositions
    # (used only if a netlist bypasses the reliable subset):
    "AND": 28.7 + 30.7,
    "OR": 28.7 + 2 * 30.7,
    "MAJ3": 7.6 + 30.7,
    "MAJ5": 6.3 + 30.7,
}
PRESET_ENERGY_AJ = 26.1

# Deterministic binary write: a pulse with switching probability ~1
# (overdriven write), energy from the MTJ model.
E_WRITE_BINARY_J = mtj.optimal_pulse(0.999).energy_j
# Stochastic bit generation at the balanced point (paper: minimum-energy
# (V_p, t_p) combination for the desired probability; p=0.5 representative).
E_SBG_J = mtj.sbg_energy(0.5)

# --- peripheral estimates (documented, not from the paper) -----------------------
# Subarray driver energy per driven column per logic cycle (SL/LBL switching
# only — logic-mode drives 2-3 columns, not a full-row read/write access).
# Calibrated so the Fig. 10 qualitative breakdown holds (logic + reset
# dominate; peripheral a minority that is larger for Stoch-IMC than for [22]).
E_DRIVER_PER_COLUMN_CYCLE_J = 0.1 * FEMTO
# BtoS memory read (256B SRAM-like LUT) per stochastic write burst.
E_BTOS_READ_J = 1 * FEMTO
# Accumulators (15nm Nangate scale: a few-bit add+register toggle per step).
E_LOCAL_ACC_J = 0.05 * FEMTO   # 1-bit input, log(m)+1-bit register, per step
E_GLOBAL_ACC_J = 0.2 * FEMTO   # log(m)+1-bit input, log(nm)+1 register, per step


@dataclasses.dataclass
class EnergyBreakdown:
    """Per-step energy in joules, mirroring Fig. 10's categories."""

    logic_j: float
    preset_j: float
    input_init_j: float
    peripheral_j: float

    @property
    def total_j(self) -> float:
        return self.logic_j + self.preset_j + self.input_init_j + self.peripheral_j

    def shares(self) -> dict[str, float]:
        t = self.total_j
        return {
            "logic": self.logic_j / t,
            "preset": self.preset_j / t,
            "input_init": self.input_init_j / t,
            "peripheral": self.peripheral_j / t,
        }


def computation_energy(sch: Schedule, stochastic: bool) -> EnergyBreakdown:
    """Eq. (4) for one executed schedule instance (one subarray pass).

    ``stochastic``: True for SC netlists (inputs SBG-written), False for
    binary netlists (inputs deterministically written).
    """
    logic = sum(GATE_ENERGY_AJ[g] * n for g, n in sch.gate_exec_counts.items()) * ATTO
    # Presets: every gate output cell (counted per lane) plus every input cell
    # (stochastic writes need a preset-to-'0' before the SBG pulse; binary
    # writes also preset for symmetric accounting).
    preset = (sch.preset_count + sch.input_cells) * PRESET_ENERGY_AJ * ATTO
    if stochastic:
        init = (sch.stochastic_input_cells * E_SBG_J
                + (sch.input_cells - sch.stochastic_input_cells) * E_WRITE_BINARY_J
                + E_BTOS_READ_J)
    else:
        init = sch.input_cells * E_WRITE_BINARY_J
    return EnergyBreakdown(logic_j=logic, preset_j=preset, input_init_j=init,
                           peripheral_j=0.0)


def peripheral_energy(n_subarrays_active: int, n_groups_active: int,
                      logic_cycles: int, avg_columns: int,
                      n_local_acc_steps: int, n_global_acc_steps: int,
                      stochastic: bool) -> float:
    """E_peripheral of Eq. (3) for one pass — charged to *active* subarrays
    only (idle subarrays' drivers are not switching)."""
    driver = (E_DRIVER_PER_COLUMN_CYCLE_J * avg_columns * logic_cycles
              * n_subarrays_active)
    acc = 0.0
    if stochastic:
        acc = (n_local_acc_steps * E_LOCAL_ACC_J * n_subarrays_active
               + n_global_acc_steps * E_GLOBAL_ACC_J * n_groups_active)
    return driver + acc


def accumulator_register_bits(n_groups: int, m_subarrays: int) -> tuple[int, int]:
    """Register widths of the local/global accumulators (Section 4-3)."""
    local = int(math.floor(math.log2(m_subarrays))) + 1
    glob = int(math.floor(math.log2(n_groups * m_subarrays))) + 1
    return local, glob
