"""STT-MRAM fault models lowered to deterministic packed-word masks.

The paper's reliability story (Table 4 bitflip tolerance, the 4.9X/216.3X
lifetime claims) rests on more than uniform soft errors: real STT-MRAM
arrays fail through *stuck-at* cells (pinned MTJ free layers, shorted
tunnel barriers), *dead rows/subarrays* (driver or word-line failures) and
*write-endurance wear* (repeated RWC passes degrading cells toward
stuck-at-0).  :class:`FaultModel` captures that taxonomy and lowers every
kind to word-level masks over packed uint32 bitstreams, applied at exactly
the injection points the existing ``bitflip_rate`` path uses (PI streams,
gate outputs, sequential outputs) under the same ``flip_key`` discipline:

* the **transient** component consumes the injection point's *raw* fault
  key through ``sc_ops.flip_bits`` — ``FaultModel(flip_rate=r)`` is
  bit-identical to the legacy ``bitflip_rate=r`` path;
* **persistent** components (stuck-at cells, dead rows) draw their cell
  maps from ``fold_in``-derived subkeys of the same fault key, so a faulty
  run is exactly reproducible (same circuit, same ``flip_key`` -> same
  masks on every backend, key_mode, device, bank slot) while never
  perturbing the transient draw;
* **static** components (``dead_cols`` spans, explicit ``sa0_words`` /
  ``sa1_words`` cell maps) are position-dependent only — the de Lima-style
  measured fault map case — and need no key at all.

``fault_model=None`` everywhere is bit-identical to today's clean path.
A ``FaultModel`` is frozen and hashable: it rides through the executor's
jit boundaries as a static argument next to ``bitflip_rate``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import bitstream as bs
from . import sc_ops

#: fold_in tags deriving the persistent-fault subkeys from an injection
#: point's fault key.  The raw (untagged) key is reserved for the transient
#: draw so the legacy bitflip path reproduces bit-exactly.
_STUCK0_TAG = 1
_STUCK1_TAG = 2
_DEAD_ROW_TAG = 3


def _check_rate(name: str, rate: float) -> float:
    rate = float(rate)
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"{name} must be in [0, 1], got {rate}")
    return rate


def _check_words(name: str, words) -> "tuple[int, ...] | None":
    if words is None:
        return None
    words = tuple(int(w) for w in words)
    for w in words:
        if not 0 <= w <= 0xFFFFFFFF:
            raise ValueError(f"{name} entries must be uint32 words, got {w:#x}")
    return words


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One deterministic STT-MRAM fault configuration.

    Parameters
    ----------
    flip_rate:
        Transient (soft-error / RWC disturb) per-bit flip probability at
        every injection point — the generalization of ``bitflip_rate``.
    stuck0_rate / stuck1_rate:
        Per-cell probability that a cell is permanently stuck at 0 / 1.
        Cell maps are drawn per injection point from ``fold_in`` subkeys of
        its fault key: each node's stream occupies its own rows of the
        array, so distinct nodes see distinct (but reproducible) cell maps.
    dead_row_rate:
        Probability that a whole 32-cell row (= one packed word) is dead
        and reads all-zeros — word-line/driver failures.
    dead_cols:
        Static ``(start, stop)`` bit-position spans (half-open, in
        ``[0, BL)``) stuck at 0 in *every* stream — dead bit-lines shared
        by all rows of the subarray.
    sa0_words / sa1_words:
        Explicit per-word cell maps (tuple of uint32, length ``BL // 32``):
        a set bit marks a cell stuck at 0 / 1, applied identically to every
        stream — the measured-fault-map case.  ``sa1`` wins over every
        zeroing fault (a cell shorted high cannot also read 0).
    wear_passes / wear_stuck_per_pass:
        Endurance wear: every recorded pass adds ``wear_stuck_per_pass`` to
        the effective stuck-at-0 rate (write failures degrade toward the
        low-resistance state).  Advance with :meth:`worn`.

    Key semantics: a faulty run is deterministic in ``flip_key`` — the
    transient draw consumes each injection point's raw fault key (so
    ``FaultModel(flip_rate=r)`` reproduces the legacy ``bitflip_rate=r``
    bit-exactly) and every persistent component draws its cell map from a
    ``fold_in`` subkey of the same key.  Same circuit + same ``flip_key``
    → same masks on every backend, key_mode, device and bank slot.

    Example::

        model = FaultModel(flip_rate=0.05, dead_row_rate=0.01)
        opts = executor.ExecOptions(bitstream_length=256, decode=True,
                                    fault_model=model,
                                    flip_key=jax.random.key(1))
        out = executor.run(executor.ExecRequest(
            circuits.sc_multiply(), {"a": 0.5, "b": 0.5},
            jax.random.key(0), opts))
    """

    flip_rate: float = 0.0
    stuck0_rate: float = 0.0
    stuck1_rate: float = 0.0
    dead_row_rate: float = 0.0
    dead_cols: "tuple[tuple[int, int], ...]" = ()
    sa0_words: "tuple[int, ...] | None" = None
    sa1_words: "tuple[int, ...] | None" = None
    wear_passes: int = 0
    wear_stuck_per_pass: float = 0.0

    def __post_init__(self):
        set_ = object.__setattr__
        for f in ("flip_rate", "stuck0_rate", "stuck1_rate", "dead_row_rate",
                  "wear_stuck_per_pass"):
            set_(self, f, _check_rate(f, getattr(self, f)))
        cols = []
        for span in self.dead_cols:
            start, stop = (int(span[0]), int(span[1]))
            if not 0 <= start < stop:
                raise ValueError(
                    f"dead_cols span must satisfy 0 <= start < stop, "
                    f"got ({start}, {stop})")
            cols.append((start, stop))
        set_(self, "dead_cols", tuple(cols))
        set_(self, "sa0_words", _check_words("sa0_words", self.sa0_words))
        set_(self, "sa1_words", _check_words("sa1_words", self.sa1_words))
        if int(self.wear_passes) < 0:
            raise ValueError("wear_passes must be >= 0")
        set_(self, "wear_passes", int(self.wear_passes))

    # ------------------------------ derived views ---------------------------------

    @property
    def effective_stuck0(self) -> float:
        """Stuck-at-0 rate including accumulated endurance wear."""
        return min(1.0, self.stuck0_rate
                   + self.wear_passes * self.wear_stuck_per_pass)

    @property
    def is_null(self) -> bool:
        """True when the model injects nothing (== ``fault_model=None``)."""
        return (self.flip_rate == 0.0 and self.effective_stuck0 == 0.0
                and self.stuck1_rate == 0.0 and self.dead_row_rate == 0.0
                and not self.dead_cols and not self.sa0_words
                and not self.sa1_words)

    @property
    def needs_keys(self) -> bool:
        """True when any component draws random masks (requires flip_key)."""
        return (self.flip_rate > 0.0 or self.effective_stuck0 > 0.0
                or self.stuck1_rate > 0.0 or self.dead_row_rate > 0.0)

    def worn(self, n_passes: int = 1) -> "FaultModel":
        """The same model after ``n_passes`` further write passes."""
        if n_passes < 0:
            raise ValueError("n_passes must be >= 0")
        return dataclasses.replace(self,
                                   wear_passes=self.wear_passes + n_passes)


def normalize_fault_model(fault_model: "FaultModel | None") -> "FaultModel | None":
    """Canonicalize for dispatch: a null model is the clean path (and must
    share its jit cache entry with ``fault_model=None``)."""
    if fault_model is None:
        return None
    if not isinstance(fault_model, FaultModel):
        raise TypeError(f"fault_model must be a FaultModel or None, "
                        f"got {type(fault_model).__name__}")
    return None if fault_model.is_null else fault_model


def _cell_mask(key: jax.Array, shape: tuple, rate: float) -> jax.Array:
    """Packed per-cell Bernoulli(rate) mask of packed-word ``shape``."""
    if rate >= 1.0:
        # The thresholded draw below covers [0, 2^32 - 1) — exact only
        # below 1.0; a fully-stuck array must mask every cell.
        return jnp.full(shape, jnp.uint32(0xFFFFFFFF))
    u = jax.random.bits(key, shape=shape + (bs.WORD_BITS,), dtype=jnp.uint32)
    thresh = jnp.uint32(min(round(rate * 4294967296.0), 4294967295))
    return bs.pack_bits((u < thresh).astype(jnp.uint32))


def _static_keep_mask(model: FaultModel, n_words: int) -> "np.ndarray | None":
    """Host-side (W,) uint32 keep-mask for the static zeroing faults
    (``dead_cols`` spans + ``sa0_words``); None when neither is set."""
    if not model.dead_cols and model.sa0_words is None:
        return None
    keep = np.full(n_words, 0xFFFFFFFF, np.uint32)
    bl = n_words * bs.WORD_BITS
    for start, stop in model.dead_cols:
        for b in range(start, min(stop, bl)):
            keep[b // bs.WORD_BITS] &= np.uint32(
                0xFFFFFFFF ^ (1 << (b % bs.WORD_BITS)))
    if model.sa0_words is not None:
        if len(model.sa0_words) != n_words:
            raise ValueError(
                f"sa0_words: got {len(model.sa0_words)} words for "
                f"W={n_words} (bitstream_length {bl})")
        keep &= ~np.asarray(model.sa0_words, np.uint32)
    return keep


def apply_faults(fkey: jax.Array, words: jax.Array, bitflip_rate: float,
                 fault_model: "FaultModel | None") -> jax.Array:
    """Inject one injection point's faults into packed stream ``words``.

    The drop-in generalization of ``sc_ops.flip_bits``: with
    ``fault_model=None`` it IS ``flip_bits(fkey, words, bitflip_rate)``
    (bit-identical legacy path); with a model, ``model.flip_rate`` replaces
    ``bitflip_rate`` for the transient draw (same raw ``fkey``) and the
    persistent/static masks follow.  Application order — transient flips,
    then every zeroing fault (random stuck-0 incl. wear, dead rows, dead
    columns, explicit sa0), then the setting faults (random stuck-1,
    explicit sa1) — so stuck-at-1 wins, matching a cell shorted high.
    """
    if fault_model is None:
        return sc_ops.flip_bits(fkey, words, bitflip_rate)
    w = sc_ops.flip_bits(fkey, words, fault_model.flip_rate)
    s0 = fault_model.effective_stuck0
    if s0 > 0.0:
        w = w & ~_cell_mask(jax.random.fold_in(fkey, _STUCK0_TAG),
                            w.shape, s0)
    if fault_model.dead_row_rate > 0.0:
        u = jax.random.uniform(jax.random.fold_in(fkey, _DEAD_ROW_TAG),
                               shape=w.shape)
        w = jnp.where(u < fault_model.dead_row_rate, jnp.uint32(0), w)
    keep = _static_keep_mask(fault_model, w.shape[-1])
    if keep is not None:
        w = w & jnp.asarray(keep)
    if fault_model.stuck1_rate > 0.0:
        w = w | _cell_mask(jax.random.fold_in(fkey, _STUCK1_TAG),
                           w.shape, fault_model.stuck1_rate)
    if fault_model.sa1_words is not None:
        if len(fault_model.sa1_words) != w.shape[-1]:
            raise ValueError(
                f"sa1_words: got {len(fault_model.sa1_words)} words for "
                f"W={w.shape[-1]}")
        w = w | jnp.asarray(np.asarray(fault_model.sa1_words, np.uint32))
    return w


def injecting(bitflip_rate: float, fault_model: "FaultModel | None") -> bool:
    """Does this (rate, model) pair inject anything at all?

    The shared gating predicate for every dispatch path: when False, the
    run takes the exact clean code path (fused plans, no fkey splits)."""
    return bitflip_rate > 0.0 or fault_model is not None
