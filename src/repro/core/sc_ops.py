"""Functional stochastic arithmetic on packed bitstreams (vectorized).

These are the value-level semantics of the Fig. 5 circuits, operating on
packed uint32 bitstream tensors of shape ``batch_shape + (BL//32,)``.  They
are used by the application accuracy path (apps.py), as the oracle for the
Pallas kernels (kernels/ref.py) and for property tests.  The netlist forms
(circuits.py) carry the cycle/energy/area accounting.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import bitstream as bs


def multiply(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fig. 4(b): independent streams, value = p_a * p_b."""
    return a & b


def scaled_add(a: jax.Array, b: jax.Array, sel: jax.Array) -> jax.Array:
    """Fig. 4(a): value = s*p_a + (1-s)*p_b with an independent select stream."""
    return (a & sel) | (b & ~sel)


def abs_sub(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fig. 4(c): value = |p_a - p_b| — requires *correlated* inputs."""
    return a ^ b


@partial(jax.jit, static_argnames=("bitstream_length", "warmup"))
def scaled_div(a: jax.Array, b: jax.Array, bitstream_length: int,
               warmup: bool = False) -> jax.Array:
    """Fig. 4(d)/5(d): Gaines JK feedback divider, E[Q] -> p_a / (p_a + p_b).

    Sequential over bitstream bits (Q init 0 per the paper): unpack, scan,
    repack.  In Stoch-IMC this executes as a wavefront across subarrays.

    ``warmup=True`` models the *streaming* steady state: in the architecture
    the Q cells persist across evaluations, so the divider does not restart
    from Q=0 for every input window.  We cycle the input streams once before
    counting, which removes the geometric warm-up bias of a cold start.
    """
    bits_a = bs.unpack_bits(a)          # (..., W, 32)
    bits_b = bs.unpack_bits(b)
    sh = bits_a.shape
    ta = jnp.moveaxis(bits_a.reshape(sh[:-2] + (sh[-2] * 32,)), -1, 0)  # (BL, ...)
    tb = jnp.moveaxis(bits_b.reshape(sh[:-2] + (sh[-2] * 32,)), -1, 0)
    if warmup:
        ta = jnp.concatenate([ta, ta], axis=0)
        tb = jnp.concatenate([tb, tb], axis=0)

    def step(q, ab):
        abit, bbit = ab
        qn = (abit & (1 - q)) | ((1 - bbit) & q)
        return qn, q  # Q is emitted *before* update (Q init 0, per paper)

    q0 = jnp.zeros(ta.shape[1:], dtype=ta.dtype)
    _, qs = jax.lax.scan(step, q0, (ta, tb))
    if warmup:
        qs = qs[bitstream_length:]
    qs = jnp.moveaxis(qs, 0, -1).reshape(sh)
    return bs.pack_bits(qs)


def sqrt_comb(a1: jax.Array, a2: jax.Array, c1: jax.Array, c2: jax.Array) -> jax.Array:
    """Fig. 5(e) reconstruction: NAND(NAND(A1,C1), NAND(A2,C2)) = 1-(1-cx)^2.

    a1/a2 are independent streams of the same value; c1/c2 constant streams
    (value SQRT_C).  See circuits.sc_sqrt for accuracy caveats.
    """
    return ~(~(a1 & c1) & ~(a2 & c2))


def exp_neg(a_copies: list[jax.Array], c: float, key: jax.Array,
            bitstream_length: int) -> jax.Array:
    """Fig. 5(f): exp(-c x) via 5th-order Maclaurin Horner ladder.

    ``a_copies`` are ``order`` independently-generated streams of x.
    """
    order = len(a_copies)
    keys = jax.random.split(key, order)
    shape = a_copies[0].shape[:-1]
    s = None
    for k in range(order, 0, -1):
        ck = bs.generate(keys[k - 1], jnp.full(shape, c / k, jnp.float32),
                         bitstream_length)
        t = a_copies[k - 1] & ck
        s = ~t if s is None else ~(t & s)
    return s


def flip_bits(key: jax.Array, words: jax.Array, rate: float) -> jax.Array:
    """Inject bitflips: each bit flips independently with probability ``rate``.

    Models soft errors / MTJ read-write-compute disturbs (Table 4).
    """
    if rate <= 0.0:
        return words
    u = jax.random.bits(key, shape=words.shape + (bs.WORD_BITS,), dtype=jnp.uint32)
    thresh = jnp.uint32(min(round(rate * 4294967296.0), 4294967295))
    mask = bs.pack_bits((u < thresh).astype(jnp.uint32))
    return words ^ mask
