"""Stoch-IMC core: the paper's contribution as a composable library.

Layers:
  mtj        — STT-MRAM switching physics (Eqs. 1-2) + BtoS pulse LUT
  bitstream  — packed unipolar bitstreams + IMC primitive gates (JAX)
  gates      — gate-level netlist IR for the 2T-1MTJ method
  circuits   — stochastic (Fig. 5) and binary netlist builders
  scheduler  — Algorithm 1 (co-scheduling + mapping)
  plan       — execution-plan compiler (leveled, type-batched fused passes)
  executor   — netlist execution: compiled plans + gate-by-gate reference
  faults     — STT-MRAM fault models (stuck-at / dead regions / wear)
  obs        — zero-dependency tracing + metrics (spans, chrome export)
  sc_ops     — vectorized functional stochastic arithmetic
  energy     — Eq. (3)-(4) energy model (paper SPICE gate energies)
  arch       — Stoch-IMC [n, m] architecture model + baselines (Table 3)
  apps       — LIT / OL / HDP / KDE applications (Fig. 9, Tables 3-4)
"""
from . import (apps, arch, bitstream, circuits, energy, executor, faults,
               gates, mtj, obs, plan, sc_ops, scheduler)

__all__ = [
    "apps", "arch", "bitstream", "circuits", "energy", "executor", "faults",
    "gates", "mtj", "obs", "plan", "sc_ops", "scheduler",
]
