"""Composed per-bit application netlists with instance-per-row placement —
the input Algorithm 1 actually receives in the paper's evaluation
(Section 5.3.2: the OL circuit is batched 16 pixel-circuits at a time, LIT
maps its 81 window operations across rows of a 128x128 subarray, etc.).

Each operation instance is placed row-locally; independent same-type gates
in different rows with column-aligned inputs fire in a single cycle
(Algorithm 1's intra-subarray parallelism), and cross-row operand moves
become scheduler-inserted BUFF copies — the paper's observation that LIT
needs "numerous copy operations" emerges naturally.

The resulting per-bit netlist executes bit-parallel across the [n, m]
subarrays: bit i of the 256-bit stream evaluates the same schedule in
subarray i (one pass for BL <= n*m).
"""
from __future__ import annotations

from .gates import Netlist, PIKind

_UID = [0]


def _u(prefix: str) -> str:
    _UID[0] += 1
    return f"{prefix}_{_UID[0]}"


def mul_at(net: Netlist, row: int, a: str, b: str) -> str:
    n1 = net.add_gate("NAND", [a, b], _u("mn"), row=row)
    return net.add_gate("NOT", [n1], _u("m"), row=row)


def sadd_at(net: Netlist, row: int, a: str, b: str, sel: str) -> str:
    sb = net.add_gate("NOT", [sel], _u("sb"), row=row)
    n1 = net.add_gate("NAND", [a, sel], _u("s1"), row=row)
    n2 = net.add_gate("NAND", [b, sb], _u("s2"), row=row)
    return net.add_gate("NAND", [n1, n2], _u("sa"), row=row)


def xor_at(net: Netlist, row: int, a: str, b: str) -> str:
    n1 = net.add_gate("NAND", [a, b], _u("x1"), row=row)
    n2 = net.add_gate("NAND", [a, n1], _u("x2"), row=row)
    n3 = net.add_gate("NAND", [b, n1], _u("x3"), row=row)
    return net.add_gate("NAND", [n2, n3], _u("x"), row=row)


def sqrt_at(net: Netlist, row: int, a1: str, a2: str, c1: str, c2: str) -> str:
    n1 = net.add_gate("NAND", [a1, c1], _u("q1"), row=row)
    n2 = net.add_gate("NAND", [a2, c2], _u("q2"), row=row)
    return net.add_gate("NAND", [n1, n2], _u("q"), row=row)


def div_at(net: Netlist, row: int, a: str, b: str) -> str:
    """JK divider combinational core (per-bit; state feedback is the
    wavefront across subarrays — cost accounted per the paper's per-bit
    schedule)."""
    q = net.add_pi(_u("Q"), kind=PIKind.STATE, row=row)
    qb = net.add_gate("NOT", [q], _u("dqb"), row=row)
    bb = net.add_gate("NOT", [b], _u("dbb"), row=row)
    n1 = net.add_gate("NAND", [a, qb], _u("d1"), row=row)
    n2 = net.add_gate("NAND", [bb, q], _u("d2"), row=row)
    out = net.add_gate("NAND", [n1, n2], _u("d"), row=row)
    net.bind_state(q, out, init=0.0)
    return out


def exp_at(net: Netlist, row: int, a_copies: list[str], consts: list[str]) -> str:
    order = len(a_copies)
    s = net.add_gate("NAND", [a_copies[-1], consts[-1]], _u("e"), row=row)
    for k in range(order - 1, 0, -1):
        t = net.add_gate("NAND", [a_copies[k - 1], consts[k - 1]], _u("et"),
                         row=row)
        u = net.add_gate("NOT", [t], _u("eu"), row=row)
        s = net.add_gate("NAND", [u, s], _u("es"), row=row)
    return s


def pi_at(net: Netlist, row: int, value_key=None, const=None, corr=None,
          copy=0) -> str:
    kind = PIKind.CONSTANT if const is not None else PIKind.STOCHASTIC
    return net.add_pi(_u("I"), kind=kind, value_key=value_key,
                      const_value=const, corr_group=corr, indep_copy=copy,
                      row=row)


def mean_tree(net: Netlist, leaves: list[tuple[str, int]]) -> tuple[str, int]:
    """Balanced MUX mean tree over (node, row) leaves; returns (root, row).

    Pair partners live in different rows — the scheduler inserts the BUFF
    moves (the paper's LIT copy overhead)."""
    level = list(leaves)
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            (a, ra), (b, rb) = level[i], level[i + 1]
            s = pi_at(net, ra, const=0.5)
            nxt.append((sadd_at(net, ra, a, b, s), ra))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


# ------------------------------- applications --------------------------------------

def lit_netlist(window: int = 81) -> Netlist:
    """LIT per-bit circuit (Fig. 9(a)): rows 0..window-1 hold per-pixel work."""
    net = Netlist("lit_app")
    squares, a1s, a2s = [], [], []
    for i in range(window):
        a1 = pi_at(net, i, value_key=f"a{i}", copy=0)
        a2 = pi_at(net, i, value_key=f"a{i}", copy=1)
        squares.append((mul_at(net, i, a1, a2), i))
        a1s.append((a1, i))
        a2s.append((a2, i))
    m_sq, r1 = mean_tree(net, squares)              # E[a^2]
    m_a1, r2 = mean_tree(net, a1s)                  # E[a]
    m_a2, r3 = mean_tree(net, a2s)                  # E[a] (independent copy)
    m_a_sq = mul_at(net, r2, m_a1, m_a2)            # E[a]^2
    var = xor_at(net, r1, m_sq, m_a_sq)             # |.| (correlated-ish, cost)
    c1 = pi_at(net, r1, const=0.9)
    c2 = pi_at(net, r1, const=0.9)
    var2 = net.add_gate("BUFF", [var], "var_cp", row=r1)
    sigma = sqrt_at(net, r1, var, var2, c1, c2)
    ones = pi_at(net, r1, const=1.0)
    half = pi_at(net, r1, const=0.5)
    scaled = sadd_at(net, r1, sigma, ones, half)    # (sigma+1)/2
    t = mul_at(net, r1, m_a1, scaled)
    net.set_outputs([t])
    return net


def ol_netlist(batch: int = 16) -> Netlist:
    """OL per-bit circuit batched ``batch`` pixels (paper Section 5.3.2)."""
    net = Netlist("ol_app")
    outs = []
    for r in range(batch):
        pis = [pi_at(net, r, value_key=f"p{r}_{j}") for j in range(6)]
        acc = pis[0]
        for j in range(1, 6):
            acc = mul_at(net, r, acc, pis[j])
        outs.append(acc)
    net.set_outputs(outs)
    return net


def hdp_netlist() -> Netlist:
    """HDP per-bit circuit (Fig. 9(c) / Eqs. (8)-(9)), ~8 rows."""
    net = Netlist("hdp_app")
    p_ed = pi_at(net, 0, value_key="p_ed")
    p_end = pi_at(net, 0, value_key="p_end")
    p_d0 = pi_at(net, 0, value_key="p_d")
    inner_e = sadd_at(net, 0, p_ed, p_end, p_d0)
    p_ned = pi_at(net, 1, value_key="p_ned")
    p_nend = pi_at(net, 1, value_key="p_nend")
    p_d1 = pi_at(net, 1, value_key="p_d", copy=1)
    inner_ne = sadd_at(net, 1, p_ned, p_nend, p_d1)
    p_e = pi_at(net, 0, value_key="p_e")
    p_hd = sadd_at(net, 0, inner_e, inner_ne, p_e)
    p_bp = pi_at(net, 2, value_key="p_bp")
    p_cp = pi_at(net, 2, value_key="p_cp")
    num1 = mul_at(net, 2, p_bp, p_cp)
    num = mul_at(net, 2, num1, p_hd)
    nbp_i = pi_at(net, 3, value_key="p_bp", copy=1)
    ncp_i = pi_at(net, 3, value_key="p_cp", copy=1)
    nbp = net.add_gate("NOT", [nbp_i], "nbp", row=3)
    ncp = net.add_gate("NOT", [ncp_i], "ncp", row=3)
    den1 = mul_at(net, 3, nbp, ncp)
    nhd = net.add_gate("NOT", [p_hd], "nhd", row=0)
    den = mul_at(net, 3, den1, nhd)
    q = div_at(net, 4, num, den)
    net.set_outputs([q])
    return net


def kde_netlist(n_hist: int = 8, n_factors: int = 5, order: int = 5) -> Netlist:
    """KDE per-bit circuit (Fig. 9(d) / Eq. (10)), 32 rows (paper 32x64)."""
    net = Netlist("kde_app")
    terms = []
    for i in range(n_hist):
        factor = None
        for f in range(n_factors):
            row = i * 4 + (f % 4)
            xa = pi_at(net, row, value_key="x_t", corr=f"c{i}_{f}", copy=2 * f)
            xb = pi_at(net, row, value_key=f"h{i}", corr=f"c{i}_{f}",
                       copy=2 * f + 1)
            d = xor_at(net, row, xa, xb)
            copies = [d] + [net.add_gate("BUFF", [d], _u("dc"), row=row)
                            for _ in range(order - 1)]
            consts = [pi_at(net, row, const=0.8 / k)
                      for k in range(1, order + 1)]
            e = exp_at(net, row, copies, consts)
            factor = e if factor is None else mul_at(net, row, factor, e)
        terms.append((factor, i * 4))
    pdf, _ = mean_tree(net, terms)
    net.set_outputs([pdf])
    return net


APP_NETLISTS = {"lit": lit_netlist, "ol": ol_netlist, "hdp": hdp_netlist,
                "kde": kde_netlist}
