"""Packed stochastic bitstreams (unipolar encoding) in JAX.

A stochastic number (SN) of value ``p`` in [0, 1] is a bitstream whose bits are
i.i.d. Bernoulli(p) (Section 2-3).  We store bitstreams *packed*, 32 bits per
``uint32`` word, so every bitwise op processes 32 bitstream bits per lane —
this is the TPU translation of the paper's bit-parallelism across subarrays
(DESIGN.md Section 2).

Shapes: a bitstream tensor for values of shape ``S`` with bitstream length
``BL`` is ``S + (BL // 32,)`` of dtype uint32.

Generation uses counter-based PRNG (stands in for the MTJ intrinsic
stochastic switching of Eqs. (1)-(2)); correlated streams share their
underlying uniforms so that XOR computes exact |a-b| (Fig. 4(c)/5(c)).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
_LANE_SHIFTS = np.arange(WORD_BITS, dtype=np.uint32)


def n_words(bitstream_length: int) -> int:
    if bitstream_length % WORD_BITS != 0:
        raise ValueError(f"bitstream length {bitstream_length} must be a multiple of {WORD_BITS}")
    return bitstream_length // WORD_BITS


def _threshold_u32(p: jax.Array) -> jax.Array:
    """Map probability p in [0,1] to a uint32 compare threshold.

    This is the digital analogue of the BtoS voltage-pulse LUT: the value is
    quantized to a threshold such that P(rand_u32 < threshold) = p.
    """
    dt = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    p = jnp.clip(p.astype(dt), 0.0, 1.0)
    scaled = jnp.round(p * dt(4294967296.0))
    # 2^32 is not representable in uint32 — and float32 cannot even hold
    # 2^32 - 1 (it rounds to 2^32), so a float-side minimum is a no-op and the
    # out-of-range float->uint32 cast it was meant to prevent is undefined
    # across XLA backends.  Clamp on the integer side instead: anything that
    # rounded to >= 2^32 maps to 0xFFFFFFFF, so p=1.0 gives an (almost-surely)
    # all-ones stream — threshold 0xFFFFFFFF covers all but one value in 2^32.
    return jnp.where(scaled >= dt(4294967296.0), jnp.uint32(0xFFFFFFFF),
                     scaled.astype(jnp.uint32))


def _uniform_u32(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    return jax.random.bits(key, shape=shape, dtype=jnp.uint32)


@partial(jax.jit, static_argnames=("bitstream_length",))
def generate(key: jax.Array, p: jax.Array, bitstream_length: int) -> jax.Array:
    """Generate packed bitstreams: shape p.shape + (BL//32,) uint32.

    Models the stochastic-number-generation step: each bit is '1' with
    probability p, independently (MTJ stochastic write per cell).
    """
    w = n_words(bitstream_length)
    u = _uniform_u32(key, p.shape + (w, WORD_BITS))
    bits = (u < _threshold_u32(p)[..., None, None]).astype(jnp.uint32)
    return pack_bits(bits)


@partial(jax.jit, static_argnames=("bitstream_length",))
def generate_correlated(key: jax.Array, ps: tuple[jax.Array, ...] | list[jax.Array],
                        bitstream_length: int) -> tuple[jax.Array, ...]:
    """Generate maximally-correlated packed streams for several values.

    All streams share the same underlying uniforms (same RNG cells written
    with different pulse amplitudes, in paper terms), so
    XOR(stream_a, stream_b) has value exactly |a - b| in expectation.
    Values must be broadcast-compatible.

    The per-stream thresholds are stacked into one leading axis and compared
    against the shared uniforms in a single broadcast — bit-identical to (but
    one dispatch instead of N of) thresholding each stream separately.
    """
    shape = jnp.broadcast_shapes(*[jnp.shape(p) for p in ps])
    w = n_words(bitstream_length)
    u = _uniform_u32(key, shape + (w, WORD_BITS))
    stacked = jnp.stack([jnp.broadcast_to(jnp.asarray(p), shape) for p in ps])
    thr = _threshold_u32(stacked)[..., None, None]        # (N, *shape, 1, 1)
    words = pack_bits((u[None] < thr).astype(jnp.uint32))  # (N, *shape, W)
    return tuple(words[i] for i in range(len(ps)))


# --- batched stream-table generation (the bulk BtoS pass) -------------------------
#
# The paper writes ALL operand streams into subarray rows in bulk before any
# gate pass runs (Sec. 2-3, Fig. 8); stream generation, not logic, dominates
# end-to-end SC cost.  ``generate_batch`` is that bulk write: every stream of
# a compiled plan's stream table (core/plan.py) generates in ONE fused
# threshold+pack pass over a stacked (N, *batch) value tensor, using the
# counter-based RNG of kernels/common.py (murmur3 finalizer) instead of one
# threefry call per stream.  Rows with equal key-lane index share their
# uniforms, so correlation groups ride through the same pass.  This is the
# ``key_mode="batched"`` discipline (executor.py): streams differ bit-wise
# from the legacy per-PI threefry splits but are statistically equivalent,
# and the jnp fallback is bit-identical to the Pallas kernel.

def stream_row_seeds(key: jax.Array, lanes) -> jax.Array:
    """Mixed per-row seeds for a stream table: row i <- hash(key seed, lane_i).

    A row's stream depends only on (key, lane, element, bit), never on how
    many other rows are generated alongside it — so concatenating tables
    (bank-level generation) or splitting them changes nothing bit-wise.
    """
    from ..kernels.sng import lane_seeds
    seed = jax.random.bits(key, (), jnp.uint32)
    return lane_seeds(seed, jnp.asarray(lanes, jnp.uint32))


def generate_batch_seeded(row_seeds: jax.Array, ps: jax.Array,
                          bitstream_length: int,
                          use_pallas: bool = False,
                          word_window: tuple | None = None) -> jax.Array:
    """Batched SNG from pre-mixed row seeds: ps (N, *batch) -> (N, *batch, W).

    Thresholds and packs by compare-and-accumulate over the 32 lane shifts —
    the (..., W, 32) unpacked uniform tensor of ``generate`` is never
    materialized.  ``use_pallas`` routes through the fused Pallas SNG kernel
    (kernels/sng.py), bit-identical to the jnp fallback.

    ``word_window=(start, n)`` generates only words ``[start, start + n)`` of
    the ``bitstream_length``-long streams — bit-identical to slicing a
    whole-stream call, because the counter-based RNG indexes absolute bit
    positions.  ``start`` may be traced (a scan chunk index); ``n`` must be
    static.  This is what lets the chunked streaming executor regenerate PI
    streams per chunk instead of holding them at full length.
    """
    from ..kernels.sng import sng_words
    w = n_words(bitstream_length)
    ps = jnp.asarray(ps)
    thr = _threshold_u32(ps).reshape(ps.shape[0], -1)      # (N, B)
    if word_window is None:
        words = sng_words(row_seeds, thr, w, use_pallas=use_pallas)
        return words.reshape(ps.shape + (w,))
    start, n_win = word_window
    words = sng_words(row_seeds, thr, n_win, use_pallas=use_pallas,
                      word_offset=start, total_words=w)
    return words.reshape(ps.shape + (n_win,))


def generate_batch(key: jax.Array, ps: jax.Array, bitstream_length: int,
                   lanes=None, use_pallas: bool = False,
                   word_window: tuple | None = None) -> jax.Array:
    """Generate N packed streams in one pass: ps (N, *batch) -> (N, *batch, W).

    ``lanes`` (default ``arange(N)``) assigns each row its key-lane index:
    rows with distinct lanes are independent; rows sharing a lane share their
    underlying uniforms (a correlation group — XOR of two such rows decodes
    exact |a - b|).  ``word_window`` as in ``generate_batch_seeded``.
    """
    ps = jnp.asarray(ps)
    if lanes is None:
        lanes = jnp.arange(ps.shape[0], dtype=jnp.uint32)
    return generate_batch_seeded(stream_row_seeds(key, lanes), ps,
                                 bitstream_length, use_pallas=use_pallas,
                                 word_window=word_window)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a (..., W, 32) {0,1} tensor into (..., W) uint32 words."""
    shifts = jnp.asarray(_LANE_SHIFTS)
    return jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    """Unpack (..., W) uint32 words into (..., W, 32) {0,1} uint32 bits."""
    shifts = jnp.asarray(_LANE_SHIFTS)
    return (words[..., None] >> shifts) & jnp.uint32(1)


def popcount(words: jax.Array) -> jax.Array:
    """Total number of set bits along the last (word) axis.

    This is the StoB conversion (Section 2-3 step 3): counting ones recovers
    the binary value.  ``lax.population_count`` is the per-word popcount; the
    sum over words mirrors the local-accumulator -> global-accumulator
    hierarchy of the Stoch-IMC architecture (Fig. 8).
    """
    per_word = jax.lax.population_count(words)
    return jnp.sum(per_word.astype(jnp.int32), axis=-1)


def to_value(words: jax.Array, bitstream_length: int) -> jax.Array:
    """Decode a packed bitstream back to its unipolar value in [0, 1]."""
    return popcount(words).astype(jnp.float32) / jnp.float32(bitstream_length)


# --- packed boolean algebra (the IMC primitive gates) ---------------------------

def not_(a: jax.Array) -> jax.Array:
    return ~a


def buff(a: jax.Array) -> jax.Array:
    return a


def and_(a: jax.Array, b: jax.Array) -> jax.Array:
    return a & b


def nand(a: jax.Array, b: jax.Array) -> jax.Array:
    return ~(a & b)


def or_(a: jax.Array, b: jax.Array) -> jax.Array:
    return a | b


def nor(a: jax.Array, b: jax.Array) -> jax.Array:
    return ~(a | b)


def xor(a: jax.Array, b: jax.Array) -> jax.Array:
    # Not an IMC primitive: realized as AND(NAND(a,b), OR(a,b)) in netlists.
    return a ^ b


def mux(a: jax.Array, b: jax.Array, sel: jax.Array) -> jax.Array:
    """Scaled addition (Fig. 4(a)): out = sel ? a : b, value = s*a + (1-s)*b."""
    return (a & sel) | (b & ~sel)


def maj3(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    return (a & b) | (a & c) | (b & c)


def maj5(a, b, c, d, e) -> jax.Array:
    # Majority-of-5 as a boolean identity over packed words.
    ab, ac, ad, ae = a & b, a & c, a & d, a & e
    bc, bd, be = b & c, b & d, b & e
    cd, ce, de = c & d, c & e, d & e
    return (
        (ab & c) | (ab & d) | (ab & e) | (ac & d) | (ac & e) | (ad & e)
        | (bc & d) | (bc & e) | (bd & e) | (cd & e)
    )


GATE_FNS = {
    "NOT": not_,
    "BUFF": buff,
    "AND": and_,
    "NAND": nand,
    "OR": or_,
    "NOR": nor,
    "XOR": xor,
    "MAJ3": maj3,
    "MAJ5": maj5,
    "NMAJ3": lambda a, b, c: ~maj3(a, b, c),
    "NMAJ5": lambda a, b, c, d, e: ~maj5(a, b, c, d, e),
}
