"""Gate-level netlist IR for the 2T-1MTJ IMC method.

Semantics (reverse-engineered from Fig. 7 and Algorithm 1, see DESIGN.md §7):

* A memory subarray is a grid of (row, column) 2T-1MTJ cells.
* A *node* is a named wire.  Every node is placed at a column; a node spans
  either **all rows** (SIMD node — e.g. every bit of a 256-bit stochastic
  stream occupies rows 0..255 of one column, Fig. 7(b)) or **one row**
  (row-local node — e.g. binary bit ``A_i`` lives in row ``i``, Fig. 7(a)).
* A gate reads its input cells and writes one output cell *within one row*
  (the logic current path is intra-row).  A SIMD gate executes in all rows
  simultaneously in a single cycle — that is the intra-subarray parallelism
  the paper's Algorithm 1 exploits.
* If a row-local gate's inputs live in different rows, a BUFF copy must first
  move the operand into the consuming row (Algorithm 1 lines 15-22; the carry
  BUFFs of Fig. 7(a)).

Primary inputs carry value metadata so netlists can be *executed* (on packed
bitstreams for stochastic circuits, on binary bit-vectors for binary ones) as
well as *scheduled* (cycles / placement / energy).
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict
from typing import Iterable, Sequence

ALL_ROWS = -1  # row marker for SIMD nodes spanning every row of the mapping

# Gates supported by the 2T-1MTJ method (Section 4-1) plus the MAJ gates used
# by the binary full-adder construction of [3, 8] (Fig. 7(a)).
SUPPORTED_GATES = ("BUFF", "NOT", "AND", "NAND", "OR", "NOR", "NMAJ3", "NMAJ5", "MAJ3", "MAJ5")
# Reliability-preferred subset used for Stoch-IMC circuits (Section 5-1).
RELIABLE_GATES = ("NOT", "BUFF", "NAND")

# Output-cell preset value required before executing each gate type ([3, 8]):
# AND/OR-like gates preset to '1', NAND/NOR-like to '0'.  Only the existence
# of a preset matters for energy/cycle accounting; every gate needs one.
GATE_ARITY = {
    "BUFF": 1, "NOT": 1,
    "AND": 2, "NAND": 2, "OR": 2, "NOR": 2,
    "MAJ3": 3, "NMAJ3": 3, "MAJ5": 5, "NMAJ5": 5,
}


class PIKind(enum.Enum):
    STOCHASTIC = "stochastic"     # value in [0,1], stochastically written (SBG)
    CONSTANT = "constant"         # constant stochastic stream (still SBG-written)
    BINARY = "binary"             # deterministically written binary bits
    STATE = "state"               # sequential feedback state (e.g. divider Q)


@dataclasses.dataclass(frozen=True)
class PrimaryInput:
    """A netlist primary input.

    ``corr_group``: streams sharing a correlation group are generated from the
    same underlying randomness (required by absolute-value subtraction).
    ``indep_copy``: distinct copies of the same value that must be generated
    independently (square root's A1/A2, the exponential's A_k copies).
    ``row``: ALL_ROWS for SIMD streams, else the row index (binary bit lanes).
    """

    name: str
    kind: PIKind = PIKind.STOCHASTIC
    value_key: str | None = None     # which user-supplied value feeds this PI
    const_value: float | None = None  # for CONSTANT streams
    corr_group: str | None = None
    indep_copy: int = 0
    row: int = ALL_ROWS


@dataclasses.dataclass(frozen=True)
class Gate:
    gid: int
    gtype: str
    inputs: tuple[str, ...]
    output: str
    row: int = ALL_ROWS

    def __post_init__(self):
        if self.gtype not in GATE_ARITY:
            raise ValueError(f"unsupported gate type {self.gtype}")
        if len(self.inputs) != GATE_ARITY[self.gtype]:
            raise ValueError(f"{self.gtype} expects {GATE_ARITY[self.gtype]} "
                             f"inputs, got {len(self.inputs)}")


class Netlist:
    """A DAG of gates over named nodes, with sequential-state support.

    Sequential circuits (the Gaines divider, Fig. 5(d)) declare STATE primary
    inputs and bind them to an output node via ``bind_state``; the executor
    iterates the combinational core over bitstream bits (a wavefront across
    subarrays in the Stoch-IMC architecture, DESIGN.md §7(d)).
    """

    def __init__(self, name: str):
        self.name = name
        self.pis: list[PrimaryInput] = []
        self.gates: list[Gate] = []
        self.outputs: list[str] = []
        # state PI -> (driving node, init value)
        self.state_bindings: dict[str, tuple[str, float]] = {}
        self._node_driver: dict[str, int] = {}
        self._gid = 0
        #: Mutation counter: bumped by every structural mutator so downstream
        #: caches (the plan compiler's per-instance memo) can detect in-place
        #: edits that leave PI/gate counts unchanged.  Structural edits MUST go
        #: through the mutators below — direct list surgery is unsupported.
        self._version = 0

    # -- construction -----------------------------------------------------------
    def add_pi(self, name: str, **kw) -> str:
        if name in self._node_driver or any(p.name == name for p in self.pis):
            raise ValueError(f"duplicate node {name}")
        self.pis.append(PrimaryInput(name=name, **kw))
        self._version += 1
        return name

    def add_gate(self, gtype: str, inputs: Sequence[str], output: str, row: int = ALL_ROWS) -> str:
        if output in self._node_driver or any(p.name == output for p in self.pis):
            raise ValueError(f"duplicate node {output}")
        g = Gate(self._gid, gtype, tuple(inputs), output, row)
        self.gates.append(g)
        self._node_driver[output] = g.gid
        self._gid += 1
        self._version += 1
        return output

    def replace_gate(self, gid: int, gtype: str | None = None,
                     inputs: Sequence[str] | None = None) -> None:
        """Replace an existing gate's type and/or inputs in place.

        The gate keeps its gid and output node.  This is the supported way to
        edit a built netlist: it bumps the mutation counter so compiled plans
        memoized against the old structure are invalidated (the gate *count*
        does not change, so count-based cache guards cannot see the edit).
        """
        old = self.gates[gid]
        assert old.gid == gid  # gids are assigned densely in append order
        new = Gate(gid, gtype if gtype is not None else old.gtype,
                   tuple(inputs) if inputs is not None else old.inputs,
                   old.output, old.row)
        self.gates[gid] = new
        self._version += 1

    def bind_state(self, state_pi: str, driving_node: str, init: float = 0.0) -> None:
        self.state_bindings[state_pi] = (driving_node, init)
        self._version += 1

    def set_outputs(self, names: Iterable[str]) -> None:
        self.outputs = list(names)
        self._version += 1

    # -- queries ----------------------------------------------------------------
    @property
    def is_sequential(self) -> bool:
        return bool(self.state_bindings)

    def pi_names(self) -> list[str]:
        return [p.name for p in self.pis]

    def node_names(self) -> list[str]:
        return self.pi_names() + [g.output for g in self.gates]

    def gate_counts(self) -> dict[str, int]:
        counts: dict[str, int] = defaultdict(int)
        for g in self.gates:
            counts[g.gtype] += 1
        return dict(counts)

    def topological_layers(self) -> list[list[Gate]]:
        """Longest-path layering (Algorithm 1 lines 1-2)."""
        level: dict[str, int] = {p.name: 0 for p in self.pis}
        layers: dict[int, list[Gate]] = defaultdict(list)
        remaining = list(self.gates)
        # Gates are appended in construction (topological) order, so one pass
        # suffices; assert to catch misuse.
        for g in remaining:
            try:
                lvl = 1 + max(level[i] for i in g.inputs)
            except KeyError as e:
                raise ValueError(f"gate {g.gid} input {e} undefined before use") from e
            level[g.output] = lvl
            layers[lvl].append(g)
        return [layers[k] for k in sorted(layers)]

    def inverse_topological_order(self) -> dict[int, int]:
        """Distance of each gate to the primary outputs (Algorithm 1 line 12)."""
        consumers: dict[str, list[Gate]] = defaultdict(list)
        for g in self.gates:
            for i in g.inputs:
                consumers[i].append(g)
        dist: dict[int, int] = {}
        for g in reversed(self.gates):
            ds = [dist[c.gid] + 1 for c in consumers[g.output]]
            dist[g.gid] = max(ds) if ds else 0
        return dist

    def validate(self) -> None:
        for g in self.gates:
            defined = set(self.pi_names()) | {h.output for h in self.gates if h.gid < g.gid}
            for i in g.inputs:
                if i not in defined:
                    raise ValueError(f"gate {g.gid}:{g.gtype} uses undefined node {i}")
        for s, (drv, _) in self.state_bindings.items():
            if s not in self.pi_names():
                raise ValueError(f"state {s} is not a PI")
            if drv not in self.node_names():
                raise ValueError(f"state driver {drv} undefined")


def restrict_to_reliable(net: Netlist) -> None:
    """Assert a Stoch-IMC netlist uses only the high-reliability gate subset."""
    bad = [g.gtype for g in net.gates if g.gtype not in RELIABLE_GATES]
    if bad:
        raise ValueError(f"netlist {net.name} uses non-reliable gates: {sorted(set(bad))}")
