"""Execution-plan compiler: lower a Netlist to fused bit-parallel passes.

The interpreter in ``executor.py`` walks a netlist gate by gate — one Python
dispatch per gate per call.  The paper's throughput, however, comes from
SIMD execution of *whole gate levels* over memory subarrays (Algorithm 1's
intra-subarray parallelism).  This module is the TPU translation of that
step: it compiles a netlist into an ``ExecutionPlan`` — a topologically
leveled schedule where every level's same-type gates are batched into ONE
fused packed-logic pass over stacked uint32 stream words (executed by
``kernels/netlist_exec.py``).

Beyond straight leveling, the compiler runs three structural cleanups before
leveling (all boolean identities, so optimized plans stay bit-identical to
the reference interpreter; disabled together with MUX fusion when per-gate
fault injection must observe every intermediate stream):

  * **BUFF elision** — copy gates become node aliases (zero passes);
  * **structural CSE** — same gate type over the same (resolved, order-
    canonicalized for commutative types) inputs computes the same stream, so
    duplicates alias the first occurrence;
  * **pattern fusion** — the 4-gate stochastic scaled addition
    ``NAND(NAND(a,s), NAND(b, NOT(s)))`` fuses to one MUX pass
    ``(a & s) | (b & ~s)``, and the 4-NAND XOR form
    ``NAND(NAND(a,n1), NAND(b,n1))`` with ``n1 = NAND(a,b)`` fuses to one
    XOR pass (the |a-b| subtractor of Fig. 5(c)) — where the 2T-1MTJ
    hardware needs 4 cycles, one VPU pass needs none of the intermediate
    cell writes.

Compilation also lays out the plan's **stream table**: every non-state PI as
one row of a stacked threshold tensor with a fixed key-lane index
(correlation-group members share a lane), so the executor's batched key mode
generates all of a plan's — or a whole bank's — input streams in ONE fused
SNG pass (core/bitstream.generate_batch / kernels/sng.py) instead of one
dispatch per PI.

Plans are cached per netlist *structure* (PIs, gates, outputs, state
bindings), so repeated executions of equal circuits — every benchmark/test
pattern — hit both the plan cache and the downstream jit cache.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict, defaultdict

from .gates import Netlist, PIKind, PrimaryInput

# Fused 3-input scaled addition: out = (a & s) | (b & ~s).  Not a 2T-1MTJ
# primitive — it exists only at the plan level (and as packed_logic's "mux").
FUSED_MUX = "MUX3"
# Fused 2-input XOR: out = a ^ b, recognized from its 4-NAND netlist form.
# Like MUX3, a plan-level op only (packed_logic's "xor").
FUSED_XOR = "XOR"

_OP_ARITY = {"MUX3": 3, "XOR": 2}

# Gate types whose input order is semantically irrelevant — their CSE key is
# order-canonicalized so NAND(a,b) and NAND(b,a) intern to one pass.
_COMMUTATIVE = {"AND", "NAND", "OR", "NOR", "XOR",
                "MAJ3", "NMAJ3", "MAJ5", "NMAJ5"}


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledOp:
    """One fused pass: all same-type gates of one level, batched.

    ``inputs[j][i]`` is the node feeding input position ``j`` of the i-th
    batched gate; ``outputs[i]`` its output node; ``gids[i]`` the originating
    gate id (used to key per-gate fault-injection streams).  For ``MUX3``,
    ``gids[i]`` is the id of the root NAND of the fused 4-gate group.

    ``neg[j]`` complements input position ``j`` of every batched gate before
    the base op is applied — how absorbed lone NOT gates survive inside their
    consuming pass (``()`` means no complemented inputs).  Gates only batch
    with same-(op, neg) peers, so the mask is pass-wide.
    """

    op: str
    gids: tuple[int, ...]
    inputs: tuple[tuple[str, ...], ...]   # arity x n_batched
    outputs: tuple[str, ...]
    neg: tuple[bool, ...] = ()            # per-input complement mask

    @property
    def n_batched(self) -> int:
        return len(self.outputs)


@dataclasses.dataclass(frozen=True)
class StreamTable:
    """Static layout of a plan's PI streams for one batched SNG pass.

    Row ``i`` describes one non-state PI: its node name, where its value
    comes from (``value_keys[i]`` into the caller's values dict, else
    ``const_values[i]``), and its fixed key-lane index ``lanes[i]``.  Lanes
    are assigned per plan — correlation groups (sorted by group name, members
    in declaration order) take lanes ``0..n_groups-1`` with every member of a
    group *sharing* its lane (shared uniforms => XOR decodes exact |a-b|),
    then the uncorrelated singles take one fresh lane each in declaration
    order.  The lane assignment mirrors the legacy per-PI key-split order, so
    the two disciplines differ only in how randomness is derived, not in
    which PI is "first".
    """

    names: tuple[str, ...]
    value_keys: tuple[str | None, ...]
    const_values: tuple[float | None, ...]
    lanes: tuple[int, ...]
    n_groups: int

    @property
    def n_rows(self) -> int:
        return len(self.names)


def build_stream_table(pis) -> StreamTable:
    """Lay out the stream table for a PI sequence (see ``StreamTable``)."""
    groups: dict[str, list[PrimaryInput]] = {}
    singles: list[PrimaryInput] = []
    for pi in pis:
        if pi.kind == PIKind.STATE:
            continue
        if pi.corr_group is not None:
            groups.setdefault(pi.corr_group, []).append(pi)
        else:
            singles.append(pi)
    rows: list[tuple[PrimaryInput, int]] = []
    for g, (_, gpis) in enumerate(sorted(groups.items())):
        rows.extend((pi, g) for pi in gpis)
    rows.extend((pi, len(groups) + k) for k, pi in enumerate(singles))
    return StreamTable(
        names=tuple(pi.name for pi, _ in rows),
        value_keys=tuple(pi.value_key for pi, _ in rows),
        const_values=tuple(pi.const_value for pi, _ in rows),
        lanes=tuple(lane for _, lane in rows),
        n_groups=len(groups),
    )


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """A netlist lowered to leveled, type-batched fused passes.

    ``eq=False``: plans are interned in the structure-keyed cache, so
    identity equality/hash is both correct and cheap as a jit static arg.

    ``aliases`` maps every *observable* node (primary output / state driver)
    elided by BUFF elision or CSE to the surviving node computing the
    identical stream; the executor re-exposes them in its node environment.
    Non-observable elided nodes need no alias — every use was rewritten to
    the survivor at compile time.  ``stream_table`` is the batched SNG
    layout of the plan's PI streams (see ``StreamTable``).

    ``serial`` is a process-wide monotone compile stamp: it gives plans a
    deterministic canonical order (bank templates sort members by it) without
    hashing structures on the serving hot path.
    """

    name: str
    pis: tuple[PrimaryInput, ...]
    n_gates: int                                  # original gate count
    levels: tuple[tuple[CompiledOp, ...], ...]
    outputs: tuple[str, ...]
    state_pis: tuple[str, ...]
    state_drivers: tuple[str, ...]
    state_inits: tuple[float, ...]
    fused: bool
    n_fused_mux: int
    stream_table: StreamTable
    aliases: tuple[tuple[str, str], ...] = ()     # elided node -> survivor
    n_fused_xor: int = 0
    n_buff_elided: int = 0
    n_cse_elided: int = 0
    n_fused_and: int = 0
    n_not_absorbed: int = 0
    serial: int = -1

    @property
    def is_sequential(self) -> bool:
        return bool(self.state_pis)

    @property
    def is_identity(self) -> bool:
        """True for the no-op padding member (no PIs, gates, or outputs)."""
        return (not self.pis and not self.n_gates and not self.outputs
                and not self.state_pis)

    @property
    def n_passes(self) -> int:
        """Fused passes executed per evaluation (vs n_gates for the
        interpreter) — the compile-time speedup headline."""
        return sum(len(level) for level in self.levels)

    @property
    def n_elided(self) -> int:
        """Nodes removed from the pass schedule by BUFF elision and CSE."""
        return self.n_buff_elided + self.n_cse_elided

    def stream_pi_names(self) -> tuple[str, ...]:
        """Non-state PIs, in declaration order (the streams the executor
        generates; state PIs are carried by the sequential scan)."""
        return tuple(p.name for p in self.pis if p.kind != PIKind.STATE)


# ------------------------- pre-leveling optimization -------------------------------

@dataclasses.dataclass(frozen=True)
class _WGate:
    """Working gate record during compilation (inputs already alias-resolved)."""

    gid: int
    gtype: str
    inputs: tuple[str, ...]
    output: str


def _elide_and_cse(gates):
    """BUFF elision + structural CSE over a topological gate list.

    Returns ``(kept, alias, n_buff, n_cse)``.  BUFF gates become aliases to
    their (resolved) input; a gate whose (type, resolved inputs) — input
    order canonicalized for commutative types — matches an earlier survivor
    aliases that survivor's output.  Both are exact stream identities: the
    interpreter computes the same deterministic function at both sites, so
    aliasing is bit-identical, not approximate.  Gates are visited in
    construction (topological) order, so alias chains resolve in one pass.
    """
    alias: dict[str, str] = {}
    seen: dict[tuple, str] = {}
    kept: list[_WGate] = []
    n_buff = n_cse = 0
    for g in gates:
        ins = tuple(alias.get(i, i) for i in g.inputs)
        if g.gtype == "BUFF":
            alias[g.output] = ins[0]
            n_buff += 1
            continue
        key = (g.gtype, tuple(sorted(ins)) if g.gtype in _COMMUTATIVE else ins)
        prev = seen.get(key)
        if prev is not None:
            alias[g.output] = prev
            n_cse += 1
            continue
        seen[key] = g.output
        kept.append(_WGate(g.gid, g.gtype, ins, g.output))
    return kept, alias, n_buff, n_cse


def _count_uses(gates) -> dict[str, int]:
    uses: dict[str, int] = defaultdict(int)
    for g in gates:
        for i in g.inputs:
            uses[i] += 1
    return uses


def _find_mux_fusions(
        gates, protected: set[str],
) -> tuple[dict[int, tuple[str, str, str]], set[int]]:
    """Detect fusable 4-gate MUX groups over a working gate list.

    Returns ``(roots, dead)``: ``roots`` maps the root NAND's gid to its
    ``(a, b, s)`` operand nodes; ``dead`` holds gids of the three absorbed
    feeder gates.  A feeder is absorbed only when its output has exactly one
    use and is neither a primary output nor a state driver — otherwise the
    intermediate stream is observable and must stay materialized.
    """
    driver = {g.output: g for g in gates}
    uses = _count_uses(gates)

    def absorbable(node: str) -> bool:
        return uses[node] == 1 and node not in protected

    roots: dict[int, tuple[str, str, str]] = {}
    dead: set[int] = set()
    for g in gates:
        if g.gtype != "NAND" or g.gid in dead:
            continue
        g1 = driver.get(g.inputs[0])
        g2 = driver.get(g.inputs[1])
        if g1 is None or g2 is None or g1.gid == g2.gid:
            continue
        if g1.gtype != "NAND" or g2.gtype != "NAND":
            continue
        if {g1.gid, g2.gid} & dead:
            continue
        found = None
        for x, y in ((g1, g2), (g2, g1)):
            # y = NAND(b, sb) with sb = NOT(s), x = NAND(a, s).
            for bi in (0, 1):
                sb_gate = driver.get(y.inputs[1 - bi])
                if sb_gate is None or sb_gate.gtype != "NOT" or sb_gate.gid in dead:
                    continue
                s = sb_gate.inputs[0]
                if s not in x.inputs:
                    continue
                a = x.inputs[1] if x.inputs[0] == s else x.inputs[0]
                b = y.inputs[bi]
                if (absorbable(x.output) and absorbable(y.output)
                        and absorbable(sb_gate.output)):
                    found = (a, b, s, x.gid, y.gid, sb_gate.gid)
                    break
            if found:
                break
        if found:
            a, b, s, xg, yg, sg = found
            roots[g.gid] = (a, b, s)
            dead.update((xg, yg, sg))
    return roots, dead


def _find_xor_fusions(gates, protected: set[str],
                      dead: set[int]) -> dict[int, tuple[str, str]]:
    """Detect the 4-NAND XOR form and fuse it to one XOR pass.

    Pattern (Fig. 5(c)'s |a-b| subtractor): ``n1 = NAND(a, b)``;
    ``root = NAND(NAND(a, n1), NAND(b, n1))`` computes ``a ^ b``.  The three
    feeder NANDs are absorbed only when they are single-purpose — ``n1`` used
    exactly by the two mid gates, each mid gate used only by the root, and
    none of them observable (primary output / state driver).  Extends
    ``dead`` in place; returns root gid -> (a, b).
    """
    driver = {g.output: g for g in gates}
    uses = _count_uses(gates)
    roots: dict[int, tuple[str, str]] = {}
    for g in gates:
        if g.gtype != "NAND" or g.gid in dead:
            continue
        x = driver.get(g.inputs[0])
        y = driver.get(g.inputs[1])
        if x is None or y is None or x.gid == y.gid:
            continue
        if x.gtype != "NAND" or y.gtype != "NAND":
            continue
        if {x.gid, y.gid} & dead:
            continue
        found = None
        for c in x.inputs:                       # shared mid node candidate
            if c not in y.inputs:
                continue
            n1 = driver.get(c)
            if n1 is None or n1.gtype != "NAND" or n1.gid in dead:
                continue
            a = x.inputs[1] if x.inputs[0] == c else x.inputs[0]
            b = y.inputs[1] if y.inputs[0] == c else y.inputs[0]
            if a == b or set(n1.inputs) != {a, b}:
                continue
            if (uses[c] == 2 and uses[x.output] == 1 and uses[y.output] == 1
                    and not {c, x.output, y.output} & protected):
                found = (a, b, x.gid, y.gid, n1.gid)
                break
        if found:
            a, b, xg, yg, ng = found
            roots[g.gid] = (a, b)
            dead.update((xg, yg, ng))
    return roots


@dataclasses.dataclass(frozen=True)
class _WOp:
    """Post-pattern-fusion working op (gate type or MUX3/XOR, + neg mask)."""

    gid: int
    op: str
    inputs: tuple[str, ...]
    neg: tuple[bool, ...]
    output: str


def _fold_ands(ops: "list[_WOp]", protected: set[str]) -> int:
    """Fold ``NOT(NAND(a, b))`` pairs into one fused AND pass.

    The 2T-1MTJ method has no AND primitive — stochastic multiplication is a
    NAND feeding a NOT (two memory cycles) — but the plan level does: the
    boolean identity ``NOT(NAND(a, b)) == AND(a, b)`` collapses the pair to
    one pass whenever the intermediate NAND output is single-use and
    unobservable.  The surviving op keeps the NOT's gid and output node (and
    the NAND's neg mask, vacuously all-False at this stage).  Mutates ``ops``
    in place; returns the number of folded pairs.
    """
    driver = {w.output: i for i, w in enumerate(ops)}
    uses = _count_uses(ops)
    dead: set[int] = set()
    n = 0
    for i, w in enumerate(ops):
        if w.op != "NOT" or w.neg[0]:
            continue
        j = driver.get(w.inputs[0])
        if j is None or j in dead:
            continue
        s = ops[j]
        if s.op != "NAND" or uses[s.output] != 1 or s.output in protected:
            continue
        ops[i] = _WOp(w.gid, "AND", s.inputs, s.neg, w.output)
        dead.add(j)
        n += 1
    if dead:
        ops[:] = [w for i, w in enumerate(ops) if i not in dead]
    return n


def _absorb_nots(ops: "list[_WOp]", protected: set[str]) -> int:
    """Fuse lone NOT gates into their consuming pass via the neg mask.

    A NOT whose output has exactly one use and is unobservable disappears:
    its consumer reads the NOT's *input* with the complement folded into the
    pass (``CompiledOp.neg``) — an exact stream identity, one fewer pass.
    Ops are visited in topological order, so NOT chains collapse step by step
    (``NOT(NOT(x))`` absorbs to a plain ``x`` read).  Mutates ``ops`` in
    place; returns the number of absorbed NOTs.
    """
    uses = _count_uses(ops)
    consumers: dict[str, list[tuple[int, int]]] = defaultdict(list)
    for i, w in enumerate(ops):
        for p, nm in enumerate(w.inputs):
            consumers[nm].append((i, p))
    dead: set[int] = set()
    n = 0
    for i, w in enumerate(ops):
        if w.op != "NOT" or i in dead:
            continue
        if w.output in protected or uses[w.output] != 1:
            continue
        (ci, pos), = consumers[w.output]
        if ci in dead:
            continue
        c = ops[ci]
        src = w.inputs[0]
        ins = list(c.inputs)
        ins[pos] = src
        neg = list(c.neg)
        # NOT with its own neg set is a double negation: absorbing it passes
        # the source through uncomplemented.
        neg[pos] = neg[pos] != (not w.neg[0])
        ops[ci] = _WOp(c.gid, c.op, tuple(ins), tuple(neg), c.output)
        consumers[src].append((ci, pos))
        uses[src] += 1
        dead.add(i)
        n += 1
    if dead:
        ops[:] = [w for i, w in enumerate(ops) if i not in dead]
    return n


# -------------------------------- compilation -------------------------------------

def _signature(net: Netlist) -> tuple:
    return (
        net.name,
        tuple(net.pis),
        tuple((g.gid, g.gtype, g.inputs, g.output) for g in net.gates),
        tuple(net.outputs),
        tuple(sorted((s, d, i) for s, (d, i) in net.state_bindings.items())),
    )


# Both structural caches are LRU-bounded: serving traffic compiles a new
# plan/bank per *bucket shape*, and an unbounded dict would grow with every
# distinct member set ever seen.  Eviction only drops interning — an evicted
# structure recompiles to a fresh (bit-identical) plan on next use — so the
# caps trade recompiles for memory, never correctness.
_PLAN_CACHE: "OrderedDict[tuple, ExecutionPlan]" = OrderedDict()
_BANK_CACHE: "OrderedDict[tuple, BankPlan]" = OrderedDict()
_CACHE_CAPS = {"plans": 1024, "banks": 256}
_EVICTIONS = {"plan_evictions": 0, "bank_evictions": 0}
# Cumulative optimizer counters across cache-missing compiles (reported by
# cache_info so perf work can see how many nodes the structural passes
# removed, and reset by clear_cache).
_OPT_COUNTS = {"buff_elided": 0, "cse_elided": 0, "mux_fused": 0,
               "xor_fused": 0, "and_fused": 0, "not_absorbed": 0}
# Monotone compile stamp for ExecutionPlan.serial.
_SERIAL = itertools.count()


def _cache_get(cache: OrderedDict, key):
    hit = cache.get(key)
    if hit is not None:
        cache.move_to_end(key)
    return hit


def _cache_put(cache: OrderedDict, key, value, cap_key: str,
               evict_key: str) -> None:
    cache[key] = value
    cache.move_to_end(key)
    while len(cache) > _CACHE_CAPS[cap_key]:
        cache.popitem(last=False)
        _EVICTIONS[evict_key] += 1


def set_cache_caps(plans: int | None = None,
                   banks: int | None = None) -> dict[str, int]:
    """Set the LRU caps (entries) of the plan/bank caches; returns the caps.

    Shrinking a cap evicts least-recently-used entries immediately (counted
    in ``cache_info()['plan_evictions'/'bank_evictions']``).
    """
    if plans is not None:
        _CACHE_CAPS["plans"] = int(plans)
        while len(_PLAN_CACHE) > _CACHE_CAPS["plans"]:
            _PLAN_CACHE.popitem(last=False)
            _EVICTIONS["plan_evictions"] += 1
    if banks is not None:
        _CACHE_CAPS["banks"] = int(banks)
        while len(_BANK_CACHE) > _CACHE_CAPS["banks"]:
            _BANK_CACHE.popitem(last=False)
            _EVICTIONS["bank_evictions"] += 1
    return dict(_CACHE_CAPS)


def cache_info() -> dict[str, int]:
    return {"plans": len(_PLAN_CACHE), "banks": len(_BANK_CACHE),
            "plan_cap": _CACHE_CAPS["plans"], "bank_cap": _CACHE_CAPS["banks"],
            **_EVICTIONS, **_OPT_COUNTS}


def clear_cache() -> None:
    _PLAN_CACHE.clear()
    _BANK_CACHE.clear()
    for k in _OPT_COUNTS:
        _OPT_COUNTS[k] = 0
    for k in _EVICTIONS:
        _EVICTIONS[k] = 0


def compile_plan(net: Netlist, fuse_mux: bool = True) -> ExecutionPlan:
    """Compile ``net`` into an ExecutionPlan (structure-cached).

    ``fuse_mux=False`` keeps every gate as its own batched op, disabling ALL
    structural optimization (MUX/XOR fusion, BUFF elision, CSE) — required
    when per-gate fault injection must observe the intermediate streams
    (Table 4), and by construction bit-identical to the interpreter in all
    cases.  The optimized default is bit-identical too (every pass is an
    exact stream identity); only the per-gate injection points differ.

    A fast per-instance memo front-runs the structural cache so the hot
    execute() path doesn't rebuild the signature every call.  The memo is
    guarded by the netlist's mutation counter (bumped by every Netlist
    mutator, including in-place ``replace_gate`` edits that leave the gate
    count unchanged) plus the PI/gate counts as a belt-and-braces check, so
    mutating a compiled netlist through its mutators always recompiles.
    """
    memo = net.__dict__.setdefault("_plan_memo", {})
    memo_key = (fuse_mux, getattr(net, "_version", None),
                len(net.pis), len(net.gates))
    hit = memo.get(memo_key)
    if hit is not None:
        return hit

    # Entries from older netlist versions can never hit again — drop them so
    # a mutate/recompile loop doesn't grow the memo (at most the two fuse_mux
    # variants of the current version remain).
    for k in [k for k in memo if k[1] != memo_key[1]]:
        del memo[k]

    key = (_signature(net), fuse_mux)
    cached = _cache_get(_PLAN_CACHE, key)
    if cached is not None:
        memo[memo_key] = cached
        return cached

    net.validate()
    protected = set(net.outputs) | {drv for drv, _ in net.state_bindings.values()}
    if fuse_mux:
        # Structural cleanups first (BUFF elision + CSE rewrite the graph the
        # pattern matchers see), then 4-gate pattern fusion on the survivors.
        gates, alias, n_buff, n_cse = _elide_and_cse(net.gates)
        # Only observable elided nodes (outputs / state drivers) need
        # re-exposing at execution time — every other use was rewritten to
        # the survivor.  Restricting the recorded aliases to those keeps the
        # next step sound: a dangling alias to a node fusion then absorbs
        # would crash the re-expose loop.
        alias = {s: d for s, d in alias.items() if s in protected}
        # An elided observable node aliases its survivor — which makes the
        # SURVIVOR observable too: resolve protection through the aliases so
        # pattern fusion cannot absorb a node some alias must re-expose.
        protected |= set(alias.values())
        mux_roots, dead = _find_mux_fusions(gates, protected)
        xor_roots = _find_xor_fusions(gates, protected, dead)
    else:
        # Per-gate fault injection must observe every intermediate stream:
        # no elision, no dedup, no fusion (mirrors the interpreter exactly).
        gates = [_WGate(g.gid, g.gtype, g.inputs, g.output) for g in net.gates]
        alias, n_buff, n_cse = {}, 0, 0
        mux_roots, dead, xor_roots = {}, set(), {}

    # Materialize the post-pattern-fusion op list, then run the NOT-directed
    # cleanups on it: AND folding (NOT(NAND) pairs) and lone-NOT absorption
    # into consuming passes.  Both run after the 4-gate matchers so the
    # NOT-bearing MUX/XOR forms are recognized first.
    ops: list[_WOp] = []
    for g in gates:
        if g.gid in dead:
            continue
        if g.gid in mux_roots:
            op, ins = FUSED_MUX, mux_roots[g.gid]
        elif g.gid in xor_roots:
            op, ins = FUSED_XOR, xor_roots[g.gid]
        else:
            op, ins = g.gtype, g.inputs
        ops.append(_WOp(g.gid, op, tuple(ins), (False,) * len(ins), g.output))
    if fuse_mux:
        n_and = _fold_ands(ops, protected)
        n_not = _absorb_nots(ops, protected)
    else:
        n_and = n_not = 0
    _OPT_COUNTS["buff_elided"] += n_buff
    _OPT_COUNTS["cse_elided"] += n_cse
    _OPT_COUNTS["mux_fused"] += len(mux_roots)
    _OPT_COUNTS["xor_fused"] += len(xor_roots)
    _OPT_COUNTS["and_fused"] += n_and
    _OPT_COUNTS["not_absorbed"] += n_not

    # Longest-path leveling over the optimized op graph (PIs at level 0).
    # Ops batch within a level by (op, neg) — a complemented-input variant is
    # its own pass.
    level: dict[str, int] = {p.name: 0 for p in net.pis}
    by_level: dict[int, dict[tuple, list[tuple[int, tuple[str, ...], str]]]] = \
        defaultdict(lambda: defaultdict(list))
    for w in ops:
        lvl = 1 + max(level[i] for i in w.inputs)
        level[w.output] = lvl
        neg = w.neg if any(w.neg) else ()
        by_level[lvl][(w.op, neg)].append((w.gid, w.inputs, w.output))

    levels = []
    for lvl in sorted(by_level):
        lvl_ops = []
        for (op, neg), entries in by_level[lvl].items():
            arity = len(entries[0][1])
            lvl_ops.append(CompiledOp(
                op=op,
                gids=tuple(e[0] for e in entries),
                inputs=tuple(tuple(e[1][j] for e in entries) for j in range(arity)),
                outputs=tuple(e[2] for e in entries),
                neg=neg,
            ))
        levels.append(tuple(lvl_ops))

    state_items = sorted(net.state_bindings.items())
    plan = ExecutionPlan(
        name=net.name,
        pis=tuple(net.pis),
        n_gates=len(net.gates),
        levels=tuple(levels),
        outputs=tuple(net.outputs),
        state_pis=tuple(s for s, _ in state_items),
        state_drivers=tuple(d for _, (d, _) in state_items),
        state_inits=tuple(i for _, (_, i) in state_items),
        fused=fuse_mux,
        n_fused_mux=len(mux_roots),
        stream_table=build_stream_table(net.pis),
        aliases=tuple(sorted(alias.items())),
        n_fused_xor=len(xor_roots),
        n_buff_elided=n_buff,
        n_cse_elided=n_cse,
        n_fused_and=n_and,
        n_not_absorbed=n_not,
        serial=next(_SERIAL),
    )
    _cache_put(_PLAN_CACHE, key, plan, "plans", "plan_evictions")
    memo[memo_key] = plan
    return plan


# ---------------------------- bank-level merging -----------------------------------
#
# The paper's Fig. 8 bank executes many circuit instances side by side: every
# subarray pass fires the same gate type across ALL columns of ALL subarrays,
# so independent circuits mapped to disjoint columns share passes.  The TPU
# translation: merge N (possibly different) netlists' plans into ONE plan
# whose levels type-batch gates *across* members — one CompiledOp pass covers
# every same-type gate of a level bank-wide, and N app instances execute as a
# single fused XLA program (executor.execute_many).

def member_prefix(index: int) -> str:
    """Node-namespace prefix for bank member ``index`` ("b3/out" etc.)."""
    return f"b{index}/"


@dataclasses.dataclass(frozen=True, eq=False)
class BankPlan:
    """N member plans merged for bank-level execution.

    Combinational members merge into one word-parallel plan (``comb``);
    sequential members merge into one plan run as a single scan (``seq``) —
    mixing them would re-execute combinational logic per bitstream bit.
    ``comb_members`` / ``seq_members`` hold the caller-order member indices of
    each group, in merge order (ascending), which is also the order of the
    per-member flat fault-key blocks (see ``executor._execute_bank``).
    """

    name: str
    members: tuple[ExecutionPlan, ...]
    comb: ExecutionPlan | None
    seq: ExecutionPlan | None
    comb_members: tuple[int, ...]
    seq_members: tuple[int, ...]
    #: Process-wide monotone build stamp (like ExecutionPlan.serial): a
    #: stable identity token that — unlike id() — can never alias a
    #: garbage-collected bank after cache eviction.
    serial: int = -1

    @property
    def n_members(self) -> int:
        return len(self.members)

    @property
    def n_identity_members(self) -> int:
        """Slots filled by the no-op identity padding plan."""
        return sum(1 for m in self.members if m.is_identity)

    @property
    def n_passes(self) -> int:
        """Fused passes per bank-wide evaluation (the merged headline)."""
        return (self.comb.n_passes if self.comb else 0) + \
               (self.seq.n_passes if self.seq else 0)

    @property
    def n_passes_looped(self) -> int:
        """Passes a per-member dispatch loop would execute (the baseline)."""
        return sum(m.n_passes for m in self.members)


def merge_plans(plans: "list[ExecutionPlan]", indices: "list[int]",
                name: str) -> ExecutionPlan:
    """Merge same-kind plans into one cross-member type-batched plan.

    ``indices`` are the members' caller-order positions — they become the node
    namespace prefixes, so the executor can scatter outputs back per member.
    Members are independent graphs, so each gate keeps its per-member level;
    merging level ``L`` across members and type-batching within it is a valid
    re-leveling of the union graph.  Gate ids are offset by the running gate
    count so they index a flat per-merge-order fault-key array.  Identity
    (padding) members contribute no nodes and are exempt from the kind check,
    so a padded bank template can carry them in either group.
    """
    if len({p.is_sequential for p in plans if not p.is_identity}) > 1:
        raise ValueError("merge_plans: cannot mix sequential and "
                         "combinational members in one merged plan")
    prefixes = [member_prefix(i) for i in indices]
    offsets = []
    off = 0
    for p in plans:
        offsets.append(off)
        off += p.n_gates

    n_levels = max(len(p.levels) for p in plans)
    levels = []
    for lvl in range(n_levels):
        by_op: dict[tuple, list[tuple]] = {}
        for p, pre, goff in zip(plans, prefixes, offsets):
            if lvl >= len(p.levels):
                continue
            for cop in p.levels[lvl]:
                by_op.setdefault((cop.op, cop.neg), []).append((cop, pre, goff))
        ops = []
        for (op, neg), entries in by_op.items():
            arity = len(entries[0][0].inputs)
            ops.append(CompiledOp(
                op=op,
                gids=tuple(goff + g for cop, _, goff in entries
                           for g in cop.gids),
                inputs=tuple(tuple(pre + n for cop, pre, _ in entries
                                   for n in cop.inputs[j])
                             for j in range(arity)),
                outputs=tuple(pre + o for cop, pre, _ in entries
                              for o in cop.outputs),
                neg=neg,
            ))
        levels.append(tuple(ops))

    pis = tuple(dataclasses.replace(
        pi, name=pre + pi.name,
        corr_group=(pre + pi.corr_group) if pi.corr_group else None)
        for p, pre in zip(plans, prefixes) for pi in p.pis)
    # NOTE: the merged stream table is laid out over the *merged* PI list, so
    # its lanes differ from the members' own tables.  Bank execution generates
    # streams from each member's table with that member's key (preserving
    # merged == looped bit-identity); the merged table exists for plans
    # executed standalone.
    return ExecutionPlan(
        name=name,
        pis=pis,
        n_gates=off,
        levels=tuple(levels),
        outputs=tuple(pre + o for p, pre in zip(plans, prefixes)
                      for o in p.outputs),
        state_pis=tuple(pre + s for p, pre in zip(plans, prefixes)
                        for s in p.state_pis),
        state_drivers=tuple(pre + d for p, pre in zip(plans, prefixes)
                            for d in p.state_drivers),
        state_inits=tuple(i for p in plans for i in p.state_inits),
        # Identity padding members are vacuously "fused"; only real members
        # decide whether the merged plan admits per-gate fault injection.
        fused=any(p.fused for p in plans if not p.is_identity),
        n_fused_mux=sum(p.n_fused_mux for p in plans),
        stream_table=build_stream_table(pis),
        aliases=tuple((pre + a, pre + b) for p, pre in zip(plans, prefixes)
                      for a, b in p.aliases),
        n_fused_xor=sum(p.n_fused_xor for p in plans),
        n_buff_elided=sum(p.n_buff_elided for p in plans),
        n_cse_elided=sum(p.n_cse_elided for p in plans),
        n_fused_and=sum(p.n_fused_and for p in plans),
        n_not_absorbed=sum(p.n_not_absorbed for p in plans),
        serial=next(_SERIAL),
    )


def _build_bank(members: "tuple[ExecutionPlan, ...]", key: tuple,
                name: str | None) -> BankPlan:
    """Merge a member-plan tuple into a (cached) BankPlan under ``key``."""
    cached = _cache_get(_BANK_CACHE, key)
    if cached is not None:
        return cached
    comb_idx = tuple(i for i, m in enumerate(members) if not m.is_sequential)
    seq_idx = tuple(i for i, m in enumerate(members) if m.is_sequential)
    bank_name = name or f"bank{len(members)}"
    comb = merge_plans([members[i] for i in comb_idx], list(comb_idx),
                       f"{bank_name}/comb") if comb_idx else None
    seq = merge_plans([members[i] for i in seq_idx], list(seq_idx),
                      f"{bank_name}/seq") if seq_idx else None
    bank = BankPlan(name=bank_name, members=members, comb=comb, seq=seq,
                    comb_members=comb_idx, seq_members=seq_idx,
                    serial=next(_SERIAL))
    _cache_put(_BANK_CACHE, key, bank, "banks", "bank_evictions")
    return bank


def compile_bank_plan(nets: "list[Netlist]", fuse_mux: bool = True,
                      name: str | None = None) -> BankPlan:
    """Compile N netlists into one bank-level plan (cached).

    Members may repeat (N instances of one circuit) and mix combinational and
    sequential netlists; equal structures intern to the same member plan, so
    the cache key is the member-plan identity tuple.  ``fuse_mux=False``
    compiles combinational members unfused (per-gate fault injection);
    sequential members always fuse — their injection points are PI/output
    streams, outside the plan (mirroring ``executor._plan_for``).
    """
    if not nets:
        raise ValueError("compile_bank_plan: need at least one netlist")
    members = tuple(compile_plan(n, fuse_mux=fuse_mux or n.is_sequential)
                    for n in nets)
    return _build_bank(members, (members, fuse_mux), name)


# --------------------------- canonical bank templates ------------------------------
#
# Serving traffic cannot afford a fresh BankPlan (and jit trace) per request
# set: the member multiset changes every arrival.  A *bank template* is the
# canonical padded form of a request multiset — distinct member structures in
# deterministic (compile-serial) order, each structure's slot count rounded up
# to a power of two, optionally topped up with no-op identity members to a
# fixed total — so every request set that fits a bucket reuses ONE BankPlan
# and ONE jit program, with unbound slots masked out at execution time
# (executor.execute_bank's ``active`` mask).

IDENTITY_NAME = "__pad__"
_IDENTITY_PLAN: "list[ExecutionPlan]" = []


def identity_plan() -> ExecutionPlan:
    """The no-op padding member: no PIs, no gates, no outputs.

    Merging it into a bank contributes zero passes and zero streams; it
    exists so a template's slot count can be padded to a fixed size.  A
    process-wide singleton (held outside the LRU cache, so eviction can never
    split its identity and fork bank-template cache keys).
    """
    if not _IDENTITY_PLAN:
        _IDENTITY_PLAN.append(compile_plan(Netlist(IDENTITY_NAME)))
    return _IDENTITY_PLAN[0]


def bucket_count(n: int, min_count: int = 1) -> int:
    """Smallest power of two >= max(n, min_count) — the slot-count bucket."""
    n = max(n, min_count, 1)
    return 1 << (n - 1).bit_length()


def template_members(plans: "list[ExecutionPlan]", n_slots: int | None = None,
                     pad_counts: bool = True,
                     pad_total: bool = False) -> "tuple[ExecutionPlan, ...]":
    """Canonical padded slot layout for a request multiset.

    Distinct structures are laid out in compile-serial order, each repeated
    to its (power-of-two-padded, when ``pad_counts``) count; identity padding
    members fill the tail up to ``n_slots`` (or, with ``pad_total`` and no
    explicit ``n_slots``, up to the next power of two of the padded member
    count).  Two request sets whose padded multisets agree produce the
    *identical* tuple — the bank-template bucket key.
    """
    counts: "dict[ExecutionPlan, int]" = {}
    for p in plans:
        counts[p] = counts.get(p, 0) + 1          # plans intern: id == structure
    members: "list[ExecutionPlan]" = []
    for p in sorted(counts, key=lambda q: q.serial):
        c = counts[p]
        members.extend([p] * (bucket_count(c) if pad_counts else c))
    if n_slots is None and pad_total:
        n_slots = bucket_count(len(members))
    if n_slots is not None:
        if len(members) > n_slots:
            raise ValueError(f"template needs {len(members)} slots, "
                             f"n_slots={n_slots}")
        members.extend([identity_plan()] * (n_slots - len(members)))
    return tuple(members)


def compile_bank_template(plans: "list[ExecutionPlan]",
                          n_slots: int | None = None, pad_counts: bool = True,
                          pad_total: bool = False,
                          name: str | None = None, scope=None) -> BankPlan:
    """Compile the canonical padded bank for a request multiset (cached).

    The returned BankPlan's member list is the ``template_members`` layout;
    bind requests to the slots holding their plan and execute with
    ``executor.execute_bank(..., active=mask)``.  Padded execution is
    bit-identical per bound slot to standalone ``execute`` — unbound slots
    only ever add masked no-op work.

    ``scope`` (any hashable, default ``None``) partitions the cache: the
    multi-bank server passes the target *device*, so each device serves from
    its own template instance — one device's LRU churn cannot evict the
    templates (and the jit executables their serials anchor) another device
    is still serving from, and bucket-warmth bookkeeping keyed on
    ``BankPlan.serial`` is automatically per device.
    """
    if not plans:
        raise ValueError("compile_bank_template: need at least one plan")
    members = template_members(plans, n_slots=n_slots, pad_counts=pad_counts,
                               pad_total=pad_total)
    return _build_bank(members, (members, True, scope),
                       name or f"tmpl{len(members)}")


def compile_bank_members(members: "tuple[ExecutionPlan, ...]",
                         name: str | None = None, scope=None) -> BankPlan:
    """Compile a bank for an *explicit* slot layout (cached).

    ``members`` is a ready-made slot tuple — typically a ``template_members``
    layout the serving dispatcher computed once and then binds requests
    against, compiling the actual bank lazily per target device (``scope``,
    see ``compile_bank_template``).  No padding is applied: the caller owns
    the layout, and re-deriving it here could re-pad identity tails into a
    different (non-canonical) tuple.
    """
    if not members:
        raise ValueError("compile_bank_members: need at least one member")
    members = tuple(members)
    return _build_bank(members, (members, True, scope),
                       name or f"tmpl{len(members)}")


def merged_pass_count(plans: "list[ExecutionPlan]") -> int:
    """Fused passes a bank merging exactly ``plans`` would execute.

    Mirrors ``merge_plans``'s batching rule — per level, one pass per
    distinct (op, neg) across members, combinational and sequential groups
    leveled independently — without building the merged plan.  Used by
    ``arch.evaluate_bank_plan`` to price padded-slot overhead: the padded
    bank's pass count minus the active members' merged pass count is the
    work padding added.
    """
    total = 0
    for seq in (False, True):
        by_level: "dict[int, set]" = defaultdict(set)
        for p in plans:
            if p.is_sequential != seq:
                continue
            for lvl, lev in enumerate(p.levels):
                for cop in lev:
                    by_level[lvl].add((cop.op, cop.neg))
        total += sum(len(s) for s in by_level.values())
    return total
