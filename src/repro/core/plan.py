"""Execution-plan compiler: lower a Netlist to fused bit-parallel passes.

The interpreter in ``executor.py`` walks a netlist gate by gate — one Python
dispatch per gate per call.  The paper's throughput, however, comes from
SIMD execution of *whole gate levels* over memory subarrays (Algorithm 1's
intra-subarray parallelism).  This module is the TPU translation of that
step: it compiles a netlist into an ``ExecutionPlan`` — a topologically
leveled schedule where every level's same-type gates are batched into ONE
fused packed-logic pass over stacked uint32 stream words (executed by
``kernels/netlist_exec.py``).

Beyond straight leveling, the compiler fuses the 4-gate stochastic scaled
addition — ``NAND(NAND(a,s), NAND(b, NOT(s)))`` — into a single MUX pass
``(a & s) | (b & ~s)``, the same fusion ``kernels/packed_logic.py`` performs
at the Pallas level (the 2T-1MTJ hardware needs 4 cycles; one VPU pass needs
none of the intermediate cell writes).  Fusion is a boolean identity, so the
fused plan stays bit-identical to the reference interpreter.

Plans are cached per netlist *structure* (PIs, gates, outputs, state
bindings), so repeated executions of equal circuits — every benchmark/test
pattern — hit both the plan cache and the downstream jit cache.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from .gates import Netlist, PIKind, PrimaryInput

# Fused 3-input scaled addition: out = (a & s) | (b & ~s).  Not a 2T-1MTJ
# primitive — it exists only at the plan level (and as packed_logic's "mux").
FUSED_MUX = "MUX3"

_OP_ARITY = {"MUX3": 3}


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledOp:
    """One fused pass: all same-type gates of one level, batched.

    ``inputs[j][i]`` is the node feeding input position ``j`` of the i-th
    batched gate; ``outputs[i]`` its output node; ``gids[i]`` the originating
    gate id (used to key per-gate fault-injection streams).  For ``MUX3``,
    ``gids[i]`` is the id of the root NAND of the fused 4-gate group.
    """

    op: str
    gids: tuple[int, ...]
    inputs: tuple[tuple[str, ...], ...]   # arity x n_batched
    outputs: tuple[str, ...]

    @property
    def n_batched(self) -> int:
        return len(self.outputs)


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """A netlist lowered to leveled, type-batched fused passes.

    ``eq=False``: plans are interned in the structure-keyed cache, so
    identity equality/hash is both correct and cheap as a jit static arg.
    """

    name: str
    pis: tuple[PrimaryInput, ...]
    n_gates: int                                  # original gate count
    levels: tuple[tuple[CompiledOp, ...], ...]
    outputs: tuple[str, ...]
    state_pis: tuple[str, ...]
    state_drivers: tuple[str, ...]
    state_inits: tuple[float, ...]
    fused: bool
    n_fused_mux: int

    @property
    def is_sequential(self) -> bool:
        return bool(self.state_pis)

    @property
    def n_passes(self) -> int:
        """Fused passes executed per evaluation (vs n_gates for the
        interpreter) — the compile-time speedup headline."""
        return sum(len(level) for level in self.levels)

    def stream_pi_names(self) -> tuple[str, ...]:
        """Non-state PIs, in declaration order (the streams the executor
        generates; state PIs are carried by the sequential scan)."""
        return tuple(p.name for p in self.pis if p.kind != PIKind.STATE)


# --------------------------------- fusion -----------------------------------------

def _find_mux_fusions(net: Netlist) -> tuple[dict[int, tuple[str, str, str]], set[int]]:
    """Detect fusable 4-gate MUX groups.

    Returns ``(roots, dead)``: ``roots`` maps the root NAND's gid to its
    ``(a, b, s)`` operand nodes; ``dead`` holds gids of the three absorbed
    feeder gates.  A feeder is absorbed only when its output has exactly one
    use and is neither a primary output nor a state driver — otherwise the
    intermediate stream is observable and must stay materialized.
    """
    driver: dict[str, any] = {g.output: g for g in net.gates}
    uses: dict[str, int] = defaultdict(int)
    for g in net.gates:
        for i in g.inputs:
            uses[i] += 1
    protected = set(net.outputs) | {drv for drv, _ in net.state_bindings.values()}

    def absorbable(node: str) -> bool:
        return uses[node] == 1 and node not in protected

    roots: dict[int, tuple[str, str, str]] = {}
    dead: set[int] = set()
    for g in net.gates:
        if g.gtype != "NAND" or g.gid in dead:
            continue
        g1 = driver.get(g.inputs[0])
        g2 = driver.get(g.inputs[1])
        if g1 is None or g2 is None or g1.gid == g2.gid:
            continue
        if g1.gtype != "NAND" or g2.gtype != "NAND":
            continue
        if {g1.gid, g2.gid} & dead:
            continue
        found = None
        for x, y in ((g1, g2), (g2, g1)):
            # y = NAND(b, sb) with sb = NOT(s), x = NAND(a, s).
            for bi in (0, 1):
                sb_gate = driver.get(y.inputs[1 - bi])
                if sb_gate is None or sb_gate.gtype != "NOT" or sb_gate.gid in dead:
                    continue
                s = sb_gate.inputs[0]
                if s not in x.inputs:
                    continue
                a = x.inputs[1] if x.inputs[0] == s else x.inputs[0]
                b = y.inputs[bi]
                if (absorbable(x.output) and absorbable(y.output)
                        and absorbable(sb_gate.output)):
                    found = (a, b, s, x.gid, y.gid, sb_gate.gid)
                    break
            if found:
                break
        if found:
            a, b, s, xg, yg, sg = found
            roots[g.gid] = (a, b, s)
            dead.update((xg, yg, sg))
    return roots, dead


# -------------------------------- compilation -------------------------------------

def _signature(net: Netlist) -> tuple:
    return (
        net.name,
        tuple(net.pis),
        tuple((g.gid, g.gtype, g.inputs, g.output) for g in net.gates),
        tuple(net.outputs),
        tuple(sorted((s, d, i) for s, (d, i) in net.state_bindings.items())),
    )


_PLAN_CACHE: dict[tuple, ExecutionPlan] = {}


def cache_info() -> dict[str, int]:
    return {"plans": len(_PLAN_CACHE)}


def clear_cache() -> None:
    _PLAN_CACHE.clear()


def compile_plan(net: Netlist, fuse_mux: bool = True) -> ExecutionPlan:
    """Compile ``net`` into an ExecutionPlan (structure-cached).

    ``fuse_mux=False`` keeps every gate as its own batched op — required when
    per-gate fault injection must observe the intermediate streams (Table 4),
    and by construction bit-identical to the interpreter in all cases.

    Netlists are treated as immutable once compiled: a fast per-instance memo
    (guarded by the PI/gate/output counts) front-runs the structural cache so
    the hot execute() path doesn't rebuild the signature every call.
    """
    memo = net.__dict__.setdefault("_plan_memo", {})
    # PIs/gates can only be appended (lengths catch that); outputs and state
    # bindings can be *replaced* at equal length, so they go in by value.
    memo_key = (fuse_mux, len(net.pis), len(net.gates), tuple(net.outputs),
                tuple(sorted(net.state_bindings.items())))
    hit = memo.get(memo_key)
    if hit is not None:
        return hit

    key = (_signature(net), fuse_mux)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        memo[memo_key] = cached
        return cached

    net.validate()
    roots, dead = _find_mux_fusions(net) if fuse_mux else ({}, set())

    # Longest-path leveling over the fused op graph (PIs at level 0).
    level: dict[str, int] = {p.name: 0 for p in net.pis}
    by_level: dict[int, dict[str, list[tuple[int, tuple[str, ...], str]]]] = \
        defaultdict(lambda: defaultdict(list))
    for g in net.gates:
        if g.gid in dead:
            continue
        if g.gid in roots:
            op, ins = FUSED_MUX, roots[g.gid]
        else:
            op, ins = g.gtype, g.inputs
        lvl = 1 + max(level[i] for i in ins)
        level[g.output] = lvl
        by_level[lvl][op].append((g.gid, ins, g.output))

    levels = []
    for lvl in sorted(by_level):
        ops = []
        for op, entries in by_level[lvl].items():
            arity = len(entries[0][1])
            ops.append(CompiledOp(
                op=op,
                gids=tuple(e[0] for e in entries),
                inputs=tuple(tuple(e[1][j] for e in entries) for j in range(arity)),
                outputs=tuple(e[2] for e in entries),
            ))
        levels.append(tuple(ops))

    state_items = sorted(net.state_bindings.items())
    plan = ExecutionPlan(
        name=net.name,
        pis=tuple(net.pis),
        n_gates=len(net.gates),
        levels=tuple(levels),
        outputs=tuple(net.outputs),
        state_pis=tuple(s for s, _ in state_items),
        state_drivers=tuple(d for _, (d, _) in state_items),
        state_inits=tuple(i for _, (_, i) in state_items),
        fused=fuse_mux,
        n_fused_mux=len(roots),
    )
    _PLAN_CACHE[key] = plan
    memo[memo_key] = plan
    return plan
