"""Execution-plan compiler: lower a Netlist to fused bit-parallel passes.

The interpreter in ``executor.py`` walks a netlist gate by gate — one Python
dispatch per gate per call.  The paper's throughput, however, comes from
SIMD execution of *whole gate levels* over memory subarrays (Algorithm 1's
intra-subarray parallelism).  This module is the TPU translation of that
step: it compiles a netlist into an ``ExecutionPlan`` — a topologically
leveled schedule where every level's same-type gates are batched into ONE
fused packed-logic pass over stacked uint32 stream words (executed by
``kernels/netlist_exec.py``).

Beyond straight leveling, the compiler fuses the 4-gate stochastic scaled
addition — ``NAND(NAND(a,s), NAND(b, NOT(s)))`` — into a single MUX pass
``(a & s) | (b & ~s)``, the same fusion ``kernels/packed_logic.py`` performs
at the Pallas level (the 2T-1MTJ hardware needs 4 cycles; one VPU pass needs
none of the intermediate cell writes).  Fusion is a boolean identity, so the
fused plan stays bit-identical to the reference interpreter.

Plans are cached per netlist *structure* (PIs, gates, outputs, state
bindings), so repeated executions of equal circuits — every benchmark/test
pattern — hit both the plan cache and the downstream jit cache.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from .gates import Netlist, PIKind, PrimaryInput

# Fused 3-input scaled addition: out = (a & s) | (b & ~s).  Not a 2T-1MTJ
# primitive — it exists only at the plan level (and as packed_logic's "mux").
FUSED_MUX = "MUX3"

_OP_ARITY = {"MUX3": 3}


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledOp:
    """One fused pass: all same-type gates of one level, batched.

    ``inputs[j][i]`` is the node feeding input position ``j`` of the i-th
    batched gate; ``outputs[i]`` its output node; ``gids[i]`` the originating
    gate id (used to key per-gate fault-injection streams).  For ``MUX3``,
    ``gids[i]`` is the id of the root NAND of the fused 4-gate group.
    """

    op: str
    gids: tuple[int, ...]
    inputs: tuple[tuple[str, ...], ...]   # arity x n_batched
    outputs: tuple[str, ...]

    @property
    def n_batched(self) -> int:
        return len(self.outputs)


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """A netlist lowered to leveled, type-batched fused passes.

    ``eq=False``: plans are interned in the structure-keyed cache, so
    identity equality/hash is both correct and cheap as a jit static arg.
    """

    name: str
    pis: tuple[PrimaryInput, ...]
    n_gates: int                                  # original gate count
    levels: tuple[tuple[CompiledOp, ...], ...]
    outputs: tuple[str, ...]
    state_pis: tuple[str, ...]
    state_drivers: tuple[str, ...]
    state_inits: tuple[float, ...]
    fused: bool
    n_fused_mux: int

    @property
    def is_sequential(self) -> bool:
        return bool(self.state_pis)

    @property
    def n_passes(self) -> int:
        """Fused passes executed per evaluation (vs n_gates for the
        interpreter) — the compile-time speedup headline."""
        return sum(len(level) for level in self.levels)

    def stream_pi_names(self) -> tuple[str, ...]:
        """Non-state PIs, in declaration order (the streams the executor
        generates; state PIs are carried by the sequential scan)."""
        return tuple(p.name for p in self.pis if p.kind != PIKind.STATE)


# --------------------------------- fusion -----------------------------------------

def _find_mux_fusions(net: Netlist) -> tuple[dict[int, tuple[str, str, str]], set[int]]:
    """Detect fusable 4-gate MUX groups.

    Returns ``(roots, dead)``: ``roots`` maps the root NAND's gid to its
    ``(a, b, s)`` operand nodes; ``dead`` holds gids of the three absorbed
    feeder gates.  A feeder is absorbed only when its output has exactly one
    use and is neither a primary output nor a state driver — otherwise the
    intermediate stream is observable and must stay materialized.
    """
    driver: dict[str, any] = {g.output: g for g in net.gates}
    uses: dict[str, int] = defaultdict(int)
    for g in net.gates:
        for i in g.inputs:
            uses[i] += 1
    protected = set(net.outputs) | {drv for drv, _ in net.state_bindings.values()}

    def absorbable(node: str) -> bool:
        return uses[node] == 1 and node not in protected

    roots: dict[int, tuple[str, str, str]] = {}
    dead: set[int] = set()
    for g in net.gates:
        if g.gtype != "NAND" or g.gid in dead:
            continue
        g1 = driver.get(g.inputs[0])
        g2 = driver.get(g.inputs[1])
        if g1 is None or g2 is None or g1.gid == g2.gid:
            continue
        if g1.gtype != "NAND" or g2.gtype != "NAND":
            continue
        if {g1.gid, g2.gid} & dead:
            continue
        found = None
        for x, y in ((g1, g2), (g2, g1)):
            # y = NAND(b, sb) with sb = NOT(s), x = NAND(a, s).
            for bi in (0, 1):
                sb_gate = driver.get(y.inputs[1 - bi])
                if sb_gate is None or sb_gate.gtype != "NOT" or sb_gate.gid in dead:
                    continue
                s = sb_gate.inputs[0]
                if s not in x.inputs:
                    continue
                a = x.inputs[1] if x.inputs[0] == s else x.inputs[0]
                b = y.inputs[bi]
                if (absorbable(x.output) and absorbable(y.output)
                        and absorbable(sb_gate.output)):
                    found = (a, b, s, x.gid, y.gid, sb_gate.gid)
                    break
            if found:
                break
        if found:
            a, b, s, xg, yg, sg = found
            roots[g.gid] = (a, b, s)
            dead.update((xg, yg, sg))
    return roots, dead


# -------------------------------- compilation -------------------------------------

def _signature(net: Netlist) -> tuple:
    return (
        net.name,
        tuple(net.pis),
        tuple((g.gid, g.gtype, g.inputs, g.output) for g in net.gates),
        tuple(net.outputs),
        tuple(sorted((s, d, i) for s, (d, i) in net.state_bindings.items())),
    )


_PLAN_CACHE: dict[tuple, ExecutionPlan] = {}
_BANK_CACHE: dict[tuple, "BankPlan"] = {}


def cache_info() -> dict[str, int]:
    return {"plans": len(_PLAN_CACHE), "banks": len(_BANK_CACHE)}


def clear_cache() -> None:
    _PLAN_CACHE.clear()
    _BANK_CACHE.clear()


def compile_plan(net: Netlist, fuse_mux: bool = True) -> ExecutionPlan:
    """Compile ``net`` into an ExecutionPlan (structure-cached).

    ``fuse_mux=False`` keeps every gate as its own batched op — required when
    per-gate fault injection must observe the intermediate streams (Table 4),
    and by construction bit-identical to the interpreter in all cases.

    A fast per-instance memo front-runs the structural cache so the hot
    execute() path doesn't rebuild the signature every call.  The memo is
    guarded by the netlist's mutation counter (bumped by every Netlist
    mutator, including in-place ``replace_gate`` edits that leave the gate
    count unchanged) plus the PI/gate counts as a belt-and-braces check, so
    mutating a compiled netlist through its mutators always recompiles.
    """
    memo = net.__dict__.setdefault("_plan_memo", {})
    memo_key = (fuse_mux, getattr(net, "_version", None),
                len(net.pis), len(net.gates))
    hit = memo.get(memo_key)
    if hit is not None:
        return hit

    # Entries from older netlist versions can never hit again — drop them so
    # a mutate/recompile loop doesn't grow the memo (at most the two fuse_mux
    # variants of the current version remain).
    for k in [k for k in memo if k[1] != memo_key[1]]:
        del memo[k]

    key = (_signature(net), fuse_mux)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        memo[memo_key] = cached
        return cached

    net.validate()
    roots, dead = _find_mux_fusions(net) if fuse_mux else ({}, set())

    # Longest-path leveling over the fused op graph (PIs at level 0).
    level: dict[str, int] = {p.name: 0 for p in net.pis}
    by_level: dict[int, dict[str, list[tuple[int, tuple[str, ...], str]]]] = \
        defaultdict(lambda: defaultdict(list))
    for g in net.gates:
        if g.gid in dead:
            continue
        if g.gid in roots:
            op, ins = FUSED_MUX, roots[g.gid]
        else:
            op, ins = g.gtype, g.inputs
        lvl = 1 + max(level[i] for i in ins)
        level[g.output] = lvl
        by_level[lvl][op].append((g.gid, ins, g.output))

    levels = []
    for lvl in sorted(by_level):
        ops = []
        for op, entries in by_level[lvl].items():
            arity = len(entries[0][1])
            ops.append(CompiledOp(
                op=op,
                gids=tuple(e[0] for e in entries),
                inputs=tuple(tuple(e[1][j] for e in entries) for j in range(arity)),
                outputs=tuple(e[2] for e in entries),
            ))
        levels.append(tuple(ops))

    state_items = sorted(net.state_bindings.items())
    plan = ExecutionPlan(
        name=net.name,
        pis=tuple(net.pis),
        n_gates=len(net.gates),
        levels=tuple(levels),
        outputs=tuple(net.outputs),
        state_pis=tuple(s for s, _ in state_items),
        state_drivers=tuple(d for _, (d, _) in state_items),
        state_inits=tuple(i for _, (_, i) in state_items),
        fused=fuse_mux,
        n_fused_mux=len(roots),
    )
    _PLAN_CACHE[key] = plan
    memo[memo_key] = plan
    return plan


# ---------------------------- bank-level merging -----------------------------------
#
# The paper's Fig. 8 bank executes many circuit instances side by side: every
# subarray pass fires the same gate type across ALL columns of ALL subarrays,
# so independent circuits mapped to disjoint columns share passes.  The TPU
# translation: merge N (possibly different) netlists' plans into ONE plan
# whose levels type-batch gates *across* members — one CompiledOp pass covers
# every same-type gate of a level bank-wide, and N app instances execute as a
# single fused XLA program (executor.execute_many).

def member_prefix(index: int) -> str:
    """Node-namespace prefix for bank member ``index`` ("b3/out" etc.)."""
    return f"b{index}/"


@dataclasses.dataclass(frozen=True, eq=False)
class BankPlan:
    """N member plans merged for bank-level execution.

    Combinational members merge into one word-parallel plan (``comb``);
    sequential members merge into one plan run as a single scan (``seq``) —
    mixing them would re-execute combinational logic per bitstream bit.
    ``comb_members`` / ``seq_members`` hold the caller-order member indices of
    each group, in merge order (ascending), which is also the order of the
    per-member flat fault-key blocks (see ``executor._execute_bank``).
    """

    name: str
    members: tuple[ExecutionPlan, ...]
    comb: ExecutionPlan | None
    seq: ExecutionPlan | None
    comb_members: tuple[int, ...]
    seq_members: tuple[int, ...]

    @property
    def n_members(self) -> int:
        return len(self.members)

    @property
    def n_passes(self) -> int:
        """Fused passes per bank-wide evaluation (the merged headline)."""
        return (self.comb.n_passes if self.comb else 0) + \
               (self.seq.n_passes if self.seq else 0)

    @property
    def n_passes_looped(self) -> int:
        """Passes a per-member dispatch loop would execute (the baseline)."""
        return sum(m.n_passes for m in self.members)


def merge_plans(plans: "list[ExecutionPlan]", indices: "list[int]",
                name: str) -> ExecutionPlan:
    """Merge same-kind plans into one cross-member type-batched plan.

    ``indices`` are the members' caller-order positions — they become the node
    namespace prefixes, so the executor can scatter outputs back per member.
    Members are independent graphs, so each gate keeps its per-member level;
    merging level ``L`` across members and type-batching within it is a valid
    re-leveling of the union graph.  Gate ids are offset by the running gate
    count so they index a flat per-merge-order fault-key array.
    """
    if len({p.is_sequential for p in plans}) > 1:
        raise ValueError("merge_plans: cannot mix sequential and "
                         "combinational members in one merged plan")
    prefixes = [member_prefix(i) for i in indices]
    offsets = []
    off = 0
    for p in plans:
        offsets.append(off)
        off += p.n_gates

    n_levels = max(len(p.levels) for p in plans)
    levels = []
    for lvl in range(n_levels):
        by_op: dict[str, list[tuple]] = {}
        for p, pre, goff in zip(plans, prefixes, offsets):
            if lvl >= len(p.levels):
                continue
            for cop in p.levels[lvl]:
                by_op.setdefault(cop.op, []).append((cop, pre, goff))
        ops = []
        for op, entries in by_op.items():
            arity = len(entries[0][0].inputs)
            ops.append(CompiledOp(
                op=op,
                gids=tuple(goff + g for cop, _, goff in entries
                           for g in cop.gids),
                inputs=tuple(tuple(pre + n for cop, pre, _ in entries
                                   for n in cop.inputs[j])
                             for j in range(arity)),
                outputs=tuple(pre + o for cop, pre, _ in entries
                              for o in cop.outputs),
            ))
        levels.append(tuple(ops))

    pis = tuple(dataclasses.replace(
        pi, name=pre + pi.name,
        corr_group=(pre + pi.corr_group) if pi.corr_group else None)
        for p, pre in zip(plans, prefixes) for pi in p.pis)
    return ExecutionPlan(
        name=name,
        pis=pis,
        n_gates=off,
        levels=tuple(levels),
        outputs=tuple(pre + o for p, pre in zip(plans, prefixes)
                      for o in p.outputs),
        state_pis=tuple(pre + s for p, pre in zip(plans, prefixes)
                        for s in p.state_pis),
        state_drivers=tuple(pre + d for p, pre in zip(plans, prefixes)
                            for d in p.state_drivers),
        state_inits=tuple(i for p in plans for i in p.state_inits),
        fused=any(p.fused for p in plans),
        n_fused_mux=sum(p.n_fused_mux for p in plans),
    )


def compile_bank_plan(nets: "list[Netlist]", fuse_mux: bool = True,
                      name: str | None = None) -> BankPlan:
    """Compile N netlists into one bank-level plan (cached).

    Members may repeat (N instances of one circuit) and mix combinational and
    sequential netlists; equal structures intern to the same member plan, so
    the cache key is the member-plan identity tuple.  ``fuse_mux=False``
    compiles combinational members unfused (per-gate fault injection);
    sequential members always fuse — their injection points are PI/output
    streams, outside the plan (mirroring ``executor._plan_for``).
    """
    if not nets:
        raise ValueError("compile_bank_plan: need at least one netlist")
    members = tuple(compile_plan(n, fuse_mux=fuse_mux or n.is_sequential)
                    for n in nets)
    key = (members, fuse_mux)
    cached = _BANK_CACHE.get(key)
    if cached is not None:
        return cached

    comb_idx = tuple(i for i, m in enumerate(members) if not m.is_sequential)
    seq_idx = tuple(i for i, m in enumerate(members) if m.is_sequential)
    bank_name = name or f"bank{len(members)}"
    comb = merge_plans([members[i] for i in comb_idx], list(comb_idx),
                       f"{bank_name}/comb") if comb_idx else None
    seq = merge_plans([members[i] for i in seq_idx], list(seq_idx),
                      f"{bank_name}/seq") if seq_idx else None
    bank = BankPlan(name=bank_name, members=members, comb=comb, seq=seq,
                    comb_members=comb_idx, seq_members=seq_idx)
    _BANK_CACHE[key] = bank
    return bank
