"""Plan compilation facade: caching front over the staged compiler pipeline.

The actual lowering lives in ``repro.core.compiler`` — a typed IR
(``compiler/ir.py``), individual stages (``compiler/stages.py``), and the
staged ``PassPipeline`` (``compiler/pipeline.py``) through which ALL compile
paths flow:

  * ``compile_plan``          — one netlist, full pipeline;
  * ``compile_bank_plan``     — N netlists, member plans merged level-wise,
                                re-entering the pipeline at the schedule stage;
  * ``compile_bank_template`` / ``compile_bank_members`` — the padded
                                canonical serving layout, same merge path.

This module is the public import surface (external code must not import
``repro.core.compiler`` internals — ruff TID251 enforces it) plus the state
the pipeline deliberately doesn't own:

  * the structure-keyed LRU plan/bank caches (interning: equal structures
    return the *same* plan object, which keys the downstream jit cache);
  * the per-netlist ``_plan_memo`` fast path, epoch-guarded so
    ``clear_cache()`` invalidates memoized plans too;
  * cumulative optimizer provenance counters (``cache_info()``).

Plans are cached per netlist *structure* (PIs, gates, outputs, state
bindings), so repeated executions of equal circuits — every benchmark/test
pattern — hit both the plan cache and the downstream jit cache.
"""
from __future__ import annotations

from collections import OrderedDict, defaultdict

from .compiler.ir import (_COMMUTATIVE, _OP_ARITY, FUSED_MUX, FUSED_XOR,  # noqa: F401
                          IDENTITY_NAME, BankPlan, CompiledOp, ExecutionPlan,
                          StreamTable, build_stream_table, member_prefix)
from .compiler.pipeline import (DEFAULT_PIPELINE, PassPipeline,  # noqa: F401
                                build_bank, lower_netlist, merge_plans,
                                next_serial)
from .compiler.stages import signature as _signature  # noqa: F401
from .gates import Netlist

# ----------------------------------- caches ----------------------------------------

# Both structural caches are LRU-bounded: serving traffic compiles a new
# plan/bank per *bucket shape*, and an unbounded dict would grow with every
# distinct member set ever seen.  Eviction only drops interning — an evicted
# structure recompiles to a fresh (bit-identical) plan on next use — so the
# caps trade recompiles for memory, never correctness.
_PLAN_CACHE: "OrderedDict[tuple, ExecutionPlan]" = OrderedDict()
_BANK_CACHE: "OrderedDict[tuple, BankPlan]" = OrderedDict()
_CACHE_CAPS = {"plans": 1024, "banks": 256}
_EVICTIONS = {"plan_evictions": 0, "bank_evictions": 0}
# Cumulative optimizer counters across cache-missing compiles (reported by
# cache_info so perf work can see how many nodes the structural passes
# removed, and reset by clear_cache).
_OPT_COUNTS = {"buff_elided": 0, "cse_elided": 0, "mux_fused": 0,
               "xor_fused": 0, "and_fused": 0, "not_absorbed": 0}
# Cache generation stamp: bumped by clear_cache() and baked into every
# per-netlist memo key, so memoized plans from before a clear can never be
# served after it (they'd resurrect cleared interning).
_CACHE_EPOCH = [0]


def _cache_get(cache: OrderedDict, key):
    hit = cache.get(key)
    if hit is not None:
        cache.move_to_end(key)
    return hit


def _cache_put(cache: OrderedDict, key, value, cap_key: str,
               evict_key: str) -> None:
    cache[key] = value
    cache.move_to_end(key)
    while len(cache) > _CACHE_CAPS[cap_key]:
        cache.popitem(last=False)
        _EVICTIONS[evict_key] += 1


def set_cache_caps(plans: int | None = None,
                   banks: int | None = None) -> dict[str, int]:
    """Set the LRU caps (entries) of the plan/bank caches; returns the caps.

    Shrinking a cap evicts least-recently-used entries immediately (counted
    in ``cache_info()['plan_evictions'/'bank_evictions']``).
    """
    if plans is not None:
        _CACHE_CAPS["plans"] = int(plans)
        while len(_PLAN_CACHE) > _CACHE_CAPS["plans"]:
            _PLAN_CACHE.popitem(last=False)
            _EVICTIONS["plan_evictions"] += 1
    if banks is not None:
        _CACHE_CAPS["banks"] = int(banks)
        while len(_BANK_CACHE) > _CACHE_CAPS["banks"]:
            _BANK_CACHE.popitem(last=False)
            _EVICTIONS["bank_evictions"] += 1
    return dict(_CACHE_CAPS)


def cache_info() -> dict[str, int]:
    return {"plans": len(_PLAN_CACHE), "banks": len(_BANK_CACHE),
            "plan_cap": _CACHE_CAPS["plans"], "bank_cap": _CACHE_CAPS["banks"],
            **_EVICTIONS, **_OPT_COUNTS}


def clear_cache() -> None:
    """Drop all structural caches AND invalidate per-netlist plan memos.

    The memos live on Netlist instances, so they can't be cleared here
    directly; instead the cache epoch is baked into every memo key — bumping
    it makes every existing memo entry unreachable (and ``compile_plan``
    prunes stale-epoch entries on its next visit to each netlist).
    """
    _PLAN_CACHE.clear()
    _BANK_CACHE.clear()
    for k in _OPT_COUNTS:
        _OPT_COUNTS[k] = 0
    for k in _EVICTIONS:
        _EVICTIONS[k] = 0
    _CACHE_EPOCH[0] += 1


# -------------------------------- compilation -------------------------------------

def compile_plan(net: Netlist, fuse_mux: bool = True) -> ExecutionPlan:
    """Compile ``net`` into an ExecutionPlan (structure-cached).

    Runs the full staged pipeline (``compiler.DEFAULT_PIPELINE``): normalize
    → elide_cse → fuse → level → schedule → liveness → stream_table → emit
    (see docs/ARCHITECTURE.md for what each stage does).

    Compilation is key-free: the plan fixes each stream PI's *key lane* in
    its stream table, but randomness is only drawn at execution time from
    the request's own PRNG key — one structure compiles once and serves any
    number of keys.

    Example::

        net = circuits.sc_multiply()
        p = compile_plan(net)
        p.n_gates, p.n_passes, p.max_live      # provenance + liveness
        executor.execute_value(net, {"a": 0.5, "b": 0.5},
                               jax.random.key(0), 256)  # runs this plan

    ``fuse_mux=False`` keeps every gate as its own batched op, disabling ALL
    structural optimization (MUX/XOR fusion, BUFF elision, CSE) — required
    when per-gate fault injection must observe the intermediate streams
    (Table 4), and by construction bit-identical to the interpreter in all
    cases.  The optimized default is bit-identical too (every pass is an
    exact stream identity); only the per-gate injection points differ.

    A fast per-instance memo front-runs the structural cache so the hot
    execute() path doesn't rebuild the signature every call.  The memo is
    guarded by the netlist's mutation counter (bumped by every Netlist
    mutator, including in-place ``replace_gate`` edits that leave the gate
    count unchanged) plus the PI/gate counts as a belt-and-braces check, so
    mutating a compiled netlist through its mutators always recompiles — and
    by the cache epoch, so ``clear_cache()`` invalidates memos too.
    """
    memo = net.__dict__.setdefault("_plan_memo", {})
    memo_key = (_CACHE_EPOCH[0], fuse_mux, getattr(net, "_version", None),
                len(net.pis), len(net.gates))
    hit = memo.get(memo_key)
    if hit is not None:
        return hit

    # Entries from older netlist versions or cache epochs can never hit again
    # — drop them so a mutate/recompile (or clear/recompile) loop doesn't grow
    # the memo (at most the two fuse_mux variants of the current version
    # remain).
    stale = [k for k in memo
             if k[0] != memo_key[0] or k[2] != memo_key[2]]
    for k in stale:
        del memo[k]

    key = (_signature(net), fuse_mux)
    cached = _cache_get(_PLAN_CACHE, key)
    if cached is not None:
        memo[memo_key] = cached
        return cached

    plan = lower_netlist(net, fuse_mux=fuse_mux)
    _OPT_COUNTS["buff_elided"] += plan.n_buff_elided
    _OPT_COUNTS["cse_elided"] += plan.n_cse_elided
    _OPT_COUNTS["mux_fused"] += plan.n_fused_mux
    _OPT_COUNTS["xor_fused"] += plan.n_fused_xor
    _OPT_COUNTS["and_fused"] += plan.n_fused_and
    _OPT_COUNTS["not_absorbed"] += plan.n_not_absorbed
    _cache_put(_PLAN_CACHE, key, plan, "plans", "plan_evictions")
    memo[memo_key] = plan
    return plan


# ---------------------------- bank-level merging -----------------------------------
#
# The paper's Fig. 8 bank executes many circuit instances side by side: every
# subarray pass fires the same gate type across ALL columns of ALL subarrays,
# so independent circuits mapped to disjoint columns share passes.  The TPU
# translation: merge N (possibly different) netlists' plans into ONE plan
# whose levels type-batch gates *across* members — one CompiledOp pass covers
# every same-type gate of a level bank-wide, and N app instances execute as a
# single fused XLA program (executor.execute_many).  The merge itself is
# ``compiler.pipeline.merge_plans`` / ``build_bank``; this layer adds caching.


def _cached_bank(members: "tuple[ExecutionPlan, ...]", key: tuple,
                 name: str | None) -> BankPlan:
    """Merge a member-plan tuple into a (cached) BankPlan under ``key``."""
    cached = _cache_get(_BANK_CACHE, key)
    if cached is not None:
        return cached
    bank = build_bank(members, name)
    _cache_put(_BANK_CACHE, key, bank, "banks", "bank_evictions")
    return bank


def compile_bank_plan(nets: "list[Netlist]", fuse_mux: bool = True,
                      name: str | None = None) -> BankPlan:
    """Compile N netlists into one bank-level plan (cached).

    Members may repeat (N instances of one circuit) and mix combinational and
    sequential netlists; equal structures intern to the same member plan, so
    the cache key is the member-plan identity tuple.  ``fuse_mux=False``
    compiles combinational members unfused (per-gate fault injection);
    sequential members always fuse — their injection points are PI/output
    streams, outside the plan (mirroring ``executor._plan_for``).

    Member ``i`` of the bank draws its streams from request ``i``'s key
    exactly as a standalone execute would, so merged execution is
    bit-identical to a loop of per-member calls.

    Example::

        nets = [circuits.sc_multiply(), circuits.sc_sqrt()]
        bank = compile_bank_plan(nets)
        bank.n_passes, bank.n_passes_looped    # cross-member pass sharing
        executor.run([executor.ExecRequest(n, v, k, opts)
                      for n, v, k in zip(nets, values, keys)])  # one dispatch
    """
    if not nets:
        raise ValueError("compile_bank_plan: need at least one netlist")
    members = tuple(compile_plan(n, fuse_mux=fuse_mux or n.is_sequential)
                    for n in nets)
    return _cached_bank(members, (members, fuse_mux), name)


# --------------------------- canonical bank templates ------------------------------
#
# Serving traffic cannot afford a fresh BankPlan (and jit trace) per request
# set: the member multiset changes every arrival.  A *bank template* is the
# canonical padded form of a request multiset — distinct member structures in
# deterministic (compile-serial) order, each structure's slot count rounded up
# to a power of two, optionally topped up with no-op identity members to a
# fixed total — so every request set that fits a bucket reuses ONE BankPlan
# and ONE jit program, with unbound slots masked out at execution time
# (executor.execute_bank's ``active`` mask).

_IDENTITY_PLAN: "list[ExecutionPlan]" = []


def identity_plan() -> ExecutionPlan:
    """The no-op padding member: no PIs, no gates, no outputs.

    Merging it into a bank contributes zero passes and zero streams; it
    exists so a template's slot count can be padded to a fixed size.  A
    process-wide singleton (held outside the LRU cache, so eviction can never
    split its identity and fork bank-template cache keys).
    """
    if not _IDENTITY_PLAN:
        _IDENTITY_PLAN.append(compile_plan(Netlist(IDENTITY_NAME)))
    return _IDENTITY_PLAN[0]


def bucket_count(n: int, min_count: int = 1) -> int:
    """Smallest power of two >= max(n, min_count) — the slot-count bucket."""
    n = max(n, min_count, 1)
    return 1 << (n - 1).bit_length()


def template_members(plans: "list[ExecutionPlan]", n_slots: int | None = None,
                     pad_counts: bool = True,
                     pad_total: bool = False) -> "tuple[ExecutionPlan, ...]":
    """Canonical padded slot layout for a request multiset.

    Distinct structures are laid out in compile-serial order, each repeated
    to its (power-of-two-padded, when ``pad_counts``) count; identity padding
    members fill the tail up to ``n_slots`` (or, with ``pad_total`` and no
    explicit ``n_slots``, up to the next power of two of the padded member
    count).  Two request sets whose padded multisets agree produce the
    *identical* tuple — the bank-template bucket key.
    """
    counts: "dict[ExecutionPlan, int]" = {}
    for p in plans:
        counts[p] = counts.get(p, 0) + 1          # plans intern: id == structure
    members: "list[ExecutionPlan]" = []
    for p in sorted(counts, key=lambda q: q.serial):
        c = counts[p]
        members.extend([p] * (bucket_count(c) if pad_counts else c))
    if n_slots is None and pad_total:
        n_slots = bucket_count(len(members))
    if n_slots is not None:
        if len(members) > n_slots:
            raise ValueError(f"template needs {len(members)} slots, "
                             f"n_slots={n_slots}")
        members.extend([identity_plan()] * (n_slots - len(members)))
    return tuple(members)


def compile_bank_template(plans: "list[ExecutionPlan]",
                          n_slots: int | None = None, pad_counts: bool = True,
                          pad_total: bool = False,
                          name: str | None = None, scope=None) -> BankPlan:
    """Compile the canonical padded bank for a request multiset (cached).

    The returned BankPlan's member list is the ``template_members`` layout;
    bind requests to the slots holding their plan and execute with
    ``executor.execute_bank(..., active=mask)``.  Padded execution is
    bit-identical per bound slot to standalone ``execute`` — unbound slots
    only ever add masked no-op work.

    ``scope`` (any hashable, default ``None``) partitions the cache: the
    multi-bank server passes the target *device*, so each device serves from
    its own template instance — one device's LRU churn cannot evict the
    templates (and the jit executables their serials anchor) another device
    is still serving from, and bucket-warmth bookkeeping keyed on
    ``BankPlan.serial`` is automatically per device.

    Each bound slot still draws from its own request's key (unbound slots
    generate nothing), so padding never perturbs results.

    Example::

        plans = [compile_plan(circuits.sc_multiply())] * 3
        tmpl = compile_bank_template(plans)    # 3 slots pad to 4
        len(tmpl.members), tmpl.members[-1].is_identity  # (4, True)
        executor.run(slot_reqs, template=tmpl, active=mask)
    """
    if not plans:
        raise ValueError("compile_bank_template: need at least one plan")
    members = template_members(plans, n_slots=n_slots, pad_counts=pad_counts,
                               pad_total=pad_total)
    return _cached_bank(members, (members, True, scope),
                        name or f"tmpl{len(members)}")


def compile_bank_members(members: "tuple[ExecutionPlan, ...]",
                         name: str | None = None, scope=None) -> BankPlan:
    """Compile a bank for an *explicit* slot layout (cached).

    ``members`` is a ready-made slot tuple — typically a ``template_members``
    layout the serving dispatcher computed once and then binds requests
    against, compiling the actual bank lazily per target device (``scope``,
    see ``compile_bank_template``).  No padding is applied: the caller owns
    the layout, and re-deriving it here could re-pad identity tails into a
    different (non-canonical) tuple.
    """
    if not members:
        raise ValueError("compile_bank_members: need at least one member")
    members = tuple(members)
    return _cached_bank(members, (members, True, scope),
                        name or f"tmpl{len(members)}")


def merged_pass_count(plans: "list[ExecutionPlan]") -> int:
    """Fused passes a bank merging exactly ``plans`` would execute.

    Mirrors ``merge_plans``'s batching rule — per level, one pass per
    distinct (op, neg) across members, combinational and sequential groups
    leveled independently — without building the merged plan.  Used by
    ``arch.evaluate_bank_plan`` to price padded-slot overhead: the padded
    bank's pass count minus the active members' merged pass count is the
    work padding added.
    """
    total = 0
    for seq in (False, True):
        by_level: "dict[int, set]" = defaultdict(set)
        for p in plans:
            if p.is_sequential != seq:
                continue
            for lvl, lev in enumerate(p.levels):
                for cop in lev:
                    by_level[lvl].add((cop.op, cop.neg))
        total += sum(len(s) for s in by_level.values())
    return total
