"""Netlist interpreter: executes gate netlists on packed bitstreams.

Bridges the structural view (circuits.py netlists, used for scheduling and
cost) and the value view (sc_ops.py): every netlist can be *run* and its
output streams decoded, so tests can assert that the scheduled circuits
compute what the paper says they compute — including sequential (stateful)
circuits like the Gaines divider, and under injected bitflips (Table 4).

Binary netlists execute on packed test-vector words: lane ``t`` of the packed
words is test vector ``t``, so one call evaluates 32*W random input
combinations at once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitstream as bs
from .gates import Netlist, PIKind
from . import sc_ops


def _gen_pi_streams(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
                    bitstream_length: int) -> dict[str, jax.Array]:
    """Generate packed streams for every PI, honoring correlation groups and
    independent-copy indices."""
    shape = jnp.broadcast_shapes(*[jnp.shape(jnp.asarray(v)) for v in values.values()]) \
        if values else ()
    streams: dict[str, jax.Array] = {}

    # Correlated groups share underlying uniforms.
    groups: dict[str, list] = {}
    singles: list = []
    for pi in net.pis:
        if pi.kind == PIKind.STATE:
            continue
        if pi.corr_group is not None:
            groups.setdefault(pi.corr_group, []).append(pi)
        else:
            singles.append(pi)

    n_keys = len(groups) + len(singles)
    keys = jax.random.split(key, max(n_keys, 1))
    ki = 0
    for gname, pis in sorted(groups.items()):
        vals = []
        for pi in pis:
            v = values[pi.value_key] if pi.value_key else pi.const_value
            vals.append(jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape))
        outs = bs.generate_correlated(keys[ki], vals, bitstream_length)
        ki += 1
        for pi, o in zip(pis, outs):
            streams[pi.name] = o
    for pi in singles:
        v = values[pi.value_key] if pi.value_key is not None else pi.const_value
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape)
        streams[pi.name] = bs.generate(keys[ki], v, bitstream_length)
        ki += 1
    return streams


def execute(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
            bitstream_length: int, bitflip_rate: float = 0.0,
            flip_key: jax.Array | None = None) -> dict[str, jax.Array]:
    """Execute a (possibly sequential) netlist; returns packed output streams.

    ``bitflip_rate`` injects faults on the PI streams and on every gate
    output stream (the paper injects at input/output nodes of the
    arithmetic operations).
    """
    streams = _gen_pi_streams(net, values, key, bitstream_length)

    if bitflip_rate > 0.0:
        assert flip_key is not None
        fkeys = jax.random.split(flip_key, len(streams) + len(net.gates))
        for i, name in enumerate(sorted(streams)):
            streams[name] = sc_ops.flip_bits(fkeys[i], streams[name], bitflip_rate)

    if not net.is_sequential:
        for gi, g in enumerate(net.gates):
            out = bs.GATE_FNS[g.gtype](*[streams[i] for i in g.inputs])
            if bitflip_rate > 0.0:
                out = sc_ops.flip_bits(fkeys[len(streams) + gi], out, bitflip_rate)
            streams[g.output] = out
        return {o: streams[o] for o in net.outputs}

    # Sequential: iterate the combinational core over bitstream bits.
    state_pis = list(net.state_bindings.keys())
    shape = next(iter(streams.values())).shape  # (..., W)
    bl = bitstream_length

    def unpack_time_major(w):
        bits = bs.unpack_bits(w)                      # (..., W, 32)
        flat = bits.reshape(bits.shape[:-2] + (bl,))
        return jnp.moveaxis(flat, -1, 0)              # (BL, ...)

    time_streams = {k: unpack_time_major(v) for k, v in streams.items()}

    def step(state, xs):
        env = dict(xs)
        for s_name in state_pis:
            env[s_name] = state[s_name]
        for g in net.gates:
            env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
        new_state = {s: env[net.state_bindings[s][0]] for s in state_pis}
        outs = {o: env[o] for o in net.outputs}
        return new_state, outs

    init = {s: jnp.full(shape[:-1], jnp.uint32(round(net.state_bindings[s][1])))
            for s in state_pis}
    _, out_seq = jax.lax.scan(step, init, time_streams)
    packed_outs = {}
    for o, seq in out_seq.items():
        seq = jnp.moveaxis(seq, 0, -1)                # (..., BL)
        bits = seq.reshape(seq.shape[:-1] + (bl // 32, 32))
        packed_outs[o] = bs.pack_bits(bits)
    if bitflip_rate > 0.0:
        for i, o in enumerate(sorted(packed_outs)):
            packed_outs[o] = sc_ops.flip_bits(fkeys[len(streams) + i],
                                              packed_outs[o], bitflip_rate)
    return packed_outs


def execute_value(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
                  bitstream_length: int, **kw) -> dict[str, jax.Array]:
    """Execute and decode each output stream to its unipolar value."""
    outs = execute(net, values, key, bitstream_length, **kw)
    return {k: bs.to_value(v, bitstream_length) for k, v in outs.items()}


def execute_binary(net: Netlist, operand_bits: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """Execute a binary netlist on packed test-vector words.

    ``operand_bits`` maps PI names to uint32 words whose lane ``t`` is the
    PI's value in test vector ``t``.  Constant PIs (const_value set) are
    filled automatically.  Inverted-polarity storage (the Fig. 7(a) trick) is
    applied by the *caller* via the netlist's value conventions.
    """
    env: dict[str, jax.Array] = {}
    shape = next(iter(operand_bits.values())).shape
    for pi in net.pis:
        if pi.name in operand_bits:
            env[pi.name] = operand_bits[pi.name]
        elif pi.const_value is not None:
            fill = jnp.uint32(0xFFFFFFFF) if pi.const_value >= 1.0 else jnp.uint32(0)
            env[pi.name] = jnp.full(shape, fill)
        else:
            raise KeyError(f"missing binary operand {pi.name}")
    for g in net.gates:
        env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
    return {o: env[o] for o in net.outputs}
