"""Netlist execution facade: compiled fused plans with a gate-by-gate reference.

Bridges the structural view (circuits.py netlists, used for scheduling and
cost) and the value view (sc_ops.py): every netlist can be *run* and its
output streams decoded, so tests can assert that the scheduled circuits
compute what the paper says they compute — including sequential (stateful)
circuits like the Gaines divider, and under injected bitflips (Table 4).

Two backends share identical semantics (bit-for-bit):

  * ``"compiled"`` (default): the netlist is lowered once by the staged
    compiler pipeline (``core/compiler/``, fronted by ``core/plan.py``) into
    leveled, type-batched fused passes and executed by
    ``kernels/netlist_exec.py`` inside a single jit — stream generation,
    logic, fault injection and state recurrence all in one XLA program.
    ``"compiled_pallas"`` additionally routes each fused pass through the
    packed-logic Pallas kernel.
  * ``"reference"``: the original Python interpreter, one dispatch per gate.
    It is the oracle the compiled path is tested against, and the fallback
    for debugging new circuits.

Binary netlists execute on packed test-vector words: lane ``t`` of the packed
words is test vector ``t``, so one call evaluates 32*W random input
combinations at once.

Orthogonal to the backend, ``key_mode`` selects the stream-generation key
discipline (both backends honor it identically): ``"batched"`` (default)
generates every PI stream of a plan — or a whole bank — in ONE fused
threshold+pack pass over the plan's stream table; ``"legacy"`` reproduces
the pre-batching per-PI threefry splits bit-exactly.

The canonical entry point is ``run()`` over ``ExecRequest``s: one request
(netlist or prebuilt plan + PI values + PRNG key + frozen ``ExecOptions``)
executes standalone, a sequence merges into one bank-level program, and
``run(requests, template=bank)`` binds slot-aligned requests onto a padded
bank template (the serving path — ``device=`` places the batch on a specific
JAX device, ``donate=`` consumes the engine-owned key rows).  The historic
``execute*`` functions remain as thin shims that build ``ExecRequest``s and
delegate to ``run()``; outputs are bit-identical (pinned by tests).

This module is a *facade*: the implementation is layered as

  * ``core/streams.py``  — PI stream generation (both key disciplines);
  * ``core/dispatch.py`` — jit boundary, value packing/normalization, bank
    execution, the reference interpreter;
  * ``core/exec_api.py`` — ``ExecOptions``/``ExecRequest``, ``run()``, and
    the historic ``execute*`` shims.

Every name importable from here before the split still is.
"""
from __future__ import annotations

from .dispatch import (_BANK_STATIC, _as_f32, _check_fault_args,  # noqa: F401
                       _check_modes, _dispatch, _dispatch_binary,
                       _dispatch_many, _execute_bank, _execute_bank_donating,
                       _execute_bank_impl, _execute_binary_compiled,
                       _execute_compiled, _execute_reference, _is_host_scalar,
                       _key_data_host, _normalize_active,
                       _normalize_batch_shapes, _normalize_keys,
                       _pack_values_seq, _plan_for, _restrict, _stack_keys,
                       _unpack_values_seq, execute_bank,
                       generate_bank_streams)
from .faults import FaultModel, apply_faults  # noqa: F401
from . import obs  # noqa: F401  (re-export: executor.obs.Trace etc.)
from .exec_api import (_MANY_TAIL, ExecOptions, ExecRequest,  # noqa: F401
                       _common_options, _many_shim, _many_tail, _run_many,
                       _run_one, _run_template, execute, execute_binary,
                       execute_many, execute_value, execute_value_many, run)
from .streams import (_BACKENDS, _KEY_MODES, DEFAULT_BACKEND,  # noqa: F401
                      DEFAULT_KEY_MODE, _gen_bank_streams, _gen_pi_streams,
                      _pi_shape, _stack_table_values)

__all__ = [
    "DEFAULT_BACKEND", "DEFAULT_KEY_MODE", "ExecOptions", "ExecRequest",
    "FaultModel", "execute", "execute_bank", "execute_binary",
    "execute_many", "execute_value", "execute_value_many",
    "generate_bank_streams", "obs", "run",
]
