"""Netlist execution: compiled fused plans with a gate-by-gate reference.

Bridges the structural view (circuits.py netlists, used for scheduling and
cost) and the value view (sc_ops.py): every netlist can be *run* and its
output streams decoded, so tests can assert that the scheduled circuits
compute what the paper says they compute — including sequential (stateful)
circuits like the Gaines divider, and under injected bitflips (Table 4).

Two backends share identical semantics (bit-for-bit):

  * ``"compiled"`` (default): the netlist is lowered once by
    ``core/plan.py`` into leveled, type-batched fused passes and executed by
    ``kernels/netlist_exec.py`` inside a single jit — stream generation,
    logic, fault injection and state recurrence all in one XLA program.
    ``"compiled_pallas"`` additionally routes each fused pass through the
    packed-logic Pallas kernel.
  * ``"reference"``: the original Python interpreter, one dispatch per gate.
    It is the oracle the compiled path is tested against, and the fallback
    for debugging new circuits.

Binary netlists execute on packed test-vector words: lane ``t`` of the packed
words is test vector ``t``, so one call evaluates 32*W random input
combinations at once.

Orthogonal to the backend, ``key_mode`` selects the stream-generation key
discipline (both backends honor it identically): ``"batched"`` (default)
generates every PI stream of a plan — or a whole bank — in ONE fused
threshold+pack pass over the plan's stream table; ``"legacy"`` reproduces
the pre-batching per-PI threefry splits bit-exactly.

The canonical entry point is ``run()`` over ``ExecRequest``s: one request
(netlist or prebuilt plan + PI values + PRNG key + frozen ``ExecOptions``)
executes standalone, a sequence merges into one bank-level program, and
``run(requests, template=bank)`` binds slot-aligned requests onto a padded
bank template (the serving path — ``device=`` places the batch on a specific
JAX device, ``donate=`` consumes the engine-owned key rows).  The historic
``execute*`` functions remain as thin shims that build ``ExecRequest``s and
delegate to ``run()``; outputs are bit-identical (pinned by tests).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import bitstream as bs
from . import sc_ops
from .gates import Netlist, PIKind
from .plan import (BankPlan, ExecutionPlan, StreamTable, build_stream_table,
                   compile_bank_plan, compile_plan, member_prefix)

#: Default backend for execute()/execute_value()/execute_binary().
DEFAULT_BACKEND = "compiled"

_BACKENDS = ("compiled", "compiled_pallas", "reference")

#: Default key discipline for PI-stream generation (see ``_gen_pi_streams``).
DEFAULT_KEY_MODE = "batched"

_KEY_MODES = ("batched", "legacy")


# ------------------------------ request API ---------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecOptions:
    """Frozen execution options shared by every entry point.

    ``backend`` / ``key_mode`` default (``None``) to the module defaults at
    run time; ``flip_key`` is required when ``bitflip_rate > 0``;
    ``batch_shape`` declares the stream batch shape when values alone cannot
    (all-const stream PIs).  ``decode`` fuses the StoB decode into the
    program (the ``execute_value`` behavior); ``binary`` runs the netlist on
    packed binary test-vector words instead of stochastic streams (the
    ``execute_binary`` behavior — ``values`` are then the operand bits and
    the stream fields are ignored).
    """

    backend: str | None = None
    key_mode: str | None = None
    bitstream_length: int = 256
    bitflip_rate: float = 0.0
    flip_key: Any = None
    batch_shape: "tuple[int, ...] | None" = None
    decode: bool = False
    binary: bool = False


@dataclasses.dataclass
class ExecRequest:
    """One canonical execution request: circuit + values + key + options.

    ``net`` is a ``Netlist`` or a prebuilt ``ExecutionPlan`` (compiled
    backends only); ``values`` its PI values (operand bit words under
    ``options.binary``); ``key`` the request's PRNG key — the bit-identity
    anchor: a request produces the same output bits whether it runs
    standalone, inside a merged bank, or bound to a padded template slot on
    any device.  ``serve.SCRequest`` subclasses this with the serving
    layer's flat constructor.
    """

    net: Any
    values: dict[str, Any]
    key: Any = None
    options: ExecOptions = dataclasses.field(default_factory=ExecOptions)

    # Flat views of the per-request option fields, so request consumers
    # (serving engine, tests) need not reach through ``options`` for the
    # fields every request carries.
    @property
    def bitstream_length(self) -> int:
        return self.options.bitstream_length

    @property
    def batch_shape(self) -> "tuple[int, ...] | None":
        return self.options.batch_shape

    @property
    def bitflip_rate(self) -> float:
        return self.options.bitflip_rate

    @property
    def flip_key(self):
        return self.options.flip_key


def _pi_shape(values: dict[str, jax.Array],
              batch_shape: tuple[int, ...] | None) -> tuple[int, ...]:
    """Common broadcast shape of the PI streams.

    Derived from the supplied values AND the caller-declared ``batch_shape``
    — so a netlist whose stream PIs are all const-valued (empty ``values``)
    can still generate batched streams for batched downstream use instead of
    silently falling back to scalar shape ``()``.
    """
    shapes = [jnp.shape(jnp.asarray(v)) for v in values.values()]
    if batch_shape is not None:
        shapes.append(tuple(batch_shape))
    return jnp.broadcast_shapes(*shapes) if shapes else ()


def _stack_table_values(table: StreamTable, values: dict[str, jax.Array],
                        shape: tuple[int, ...]) -> jax.Array:
    """Stack the stream table's row values into one (n_rows, *shape) tensor."""
    rows = []
    for vk, const in zip(table.value_keys, table.const_values):
        v = values[vk] if vk is not None else const
        rows.append(jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape))
    return jnp.stack(rows)


def _gen_pi_streams(pis, values: dict[str, jax.Array], key: jax.Array,
                    bitstream_length: int, key_mode: str = DEFAULT_KEY_MODE,
                    batch_shape: tuple[int, ...] | None = None,
                    use_pallas: bool = False,
                    table: StreamTable | None = None) -> dict[str, jax.Array]:
    """Generate packed streams for every PI, honoring correlation groups and
    independent-copy indices.  ``pis`` is any sequence of PrimaryInput.

    ``key_mode`` selects the key discipline (identical for every backend, so
    reference and compiled stay bit-for-bit interchangeable):

      * ``"batched"`` (default): ONE fused threshold+pack pass generates all
        streams from the plan's stream table (``bs.generate_batch``) —
        correlation groups share a key lane, singles get one lane each.
      * ``"legacy"``: one PRNG split per correlation group / single PI, one
        ``bs.generate*`` dispatch each — bit-exactly the pre-batching
        behavior, kept for reproducibility pins.

    The two modes differ bit-wise but are statistically equivalent (same
    Bernoulli marginals, same correlation structure).
    """
    shape = _pi_shape(values, batch_shape)
    if key_mode == "batched":
        if table is None:
            table = build_stream_table(pis)
        if not table.names:
            return {}
        ps = _stack_table_values(table, values, shape)
        words = bs.generate_batch(key, ps, bitstream_length,
                                  lanes=jnp.asarray(table.lanes, jnp.uint32),
                                  use_pallas=use_pallas)
        return {name: words[i] for i, name in enumerate(table.names)}
    if key_mode != "legacy":
        raise ValueError(f"unknown key_mode {key_mode!r}; "
                         f"expected one of {_KEY_MODES}")

    streams: dict[str, jax.Array] = {}

    # Correlated groups share underlying uniforms.
    groups: dict[str, list] = {}
    singles: list = []
    for pi in pis:
        if pi.kind == PIKind.STATE:
            continue
        if pi.corr_group is not None:
            groups.setdefault(pi.corr_group, []).append(pi)
        else:
            singles.append(pi)

    n_keys = len(groups) + len(singles)
    keys = jax.random.split(key, max(n_keys, 1))
    ki = 0
    for gname, gpis in sorted(groups.items()):
        vals = []
        for pi in gpis:
            v = values[pi.value_key] if pi.value_key else pi.const_value
            vals.append(jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape))
        outs = bs.generate_correlated(keys[ki], vals, bitstream_length)
        ki += 1
        for pi, o in zip(gpis, outs):
            streams[pi.name] = o
    for pi in singles:
        v = values[pi.value_key] if pi.value_key is not None else pi.const_value
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32), shape)
        streams[pi.name] = bs.generate(keys[ki], v, bitstream_length)
        ki += 1
    return streams


# ------------------------------ compiled backend ----------------------------------

@partial(jax.jit, static_argnames=("plan", "bitstream_length", "bitflip_rate",
                                   "use_pallas", "decode", "key_mode",
                                   "batch_shape"))
def _execute_compiled(plan: ExecutionPlan, values: dict[str, jax.Array],
                      key: jax.Array, flip_key, bitstream_length: int,
                      bitflip_rate: float, use_pallas: bool,
                      decode: bool = False,
                      key_mode: str = DEFAULT_KEY_MODE,
                      batch_shape: tuple[int, ...] | None = None) -> dict[str, jax.Array]:
    """Whole-netlist execution as one XLA program.

    Mirrors the reference interpreter's key discipline exactly (whatever the
    ``key_mode``): one fkey per sorted PI stream, then one per gate id
    (combinational) / per sorted output (sequential).  ``decode=True`` folds
    the StoB popcount decode into the same program (used by execute_value),
    leaving one dispatch per call.  In batched key mode the PI streams come
    from ONE fused SNG pass over the plan's stream table — generation, logic,
    fault injection and decode are all one XLA program either way.
    """
    from ..kernels import netlist_exec

    streams = _gen_pi_streams(plan.pis, values, key, bitstream_length,
                              key_mode=key_mode, batch_shape=batch_shape,
                              use_pallas=use_pallas, table=plan.stream_table)

    gate_fkeys = None
    if bitflip_rate > 0.0:
        fkeys = jax.random.split(flip_key, len(streams) + plan.n_gates)
        for i, name in enumerate(sorted(streams)):
            streams[name] = sc_ops.flip_bits(fkeys[i], streams[name], bitflip_rate)
        gate_fkeys = fkeys[len(streams):]

    if not plan.is_sequential:
        env = dict(streams)
        netlist_exec.run_combinational(plan, env, gate_fkeys=gate_fkeys,
                                       bitflip_rate=bitflip_rate,
                                       use_pallas=use_pallas)
        packed_outs = {o: env[o] for o in plan.outputs}
    else:
        packed_outs = netlist_exec.run_sequential(
            plan, streams, use_pallas=use_pallas,
            n_words=bs.n_words(bitstream_length))
        if bitflip_rate > 0.0:
            for i, o in enumerate(sorted(packed_outs)):
                packed_outs[o] = sc_ops.flip_bits(gate_fkeys[i], packed_outs[o],
                                                  bitflip_rate)
    if decode:
        return {o: bs.to_value(w, bitstream_length)
                for o, w in packed_outs.items()}
    return packed_outs


def _binary_env(pis, operand_bits: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """PI env for a binary netlist: supplied operands + const-PI fills."""
    env: dict[str, jax.Array] = {}
    shape = next(iter(operand_bits.values())).shape
    for pi in pis:
        if pi.name in operand_bits:
            env[pi.name] = operand_bits[pi.name]
        elif pi.const_value is not None:
            c = float(pi.const_value)
            if c == 0.0:
                fill = jnp.uint32(0)
            elif c == 1.0:
                fill = jnp.uint32(0xFFFFFFFF)
            else:
                # A binary constant cell holds one bit; flooring 0 < c < 1 to
                # an all-zeros word would silently miscompute.
                raise ValueError(
                    f"binary PI {pi.name}: const_value must be 0.0 or 1.0, "
                    f"got {pi.const_value}")
            env[pi.name] = jnp.full(shape, fill)
        else:
            raise KeyError(f"missing binary operand {pi.name}")
    return env


@partial(jax.jit, static_argnames=("plan", "use_pallas"))
def _execute_binary_compiled(plan: ExecutionPlan,
                             operand_bits: dict[str, jax.Array],
                             use_pallas: bool) -> dict[str, jax.Array]:
    from ..kernels import netlist_exec

    env = _binary_env(plan.pis, operand_bits)
    netlist_exec.run_combinational(plan, env, use_pallas=use_pallas)
    return {o: env[o] for o in plan.outputs}


def _plan_for(net: Netlist, bitflip_rate: float) -> ExecutionPlan:
    # Per-gate fault injection must observe the 4-gate MUX intermediates, so
    # the fused plan is only valid for clean combinational runs; sequential
    # runs inject at PI/output streams only (like the reference) and may fuse.
    fuse = bitflip_rate == 0.0 or net.is_sequential
    return compile_plan(net, fuse_mux=fuse)


# -------------------------------- public API --------------------------------------

def _check_modes(backend: str | None, key_mode: str | None) -> tuple[str, str]:
    backend = backend or DEFAULT_BACKEND
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {_BACKENDS}")
    key_mode = key_mode or DEFAULT_KEY_MODE
    if key_mode not in _KEY_MODES:
        raise ValueError(f"unknown key_mode {key_mode!r}; "
                         f"expected one of {_KEY_MODES}")
    return backend, key_mode


def _dispatch(net: Netlist, values, key, bitstream_length: int,
              bitflip_rate: float, flip_key, backend: str | None,
              decode: bool, key_mode: str | None = None,
              batch_shape: tuple[int, ...] | None = None) -> dict[str, jax.Array]:
    backend, key_mode = _check_modes(backend, key_mode)
    if batch_shape is not None:
        batch_shape = tuple(batch_shape)   # hashable for the jit static arg
    if bitflip_rate > 0.0 and flip_key is None:
        raise ValueError("bitflip_rate > 0 requires flip_key")
    if backend == "reference":
        outs = _execute_reference(net, values, key, bitstream_length,
                                  bitflip_rate, flip_key, key_mode=key_mode,
                                  batch_shape=batch_shape)
        if decode:
            outs = {k: bs.to_value(v, bitstream_length) for k, v in outs.items()}
        return outs
    plan = _plan_for(net, bitflip_rate)
    values = {k: jnp.asarray(v, jnp.float32) for k, v in values.items()}
    return _execute_compiled(plan, values, key, flip_key, bitstream_length,
                             float(bitflip_rate),
                             backend == "compiled_pallas", decode=decode,
                             key_mode=key_mode, batch_shape=batch_shape)


def execute(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
            bitstream_length: int, bitflip_rate: float = 0.0,
            flip_key: jax.Array | None = None,
            backend: str | None = None, key_mode: str | None = None,
            batch_shape: tuple[int, ...] | None = None) -> dict[str, jax.Array]:
    """Execute a (possibly sequential) netlist; returns packed output streams.

    ``bitflip_rate`` injects faults on the PI streams and on every gate
    output stream (the paper injects at input/output nodes of the
    arithmetic operations).  ``backend`` selects the execution engine (see
    module docstring); all backends are bit-identical.  ``key_mode`` selects
    the stream-generation key discipline (``"batched"`` default — one fused
    SNG pass for all PI streams; ``"legacy"`` — one PRNG split per stream,
    bit-exactly the pre-batching behavior); both backends honor it
    identically.  ``batch_shape`` declares the stream batch shape when it is
    not derivable from ``values`` (e.g. all stream PIs const-valued).

    Thin shim over ``run()``: builds one ``ExecRequest`` — bit-identical.
    """
    return run(ExecRequest(net, values, key, ExecOptions(
        backend=backend, key_mode=key_mode,
        bitstream_length=bitstream_length, bitflip_rate=bitflip_rate,
        flip_key=flip_key, batch_shape=batch_shape)))


def execute_value(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
                  bitstream_length: int, bitflip_rate: float = 0.0,
                  flip_key: jax.Array | None = None,
                  backend: str | None = None, key_mode: str | None = None,
                  batch_shape: tuple[int, ...] | None = None) -> dict[str, jax.Array]:
    """Execute and decode each output stream to its unipolar value.

    On the compiled backends the decode is fused into the execution program
    (single dispatch per call).  Thin shim over ``run()``."""
    return run(ExecRequest(net, values, key, ExecOptions(
        backend=backend, key_mode=key_mode,
        bitstream_length=bitstream_length, bitflip_rate=bitflip_rate,
        flip_key=flip_key, batch_shape=batch_shape, decode=True)))


def _dispatch_binary(net: Netlist, operand_bits: dict[str, jax.Array],
                     backend: str | None) -> dict[str, jax.Array]:
    backend = backend or DEFAULT_BACKEND
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {_BACKENDS}")
    if backend == "reference":
        env = _binary_env(net.pis, operand_bits)
        for g in net.gates:
            env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
        return {o: env[o] for o in net.outputs}
    plan = compile_plan(net, fuse_mux=True)
    return _execute_binary_compiled(plan, dict(operand_bits),
                                    backend == "compiled_pallas")


def execute_binary(net: Netlist, operand_bits: dict[str, jax.Array],
                   backend: str | None = None) -> dict[str, jax.Array]:
    """Execute a binary netlist on packed test-vector words.

    ``operand_bits`` maps PI names to uint32 words whose lane ``t`` is the
    PI's value in test vector ``t``.  Constant PIs (const_value set) are
    filled automatically.  Inverted-polarity storage (the Fig. 7(a) trick) is
    applied by the *caller* via the netlist's value conventions.

    Thin shim over ``run()`` (``options.binary``) — bit-identical.
    """
    return run(ExecRequest(net, dict(operand_bits), options=ExecOptions(
        backend=backend, binary=True)))


# ----------------------------- bank-level execution -------------------------------

def _restrict(x: jax.Array, batch: tuple[int, ...]) -> jax.Array:
    """Undo a broadcast: restrict ``x`` of shape (*common, W) to (*batch, W).

    Exact, not approximate: a merged member's nodes only ever combine
    elementwise with that member's own (broadcast) streams, so the restricted
    entries equal the member's native computation bit for bit.
    """
    want = len(batch) + 1
    if x.ndim == want and x.shape[:-1] == batch:
        return x
    x = x[(0,) * (x.ndim - want)]
    for ax, d in enumerate(batch):
        if d == 1 and x.shape[ax] != 1:
            x = jax.lax.slice_in_dim(x, 0, 1, axis=ax)
    return x


def _gen_bank_streams(bank: BankPlan, values_seq, keys, bitstream_length: int,
                      key_mode: str, use_pallas: bool,
                      batch_shapes, active=None) -> list[dict[str, jax.Array]]:
    """Per-member PI streams for a whole bank (list indexed by member).

    Batched key mode is the paper's bulk BtoS pass bank-wide: every member's
    stream-table rows stack into ONE threshold tensor per distinct batch
    shape and generate in one fused SNG pass — instead of one dispatch per
    PI per member.  Each row's randomness is keyed by (member key, fixed
    key-lane index), independent of the stacking, so a merged run stays
    bit-identical to a loop of per-member ``execute`` calls in the same mode.

    ``active`` (None = all) masks padded template slots: inactive members
    contribute NO rows to the fused SNG pass — their PI streams are zero
    words (value-0.0 constants, nearly free), just enough to keep the merged
    logic passes well-formed.  Active members' streams are untouched by the
    masking, so padded execution stays bit-identical per bound slot.
    """
    n = bank.n_members
    streams: list[dict[str, jax.Array]] = [{} for _ in range(n)]
    w = bs.n_words(bitstream_length)

    def masked(i: int) -> bool:
        return active is not None and not active[i]

    def zero_fill(i: int) -> dict[str, jax.Array]:
        return {nm: jnp.zeros((w,), jnp.uint32)
                for nm in bank.members[i].stream_table.names}

    if key_mode != "batched":
        for i, plan in enumerate(bank.members):
            if masked(i):
                streams[i] = zero_fill(i)
                continue
            streams[i] = _gen_pi_streams(
                plan.pis, values_seq[i], keys[i], bitstream_length,
                key_mode=key_mode,
                batch_shape=batch_shapes[i] if batch_shapes else None)
        return streams

    # Group member tables by broadcast shape; one fused SNG pass per shape.
    buckets: dict[tuple[int, ...], list[tuple[int, jax.Array, jax.Array]]] = {}
    for i, plan in enumerate(bank.members):
        table = plan.stream_table
        if not table.names:
            continue
        if masked(i):
            streams[i] = zero_fill(i)
            continue
        shape = _pi_shape(values_seq[i],
                          batch_shapes[i] if batch_shapes else None)
        ps = _stack_table_values(table, values_seq[i], shape)
        seeds = bs.stream_row_seeds(keys[i],
                                    jnp.asarray(table.lanes, jnp.uint32))
        buckets.setdefault(shape, []).append((i, ps, seeds))
    for entries in buckets.values():
        ps = jnp.concatenate([e[1] for e in entries])
        seeds = jnp.concatenate([e[2] for e in entries])
        words = bs.generate_batch_seeded(seeds, ps, bitstream_length,
                                         use_pallas=use_pallas)
        off = 0
        for i, ps_i, _ in entries:
            names = bank.members[i].stream_table.names
            for k, nm in enumerate(names):
                streams[i][nm] = words[off + k]
            off += len(names)
    return streams


@partial(jax.jit, static_argnames=("bank", "bitstream_length", "key_mode",
                                   "use_pallas", "batch_shapes", "active"))
def _generate_bank_streams_jit(bank: BankPlan, values_seq, keys,
                               bitstream_length: int, key_mode: str,
                               use_pallas: bool, batch_shapes, active=None):
    return _gen_bank_streams(bank, values_seq, keys, bitstream_length,
                             key_mode, use_pallas, batch_shapes, active=active)


def generate_bank_streams(bank: BankPlan, values_seq, keys,
                          bitstream_length: int,
                          key_mode: str = DEFAULT_KEY_MODE,
                          use_pallas: bool = False, batch_shapes=None,
                          active=None):
    """Generate (only) every member's PI streams — no logic passes.

    The stream-generation phase of ``_execute_bank`` as its own jitted entry
    point, used by the benchmarks to split bank wall-clock into gen vs pass
    time.  Accepts the same calling convention as ``execute_many`` (``keys``
    may be one key, split N ways; ``batch_shapes`` entries may be any
    sequence; ``active`` masks padded template slots down to zero-word
    fills).  Returns one ``{pi_name: packed words}`` dict per member.
    """
    values_seq = tuple(values_seq)
    if len(values_seq) != bank.n_members:
        raise ValueError(f"values: got {len(values_seq)} for "
                         f"{bank.n_members} members")
    keys = _normalize_keys(keys, bank.n_members)
    batch_shapes = _normalize_batch_shapes(batch_shapes, bank.n_members,
                                           "members")
    active = _normalize_active(active, bank.n_members)
    return _generate_bank_streams_jit(bank, values_seq, keys,
                                      bitstream_length, key_mode, use_pallas,
                                      batch_shapes, active)


def _execute_bank_impl(bank: BankPlan, values_seq, keys, flip_keys,
                       bitstream_length: int, bitflip_rate: float,
                       use_pallas: bool, decode: bool,
                       key_mode: str = DEFAULT_KEY_MODE, batch_shapes=None,
                       active=None, scalar_names=None):
    """Whole-bank execution of N member netlists as one XLA program.

    Stream generation and fault keying stay *per member*: member ``i``'s
    streams are drawn from ``keys[i]`` / ``flip_keys[i]`` exactly as a
    standalone ``execute`` call (same ``key_mode``) would draw them, so a
    merged run is bit-identical to a loop of per-member runs.  The logic
    merges — all combinational members execute through one merged plan
    (cross-member type-batched levels), all sequential members through one
    merged scan — and in batched key mode the stream generation merges too
    (one fused SNG pass per distinct member batch shape).

    ``active`` (static; None = all) is the padded-template slot mask: an
    inactive slot generates no real streams (zero-word fills), skips fault
    injection on its streams, and returns ``None`` instead of outputs.  Its
    *gate fault-key block* is still allocated when injecting — the merged
    plan's flat gid offsets cover every member — so active slots see exactly
    the keys a standalone run would.
    """
    from ..kernels import netlist_exec

    if scalar_names is not None:
        # Packed-slot layout (see execute_bank): slot i's host-scalar PI
        # values arrive as one f32 vector; rebuild the per-name dict at
        # trace time.  The unpack slices are free after fusion, and the jit
        # boundary sees one leaf per slot instead of one per PI.
        packed_seq, rest_seq = values_seq
        values_seq = tuple(
            {**{nm: packed_seq[i][j]
                for j, nm in enumerate(scalar_names[i])}, **rest_seq[i]}
            for i in range(len(scalar_names)))

    comb_env: dict[str, jax.Array] = {}
    seq_words: dict[str, jax.Array] = {}
    comb_gate_fkeys: list[jax.Array] = []
    seq_out_fkeys: dict[int, jax.Array | None] = {}
    native_batch: dict[int, tuple[int, ...]] = {}
    member_streams = _gen_bank_streams(bank, values_seq, keys,
                                       bitstream_length, key_mode, use_pallas,
                                       batch_shapes, active=active)
    for i, plan in enumerate(bank.members):
        pre = member_prefix(i)
        streams = member_streams[i]
        masked = active is not None and not active[i]
        tail = None
        if bitflip_rate > 0.0 and len(streams) + plan.n_gates > 0:
            fkeys = jax.random.split(flip_keys[i], len(streams) + plan.n_gates)
            if not masked:
                for j, nm in enumerate(sorted(streams)):
                    streams[nm] = sc_ops.flip_bits(fkeys[j], streams[nm],
                                                   bitflip_rate)
            tail = fkeys[len(streams):]
        native_batch[i] = (next(iter(streams.values())).shape[:-1]
                           if streams else ())
        target = seq_words if plan.is_sequential else comb_env
        for nm, v in streams.items():
            target[pre + nm] = v
        if plan.is_sequential:
            seq_out_fkeys[i] = tail
        elif tail is not None:
            # Flat per-gate key blocks in merge (= ascending member) order:
            # the merged plan's gids are offset to index this concatenation.
            comb_gate_fkeys.append(tail)

    outs: list = [None] * bank.n_members
    if bank.comb is not None:
        gf = jnp.concatenate(comb_gate_fkeys) if comb_gate_fkeys else None
        netlist_exec.run_combinational(bank.comb, comb_env, gate_fkeys=gf,
                                       bitflip_rate=bitflip_rate,
                                       use_pallas=use_pallas)
        for i in bank.comb_members:
            if active is not None and not active[i]:
                continue
            pre = member_prefix(i)
            outs[i] = {o: comb_env[pre + o] for o in bank.members[i].outputs}
    if bank.seq is not None:
        packed = netlist_exec.run_sequential(
            bank.seq, seq_words, use_pallas=use_pallas,
            n_words=bs.n_words(bitstream_length))
        for i in bank.seq_members:
            if active is not None and not active[i]:
                continue
            pre = member_prefix(i)
            m = {o: _restrict(packed[pre + o], native_batch[i])
                 for o in bank.members[i].outputs}
            if bitflip_rate > 0.0:
                tail = seq_out_fkeys[i]
                for j, o in enumerate(sorted(m)):
                    m[o] = sc_ops.flip_bits(tail[j], m[o], bitflip_rate)
            outs[i] = m
    if decode:
        outs = [m if m is None else
                {o: bs.to_value(w, bitstream_length) for o, w in m.items()}
                for m in outs]
    return tuple(outs)


_BANK_STATIC = ("bank", "bitstream_length", "bitflip_rate", "use_pallas",
                "decode", "key_mode", "batch_shapes", "active",
                "scalar_names")
_execute_bank = partial(jax.jit, static_argnames=_BANK_STATIC)(
    _execute_bank_impl)
#: Donating variant (its own jit cache): XLA reuses the stacked key rows'
#: buffers (argnums 2/3).  Only safe when the caller owns those arrays and
#: never reads them after the call — the serve engine's per-batch stacks.
#: Slot *values* are never donated: they may alias caller-held request
#: arrays.
_execute_bank_donating = partial(jax.jit, static_argnames=_BANK_STATIC,
                                 donate_argnums=(2, 3))(_execute_bank_impl)


#: type -> "is a jax.Array subclass" memo: ``isinstance(v, jax.Array)`` goes
#: through ABC registration machinery, which shows up at bank-dispatch rates
#: (thousands of value leaves per batch).
_IS_JAX_ARRAY: dict = {}


def _as_f32(v) -> jax.Array:
    """asarray(v, float32), skipping the (surprisingly costly) conversion
    machinery on the serving hot path when the caller already holds f32."""
    t = type(v)
    is_jax = _IS_JAX_ARRAY.get(t)
    if is_jax is None:
        is_jax = _IS_JAX_ARRAY.setdefault(t, isinstance(v, jax.Array))
    if is_jax and v.dtype == jnp.float32:
        return v
    return jnp.asarray(v, jnp.float32)


def _is_host_scalar(v) -> bool:
    t = type(v)
    is_jax = _IS_JAX_ARRAY.get(t)
    if is_jax is None:
        is_jax = _IS_JAX_ARRAY.setdefault(t, isinstance(v, jax.Array))
    return not is_jax and np.ndim(v) == 0


def _pack_values_seq(values_seq):
    """Slot-packed jit layout for bank dispatch: ``(packed, rest), names``.

    Each slot's *host scalar* PI values (python/numpy scalars — the serving
    admission format) collapse into one f32 vector, so the jit boundary
    flattens/transfers one leaf per slot instead of one per PI (a LIT slot
    alone carries 81).  ``names[i]`` records slot i's packed PI names in
    sorted order (a static jit argument); `_execute_bank_impl` rebuilds the
    dicts at trace time.  jax-array leaves are NOT packed — pulling them
    back to host would force a device sync — and flow through ``rest``
    unchanged, as do non-scalar (batched) values.
    """
    packed, rest, names = [], [], []
    for vals in values_seq:
        s = sorted(k for k, v in vals.items() if _is_host_scalar(v))
        names.append(tuple(s))
        packed.append(np.asarray([vals[k] for k in s], np.float32))
        if len(s) == len(vals):
            rest.append({})
        else:
            sset = set(s)
            rest.append({k: _as_f32(v) for k, v in vals.items()
                         if k not in sset})
    return (tuple(packed), tuple(rest)), tuple(names)


def _normalize_batch_shapes(batch_shapes, n: int, what: str = "netlists"):
    """Coerce per-member batch shapes to a hashable tuple-of-tuples (jit
    static arg) and validate the member count; None passes through."""
    if batch_shapes is None:
        return None
    batch_shapes = tuple(tuple(b) if b is not None else None
                         for b in batch_shapes)
    if len(batch_shapes) != n:
        raise ValueError(
            f"batch_shapes: got {len(batch_shapes)} for {n} {what}")
    return batch_shapes


def _normalize_active(active, n: int):
    """Coerce a slot-active mask to a hashable bool tuple (jit static arg).

    ``None`` and all-True both normalize to ``None`` — a fully-bound bank
    must share its jit trace with the mask-free ``execute_many`` path.
    """
    if active is None:
        return None
    active = tuple(bool(a) for a in active)
    if len(active) != n:
        raise ValueError(f"active: got {len(active)} for {n} slots")
    return None if all(active) else active


def _normalize_keys(keys, n: int, what: str = "keys") -> jax.Array:
    """Accept one key (split n ways), a key array, or a sequence of keys.

    Returns a stacked (n,) key array — members index it *inside* the jitted
    program, so the per-member key slicing costs no host dispatches.
    """
    if isinstance(keys, (list, tuple)):
        keys = jnp.stack(keys)
    elif jnp.ndim(keys) == 0:
        keys = jax.random.split(keys, n)
    if keys.shape[0] != n:
        raise ValueError(f"{what}: got {keys.shape[0]} for {n} netlists")
    return keys


def _dispatch_many(nets, values_seq, keys, bitstream_length: int,
                   bitflip_rate: float, flip_keys, backend: str | None,
                   decode: bool, key_mode: str | None = None,
                   batch_shapes=None) -> list:
    backend, key_mode = _check_modes(backend, key_mode)
    n = len(nets)
    if n == 0:
        raise ValueError("execute_many: need at least one netlist")
    if len(values_seq) != n:
        raise ValueError(f"values: got {len(values_seq)} for {n} netlists")
    batch_shapes = _normalize_batch_shapes(batch_shapes, n)
    keys = _normalize_keys(keys, n)
    if bitflip_rate > 0.0:
        if flip_keys is None:
            raise ValueError("bitflip_rate > 0 requires flip_keys")
        flip_keys = _normalize_keys(flip_keys, n, "flip_keys")
    else:
        flip_keys = None
    if backend == "reference":
        return [_dispatch(net, dict(vals), keys[i], bitstream_length,
                          bitflip_rate,
                          flip_keys[i] if flip_keys is not None else None,
                          backend, decode, key_mode=key_mode,
                          batch_shape=batch_shapes[i] if batch_shapes else None)
                for i, (net, vals) in enumerate(zip(nets, values_seq))]
    bank = compile_bank_plan(list(nets), fuse_mux=bitflip_rate == 0.0)
    values_seq, scalar_names = _pack_values_seq(values_seq)
    outs = _execute_bank(bank, values_seq, keys, flip_keys, bitstream_length,
                         float(bitflip_rate), backend == "compiled_pallas",
                         decode, key_mode=key_mode, batch_shapes=batch_shapes,
                         scalar_names=scalar_names)
    return list(outs)


#: Legacy positional tail of execute_many/execute_value_many after
#: (nets, values_seq); the *args/**kwargs shim reassembles it so the
#: deprecated plural-kwarg spellings (keys=/batch_shapes=) can be detected.
_MANY_TAIL = ("keys", "bitstream_length", "bitflip_rate", "flip_keys",
              "backend", "key_mode", "batch_shapes")


def _many_tail(fn_name: str, args: tuple, kwargs: dict) -> tuple:
    for bad in ("keys", "batch_shapes"):
        if bad in kwargs:
            warnings.warn(
                f"{fn_name}({bad}=...) is deprecated: build per-member "
                f"ExecRequests (each carrying its own key / "
                f"options.batch_shape) and call executor.run([...])",
                DeprecationWarning, stacklevel=3)
    if len(args) > len(_MANY_TAIL):
        raise TypeError(f"{fn_name}: too many positional arguments")
    params = dict(zip(_MANY_TAIL, args))
    dup = sorted(set(params) & set(kwargs))
    if dup:
        raise TypeError(f"{fn_name}: got multiple values for {dup}")
    params.update(kwargs)
    unknown = sorted(set(params) - set(_MANY_TAIL))
    if unknown:
        raise TypeError(f"{fn_name}: unexpected keyword arguments {unknown}")
    missing = sorted({"keys", "bitstream_length"} - set(params))
    if missing:
        raise TypeError(f"{fn_name}: missing required arguments {missing}")
    return (params["keys"], params["bitstream_length"],
            params.get("bitflip_rate", 0.0), params.get("flip_keys"),
            params.get("backend"), params.get("key_mode"),
            params.get("batch_shapes"))


def _many_shim(fn_name: str, nets, values_seq, args, kwargs,
               decode: bool) -> list:
    """Shared execute_many/execute_value_many shim: build per-member
    ``ExecRequest``s and delegate to ``run()`` — bit-identical to the legacy
    plural-kwarg path (stacking per-member key rows reproduces the original
    key array exactly)."""
    (keys, bitstream_length, bitflip_rate, flip_keys, backend, key_mode,
     batch_shapes) = _many_tail(fn_name, args, kwargs)
    n = len(nets)
    if n == 0:
        raise ValueError("execute_many: need at least one netlist")
    if len(values_seq) != n:
        raise ValueError(f"values: got {len(values_seq)} for {n} netlists")
    keys = _normalize_keys(keys, n)
    batch_shapes = _normalize_batch_shapes(batch_shapes, n)
    if bitflip_rate > 0.0:
        if flip_keys is None:
            raise ValueError("bitflip_rate > 0 requires flip_keys")
        flip_keys = _normalize_keys(flip_keys, n, "flip_keys")
    reqs = [ExecRequest(net, vals, keys[i], ExecOptions(
                backend=backend, key_mode=key_mode,
                bitstream_length=bitstream_length,
                bitflip_rate=bitflip_rate,
                flip_key=flip_keys[i] if bitflip_rate > 0.0 else None,
                batch_shape=batch_shapes[i] if batch_shapes else None,
                decode=decode))
            for i, (net, vals) in enumerate(zip(nets, values_seq))]
    return run(reqs)


def execute_many(nets, values_seq, /, *args, **kwargs) -> list:
    """Execute N (possibly different) netlists as ONE fused bank-level plan.

    Legacy signature: ``execute_many(nets, values_seq, keys,
    bitstream_length, bitflip_rate=0.0, flip_keys=None, backend=None,
    key_mode=None, batch_shapes=None)``.

    ``nets[i]`` runs with PI values ``values_seq[i]`` and PRNG key ``keys[i]``
    (``keys`` may also be a single key, which is split N ways).  Returns one
    packed-output dict per member, bit-identical to calling ``execute`` per
    netlist with the same per-member keys and ``key_mode`` — the merged plan
    batches same-type gates of each level *across* members (core/plan.py bank
    merging), and in batched key mode all members' PI streams generate in one
    fused SNG pass per distinct batch shape, so the whole bank runs in a
    single jit dispatch instead of N.  Member batch shapes may differ
    (``batch_shapes[i]`` declares member i's shape when its values alone
    cannot, e.g. all-const stream PIs).  ``bitflip_rate`` injects per-member
    faults keyed by ``flip_keys[i]`` (single key allowed, split N ways).

    .. deprecated:: the plural-kwarg spellings ``keys=`` / ``batch_shapes=``
       — build per-member ``ExecRequest``s and call ``run([...])`` instead;
       this shim stays bit-identical but warns.
    """
    return _many_shim("execute_many", nets, values_seq, args, kwargs,
                      decode=False)


def execute_value_many(nets, values_seq, /, *args, **kwargs) -> list:
    """``execute_many`` with the StoB decode fused into the same program.

    Same legacy signature and deprecation notes as ``execute_many``.
    """
    return _many_shim("execute_value_many", nets, values_seq, args, kwargs,
                      decode=True)


def execute_bank(bank: BankPlan, values_seq, keys, bitstream_length: int,
                 *, active=None, bitflip_rate: float = 0.0, flip_keys=None,
                 backend: str | None = None, key_mode: str | None = None,
                 batch_shapes=None, decode: bool = False,
                 device=None, donate: bool = False) -> list:
    """Execute a prebuilt (possibly padded) BankPlan slot-wise.

    The serving-engine entry point (``repro.serve.sc_engine``): ``bank`` is
    typically a canonical template from ``plan.compile_bank_template`` whose
    slots outnumber the bound requests.  ``values_seq[i]`` / ``keys[i]`` /
    ``batch_shapes[i]`` / ``flip_keys[i]`` feed slot ``i``; ``active[i] =
    False`` masks slot ``i`` out — no streams are generated for it (zero-word
    fills keep the merged passes well-formed), and its entry in the returned
    list is ``None``.  Unbound slots' ``values_seq`` entries should be empty
    dicts; their key rows are placeholders (any same-dtype key).

    Every *bound* slot's outputs are bit-identical to a standalone
    ``execute`` of that member with the same key, ``key_mode`` and flip key —
    padding never perturbs active streams.  ``decode=True`` fuses the StoB
    decode into the program (the ``execute_value_many`` analogue).  Bank
    plans only execute on the compiled backends.

    ``device`` (a ``jax.Device``) commits the stacked key rows there before
    dispatch; jit places the whole bank execution with its committed
    argument, so the program runs on that device and the outputs live there
    — the multi-bank server's sharded placement.  Only the key arrays are
    committed (one buffer each): committing the per-slot values pytree
    leaf-by-leaf costs more host time than the dispatch itself, while
    uncommitted values follow the keys in one transfer.  Values already
    committed to a *different* device raise jax's colocation error — pass
    host/uncommitted values when sharding.  ``donate=True`` lets XLA consume
    the stacked key-row buffers (never the slot values, which may alias
    caller arrays); only pass it when the key rows are call-owned scratch,
    like the serve engine's per-batch stacks.
    """
    backend, key_mode = _check_modes(backend, key_mode)
    if backend == "reference":
        raise ValueError("execute_bank runs compiled BankPlans; use "
                         "execute()/execute_many() for the reference backend")
    n = bank.n_members
    if len(values_seq) != n:
        raise ValueError(f"values: got {len(values_seq)} for {n} slots")
    values_seq, scalar_names = _pack_values_seq(values_seq)
    keys = _normalize_keys(keys, n)
    batch_shapes = _normalize_batch_shapes(batch_shapes, n, "slots")
    active = _normalize_active(active, n)
    if bitflip_rate > 0.0:
        if flip_keys is None:
            raise ValueError("bitflip_rate > 0 requires flip_keys")
        flip_keys = _normalize_keys(flip_keys, n, "flip_keys")
    else:
        flip_keys = None
    if device is not None:
        keys = jax.device_put(keys, device)
        if flip_keys is not None:
            flip_keys = jax.device_put(flip_keys, device)
    args = (bank, values_seq, keys, flip_keys, bitstream_length,
            float(bitflip_rate), backend == "compiled_pallas", decode)
    kw = dict(key_mode=key_mode, batch_shapes=batch_shapes, active=active,
              scalar_names=scalar_names)
    if donate:
        # Donation is best-effort: when no output can alias a key-row buffer
        # (the common case — outputs are packed words, not keys) XLA ignores
        # it and jax warns; that advisory is noise on a hot serving path.
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore",
                                    message="Some donated buffers were not")
            outs = _execute_bank_donating(*args, **kw)
    else:
        outs = _execute_bank(*args, **kw)
    return list(outs)


# ------------------------------ run() entry point ---------------------------------

def _key_data_host(k) -> np.ndarray:
    # The public unwrap (jax.random.key_data) dispatches an XLA op per key —
    # at serving rates that is the single largest per-batch host cost.  The
    # raw buffer is directly reachable on current jax; fall back to the
    # public path if the internal layout ever changes.
    base = getattr(k, "_base_array", None)
    if base is not None:
        return np.asarray(base)
    return np.asarray(jax.random.key_data(k))


def _stack_keys(keys: list):
    """Stack per-slot PRNG keys into one (n,) key array, host-side.

    ``jnp.stack`` over typed keys dispatches one expand_dims per slot plus a
    concatenate; staging the raw key data through numpy collapses that to
    ONE device put, bit-identical to the stacked keys (same key data, same
    impl).  Repeated slot keys (the unbound-slot placeholder) unwrap once.
    """
    try:
        memo: dict[int, np.ndarray] = {}
        rows = []
        for k in keys:
            d = memo.get(id(k))
            if d is None:
                d = memo[id(k)] = _key_data_host(k)
            rows.append(d)
        return jax.random.wrap_key_data(jnp.asarray(np.stack(rows)),
                                        impl=jax.random.key_impl(keys[0]))
    except (TypeError, AttributeError):
        return jnp.stack(keys)


_SHARED_OPTION_FIELDS = ("backend", "key_mode", "bitstream_length",
                         "bitflip_rate", "decode", "binary")


def _common_options(reqs: "list[ExecRequest]") -> ExecOptions:
    """The options every request of a merged batch must agree on (per-slot
    fields — key, flip_key, batch_shape, values — stay per request)."""
    o0 = reqs[0].options
    for r in reqs[1:]:
        for f in _SHARED_OPTION_FIELDS:
            if getattr(r.options, f) != getattr(o0, f):
                raise ValueError(
                    f"run: requests disagree on options.{f}: "
                    f"{getattr(o0, f)!r} vs {getattr(r.options, f)!r} "
                    f"(group requests by shared options, or pass options=)")
    return o0


def _run_one(req: ExecRequest, device=None,
             options: ExecOptions | None = None):
    o = options or req.options
    if o.binary:
        return _dispatch_binary(req.net, req.values, o.backend)
    values, key, flip_key = req.values, req.key, o.flip_key
    if device is not None:
        # Commit only the key(s): jit places the program with its committed
        # argument, and uncommitted values follow in one transfer (committing
        # a values pytree leaf-by-leaf costs more than the dispatch).
        key = jax.device_put(key, device)
        if flip_key is not None:
            flip_key = jax.device_put(flip_key, device)
    if isinstance(req.net, ExecutionPlan):
        backend, key_mode = _check_modes(o.backend, o.key_mode)
        if backend == "reference":
            raise ValueError("the reference backend interprets netlists; "
                             "pass the Netlist, not its ExecutionPlan")
        if o.bitflip_rate > 0.0 and flip_key is None:
            raise ValueError("bitflip_rate > 0 requires flip_key")
        batch_shape = (tuple(o.batch_shape)
                       if o.batch_shape is not None else None)
        values = {k: _as_f32(v) for k, v in values.items()}
        return _execute_compiled(req.net, values, key, flip_key,
                                 o.bitstream_length, float(o.bitflip_rate),
                                 backend == "compiled_pallas", decode=o.decode,
                                 key_mode=key_mode, batch_shape=batch_shape)
    return _dispatch(req.net, values, key, o.bitstream_length,
                     o.bitflip_rate, flip_key, o.backend, decode=o.decode,
                     key_mode=o.key_mode, batch_shape=o.batch_shape)


def _run_many(reqs: "list[ExecRequest]", device=None,
              options: ExecOptions | None = None) -> list:
    if not reqs:
        raise ValueError("run: need at least one request")
    shared = options or _common_options(reqs)
    if shared.binary:
        raise ValueError("run: binary requests execute one at a time")
    for r in reqs:
        if not isinstance(r.net, Netlist):
            raise TypeError("run([...]) merges netlists into one bank; pass "
                            "template= to execute a prebuilt BankPlan")
    rate = float(shared.bitflip_rate)
    flip_keys = None
    if rate > 0.0:
        flip_keys = [r.options.flip_key for r in reqs]
        if any(fk is None for fk in flip_keys):
            raise ValueError("bitflip_rate > 0 requires a flip_key on every "
                             "request")
    batch_shapes = [r.options.batch_shape for r in reqs]
    if all(b is None for b in batch_shapes):
        batch_shapes = None
    values_seq = [r.values for r in reqs]
    keys = [r.key for r in reqs]
    if device is not None:
        # Commit only the keys (see _run_one): the program follows them.
        keys = jax.device_put(keys, device)
        if flip_keys is not None:
            flip_keys = jax.device_put(flip_keys, device)
    return _dispatch_many([r.net for r in reqs], values_seq, keys,
                          shared.bitstream_length, rate, flip_keys,
                          shared.backend, shared.decode,
                          key_mode=shared.key_mode,
                          batch_shapes=batch_shapes)


def _run_template(reqs, bank: BankPlan, active=None, device=None,
                  donate: bool = False,
                  options: ExecOptions | None = None) -> list:
    """Slot-aligned template execution: ``reqs[i]`` feeds template slot ``i``
    (``None`` = unbound slot, masked out)."""
    n = bank.n_members
    if len(reqs) != n:
        raise ValueError(f"run: got {len(reqs)} slot requests for {n} slots")
    bound = [(i, r) for i, r in enumerate(reqs) if r is not None]
    if not bound:
        raise ValueError("run: template batch needs at least one bound slot")
    shared = options or _common_options([r for _, r in bound])
    if shared.binary:
        raise ValueError("run: binary requests execute one at a time")
    rate = float(shared.bitflip_rate)
    if active is None:
        active = [r is not None for r in reqs]
    # Placeholder rows for unbound slots: any same-impl key works (masked
    # slots draw no streams); reusing the first bound key row unwraps once.
    key0 = bound[0][1].key
    fk0 = bound[0][1].options.flip_key
    values_seq: list = [{} for _ in range(n)]
    key_rows: list = [key0] * n
    flip_rows: list = [fk0 if fk0 is not None else key0] * n
    batch_shapes: list = [None] * n
    for i, r in bound:
        values_seq[i] = r.values
        key_rows[i] = r.key
        batch_shapes[i] = r.options.batch_shape
        if rate > 0.0:
            if r.options.flip_key is None:
                raise ValueError("bitflip_rate > 0 requires a flip_key on "
                                 "every request")
            flip_rows[i] = r.options.flip_key
    return execute_bank(
        bank, values_seq, _stack_keys(key_rows), shared.bitstream_length,
        active=active, bitflip_rate=rate,
        flip_keys=_stack_keys(flip_rows) if rate > 0.0 else None,
        backend=shared.backend, key_mode=shared.key_mode,
        batch_shapes=batch_shapes, decode=shared.decode,
        device=device, donate=donate)


def run(request_or_requests, *, template: BankPlan | None = None,
        active=None, device=None, donate: bool = False,
        options: ExecOptions | None = None):
    """Canonical execution entry point over ``ExecRequest``s.

    * ``run(req)`` — execute one request (netlist or prebuilt plan);
      returns its output dict (decoded when ``options.decode``).
    * ``run([req, ...])`` — merge the requests' netlists into ONE fused
      bank-level program (the ``execute_many`` path); returns one output
      dict per request, bit-identical to running each alone.
    * ``run(slot_reqs, template=bank)`` — bind slot-aligned requests
      (``None`` = unbound) onto a padded bank template and execute with the
      unbound slots masked; returns one entry per slot (``None`` where
      unbound).  This is the serving engine's path.

    Batch paths require the requests to agree on the shared option fields
    (backend / key_mode / bitstream_length / bitflip_rate / decode); pass
    ``options=`` to supply them explicitly instead (per-slot key, flip_key,
    batch_shape and values always come from each request).  ``device``
    commits the batch inputs to one JAX device before dispatch;
    ``donate`` forwards to ``execute_bank`` (template path only).
    """
    if isinstance(request_or_requests, ExecRequest):
        return _run_one(request_or_requests, device=device, options=options)
    reqs = list(request_or_requests)
    if template is not None:
        return _run_template(reqs, template, active=active, device=device,
                             donate=donate, options=options)
    return _run_many(reqs, device=device, options=options)


# ----------------------------- reference backend ----------------------------------

def _execute_reference(net: Netlist, values: dict[str, jax.Array],
                       key: jax.Array, bitstream_length: int,
                       bitflip_rate: float = 0.0,
                       flip_key: jax.Array | None = None,
                       key_mode: str = DEFAULT_KEY_MODE,
                       batch_shape: tuple[int, ...] | None = None) -> dict[str, jax.Array]:
    """Gate-by-gate interpreter: the oracle for the compiled plans.

    Stream generation honors the same ``key_mode`` as the compiled backends
    (the discipline lives in ``_gen_pi_streams``, upstream of interpretation),
    so reference and compiled outputs stay bit-for-bit comparable in either
    mode."""
    streams = _gen_pi_streams(net.pis, values, key, bitstream_length,
                              key_mode=key_mode, batch_shape=batch_shape)

    if bitflip_rate > 0.0:
        if flip_key is None:
            raise ValueError("bitflip_rate > 0 requires flip_key")
        fkeys = jax.random.split(flip_key, len(streams) + len(net.gates))
        for i, name in enumerate(sorted(streams)):
            streams[name] = sc_ops.flip_bits(fkeys[i], streams[name], bitflip_rate)

    if not net.is_sequential:
        # Snapshot the PI-stream count: gate outputs are appended to the env
        # below, and letting the flip-key index grow with it would silently
        # clamp past the end of ``fkeys`` and reuse the last key.
        n_streams = len(streams)
        for gi, g in enumerate(net.gates):
            out = bs.GATE_FNS[g.gtype](*[streams[i] for i in g.inputs])
            if bitflip_rate > 0.0:
                out = sc_ops.flip_bits(fkeys[n_streams + gi], out, bitflip_rate)
            streams[g.output] = out
        return {o: streams[o] for o in net.outputs}

    # Sequential: iterate the combinational core over bitstream bits.
    state_pis = list(net.state_bindings.keys())
    # State-only recurrences have no streams to read the shape from.
    shape = (next(iter(streams.values())).shape if streams
             else (bitstream_length // bs.WORD_BITS,))  # (..., W)
    bl = bitstream_length

    def unpack_time_major(w):
        bits = bs.unpack_bits(w)                      # (..., W, 32)
        flat = bits.reshape(bits.shape[:-2] + (bl,))
        return jnp.moveaxis(flat, -1, 0)              # (BL, ...)

    time_streams = {k: unpack_time_major(v) for k, v in streams.items()}

    def step(state, xs):
        env = dict(xs) if xs is not None else {}
        for s_name in state_pis:
            env[s_name] = state[s_name]
        for g in net.gates:
            env[g.output] = bs.GATE_FNS[g.gtype](*[env[i] for i in g.inputs])
        new_state = {s: env[net.state_bindings[s][0]] for s in state_pis}
        outs = {o: env[o] for o in net.outputs}
        return new_state, outs

    init = {s: jnp.full(shape[:-1], jnp.uint32(round(net.state_bindings[s][1])))
            for s in state_pis}
    _, out_seq = jax.lax.scan(step, init, time_streams or None,
                              length=None if time_streams else bl)
    packed_outs = {}
    for o, seq in out_seq.items():
        seq = jnp.moveaxis(seq, 0, -1)                # (..., BL)
        bits = seq.reshape(seq.shape[:-1] + (bl // 32, 32))
        # Mask to bit 0 before packing: inverting gates (~x) leave garbage
        # in bits 1..31 of the per-step values, which pack_bits would sum
        # into other bit positions of the word.
        packed_outs[o] = bs.pack_bits(bits & jnp.uint32(1))
    if bitflip_rate > 0.0:
        for i, o in enumerate(sorted(packed_outs)):
            packed_outs[o] = sc_ops.flip_bits(fkeys[len(streams) + i],
                                              packed_outs[o], bitflip_rate)
    return packed_outs
