"""Netlist builders: stochastic arithmetic (Fig. 5) and binary IMC baselines.

Stochastic circuits use only the reliability-preferred gate subset
{NOT, BUFF, NAND} (Section 5-1).  Binary circuits use the NMAJ3/NMAJ5 full
adder of [3, 8] with the polarity-alternating carry trick of Fig. 7(a)
(DESIGN.md §7).  Where the paper's figures are unavailable, reconstruction
choices are documented inline and in DESIGN.md §7.
"""
from __future__ import annotations

from .gates import Netlist, PIKind


# =============================== stochastic ops ===================================

def sc_multiply() -> Netlist:
    """Fig. 5(b): multiplication = AND = NOT(NAND).  value = a*b."""
    n = Netlist("sc_multiply")
    a = n.add_pi("A", value_key="a")
    b = n.add_pi("B", value_key="b")
    t = n.add_gate("NAND", [a, b], "n1")
    n.add_gate("NOT", [t], "out")
    n.set_outputs(["out"])
    return n


def sc_scaled_add(select: float = 0.5) -> Netlist:
    """Fig. 5(a): scaled addition = MUX.  value = s*a + (1-s)*b.

    NAND form: out = NAND(NAND(A,S), NAND(B,S_bar)) — 4 gates / 7 columns,
    matching Table 2's 256x7 array and Fig. 7(b)'s 4-cycle schedule.
    """
    n = Netlist("sc_scaled_add")
    a = n.add_pi("A", value_key="a")
    b = n.add_pi("B", value_key="b")
    s = n.add_pi("S", kind=PIKind.CONSTANT, const_value=select)
    sb = n.add_gate("NOT", [s], "S_bar")
    n1 = n.add_gate("NAND", [a, s], "n1")
    n2 = n.add_gate("NAND", [b, sb], "n2")
    n.add_gate("NAND", [n1, n2], "out")
    n.set_outputs(["out"])
    return n


def sc_scaled_add_var() -> Netlist:
    """Scaled addition with a *variable* (stochastic) select stream.

    Used by the HDP application (Eq. (9)): MUX with select P(D)/P(E) computes
    s*a + (1-s)*b, i.e. probability-weighted mixing.
    """
    n = Netlist("sc_scaled_add_var")
    a = n.add_pi("A", value_key="a")
    b = n.add_pi("B", value_key="b")
    s = n.add_pi("S", value_key="s")
    sb = n.add_gate("NOT", [s], "S_bar")
    n1 = n.add_gate("NAND", [a, s], "n1")
    n2 = n.add_gate("NAND", [b, sb], "n2")
    n.add_gate("NAND", [n1, n2], "out")
    n.set_outputs(["out"])
    return n


def sc_abs_sub() -> Netlist:
    """Fig. 5(c): |a-b| = XOR over *correlated* streams (shared randomness).

    Four-NAND XOR: n1=NAND(A,B); out=NAND(NAND(A,n1), NAND(B,n1)).
    """
    n = Netlist("sc_abs_sub")
    a = n.add_pi("A", value_key="a", corr_group="g0")
    b = n.add_pi("B", value_key="b", corr_group="g0")
    n1 = n.add_gate("NAND", [a, b], "n1")
    n2 = n.add_gate("NAND", [a, n1], "n2")
    n3 = n.add_gate("NAND", [b, n1], "n3")
    n.add_gate("NAND", [n2, n3], "out")
    n.set_outputs(["out"])
    return n


def sc_scaled_div() -> Netlist:
    """Fig. 5(d): scaled division via the Gaines JK feedback unit.

    Q <- (A AND Q_bar) OR (B_bar AND Q), Q init 0 (per the paper)
       = NAND(NAND(A, Q_bar), NAND(B_bar, Q));  E[Q] -> a / (a + b).
    Sequential across bitstream bits: executed as a wavefront across
    subarrays in the Stoch-IMC architecture (DESIGN.md §7(d)).
    """
    n = Netlist("sc_scaled_div")
    a = n.add_pi("A", value_key="a")
    b = n.add_pi("B", value_key="b")
    q = n.add_pi("Q", kind=PIKind.STATE)
    qb = n.add_gate("NOT", [q], "Q_bar")
    bb = n.add_gate("NOT", [b], "B_bar")
    n1 = n.add_gate("NAND", [a, qb], "n1")
    n2 = n.add_gate("NAND", [bb, q], "n2")
    qn = n.add_gate("NAND", [n1, n2], "Q_next")
    n.bind_state(q, qn, init=0.0)
    n.set_outputs([qn])
    return n


SQRT_C = 0.9  # least-squares fit of 1-(1-c*x)^2 to sqrt(x) on [0,1]


def sc_sqrt() -> Netlist:
    """Fig. 5(e): square root — reconstructed circuit (DESIGN.md §7(e)).

    Two independently-generated copies A1, A2 of the same value and two
    constant streams C1, C2 (paper's description); combinational form
    out = NAND(NAND(A1,C1), NAND(A2,C2)) = 1-(1-c x)^2 = 2c*x - c^2*x^2,
    c = 0.9.  Used for cycle/energy/area accounting; the accuracy path of the
    applications uses a value-faithful sqrt sampling model (apps.py), since no
    two-copy combinational circuit can match sqrt near 0.
    """
    n = Netlist("sc_sqrt")
    a1 = n.add_pi("A1", value_key="a", indep_copy=0)
    a2 = n.add_pi("A2", value_key="a", indep_copy=1)
    c1 = n.add_pi("C1", kind=PIKind.CONSTANT, const_value=SQRT_C)
    c2 = n.add_pi("C2", kind=PIKind.CONSTANT, const_value=SQRT_C)
    n1 = n.add_gate("NAND", [a1, c1], "n1")
    n2 = n.add_gate("NAND", [a2, c2], "n2")
    n.add_gate("NAND", [n1, n2], "out")
    n.set_outputs(["out"])
    return n


def sc_exp(c: float = 1.0, order: int = 5) -> Netlist:
    """Fig. 5(f): exp(-c*a), 0 < c <= 1, 5th-order Maclaurin in Horner form.

    s_5 = NAND(A5, C5) = 1 - (c/5) a
    s_k = NAND(AND(A_k, C_k), s_{k+1}) = 1 - (c/k) a s_{k+1},   k = 4..1
    with independent copies A_k and constant streams C_k = c/k.  Unbiased
    under independence (each A_k independent of s_{k+1}).
    """
    if not (0.0 < c <= 1.0):
        raise ValueError("exp(-c a) requires 0 < c <= 1 for unipolar encoding")
    n = Netlist(f"sc_exp_c{c:g}")
    a_copies = [n.add_pi(f"A{k}", value_key="a", indep_copy=k - 1)
                for k in range(1, order + 1)]
    consts = [n.add_pi(f"C{k}", kind=PIKind.CONSTANT, const_value=c / k)
              for k in range(1, order + 1)]
    s = n.add_gate("NAND", [a_copies[order - 1], consts[order - 1]], f"s{order}")
    for k in range(order - 1, 0, -1):
        t = n.add_gate("NAND", [a_copies[k - 1], consts[k - 1]], f"t{k}")
        u = n.add_gate("NOT", [t], f"u{k}")
        s = n.add_gate("NAND", [u, s], f"s{k}")
    n.set_outputs([s])
    return n


def sc_mux_tree(leaf_names: list[str], netlist: Netlist, prefix: str = "m") -> str:
    """Balanced MUX tree computing the *mean* of the leaves (scaled adds, S=0.5).

    Returns the root node name.  Leaves must already exist in ``netlist``.
    Used by the application circuits (LIT window mean, KDE history mean).
    """
    level = list(leaf_names)
    const_id = 0
    depth = 0
    while len(level) > 1:
        nxt: list[str] = []
        for i in range(0, len(level) - 1, 2):
            s = netlist.add_pi(f"{prefix}_S{depth}_{i}", kind=PIKind.CONSTANT,
                               const_value=0.5)
            sb = netlist.add_gate("NOT", [s], f"{prefix}_Sb{depth}_{i}")
            n1 = netlist.add_gate("NAND", [level[i], s], f"{prefix}_n1_{depth}_{i}")
            n2 = netlist.add_gate("NAND", [level[i + 1], sb], f"{prefix}_n2_{depth}_{i}")
            nxt.append(netlist.add_gate("NAND", [n1, n2], f"{prefix}_o{depth}_{i}"))
            const_id += 1
        if len(level) % 2 == 1:
            # Odd leaf passes through at half weight next round: pair it with
            # itself is biased; standard practice pads with the leaf itself.
            nxt.append(level[-1])
        level = nxt
        depth += 1
    return level[0]


# ================================ binary ops =====================================

def binary_ripple_carry_adder(n_bits: int) -> Netlist:
    """n-bit in-memory binary adder (Fig. 7(a)), one bit lane per row.

    Per-row full adder of [3, 8]: carry-out = NMAJ3(a, b, c); sum via NMAJ5
    with the doubled complemented-carry operand (its BUFF copy).  Rows
    alternate stored-input polarity so the complemented carry feeds the next
    row directly: even rows store (a, b) true and produce the inverted carry;
    odd rows store (a_bar, b_bar) and produce the true carry (DESIGN.md §7).
    Schedules to 2(n-1)+3 cycles (even n) / 2(n-1)+4 (odd n) — the paper's
    formula; 9 cycles at n=4.
    """
    net = Netlist(f"bin_add_{n_bits}")
    a = [net.add_pi(f"A{i}", kind=PIKind.BINARY, value_key="a", row=i)
         for i in range(n_bits)]
    b = [net.add_pi(f"B{i}", kind=PIKind.BINARY, value_key="b", row=i)
         for i in range(n_bits)]
    c0 = net.add_pi("C0", kind=PIKind.BINARY, const_value=0.0, row=0)

    carry = c0  # carry node resident in row i (polarity alternates)
    sums: list[str] = []
    for i in range(n_bits):
        nc = net.add_gate("NMAJ3", [a[i], b[i], carry], f"nc{i + 1}", row=i)
        cc = net.add_gate("BUFF", [nc], f"cc{i}", row=i)  # doubled operand copy
        ns = net.add_gate("NMAJ5", [a[i], b[i], carry, nc, cc], f"ns{i}", row=i)
        if i % 2 == 0:
            sums.append(net.add_gate("NOT", [ns], f"s{i}", row=i))
        else:
            sums.append(ns)  # inverted-polarity row yields the true sum directly
        if i + 1 < n_bits:
            carry = net.add_gate("BUFF", [nc], f"c{i + 1}", row=i + 1)  # cross-row
        else:
            carry = nc  # final carry (complemented on even-polarity MSB rows)
    net.set_outputs(sums + [carry])
    return net


def rca_prepare_inputs(a: "jnp.ndarray", b: "jnp.ndarray", n_bits: int) -> dict:
    """Pack integer operand vectors into the Fig. 7(a) polarity convention.

    Lane ``t`` of each PI word is test-vector ``t``.  Odd rows store inverted
    bits (the alternating-polarity carry trick).
    """
    import jax.numpy as jnp
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    bits = {}
    full = jnp.uint32(0xFFFFFFFF)
    for i in range(n_bits):
        abit = jnp.where((a >> i) & 1 == 1, full, jnp.uint32(0))
        bbit = jnp.where((b >> i) & 1 == 1, full, jnp.uint32(0))
        if i % 2 == 1:
            abit, bbit = ~abit, ~bbit
        bits[f"A{i}"] = abit
        bits[f"B{i}"] = bbit
    return bits


def rca_decode_outputs(outs: dict, n_bits: int) -> "jnp.ndarray":
    """Decode the adder outputs (sum bits + final carry) to integers."""
    import jax.numpy as jnp
    assert n_bits < 31, "decode uses uint32 accumulation"
    total = jnp.zeros_like(next(iter(outs.values())), dtype=jnp.uint32)
    for i in range(n_bits):
        name = f"s{i}" if i % 2 == 0 else f"ns{i}"
        total = total + (outs[name] & jnp.uint32(1)) * jnp.uint32(1 << i)
    carry = outs[f"nc{n_bits}"]
    if (n_bits - 1) % 2 == 0:  # MSB row even polarity -> carry stored inverted
        carry = ~carry
    total = total + (carry & jnp.uint32(1)) * jnp.uint32(1 << n_bits)
    return total


def binary_adder_nand_serial(n_bits: int) -> Netlist:
    """Single-row serial binary adder from 9-NAND full adders.

    Matches the paper's Table 2 binary scaled-addition layout (1 x 88 for
    8 bits: 17 input cells + ~9 gates per FA), which serializes completely in
    one row — the baseline the stochastic 0.056X timing ratio is against.
    """
    net = Netlist(f"bin_add_nand_{n_bits}")
    a = [net.add_pi(f"A{i}", kind=PIKind.BINARY, value_key="a", row=0)
         for i in range(n_bits)]
    b = [net.add_pi(f"B{i}", kind=PIKind.BINARY, value_key="b", row=0)
         for i in range(n_bits)]
    carry = net.add_pi("C0", kind=PIKind.BINARY, const_value=0.0, row=0)
    sums = []
    for i in range(n_bits):
        # 9-NAND full adder (all gates in row 0).
        n1 = net.add_gate("NAND", [a[i], b[i]], f"n1_{i}", row=0)
        n2 = net.add_gate("NAND", [a[i], n1], f"n2_{i}", row=0)
        n3 = net.add_gate("NAND", [b[i], n1], f"n3_{i}", row=0)
        h = net.add_gate("NAND", [n2, n3], f"h_{i}", row=0)       # a xor b
        n4 = net.add_gate("NAND", [h, carry], f"n4_{i}", row=0)
        n5 = net.add_gate("NAND", [h, n4], f"n5_{i}", row=0)
        n6 = net.add_gate("NAND", [carry, n4], f"n6_{i}", row=0)
        sums.append(net.add_gate("NAND", [n5, n6], f"s{i}", row=0))
        carry = net.add_gate("NAND", [n4, n1], f"c{i + 1}", row=0)
    net.set_outputs(sums + [carry])
    return net


def binary_multiplier(n_bits: int) -> Netlist:
    """n x n-bit in-memory multiplier: AND partial products + adder-tree
    reduction (Wallace-style) built from the same NMAJ3/NMAJ5 full adders.

    The structure (not the exact Wallace wiring) is what drives cycle/energy
    counts; partial products of weight w map to row w so that same-weight
    reductions are intra-row.  AND = NOT(NAND).
    """
    net = Netlist(f"bin_mul_{n_bits}")
    a = [net.add_pi(f"A{i}", kind=PIKind.BINARY, value_key="a", row=i)
         for i in range(n_bits)]
    b = [net.add_pi(f"B{j}", kind=PIKind.BINARY, value_key="b", row=j)
         for j in range(n_bits)]

    # Partial products: pp[i][j] = a_i AND b_j at weight i+j, mapped to row (i+j) % n.
    columns: dict[int, list[str]] = {}
    for i in range(n_bits):
        for j in range(n_bits):
            w = i + j
            row = w % n_bits
            ai, bj = a[i], b[j]
            nn = net.add_gate("NAND", [ai, bj], f"pp_n_{i}_{j}", row=row)
            pp = net.add_gate("NOT", [nn], f"pp_{i}_{j}", row=row)
            columns.setdefault(w, []).append(pp)

    # Carry-save reduction: repeatedly compress 3 same-weight terms with a FA.
    fa_id = 0

    def full_add(x: str, y: str, z: str, row: int) -> tuple[str, str]:
        nonlocal fa_id
        nc = net.add_gate("NMAJ3", [x, y, z], f"fa{fa_id}_nc", row=row)
        cc = net.add_gate("BUFF", [nc], f"fa{fa_id}_cc", row=row)
        ns = net.add_gate("NMAJ5", [x, y, z, nc, cc], f"fa{fa_id}_ns", row=row)
        s = net.add_gate("NOT", [ns], f"fa{fa_id}_s", row=row)
        c = net.add_gate("NOT", [nc], f"fa{fa_id}_c", row=row)
        fa_id += 1
        return s, c

    max_w = 2 * n_bits - 2
    w = 0
    while w <= max_w:
        terms = columns.get(w, [])
        while len(terms) >= 3:
            x, y, z = terms.pop(), terms.pop(), terms.pop()
            s, c = full_add(x, y, z, row=w % n_bits)
            terms.append(s)
            columns.setdefault(w + 1, []).append(c)
            max_w = max(max_w, w + 1)
        w += 1

    # Final ripple over remaining <=2-term columns.
    outs: list[str] = []
    carry: str | None = None
    for w in range(2 * n_bits):
        terms = list(columns.get(w, []))
        if carry is not None:
            terms.append(carry)
        row = w % n_bits
        if not terms:
            break
        if len(terms) == 1:
            outs.append(terms[0])
            carry = None
        elif len(terms) == 2:
            zero = net.add_pi(f"Z{w}", kind=PIKind.BINARY, const_value=0.0, row=row)
            s, c = full_add(terms[0], terms[1], zero, row)
            outs.append(s)
            carry = c
        else:
            s, c = full_add(terms[0], terms[1], terms[2], row)
            outs.append(s)
            carry = c
    net.set_outputs(outs)
    return net


def binary_subtractor(n_bits: int) -> Netlist:
    """a - b via two's complement: invert b (NOT per row) and add with c0=1."""
    net = Netlist(f"bin_sub_{n_bits}")
    a = [net.add_pi(f"A{i}", kind=PIKind.BINARY, value_key="a", row=i)
         for i in range(n_bits)]
    b = [net.add_pi(f"B{i}", kind=PIKind.BINARY, value_key="b", row=i)
         for i in range(n_bits)]
    c0 = net.add_pi("C0", kind=PIKind.BINARY, const_value=1.0, row=0)
    nb = [net.add_gate("NOT", [b[i]], f"nb{i}", row=i) for i in range(n_bits)]
    carry = c0
    sums = []
    for i in range(n_bits):
        nc = net.add_gate("NMAJ3", [a[i], nb[i], carry], f"nc{i + 1}", row=i)
        cc = net.add_gate("BUFF", [nc], f"cc{i}", row=i)
        ns = net.add_gate("NMAJ5", [a[i], nb[i], carry, nc, cc], f"ns{i}", row=i)
        s = net.add_gate("NOT", [ns], f"s{i}", row=i)
        sums.append(s)
        if i + 1 < n_bits:
            # True-polarity carry for the next row needs an extra inversion
            # (no polarity trick here: b is already inverted per-row).
            c_true = net.add_gate("NOT", [nc], f"ct{i + 1}", row=i)
            carry = net.add_gate("BUFF", [c_true], f"c{i + 1}", row=i + 1)
        else:
            carry = nc
    net.set_outputs(sums + [carry])
    return net


def binary_divider(n_bits: int) -> Netlist:
    """Non-restoring array divider: n_bits stages of conditional add/subtract.

    Cost-accounting construction (the paper uses a "non-storing array
    division unit"): n stages x (n-bit adder/subtractor + quotient logic).
    """
    net = Netlist(f"bin_div_{n_bits}")
    a = [net.add_pi(f"A{i}", kind=PIKind.BINARY, value_key="a", row=i)
         for i in range(n_bits)]
    b = [net.add_pi(f"B{i}", kind=PIKind.BINARY, value_key="b", row=i)
         for i in range(n_bits)]
    rem = [net.add_pi(f"R{i}", kind=PIKind.BINARY, const_value=0.0, row=i)
           for i in range(n_bits)]
    quot: list[str] = []
    for s_idx in range(n_bits):
        # Shift-in handled by renaming; per stage: subtract b from remainder.
        carry = net.add_pi(f"c_{s_idx}_0", kind=PIKind.BINARY, const_value=1.0, row=0)
        new_rem: list[str] = []
        for i in range(n_bits):
            nb = net.add_gate("NOT", [b[i]], f"nb_{s_idx}_{i}", row=i)
            x = rem[i] if s_idx == 0 else rem[i]
            nc = net.add_gate("NMAJ3", [x, nb, carry], f"nc_{s_idx}_{i}", row=i)
            cc = net.add_gate("BUFF", [nc], f"cc_{s_idx}_{i}", row=i)
            ns = net.add_gate("NMAJ5", [x, nb, carry, nc, cc], f"ns_{s_idx}_{i}", row=i)
            s = net.add_gate("NOT", [ns], f"s_{s_idx}_{i}", row=i)
            new_rem.append(s)
            if i + 1 < n_bits:
                ct = net.add_gate("NOT", [nc], f"ct_{s_idx}_{i}", row=i)
                carry = net.add_gate("BUFF", [ct], f"c_{s_idx}_{i + 1}", row=i + 1)
        sign = net.add_gate("NOT", [nc], f"q_{s_idx}", row=n_bits - 1)
        quot.append(sign)
        # Restore-select: rem = sign ? new_rem : rem  (MUX per bit: 4 gates)
        restored: list[str] = []
        for i in range(n_bits):
            if i != n_bits - 1:
                sgn = net.add_gate("BUFF", [sign], f"sgncp_{s_idx}_{i}", row=i)
            else:
                sgn = sign
            sb = net.add_gate("NOT", [sgn], f"sb_{s_idx}_{i}", row=i)
            n1 = net.add_gate("NAND", [new_rem[i], sgn], f"mx1_{s_idx}_{i}", row=i)
            n2 = net.add_gate("NAND", [rem[i], sb], f"mx2_{s_idx}_{i}", row=i)
            restored.append(net.add_gate("NAND", [n1, n2], f"rem_{s_idx}_{i}", row=i))
        rem = restored
    net.set_outputs(quot)
    return net


def binary_subtractor_serial(n_bits: int) -> Netlist:
    """Single-row serial subtractor (paper Table 2's 1x90 binary layout):
    per bit, invert b then a 9-NAND full adder, all in row 0, c0 = 1."""
    net = Netlist(f"bin_sub_serial_{n_bits}")
    a = [net.add_pi(f"A{i}", kind=PIKind.BINARY, value_key="a", row=0)
         for i in range(n_bits)]
    b = [net.add_pi(f"B{i}", kind=PIKind.BINARY, value_key="b", row=0)
         for i in range(n_bits)]
    carry = net.add_pi("C0", kind=PIKind.BINARY, const_value=1.0, row=0)
    sums = []
    for i in range(n_bits):
        nb = net.add_gate("NOT", [b[i]], f"nb{i}", row=0)
        n1 = net.add_gate("NAND", [a[i], nb], f"n1_{i}", row=0)
        n2 = net.add_gate("NAND", [a[i], n1], f"n2_{i}", row=0)
        n3 = net.add_gate("NAND", [nb, n1], f"n3_{i}", row=0)
        h = net.add_gate("NAND", [n2, n3], f"h_{i}", row=0)
        n4 = net.add_gate("NAND", [h, carry], f"n4_{i}", row=0)
        n5 = net.add_gate("NAND", [h, n4], f"n5_{i}", row=0)
        n6 = net.add_gate("NAND", [carry, n4], f"n6_{i}", row=0)
        sums.append(net.add_gate("NAND", [n5, n6], f"s{i}", row=0))
        carry = net.add_gate("NAND", [n4, n1], f"c{i + 1}", row=0)
    net.set_outputs(sums + [carry])
    return net


# --- composable sub-circuit builders (for the sqrt / exp compositions) ------------

def _rca_into(net: Netlist, prefix: str, a: list, b: list, carry: str) -> list:
    """Row-parallel ripple-carry adder over existing nodes; returns sums."""
    n_bits = len(a)
    sums = []
    for i in range(n_bits):
        nc = net.add_gate("NMAJ3", [a[i], b[i], carry], f"{prefix}_nc{i}", row=i)
        cc = net.add_gate("BUFF", [nc], f"{prefix}_cc{i}", row=i)
        ns = net.add_gate("NMAJ5", [a[i], b[i], carry, nc, cc],
                          f"{prefix}_ns{i}", row=i)
        sums.append(net.add_gate("NOT", [ns], f"{prefix}_s{i}", row=i))
        if i + 1 < n_bits:
            ct = net.add_gate("NOT", [nc], f"{prefix}_ct{i}", row=i)
            carry = net.add_gate("BUFF", [ct], f"{prefix}_c{i + 1}", row=i + 1)
    return sums


def _mul_into(net: Netlist, prefix: str, a: list, b: list) -> list:
    """Array multiplier over existing nodes (schoolbook rows of RCAs);
    returns the low n_bits of the product (fixed-point truncation)."""
    n_bits = len(a)
    acc = None
    for j in range(n_bits):
        row_pp = []
        for i in range(n_bits - j):
            nn = net.add_gate("NAND", [a[i], b[j]], f"{prefix}_ppn{i}_{j}",
                              row=(i + j) % n_bits)
            row_pp.append(net.add_gate("NOT", [nn], f"{prefix}_pp{i}_{j}",
                                       row=(i + j) % n_bits))
        padded = [net.add_pi(f"{prefix}_z{j}_{i}", kind=PIKind.BINARY,
                             const_value=0.0, row=i) for i in range(j)] + row_pp
        if acc is None:
            acc = padded
        else:
            c0 = net.add_pi(f"{prefix}_c0_{j}", kind=PIKind.BINARY,
                            const_value=0.0, row=0)
            acc = _rca_into(net, f"{prefix}_add{j}", acc, padded, c0)
    return acc


def _div_into(net: Netlist, prefix: str, a: list, b: list) -> list:
    """Non-restoring array divider over existing nodes; returns quotient."""
    n_bits = len(a)
    rem = [net.add_pi(f"{prefix}_r{i}", kind=PIKind.BINARY, const_value=0.0,
                      row=i) for i in range(n_bits)]
    quot = []
    for s_idx in range(n_bits):
        carry = net.add_pi(f"{prefix}_c_{s_idx}", kind=PIKind.BINARY,
                           const_value=1.0, row=0)
        nb = [net.add_gate("NOT", [b[i]], f"{prefix}_nb_{s_idx}_{i}", row=i)
              for i in range(n_bits)]
        diff = _rca_into(net, f"{prefix}_sub{s_idx}", rem, nb, carry)
        sign = net.add_gate("NOT", [diff[-1]], f"{prefix}_q{s_idx}",
                            row=n_bits - 1)
        quot.append(sign)
        restored = []
        for i in range(n_bits):
            sg = (net.add_gate("BUFF", [sign], f"{prefix}_sg_{s_idx}_{i}", row=i)
                  if i != n_bits - 1 else sign)
            sb = net.add_gate("NOT", [sg], f"{prefix}_sb_{s_idx}_{i}", row=i)
            m1 = net.add_gate("NAND", [diff[i], sg], f"{prefix}_m1_{s_idx}_{i}", row=i)
            m2 = net.add_gate("NAND", [rem[i], sb], f"{prefix}_m2_{s_idx}_{i}", row=i)
            restored.append(net.add_gate("NAND", [m1, m2],
                                         f"{prefix}_rm_{s_idx}_{i}", row=i))
        rem = restored
    return quot


def binary_sqrt(n_bits: int, newton_steps: int = 3) -> Netlist:
    """Binary square root via ``newton_steps`` Newton-Raphson iterations
    y' = (y + x/y) / 2 -- each step composes a full array divider and an
    adder (paper Section 5-1; Table 2's 32x1413 scale)."""
    net = Netlist(f"bin_sqrt_{n_bits}")
    x = [net.add_pi(f"X{i}", kind=PIKind.BINARY, value_key="a", row=i)
         for i in range(n_bits)]
    cur = x
    for step in range(newton_steps):
        q = _div_into(net, f"st{step}_div", x, cur)         # x / y
        c0 = net.add_pi(f"st{step}_ac", kind=PIKind.BINARY, const_value=0.0,
                        row=0)
        cur = _rca_into(net, f"st{step}_add", cur, q, c0)   # y + x/y (>>1 free)
    net.set_outputs(cur)
    return net


def binary_exp(n_bits: int, order: int = 5) -> Netlist:
    """Binary exp(-cx), 5th-order Maclaurin in Horner form: ``order`` stages
    of (full array multiply + add) -- paper Section 5-1 (Table 2's 17x1255
    scale)."""
    net = Netlist(f"bin_exp_{n_bits}")
    x = [net.add_pi(f"X{i}", kind=PIKind.BINARY, value_key="a", row=i)
         for i in range(n_bits)]
    acc = [net.add_pi(f"K{i}", kind=PIKind.BINARY, const_value=1.0, row=i)
           for i in range(n_bits)]
    for stage in range(order):
        prod = _mul_into(net, f"e{stage}_mul", acc, x)      # acc * x
        const = [net.add_pi(f"e{stage}_k{i}", kind=PIKind.BINARY,
                            const_value=0.0, row=i) for i in range(n_bits)]
        c0 = net.add_pi(f"e{stage}_c0", kind=PIKind.BINARY, const_value=1.0,
                        row=0)
        acc = _rca_into(net, f"e{stage}_add", prod, const, c0)
    net.set_outputs(acc)
    return net
