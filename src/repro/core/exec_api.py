"""Request/options API and the ``run()`` entry point + historic shims.

Top layer of the executor stack (``streams`` <- ``dispatch`` <-
``exec_api`` <- the ``executor`` facade).  Defines the canonical request
types (``ExecOptions``, ``ExecRequest``), the ``run()`` entry point over
them, and the historic ``execute*`` functions as thin shims that build
``ExecRequest``s and delegate to ``run()`` — outputs are bit-identical
(pinned by tests).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax

from . import obs
from .dispatch import (_as_f32, _check_fault_args, _check_modes, _dispatch,
                       _dispatch_binary, _dispatch_many, _execute_compiled,
                       _normalize_batch_shapes, _normalize_keys, _stack_keys,
                       execute_bank)
from .faults import FaultModel
from .gates import Netlist
from .plan import BankPlan, ExecutionPlan


@dataclasses.dataclass(frozen=True)
class ExecOptions:
    """Frozen execution options shared by every entry point.

    ``backend`` / ``key_mode`` default (``None``) to the module defaults at
    run time; ``flip_key`` is required when ``bitflip_rate > 0``;
    ``batch_shape`` declares the stream batch shape when values alone cannot
    (all-const stream PIs).  ``decode`` fuses the StoB decode into the
    program (the ``execute_value`` behavior); ``binary`` runs the netlist on
    packed binary test-vector words instead of stochastic streams (the
    ``execute_binary`` behavior — ``values`` are then the operand bits and
    the stream fields are ignored).

    ``fault_model`` (a ``core.faults.FaultModel``) generalizes
    ``bitflip_rate`` to the STT-MRAM fault taxonomy — transient flips plus
    stuck-at cells, dead rows/columns and endurance wear — keyed by the same
    ``flip_key`` discipline (required whenever the model has random
    components); the two fields are mutually exclusive.  ``deadline_ms`` is
    a *serving* knob: the bank server bounds the request's total wall time
    (queue + retries + device) by it, failing the ticket with
    ``DeadlineExceeded`` when it passes; the execution paths themselves
    ignore it.

    ``word_chunk`` (words, must divide ``bitstream_length / 32``) streams a
    combinational execution chunk-by-chunk via ``lax.scan`` instead of
    materializing full-length node streams — peak live words drop to about
    ``plan.max_live * word_chunk`` (see the compiler's liveness stage).
    Single-request compiled paths only; bit-identical to unchunked runs.
    ``interpret`` forces Pallas interpret mode on (True) or off (False) for
    the pallas/megakernel backends; ``None`` auto-detects (compiled on TPU,
    interpret elsewhere).

    ``trace`` (a ``core.obs.Trace``, default None = tracing off) makes that
    trace current for the duration of the ``run()`` call, so host-side
    executor spans (value packing, key staging, device transfer, dispatch)
    and compiler per-stage spans land in it.  Tracing never perturbs
    outputs — results are bit-identical with it on or off (pinned by
    tests) — and the field is excluded from options equality, so it does
    not affect batch option-agreement.

    Example::

        from repro.core import circuits, executor, obs
        import jax
        tr = obs.Trace()
        net = circuits.sc_multiply()
        out = executor.run(executor.ExecRequest(
            net, {"a": 0.5, "b": 0.5}, jax.random.key(0),
            executor.ExecOptions(bitstream_length=256, decode=True,
                                 trace=tr)))
        assert "exec.dispatch" in tr.summary()["spans"]
    """

    backend: str | None = None
    key_mode: str | None = None
    bitstream_length: int = 256
    bitflip_rate: float = 0.0
    flip_key: Any = None
    batch_shape: "tuple[int, ...] | None" = None
    decode: bool = False
    binary: bool = False
    fault_model: "FaultModel | None" = None
    deadline_ms: "float | None" = None
    word_chunk: "int | None" = None
    interpret: "bool | None" = None
    trace: Any = dataclasses.field(default=None, compare=False)


@dataclasses.dataclass
class ExecRequest:
    """One canonical execution request: circuit + values + key + options.

    ``net`` is a ``Netlist`` or a prebuilt ``ExecutionPlan`` (compiled
    backends only); ``values`` its PI values (operand bit words under
    ``options.binary``); ``key`` the request's PRNG key — the bit-identity
    anchor: a request produces the same output bits whether it runs
    standalone, inside a merged bank, or bound to a padded template slot on
    any device.  ``serve.SCRequest`` subclasses this with the serving
    layer's flat constructor.

    Example::

        import jax
        from repro.core import circuits, executor
        req = executor.ExecRequest(circuits.sc_multiply(),
                                   {"a": 0.5, "b": 0.5}, jax.random.key(0),
                                   executor.ExecOptions(bitstream_length=512,
                                                        decode=True))
        out = executor.run(req)        # {"out": ~0.25}
    """

    net: Any
    values: dict[str, Any]
    key: Any = None
    options: ExecOptions = dataclasses.field(default_factory=ExecOptions)

    # Flat views of the per-request option fields, so request consumers
    # (serving engine, tests) need not reach through ``options`` for the
    # fields every request carries.
    @property
    def bitstream_length(self) -> int:
        return self.options.bitstream_length

    @property
    def batch_shape(self) -> "tuple[int, ...] | None":
        return self.options.batch_shape

    @property
    def bitflip_rate(self) -> float:
        return self.options.bitflip_rate

    @property
    def flip_key(self):
        return self.options.flip_key

    @property
    def fault_model(self) -> "FaultModel | None":
        return self.options.fault_model

    @property
    def deadline_ms(self) -> "float | None":
        return self.options.deadline_ms


# -------------------------------- shim API ----------------------------------------

def execute(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
            bitstream_length: int, bitflip_rate: float = 0.0,
            flip_key: jax.Array | None = None,
            backend: str | None = None, key_mode: str | None = None,
            batch_shape: tuple[int, ...] | None = None,
            fault_model: "FaultModel | None" = None) -> dict[str, jax.Array]:
    """Execute a (possibly sequential) netlist; returns packed output streams.

    ``bitflip_rate`` injects faults on the PI streams and on every gate
    output stream (the paper injects at input/output nodes of the
    arithmetic operations); ``fault_model`` generalizes it to the STT-MRAM
    taxonomy (stuck-at, dead regions, wear — ``core/faults.py``), keyed by
    the same ``flip_key``.  ``backend`` selects the execution engine (see
    ``executor`` module docstring); all backends are bit-identical.
    ``key_mode`` selects the stream-generation key discipline (``"batched"``
    default — one fused SNG pass for all PI streams; ``"legacy"`` — one PRNG
    split per stream, bit-exactly the pre-batching behavior); both backends
    honor it identically.  ``batch_shape`` declares the stream batch shape
    when it is not derivable from ``values`` (e.g. all stream PIs
    const-valued).

    Thin shim over ``run()``: builds one ``ExecRequest`` — bit-identical.
    """
    return run(ExecRequest(net, values, key, ExecOptions(
        backend=backend, key_mode=key_mode,
        bitstream_length=bitstream_length, bitflip_rate=bitflip_rate,
        flip_key=flip_key, batch_shape=batch_shape,
        fault_model=fault_model)))


def execute_value(net: Netlist, values: dict[str, jax.Array], key: jax.Array,
                  bitstream_length: int, bitflip_rate: float = 0.0,
                  flip_key: jax.Array | None = None,
                  backend: str | None = None, key_mode: str | None = None,
                  batch_shape: tuple[int, ...] | None = None,
                  fault_model: "FaultModel | None" = None) -> dict[str, jax.Array]:
    """Execute and decode each output stream to its unipolar value.

    On the compiled backends the decode is fused into the execution program
    (single dispatch per call).  Thin shim over ``run()``."""
    return run(ExecRequest(net, values, key, ExecOptions(
        backend=backend, key_mode=key_mode,
        bitstream_length=bitstream_length, bitflip_rate=bitflip_rate,
        flip_key=flip_key, batch_shape=batch_shape, decode=True,
        fault_model=fault_model)))


def execute_binary(net: Netlist, operand_bits: dict[str, jax.Array],
                   backend: str | None = None) -> dict[str, jax.Array]:
    """Execute a binary netlist on packed test-vector words.

    ``operand_bits`` maps PI names to uint32 words whose lane ``t`` is the
    PI's value in test vector ``t``.  Constant PIs (const_value set) are
    filled automatically.  Inverted-polarity storage (the Fig. 7(a) trick) is
    applied by the *caller* via the netlist's value conventions.

    Thin shim over ``run()`` (``options.binary``) — bit-identical.
    """
    return run(ExecRequest(net, dict(operand_bits), options=ExecOptions(
        backend=backend, binary=True)))


#: Legacy positional tail of execute_many/execute_value_many after
#: (nets, values_seq); the *args/**kwargs shim reassembles it so the
#: deprecated plural-kwarg spellings (keys=/batch_shapes=) can be detected.
_MANY_TAIL = ("keys", "bitstream_length", "bitflip_rate", "flip_keys",
              "backend", "key_mode", "batch_shapes")


def _many_tail(fn_name: str, args: tuple, kwargs: dict) -> tuple:
    for bad in ("keys", "batch_shapes"):
        if bad in kwargs:
            warnings.warn(
                f"{fn_name}({bad}=...) is deprecated: build per-member "
                f"ExecRequests (each carrying its own key / "
                f"options.batch_shape) and call executor.run([...])",
                DeprecationWarning, stacklevel=3)
    if len(args) > len(_MANY_TAIL):
        raise TypeError(f"{fn_name}: too many positional arguments")
    params = dict(zip(_MANY_TAIL, args))
    dup = sorted(set(params) & set(kwargs))
    if dup:
        raise TypeError(f"{fn_name}: got multiple values for {dup}")
    params.update(kwargs)
    unknown = sorted(set(params) - set(_MANY_TAIL))
    if unknown:
        raise TypeError(f"{fn_name}: unexpected keyword arguments {unknown}")
    missing = sorted({"keys", "bitstream_length"} - set(params))
    if missing:
        raise TypeError(f"{fn_name}: missing required arguments {missing}")
    return (params["keys"], params["bitstream_length"],
            params.get("bitflip_rate", 0.0), params.get("flip_keys"),
            params.get("backend"), params.get("key_mode"),
            params.get("batch_shapes"))


def _many_shim(fn_name: str, nets, values_seq, args, kwargs,
               decode: bool) -> list:
    """Shared execute_many/execute_value_many shim: build per-member
    ``ExecRequest``s and delegate to ``run()`` — bit-identical to the legacy
    plural-kwarg path (stacking per-member key rows reproduces the original
    key array exactly)."""
    (keys, bitstream_length, bitflip_rate, flip_keys, backend, key_mode,
     batch_shapes) = _many_tail(fn_name, args, kwargs)
    n = len(nets)
    if n == 0:
        raise ValueError("execute_many: need at least one netlist")
    if len(values_seq) != n:
        raise ValueError(f"values: got {len(values_seq)} for {n} netlists")
    keys = _normalize_keys(keys, n)
    batch_shapes = _normalize_batch_shapes(batch_shapes, n)
    if bitflip_rate > 0.0:
        if flip_keys is None:
            raise ValueError("bitflip_rate > 0 requires flip_keys")
        flip_keys = _normalize_keys(flip_keys, n, "flip_keys")
    reqs = [ExecRequest(net, vals, keys[i], ExecOptions(
                backend=backend, key_mode=key_mode,
                bitstream_length=bitstream_length,
                bitflip_rate=bitflip_rate,
                flip_key=flip_keys[i] if bitflip_rate > 0.0 else None,
                batch_shape=batch_shapes[i] if batch_shapes else None,
                decode=decode))
            for i, (net, vals) in enumerate(zip(nets, values_seq))]
    return run(reqs)


def execute_many(nets, values_seq, /, *args, **kwargs) -> list:
    """Execute N (possibly different) netlists as ONE fused bank-level plan.

    Legacy signature: ``execute_many(nets, values_seq, keys,
    bitstream_length, bitflip_rate=0.0, flip_keys=None, backend=None,
    key_mode=None, batch_shapes=None)``.

    ``nets[i]`` runs with PI values ``values_seq[i]`` and PRNG key ``keys[i]``
    (``keys`` may also be a single key, which is split N ways).  Returns one
    packed-output dict per member, bit-identical to calling ``execute`` per
    netlist with the same per-member keys and ``key_mode`` — the merged plan
    batches same-type gates of each level *across* members (core/plan.py bank
    merging), and in batched key mode all members' PI streams generate in one
    fused SNG pass per distinct batch shape, so the whole bank runs in a
    single jit dispatch instead of N.  Member batch shapes may differ
    (``batch_shapes[i]`` declares member i's shape when its values alone
    cannot, e.g. all-const stream PIs).  ``bitflip_rate`` injects per-member
    faults keyed by ``flip_keys[i]`` (single key allowed, split N ways).

    .. deprecated:: the plural-kwarg spellings ``keys=`` / ``batch_shapes=``
       — build per-member ``ExecRequest``s and call ``run([...])`` instead;
       this shim stays bit-identical but warns.
    """
    return _many_shim("execute_many", nets, values_seq, args, kwargs,
                      decode=False)


def execute_value_many(nets, values_seq, /, *args, **kwargs) -> list:
    """``execute_many`` with the StoB decode fused into the same program.

    Same legacy signature and deprecation notes as ``execute_many``.
    """
    return _many_shim("execute_value_many", nets, values_seq, args, kwargs,
                      decode=True)


# ------------------------------ run() entry point ---------------------------------

_SHARED_OPTION_FIELDS = ("backend", "key_mode", "bitstream_length",
                         "bitflip_rate", "decode", "binary", "fault_model",
                         "word_chunk", "interpret")


def _common_options(reqs: "list[ExecRequest]") -> ExecOptions:
    """The options every request of a merged batch must agree on (per-slot
    fields — key, flip_key, batch_shape, values — stay per request)."""
    o0 = reqs[0].options
    for r in reqs[1:]:
        for f in _SHARED_OPTION_FIELDS:
            if getattr(r.options, f) != getattr(o0, f):
                raise ValueError(
                    f"run: requests disagree on options.{f}: "
                    f"{getattr(o0, f)!r} vs {getattr(r.options, f)!r} "
                    f"(group requests by shared options, or pass options=)")
    return o0


def _run_one(req: ExecRequest, device=None,
             options: ExecOptions | None = None):
    o = options or req.options
    if o.binary:
        return _dispatch_binary(req.net, req.values, o.backend)
    values, key, flip_key = req.values, req.key, o.flip_key
    if device is not None:
        # Commit only the key(s): jit places the program with its committed
        # argument, and uncommitted values follow in one transfer (committing
        # a values pytree leaf-by-leaf costs more than the dispatch).
        with obs.span("exec.device_transfer", device=str(device)):
            key = jax.device_put(key, device)
            if flip_key is not None:
                flip_key = jax.device_put(flip_key, device)
    if isinstance(req.net, ExecutionPlan):
        backend, key_mode = _check_modes(o.backend, o.key_mode)
        if backend == "reference":
            raise ValueError("the reference backend interprets netlists; "
                             "pass the Netlist, not its ExecutionPlan")
        fault_model = _check_fault_args(o.bitflip_rate, o.fault_model,
                                        flip_key)
        batch_shape = (tuple(o.batch_shape)
                       if o.batch_shape is not None else None)
        values = {k: _as_f32(v) for k, v in values.items()}
        with obs.span("exec.dispatch", plan=req.net.name,
                      bitstream_length=o.bitstream_length):
            return _execute_compiled(
                req.net, values, key, flip_key,
                o.bitstream_length, float(o.bitflip_rate),
                backend == "compiled_pallas", decode=o.decode,
                key_mode=key_mode, batch_shape=batch_shape,
                fault_model=fault_model, word_chunk=o.word_chunk,
                megakernel=backend == "compiled_megakernel",
                interpret=o.interpret)
    return _dispatch(req.net, values, key, o.bitstream_length,
                     o.bitflip_rate, flip_key, o.backend, decode=o.decode,
                     key_mode=o.key_mode, batch_shape=o.batch_shape,
                     fault_model=o.fault_model, word_chunk=o.word_chunk,
                     interpret=o.interpret)


def _run_many(reqs: "list[ExecRequest]", device=None,
              options: ExecOptions | None = None) -> list:
    if not reqs:
        raise ValueError("run: need at least one request")
    shared = options or _common_options(reqs)
    if shared.binary:
        raise ValueError("run: binary requests execute one at a time")
    if shared.word_chunk is not None:
        raise ValueError("run: word_chunk streams single-plan executions; "
                         "bank-merged batches run unchunked")
    for r in reqs:
        if not isinstance(r.net, Netlist):
            raise TypeError("run([...]) merges netlists into one bank; pass "
                            "template= to execute a prebuilt BankPlan")
    rate = float(shared.bitflip_rate)
    model = shared.fault_model
    flip_keys = None
    if rate > 0.0 or (model is not None and model.needs_keys):
        flip_keys = [r.options.flip_key for r in reqs]
        if any(fk is None for fk in flip_keys):
            raise ValueError("fault injection requires a flip_key on every "
                             "request")
    batch_shapes = [r.options.batch_shape for r in reqs]
    if all(b is None for b in batch_shapes):
        batch_shapes = None
    values_seq = [r.values for r in reqs]
    keys = [r.key for r in reqs]
    if device is not None:
        # Commit only the keys (see _run_one): the program follows them.
        with obs.span("exec.device_transfer", device=str(device)):
            keys = jax.device_put(keys, device)
            if flip_keys is not None:
                flip_keys = jax.device_put(flip_keys, device)
    return _dispatch_many([r.net for r in reqs], values_seq, keys,
                          shared.bitstream_length, rate, flip_keys,
                          shared.backend, shared.decode,
                          key_mode=shared.key_mode,
                          batch_shapes=batch_shapes, fault_model=model)


def _run_template(reqs, bank: BankPlan, active=None, device=None,
                  donate: bool = False,
                  options: ExecOptions | None = None) -> list:
    """Slot-aligned template execution: ``reqs[i]`` feeds template slot ``i``
    (``None`` = unbound slot, masked out)."""
    n = bank.n_members
    if len(reqs) != n:
        raise ValueError(f"run: got {len(reqs)} slot requests for {n} slots")
    bound = [(i, r) for i, r in enumerate(reqs) if r is not None]
    if not bound:
        raise ValueError("run: template batch needs at least one bound slot")
    shared = options or _common_options([r for _, r in bound])
    if shared.binary:
        raise ValueError("run: binary requests execute one at a time")
    if shared.word_chunk is not None:
        raise ValueError("run: word_chunk streams single-plan executions; "
                         "template banks run unchunked")
    rate = float(shared.bitflip_rate)
    model = shared.fault_model
    need_keys = rate > 0.0 or (model is not None and model.needs_keys)
    if active is None:
        active = [r is not None for r in reqs]
    # Placeholder rows for unbound slots: any same-impl key works (masked
    # slots draw no streams); reusing the first bound key row unwraps once.
    key0 = bound[0][1].key
    fk0 = bound[0][1].options.flip_key
    values_seq: list = [{} for _ in range(n)]
    key_rows: list = [key0] * n
    flip_rows: list = [fk0 if fk0 is not None else key0] * n
    batch_shapes: list = [None] * n
    for i, r in bound:
        values_seq[i] = r.values
        key_rows[i] = r.key
        batch_shapes[i] = r.options.batch_shape
        if need_keys:
            if r.options.flip_key is None:
                raise ValueError("fault injection requires a flip_key on "
                                 "every request")
            flip_rows[i] = r.options.flip_key
    return execute_bank(
        bank, values_seq, _stack_keys(key_rows), shared.bitstream_length,
        active=active, bitflip_rate=rate,
        flip_keys=_stack_keys(flip_rows) if need_keys else None,
        backend=shared.backend, key_mode=shared.key_mode,
        batch_shapes=batch_shapes, decode=shared.decode,
        device=device, donate=donate, fault_model=model,
        interpret=shared.interpret)


def run(request_or_requests, *, template: BankPlan | None = None,
        active=None, device=None, donate: bool = False,
        options: ExecOptions | None = None):
    """Canonical execution entry point over ``ExecRequest``s.

    * ``run(req)`` — execute one request (netlist or prebuilt plan);
      returns its output dict (decoded when ``options.decode``).
    * ``run([req, ...])`` — merge the requests' netlists into ONE fused
      bank-level program (the ``execute_many`` path); returns one output
      dict per request, bit-identical to running each alone.
    * ``run(slot_reqs, template=bank)`` — bind slot-aligned requests
      (``None`` = unbound) onto a padded bank template and execute with the
      unbound slots masked; returns one entry per slot (``None`` where
      unbound).  This is the serving engine's path.

    Batch paths require the requests to agree on the shared option fields
    (backend / key_mode / bitstream_length / bitflip_rate / decode); pass
    ``options=`` to supply them explicitly instead (per-slot key, flip_key,
    batch_shape and values always come from each request).  ``device``
    commits the batch inputs to one JAX device before dispatch;
    ``donate`` forwards to ``execute_bank`` (template path only).

    ``key`` semantics are the bit-identity anchor: a request's output bits
    depend only on its own key (and ``key_mode``), never on which batch,
    slot, or device it executed in.

    Example::

        import jax
        from repro.core import circuits, executor
        net = circuits.sc_multiply()
        req = executor.ExecRequest(net, {"a": 0.25, "b": 0.5},
                                   jax.random.key(7),
                                   executor.ExecOptions(decode=True))
        alone = executor.run(req)
        merged = executor.run([req, req])      # one fused bank program
        assert float(alone["out"]) == float(merged[0]["out"])
    """
    if isinstance(request_or_requests, ExecRequest):
        reqs: "list[ExecRequest]" = [request_or_requests]
        single = True
    else:
        reqs = list(request_or_requests)
        single = False
    tr = options.trace if options is not None and options.trace is not None \
        else next((r.options.trace for r in reqs
                   if r is not None and r.options.trace is not None), None)
    if tr is None:
        return _run_any(reqs, single, template, active, device, donate,
                        options)
    with obs.tracing(tr):
        return _run_any(reqs, single, template, active, device, donate,
                        options)


def _run_any(reqs, single, template, active, device, donate, options):
    if single:
        return _run_one(reqs[0], device=device, options=options)
    if template is not None:
        return _run_template(reqs, template, active=active, device=device,
                             donate=donate, options=options)
    return _run_many(reqs, device=device, options=options)
